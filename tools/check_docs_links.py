#!/usr/bin/env python
"""Docs link checker (CI: the "Docs link check" step; also run by
tests/test_docs_links.py so a dead link fails tier-1 locally).

Checks two classes of references:

* relative markdown links ``[text](path)`` in ``docs/*.md`` and the root
  ``*.md`` files — the target file must exist (``#fragments`` are stripped,
  ``http(s)://`` / ``mailto:`` links are skipped);
* ``docs/<NAME>.md`` mentions inside ``examples/*.py`` and
  ``src/repro/serve/*.py`` docstrings/comments — every doc a module points
  its reader at must exist (this is what caught the stale ``DESIGN.md §4``
  references the serving docstrings used to carry).

Exit code 0 = clean, 1 = dead links (listed on stderr).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PY_DOC_REF = re.compile(r"docs/[A-Za-z0-9_.-]+\.md")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def md_files():
    yield from sorted(ROOT.glob("*.md"))
    yield from sorted((ROOT / "docs").glob("*.md"))


def py_files():
    yield from sorted((ROOT / "examples").glob("*.py"))
    yield from sorted((ROOT / "src" / "repro" / "serve").glob("*.py"))


def check() -> list:
    dead = []
    for f in md_files():
        for m in MD_LINK.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (f.parent / path).exists():
                dead.append((str(f.relative_to(ROOT)), target))
    for f in py_files():
        for m in PY_DOC_REF.finditer(f.read_text()):
            if not (ROOT / m.group(0)).exists():
                dead.append((str(f.relative_to(ROOT)), m.group(0)))
    return dead


def main() -> int:
    dead = check()
    n_files = len(list(md_files())) + len(list(py_files()))
    if dead:
        for src, target in dead:
            print(f"DEAD LINK: {src} -> {target}", file=sys.stderr)
        return 1
    print(f"docs link check: {n_files} files, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Diff a fresh ``BENCH_summary.json`` against the committed baseline.

The ``modeled_*`` columns are deterministic functions of the planners
and cost models — they move only when code moves — so the bench-smoke CI
job fails when a fresh run's modeled numbers regress beyond ``--tol`` on
any row present in both summaries.  ``modeled_*_s`` fields are seconds
(lower is better, fails on increase); ``modeled_*_rps`` / ``_tput`` /
``_goodput`` fields are rates (higher is better, fails on decrease).
Wall-clock fields are machine noise and are ignored both as row identity
and as comparison targets.  Rows or whole benches that exist on only one
side are reported but do not fail (benches evolve); the gate is strictly
"what we still model must not have gotten slower".

Usage::

    python tools/check_bench_regression.py --baseline BENCH_summary.json \
        --fresh BENCH_summary.fresh.json [--tol 0.05]
"""

from __future__ import annotations

import argparse
import json
import sys


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _volatile(field: str) -> bool:
    """Machine-noise fields: never identity, never compared."""
    return field.startswith("wall")


def _compared_lower(field: str) -> bool:
    """Deterministic modeled seconds — regression = got bigger."""
    return field.startswith("modeled_") and field.endswith("_s")


def _compared_higher(field: str) -> bool:
    """Deterministic modeled rates — regression = got smaller."""
    return field.startswith("modeled_") and \
        field.endswith(("_rps", "_tput", "_goodput"))


def _compared(field: str) -> bool:
    return _compared_lower(field) or _compared_higher(field)


def row_key(row: dict) -> tuple:
    """Identity of a row: every stable, non-compared field, stringified."""
    return tuple(sorted((f, str(v)) for f, v in row.items()
                        if not _volatile(f) and not _compared(f)))


def compare(baseline: dict, fresh: dict, tol: float):
    """(regressions, notes) between two summary ``benches`` dicts."""
    regressions, notes = [], []
    for bench in sorted(fresh):
        if bench not in baseline:
            notes.append(f"{bench}: new bench (no baseline) — skipped")
            continue
        base_rows = {}
        for row in baseline[bench]:
            base_rows.setdefault(row_key(row), []).append(row)
        for row in fresh[bench]:
            matches = base_rows.get(row_key(row))
            if not matches:
                notes.append(f"{bench}: row {row_key(row)[:3]}... has no "
                             "baseline — skipped")
                continue
            base = matches.pop(0)
            for f, v in row.items():
                if not (_compared(f) and _is_num(v) and _is_num(base.get(f))):
                    continue
                if _compared_lower(f) and v > base[f] * (1.0 + tol) + 1e-12:
                    regressions.append(
                        f"{bench}: {dict(row_key(row))} {f} "
                        f"{base[f]:.6g} -> {v:.6g} "
                        f"(+{(v / base[f] - 1.0) * 100:.1f}% > {tol:.0%})")
                elif _compared_higher(f) \
                        and v < base[f] * (1.0 - tol) - 1e-12:
                    regressions.append(
                        f"{bench}: {dict(row_key(row))} {f} "
                        f"{base[f]:.6g} -> {v:.6g} "
                        f"({(v / base[f] - 1.0) * 100:.1f}% < -{tol:.0%})")
    for bench in sorted(baseline):
        if bench not in fresh:
            notes.append(f"{bench}: in baseline only — not re-run")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_summary.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tol", type=float, default=0.05,
                    help="allowed fractional slowdown per modeled field")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    regressions, notes = compare(base.get("benches", {}),
                                 fresh.get("benches", {}), args.tol)
    for n in notes:
        print(f"[note] {n}")
    if regressions:
        print(f"\n{len(regressions)} modeled-time regression(s) "
              f"beyond {args.tol:.0%}:")
        for r in regressions:
            print(f"  REGRESSION {r}")
        return 1
    print("bench regression check OK "
          f"(tol {args.tol:.0%}, {len(notes)} note(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Paper Fig. 7 (Cannon matmul): ring collective matmul strong scaling.

Fixed-size square product C = A x B (the paper's 30240^2 scaled to CPU:
N=1024), 1..8 devices, THREE execution modes per device count:

* ``none``  — all-gather X + one big GEMM (the MPI+X baseline shape);
* ``host``  — the unidirectional host-level ring: one dot + collective-
              permute HLO pair per step, overlap left to the XLA scheduler;
* ``fused`` — the fused bidirectional ring (one kernel, planner-scheduled
              stripe slots, ``ceil((n-1)/2)`` exchange steps).

All virtual devices share one physical core here, so wall time cannot show
parallel speedup; the modeled columns apply a per-step comm/compute model at
the PAPER's problem size (30240^2, bf16, v5e: 197 TFLOP/s peak, 50 GB/s per
ICI link direction) driven by the SAME RingPlan schedule the kernels
execute: each step costs ``max(gemms·t_c, t_x)`` (+ a per-step dispatch
overhead for the host loop, which the fused kernel pays once).  The fused
mode's per-stripe step time and modeled total must never exceed the host
ring's — asserted here, so the benchmark doubles as a regression gate.
"""

from __future__ import annotations

import math

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core.compat import make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.kernels.plan import RingPlan
from repro.kernels.ring_matmul.ops import ring_allgather_matmul

from .common import timeit, write_csv

# v5e-flavored model constants (per chip / per ICI link direction)
PEAK_FLOPS = 197e12
LINK_BW = 50e9               # bytes/s, each direction
DISPATCH_OVERHEAD = 5e-6     # per host-loop step (launch + schedule slack)
PAPER_N = 30240
PAPER_ITEM = 2               # bf16

MODES = ("none", "host", "fused")


def _modeled(ndev: int, mode: str):
    """(total_s, per-stripe step_s) under the per-step comm/compute model."""
    t_c = 2 * PAPER_N * (PAPER_N / ndev) ** 2 / PEAK_FLOPS   # one stripe GEMM
    stripe_bytes = (PAPER_N / ndev) * PAPER_N * PAPER_ITEM
    t_x = stripe_bytes / LINK_BW
    if ndev == 1:
        return t_c, t_c
    if mode == "none":
        total = ndev * t_c + (ndev - 1) * t_x       # gather, THEN compute
        return total, total / ndev
    if mode == "host":
        # n-1 overlapped steps + the final stripe's GEMM, one dispatch each
        step = max(t_c, t_x) + DISPATCH_OVERHEAD
        return (ndev - 1) * step + t_c, step
    # fused: walk the actual bidirectional schedule
    plan = RingPlan(n=ndev, direction="bidi", slots=2)
    total, worst_per_stripe = DISPATCH_OVERHEAD, 0.0
    for st in plan.schedule():
        gemms = int(st.compute_cw) + int(st.compute_ccw)
        comm = t_x if (st.send_cw or st.send_ccw) else 0.0
        dt = max(gemms * t_c, comm)
        total += dt
        if gemms:
            worst_per_stripe = max(worst_per_stripe, dt / gemms)
    return total, worst_per_stripe


def run(quick: bool = False, N: int = 1024):
    if quick:
        N = 512
    A = np.random.RandomState(0).randn(N, N).astype(np.float32)
    B = np.random.RandomState(1).randn(N, N).astype(np.float32)
    base_modeled = _modeled(1, "none")[0]
    rows = []
    outputs = {}
    for ndev in (1, 2, 4, 8):
        mesh = make_mesh((ndev,), ("x",), axis_types="auto")
        g = DiompGroup(("x",), name="ring")
        for mode in MODES:
            f = jax.jit(shard_map(
                lambda a, b, m=mode: ring_allgather_matmul(
                    a, b, g, overlap=m != "none",
                    impl=m if m != "none" else None),
                mesh=mesh, in_specs=(P("x", None), P(None, "x")),
                out_specs=P(None, "x")))
            t = timeit(f, A, B, iters=3)
            outputs[(ndev, mode)] = np.asarray(f(A, B))
            total, step = _modeled(ndev, mode)
            rows.append({
                "devices": ndev,
                "mode": mode,
                "exchange_steps": 0 if mode == "none" or ndev == 1 else (
                    math.ceil((ndev - 1) / 2) if mode == "fused"
                    else ndev - 1),
                "wall_s": round(t, 4),
                "wall_note": "1-core CPU serializes devices",
                "modeled_step_s": round(step, 6),
                "modeled_total_s": round(total, 4),
                "modeled_v5e_speedup": round(base_modeled / total, 2),
                "per_rank_comm_MB": round(
                    (ndev - 1) / ndev * N * N * 4 / 2**20, 1),
            })
    # the fused schedule must never model slower than the host ring
    by_key = {(r["devices"], r["mode"]): r for r in rows}
    for ndev in (2, 4, 8):
        fused, host = by_key[(ndev, "fused")], by_key[(ndev, "host")]
        assert fused["modeled_step_s"] <= host["modeled_step_s"], (fused, host)
        assert fused["modeled_total_s"] <= host["modeled_total_s"], (fused, host)

    # correctness: every mode, every device count, against the dense product
    want = A @ B
    scale = np.abs(want).max()
    err = max(np.abs(out - want).max() / scale for out in outputs.values())
    assert err < 1e-4, err
    path = write_csv("matmul.csv", rows)
    print(f"[bench_matmul] -> {path} (err={err:.1e})")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 7 (Cannon matmul): ring collective matmul strong scaling.

Fixed-size square product C = A x B (the paper's 30240^2 scaled to CPU:
N=1024), 1..8 devices, ring exchange with compute/communication overlap on
vs off.  Speedups are relative to the 1-device run, like the paper's
single-node baseline.  Superlinearity on real pods comes from per-rank
working sets dropping into faster cache levels — on the CPU smoke mesh we
report the measured scaling plus the per-rank comm volume model showing the
per-GPU communication decrease the paper credits.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.kernels.ring_matmul.ops import ring_allgather_matmul

from .common import timeit, write_csv


def run(quick: bool = False, N: int = 1024):
    if quick:
        N = 512
    A = np.random.RandomState(0).randn(N, N).astype(np.float32)
    B = np.random.RandomState(1).randn(N, N).astype(np.float32)
    base = None
    rows = []
    for ndev in (1, 2, 4, 8):
        mesh = make_mesh((ndev,), ("x",), axis_types="auto")
        g = DiompGroup(("x",), name="ring")
        for overlap in (False, True):
            f = jax.jit(shard_map(
                lambda a, b: ring_allgather_matmul(a, b, g, overlap=overlap),
                mesh=mesh, in_specs=(P("x", None), P(None, "x")),
                out_specs=P(None, "x")))
            t = timeit(f, A, B, iters=3)
            if base is None:
                base = t
            # NOTE: all virtual devices share ONE physical core here, so
            # measured wall time cannot show parallel speedup; the modeled
            # column applies the v5e compute/comm overlap model at the
            # PAPER's problem size (30240^2, bf16): compute N^3/ndev at
            # peak, ring transfer overlapped -> max(t_c, t_x).
            Np = 30240
            t_c = 2 * Np ** 3 / ndev / 197e12
            t_x = (ndev - 1) / ndev * Np * Np * 2 / 50e9
            modeled = max(t_c, t_x) if overlap else t_c + t_x
            base_modeled = 2 * Np ** 3 / 197e12
            rows.append({
                "devices": ndev,
                "overlap": overlap,
                "wall_s": round(t, 4),
                "wall_note": "1-core CPU serializes devices",
                "modeled_v5e_speedup": round(base_modeled / modeled, 2),
                "per_rank_comm_MB": round(
                    (ndev - 1) / ndev * N * N * 4 / 2**20, 1),
            })
    # correctness spot check on the last mesh
    got = np.asarray(f(A, B))
    err = np.abs(got - A @ B).max() / np.abs(A @ B).max()
    assert err < 1e-4, err
    path = write_csv("matmul.csv", rows)
    print(f"[bench_matmul] -> {path} (err={err:.1e})")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

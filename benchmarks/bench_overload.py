"""Overload economics: SLO-policed serving vs admit-everything baseline.

Drives the REAL ``ServeEngine`` (reduced model, real device calls) through
the same seeded bursty trace (``serve/trace.py``: Poisson bursts,
heavy-tail prompt lengths, priority tiers) twice — once with no SLO layer
(the admit-everything baseline: deadlines recorded but never enforced)
and once under an ``SLOPolicy`` (deadline-aware admission, shedding,
degraded modes) — on a ``ManualClock`` advanced a fixed ``DT`` modeled
seconds per engine step.  Because time is modeled, every latency/goodput
column is a deterministic function of the code (machine-independent), so
the ``modeled_*`` columns are CI-gated trajectory like every other bench.

Written to ``overload.csv`` / ``BENCH_summary.json``.  In-bench gates
(the ISSUE 8 acceptance criteria):

* SLO goodput (deadline-met completions) >= the baseline's;
* the SLO engine serves ZERO tokens past any deadline and completes ZERO
  deadline-violating requests (violators are shed/cancelled instead);
* an identical seed reproduces the identical admit/shed/degrade decision
  log (sha256 digest compared across two independent drives).
"""

from __future__ import annotations

import hashlib
import time

import numpy as np

from repro import configs
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine
from repro.serve.slo import ManualClock, SLOPolicy, TierPolicy, percentile
from repro.serve.trace import bursty_trace

from .common import smoke_mesh, write_csv

DT = 0.02          # modeled seconds per engine step
SEED = 17
# per-tier deadlines (ttft_s, total_s), passed EXPLICITLY to both engines
# so the baseline records (but never enforces) the same contracts
DEADLINES = {0: (None, 1.2), 1: (0.6, 2.0), 2: (0.3, 1.0)}


def _policy() -> SLOPolicy:
    return SLOPolicy(
        tiers={t: TierPolicy(ttft_deadline_s=d[0], total_deadline_s=d[1])
               for t, d in DEADLINES.items()},
        max_queue=16, queue_high=6, queue_low=2, min_step_s=DT,
        degrade_sustain_steps=5, degrade_recover_steps=10,
        degraded_max_new=4, degraded_chunk=4)


def _trace(n: int):
    return bursty_trace(SEED, n, burst_rate_per_s=6.0, mean_burst=5.0,
                        min_prompt=4, max_prompt=24,
                        max_new_choices=(6, 10),
                        tier_weights=(0.25, 0.45, 0.30))


def _drive(mesh, params, trace, *, slo):
    cfg = configs.get_reduced("stablelm-3b")
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    clk = ManualClock()
    eng = ServeEngine(cfg, mesh, ctx, params, slots=2, max_len=64,
                      prefill_chunk=8, page_tokens=16,
                      slo=_policy() if slo else None, clock=clk)
    rng = np.random.RandomState(0)
    pending = [(t, rng.randint(0, cfg.vocab_size, t.prompt_len)
                .astype(np.int32)) for t in trace]
    reqs = []
    while pending or eng.active or eng.queue or eng.preempted:
        while pending and pending[0][0].arrival_s <= clk.now():
            t, prompt = pending.pop(0)
            ttft_d, total_d = DEADLINES[t.priority]
            reqs.append(eng.submit(prompt, max_new=t.max_new,
                                   priority=t.priority,
                                   ttft_deadline_s=ttft_d,
                                   total_deadline_s=total_d))
        eng.step()
        clk.advance(DT)
        assert eng.steps < 5000, "overload drive did not converge"
    return eng, clk, reqs


def _digest(slo_log) -> str:
    return hashlib.sha256(repr(slo_log).encode()).hexdigest()[:16]


def _rows(mode: str, eng, clk, reqs) -> list:
    st = eng.latency_stats()
    done = [r for r in eng._all if r.done]
    ttft = [r.first_token_t - r.submit_t for r in done
            if r.first_token_t is not None]
    makespan = clk.now()
    row = {
        "bench": "overload",
        "mode": mode,
        "seed": SEED,
        "requests": len(reqs),
        "completed": st["requests_done"],
        "goodput": st["goodput"],
        "deadline_violations": st["deadline_violations"],
        "shed_total": st["shed_total"],
        "tokens_late": st["tokens_late"],
        "tokens_wasted": st["tokens_wasted"],
        "engine_steps": st["engine_steps"],
        "decision_digest": _digest(eng.slo_log),
        "modeled_makespan_s": round(makespan, 6),
        "modeled_p50_ttft_s": round(percentile(ttft, 50) or 0.0, 6),
        "modeled_p99_ttft_s": round(percentile(ttft, 99) or 0.0, 6),
        "modeled_goodput_rps": round(st["goodput"] / makespan, 6),
    }
    rows = [row]
    for tier in sorted(DEADLINES):
        sub = [r for r in eng._all if r.priority == tier]
        tdone = [r for r in sub if r.done]
        tttft = [r.first_token_t - r.submit_t for r in tdone
                 if r.first_token_t is not None]
        rows.append({
            "bench": "overload_tier",
            "mode": mode,
            "seed": SEED,
            "tier": tier,
            "submitted": len(sub),
            "completed": len(tdone),
            "goodput": sum(1 for r in tdone if r.deadline_met()),
            "shed": sum(1 for r in sub if r.shed_reason is not None),
            "modeled_p99_ttft_s": round(percentile(tttft, 99) or 0.0, 6),
        })
    return rows


def run(quick: bool = False) -> list:
    import jax

    mesh = smoke_mesh()
    cfg = configs.get_reduced("stablelm-3b")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    trace = _trace(24 if quick else 72)

    t0 = time.perf_counter()
    base_eng, base_clk, base_reqs = _drive(mesh, params, trace, slo=False)
    slo_eng, slo_clk, slo_reqs = _drive(mesh, params, trace, slo=True)
    # determinism gate: an independent drive replays the decision log
    slo2_eng, _, _ = _drive(mesh, params, trace, slo=True)
    wall = time.perf_counter() - t0

    assert slo_eng.slo_log == slo2_eng.slo_log, \
        "identical seed must reproduce the identical decision log"
    base_st = base_eng.latency_stats()
    slo_st = slo_eng.latency_stats()
    # the SLO layer's whole point: no worse goodput, zero late service
    assert slo_st["goodput"] >= base_st["goodput"], \
        (slo_st["goodput"], base_st["goodput"])
    assert slo_st["tokens_late"] == 0, slo_st["tokens_late"]
    assert slo_st["deadline_violations"] == 0, slo_st["deadline_violations"]
    # the trace actually overloads the baseline, or the comparison is vacuous
    assert base_st["deadline_violations"] + base_st["tokens_late"] > 0, \
        "trace did not overload the admit-everything baseline"

    rows = _rows("baseline", base_eng, base_clk, base_reqs) \
        + _rows("slo", slo_eng, slo_clk, slo_reqs)
    for r in rows:
        if r["bench"] == "overload":
            r["wall_s"] = round(wall, 3)
    write_csv("overload.csv", [r for r in rows if r["bench"] == "overload"])
    write_csv("overload_tiers.csv",
              [r for r in rows if r["bench"] == "overload_tier"])
    print(f"  baseline: {base_st['goodput']}/{len(base_reqs)} goodput, "
          f"{base_st['deadline_violations']} violations, "
          f"{base_st['tokens_late']} late tokens")
    print(f"  slo:      {slo_st['goodput']}/{len(slo_reqs)} goodput, "
          f"{slo_st['shed_total']} shed "
          f"({slo_st['shed']}), 0 violations, 0 late tokens, "
          f"digest {_digest(slo_eng.slo_log)}")
    return rows

"""Paper Fig. 8 + Listings 1-2: Minimod halo exchange — three modes.

The acoustic-isotropic 25-point stencil through the real application driver
(:mod:`repro.apps.minimod`), swept over THREE halo modes per device count:

* ``none``  — two-sided MPI emulation (paper Listing 2): gather all slabs,
              select, barrier; compute strictly after;
* ``host``  — one-sided puts + one fence (paper Listing 1), full-grid
              compute after the fence, overlap left to the XLA scheduler;
* ``fused`` — the halo-overlapped step: boundary slabs computed first and
              put one-sided while the interior runs under the exchange
              (schedule from ``OverlapPlanner.plan_halo_slots``).

All virtual devices share one physical core here, so wall time cannot show
parallel speedup; the ``modeled_*`` columns apply a per-step comm/compute
model at the paper's scale (1024^3, f32, v5e: 197 TFLOP/s, 819 GB/s HBM —
a stencil is memory-bound, so the cell time is the max of the flop and
HBM-stream costs — and 50 GB/s per ICI link direction) driven by the
``HaloPlan.schedule()`` planned FOR that scale (the ``run_overlap`` /
``modeled_overlap`` columns report the sweep run's and the model's plans
separately — the small CI grid may fall back where 1024^3 overlaps).  The fused mode's modeled step must never exceed the host mode's
at any swept rank count — asserted here, so the benchmark doubles as a
regression gate — and the fused run's put bytes must match the RMATracker
halo windows exactly.  The LOC row keeps the paper's programmability claim
(one-sided halo code ≈ half the two-sided lines).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.minimod import MODES, halo_loc, run_minimod
from repro.core.backends import LinkModel, ring_allgather_time
from repro.kernels.plan import OverlapPlanner
from repro.kernels.stencil.ref import RADIUS

from .common import write_csv

# v5e-flavored model constants (per chip / per ICI link direction)
PEAK_FLOPS = 197e12
HBM_BW = 819e9               # bytes/s per chip
LINK = LinkModel()           # 50 GB/s per direction, 1 us hop latency
DISPATCH_OVERHEAD = LINK.dispatch_s        # per host-issued collective
PAPER_G = 1024               # paper-scale Minimod grid (1024^3), f32
PAPER_ITEM = 4
# one 8th-order star: 24 neighbor adds + 4 coefficient FMAs per axis pair
# + the leapfrog update — ~33 flops/cell
FLOPS_PER_CELL = 33
# a stencil is memory-bound: per output cell the step streams u, u_prev,
# the velocity model and the output (the 25-point star reuses u through
# VMEM) — 4 f32 touches
BYTES_PER_CELL = 4 * PAPER_ITEM
CELL_T = max(FLOPS_PER_CELL / PEAK_FLOPS, BYTES_PER_CELL / HBM_BW)


def _modeled(ndev: int, mode: str):
    """(per-step seconds, exchanged bytes/rank, modeled-plan overlap) at
    the paper's scale, walking the HaloPlan schedule planned FOR that
    scale — which can differ from the quick CI run's plan (the small
    sweep grid may have no interior and fall back while 1024^3 overlaps;
    the row reports both plans' overlap flags)."""
    z_loc = PAPER_G // ndev
    plane = PAPER_G * PAPER_G
    t_all = z_loc * plane * CELL_T
    if ndev == 1:
        return t_all, 0, False
    plan = OverlapPlanner().plan_halo_slots(
        z_loc, PAPER_G, PAPER_G, jnp.float32, ndev, halo=RADIUS)
    t_x = plan.slab_bytes / LINK.bandwidth_Bps + LINK.latency_s
    t_bnd = 2 * RADIUS * plane * CELL_T
    t_int = plan.interior_z * plane * CELL_T

    if mode == "none":
        # two allgathers materialize every slab on every rank, then the
        # whole grid computes — nothing overlaps
        t_gather = 2 * (DISPATCH_OVERHEAD + ring_allgather_time(
            plan.slab_bytes * ndev, ndev, LINK))
        return (t_gather + LINK.latency_s + t_all,
                2 * plan.slab_bytes * (ndev - 1), False)

    sched = plan.schedule(carried=True) if mode == "fused" \
        else ("put", "fence", "all")         # the serialized listing-1 step
    t, in_flight = DISPATCH_OVERHEAD, 0.0
    for phase in sched:
        if phase == "boundary":
            t += t_bnd
        elif phase == "put":
            in_flight = t_x                  # started, not waited
        elif phase == "interior":
            t += max(t_int, in_flight)       # compute hides the wire
            in_flight = 0.0
        elif phase == "fence":
            t += in_flight + LINK.latency_s
            in_flight = 0.0
        elif phase == "all":
            t += t_all
    return t, plan.halo_bytes_per_step, mode == "fused" and plan.overlap


def run(quick: bool = False, grid: int = 48, steps: int = 5):
    if quick:
        grid, steps = 32, 3
    rows = []
    fields = {}
    base_modeled = _modeled(1, "none")[0]
    for ndev in (1, 2, 4, 8):
        for mode in MODES:
            r = run_minimod(grid=(grid, grid, grid), steps=steps, nz=ndev,
                            mode=mode)
            fields[(ndev, mode)] = r.field
            step_s, halo_bytes, modeled_overlap = _modeled(ndev, mode)
            rows.append({
                "devices": ndev,
                "mode": mode,
                "wall_s": round(r.wall_s, 4),
                "wall_note": "1-core CPU serializes devices",
                "modeled_step_s": round(step_s, 6),
                "modeled_v5e_speedup": round(base_modeled / step_s, 2),
                "halo_MB_per_step": round(halo_bytes / 2**20, 2),
                # one-sided traffic from the RMATracker halo windows — it
                # covers BOTH one-sided styles (the listing-1 host path
                # logs `halo_exchange`, not leaf `put`s, on the OMPCCL
                # call log, whose per-op semantics are pinned by tests)
                "halo_puts": r.tracker_puts,
                "halo_put_bytes": r.tracker_put_bytes,
                "run_overlap": r.plan.overlap,
                "modeled_overlap": modeled_overlap,
            })
            if mode == "fused":
                # acceptance: wire bytes on the OMPCCL log == the RMA
                # tracker's halo-window accounting, exactly
                assert r.put_bytes == r.tracker_put_bytes, \
                    (r.put_bytes, r.tracker_put_bytes)
                assert r.puts == r.tracker_puts, (r.puts, r.tracker_puts)

    # the fused schedule must never model slower than the host listing
    by_key = {(r["devices"], r["mode"]): r for r in rows}
    for ndev in (2, 4, 8):
        fused, host = by_key[(ndev, "fused")], by_key[(ndev, "host")]
        assert fused["modeled_step_s"] <= host["modeled_step_s"], (fused, host)

    # correctness: every mode propagates the identical wavefield
    want = fields[(1, "fused")]
    err = max(np.abs(f - want).max() for f in fields.values())
    assert err < 5e-5, err

    # heterogeneous ranks: asymmetric Z extents over the PGAS plan
    r = run_minimod(shape="minimod_hetero", steps=steps, mode="fused")
    rows.append({
        "devices": f"{r.nz}x{r.ny} hetero {r.z_extents}",
        "mode": "fused",
        "wall_s": round(r.wall_s, 4),
        "wall_note": f"asymmetric PGAS bytes {r.region_sizes}",
        "modeled_step_s": "-",
        "modeled_v5e_speedup": "-",
        "halo_MB_per_step": "-",
        "halo_puts": r.tracker_puts,
        "halo_put_bytes": r.tracker_put_bytes,
        "run_overlap": r.plan.overlap,
        "modeled_overlap": "-",
    })
    assert r.put_bytes == r.tracker_put_bytes

    # programmability: LOC of the two halo styles (paper's Fig. 8 claim)
    loc = halo_loc()
    rows.append({
        "devices": "-", "mode": f"LOC diomp={loc['diomp']} "
        f"two_sided={loc['two_sided']}",
        "wall_s": "-", "wall_note": "-", "modeled_step_s": "-",
        "modeled_v5e_speedup": round(loc["two_sided"] / loc["diomp"], 2),
        "halo_MB_per_step": "-", "halo_puts": "-", "halo_put_bytes": "-",
        "run_overlap": "-", "modeled_overlap": "-",
    })
    path = write_csv("minimod.csv", rows)
    print(f"[bench_minimod] -> {path} (err={err:.1e})")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

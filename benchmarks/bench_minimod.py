"""Paper Fig. 8 + Listings 1-2: Minimod halo exchange — DiOMP vs two-sided.

The acoustic-isotropic 25-point stencil, Z-sharded across devices, halo
exchange each step via (a) DiOMP one-sided ``halo_exchange`` (two puts + one
fence — paper Listing 1) vs (b) the MPI-shaped two-sided emulation
(gather-all + select + barrier — Listing 2's Isend/Irecv/Waitall).  Reports
wall times, scaling 1..8 devices, and the LOC comparison of the two halo
implementations (the paper's programmability claim).
"""

from __future__ import annotations

import inspect

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ompccl, rma
from repro.core.compat import axis_size, make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.kernels.stencil.ref import RADIUS, wave_step_ref

from .common import timeit, write_csv


def _halo_diomp(u, g):
    """Halo exchange, DiOMP style (paper Listing 1): puts + fence."""
    left, right = rma.halo_exchange(u, g, halo=RADIUS, axis=0)
    return left, right


def _halo_two_sided(u, g):
    """MPI style (paper Listing 2): explicit sends, receives and Waitall."""
    n = axis_size(g.axes[0])
    idx = jax.lax.axis_index(g.axes[0])
    down = jax.lax.slice_in_dim(u, u.shape[0] - RADIUS, u.shape[0], axis=0)
    up = jax.lax.slice_in_dim(u, 0, RADIUS, axis=0)
    all_down = ompccl.allgather(down, g, axis=0)     # every Isend materialized
    all_up = ompccl.allgather(up, g, axis=0)
    left = jax.lax.dynamic_slice_in_dim(
        all_down, ((idx - 1) % n) * RADIUS, RADIUS, axis=0)
    right = jax.lax.dynamic_slice_in_dim(
        all_up, ((idx + 1) % n) * RADIUS, RADIUS, axis=0)
    left = jnp.where(idx == 0, jnp.zeros_like(left), left)
    right = jnp.where(idx == n - 1, jnp.zeros_like(right), right)
    token = ompccl.barrier_value(g)                  # MPI_Waitall
    return left + 0 * token, right + 0 * token


def _dist_step(u, u_prev, c2dt2, g, halo_fn):
    left, right = halo_fn(u, g)
    up = jnp.concatenate([left, u, right], axis=0)
    nxt = wave_step_ref(up, jnp.pad(u_prev, ((RADIUS, RADIUS), (0, 0), (0, 0))),
                        c2dt2)
    return nxt[RADIUS:-RADIUS]


def run(quick: bool = False, grid: int = 64, steps: int = 5):
    if quick:
        grid, steps = 48, 3
    rows = []
    base = {}
    for ndev in (1, 2, 4, 8):
        mesh = make_mesh((ndev,), ("z",), axis_types="auto")
        g = DiompGroup(("z",), name="z")
        u0 = np.zeros((grid, grid, grid), np.float32)
        u0[grid // 2, grid // 2, grid // 2] = 1.0
        up0 = np.zeros_like(u0)

        for name, halo in (("diomp", _halo_diomp), ("two_sided",
                                                    _halo_two_sided)):
            def many(u, u_prev):
                def body(carry, _):
                    u, u_prev = carry
                    nxt = _dist_step(u, u_prev, 0.1, g, halo)
                    return (nxt, u), None
                (u, u_prev), _ = jax.lax.scan(body, (u, u_prev), None,
                                              length=steps)
                return u

            f = jax.jit(shard_map(many, mesh=mesh,
                                  in_specs=(P("z"), P("z")),
                                  out_specs=P("z")))
            t = timeit(f, u0, up0, iters=3)
            if ndev == 1:
                base[name] = t
            rows.append({
                "devices": ndev, "impl": name, "wall_s": round(t, 4),
                "speedup": round(base[name] / t, 2),
            })
    # programmability: LOC of the two halo implementations (paper's claim:
    # DiOMP needs about half the lines)
    loc_diomp = len(inspect.getsource(_halo_diomp).strip().splitlines())
    loc_two = len(inspect.getsource(_halo_two_sided).strip().splitlines())
    rows.append({"devices": "-", "impl": f"LOC diomp={loc_diomp} "
                 f"two_sided={loc_two}", "wall_s": "-",
                 "speedup": round(loc_two / loc_diomp, 2)})
    path = write_csv("minimod.csv", rows)
    print(f"[bench_minimod] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

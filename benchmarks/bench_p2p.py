"""Paper Figs. 3-5: point-to-point RMA — one-sided put/get vs two-sided.

DiOMP's claim: one-sided RMA (put + fence) beats MPI two-sided because the
receiver never participates and no tag-matching handshake serializes the
wire.  TPU adaptation: our put IS a single collective-permute; the
"MPI two-sided" emulation models send/recv semantics SPMD-style — an
all-gather (receiver-driven copy of every candidate message) followed by a
select + explicit barrier (the MPI_Waitall).  We measure wall time on the
8-virtual-device CPU mesh (relative cost of the extra data movement is
real) and report the analytic ICI model for the production pod alongside.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ompccl, rma
from repro.core.compat import make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.core.ompccl import LinkModel

from .common import smoke_mesh, timeit, write_csv

SIZES = [4, 256, 4096, 65_536, 1_048_576, 8_388_608, 67_108_864]  # bytes


def run(quick: bool = False):
    mesh = make_mesh((8,), ("x",), axis_types="auto")
    g = DiompGroup(("x",), name="ring")
    link = LinkModel()
    rows = []
    sizes = SIZES[:5] if quick else SIZES
    for nbytes in sizes:
        n = max(nbytes // 4, 1)
        x = np.arange(8 * n, dtype=np.float32).reshape(8, n)

        put = jax.jit(shard_map(
            lambda v: rma.ompx_fence(rma.ompx_put(v, g)),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))

        def two_sided(v):
            # MPI-ish: gather all candidate messages, select the matching
            # one (tag match), then barrier (Waitall)
            allv = ompccl.allgather(v, g, axis=0)
            idx = jax.lax.axis_index("x")
            src = (idx - 1) % 8
            got = jax.lax.dynamic_slice_in_dim(allv, src * v.shape[0],
                                               v.shape[0], axis=0)
            return got + 0 * ompccl.barrier_value(g)

        two = jax.jit(shard_map(two_sided, mesh=mesh, in_specs=P("x"),
                                out_specs=P("x")))

        t_put = timeit(put, x) * 1e6
        t_two = timeit(two, x) * 1e6
        # analytic ICI (v5e): one-sided = B/bw + lat; two-sided adds the
        # rendezvous handshake + n-1x gather traffic for unmatched messages
        a_put = (nbytes / link.bandwidth_Bps + link.latency_s) * 1e6
        a_two = (2 * link.latency_s + 7 / 8 * 8 * nbytes /
                 link.bandwidth_Bps + link.latency_s) * 1e6
        rows.append({
            "bytes": nbytes,
            "diomp_put_us_cpu": round(t_put, 1),
            "two_sided_us_cpu": round(t_two, 1),
            "cpu_ratio": round(t_two / t_put, 2),
            "diomp_put_us_ici_model": round(a_put, 2),
            "two_sided_us_ici_model": round(a_two, 2),
        })
    path = write_csv("p2p.csv", rows)
    print(f"[bench_p2p] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

"""Loop-aware cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each while body ONCE — a 61-layer scan
with 8 grad-accumulation microsteps under-reports FLOPs and collective bytes
by ~500x.  This analyzer parses the HLO text, builds the computation call
graph with multipliers (while bodies x known_trip_count, fusion/call bodies
x 1 per call site), and accumulates:

* **flops** — 2 x prod(result dims) x prod(contracting dims) per ``dot``
  (MXU work; elementwise VPU flops are not counted — they are bandwidth-
  bound and show up in the memory term);
* **bytes** — per top-level instruction: result + operand buffer bytes
  (fusion-internal instructions excluded — they never touch HBM; aliasing
  ops like bitcast/GTE/tuple skipped; in-place dynamic-update-slice charged
  only its updated window).  An HBM-traffic UPPER BOUND: CPU fusion
  boundaries are coarser than TPU's, so elementwise chains that a TPU
  compile would fuse appear as distinct buffer round-trips here;
* **dot_bytes** — operand+result bytes of dot ops only: the traffic that
  must reach the MXU regardless of fusion quality.  The memory roofline
  term uses this (TPU-realistic lower bound);
* **collective bytes** — result bytes per collective kind (all-reduce /
  all-gather / reduce-scatter / all-to-all / collective-permute), the
  operands that cross ICI.

Every quantity is per chip (the partitioned module is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|token|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|[suc]\d+)"
                       r"\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%([\w\.\-]+)")
_COND = re.compile(r"condition=%([\w\.\-]+)")
_CALLS = re.compile(r"calls=%([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%([\w\.\-]+)")
_BRANCHES = re.compile(r"(?:true|false|branch)_computation[s]?=\{?%?([\w\.\-, %]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "domain", "iota",
             # control flow: their bodies' instructions account the traffic;
             # counting the carried tuple here would double-count it
             "while", "conditional", "call", "optimization-barrier"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(type_str: str) -> Tuple[int, Optional[List[int]]]:
    """(total bytes, dims of the first array shape or None)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for dt, dims in _SHAPE_RE.findall(type_str):
        dl = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
        if first_dims is None:
            first_dims = dl
    return total, first_dims


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    out_bytes: int
    out_dims: Optional[List[int]]
    operands: List[str]
    attrs: str


def _split_args(rest: str) -> Tuple[str, str]:
    """Split 'args), attrs...' at the matching close paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def _parse(text: str):
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            else:
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        args, attrs = _split_args(rest)
        out_bytes, out_dims = _shape_info(type_str)
        operands = re.findall(r"%([\w\.\-]+)", args)
        comps[cur].append(_Instr(name, op, out_bytes, out_dims, operands,
                                 attrs))
    return comps, entry


def _multipliers(comps, entry) -> Tuple[Dict[str, float], set]:
    """comp name -> total invocation count; plus the fusion-internal set."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    fused: set = set()
    if entry is None:
        return {c: 1.0 for c in comps}, fused
    mult[entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graph is a DAG)
    for _ in range(64):
        changed = False

        def bump(callee, amount, is_fusion=False):
            nonlocal changed
            if callee not in mult:
                return
            if is_fusion:
                fused.add(callee)
            if amount > mult[callee]:
                mult[callee] = amount
                changed = True

        for cname, instrs in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                if ins.op == "while":
                    trip = 1
                    tm = _TRIP.search(ins.attrs)
                    if tm:
                        trip = int(tm.group(1))
                    bm = _BODY.search(ins.attrs)
                    cm = _COND.search(ins.attrs)
                    if bm:
                        bump(bm.group(1), m * trip)
                    if cm:
                        bump(cm.group(1), m * (trip + 1))
                elif ins.op == "fusion":
                    fm = _CALLS.search(ins.attrs)
                    if fm:
                        bump(fm.group(1), m, is_fusion=True)
                elif ins.op in ("call", "custom-call", "reduce", "scatter",
                                "sort", "map", "reduce-window", "select-and-scatter",
                                "all-reduce", "reduce-scatter"):
                    am = _TO_APPLY.search(ins.attrs)
                    if am:
                        bump(am.group(1), m, is_fusion=True)
                elif ins.op == "conditional":
                    for g in _BRANCHES.findall(ins.attrs):
                        for nm in re.findall(r"[\w\.\-]+", g):
                            bump(nm, m)
        if not changed:
            break
    return mult, fused


def analyze_hlo(text: str) -> "HloCost":
    comps, entry = _parse(text)
    mult, fused = _multipliers(comps, entry)

    flops = 0.0
    bytes_ = 0.0
    dot_bytes = 0.0
    coll: Dict[str, float] = {}

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = {i.name: i for i in instrs}
        for ins in instrs:
            # MXU flops: dots anywhere (including inside fusions)
            if ins.op == "dot" and ins.out_dims is not None and ins.operands:
                lhs = symtab.get(ins.operands[0])
                contract = 1
                cm = _LHS_C.search(ins.attrs)
                if lhs is not None and lhs.out_dims and cm:
                    for idx in cm.group(1).split(","):
                        if idx:
                            contract *= lhs.out_dims[int(idx)]
                n_out = 1
                for d in ins.out_dims:
                    n_out *= d
                flops += m * 2.0 * n_out * contract
                opnd = sum(symtab[o].out_bytes for o in ins.operands
                           if o in symtab)
                dot_bytes += m * (ins.out_bytes + opnd)
            # collectives (result bytes = wire payload per chip)
            base = ins.op.replace("-start", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                coll[base] = coll.get(base, 0.0) + m * ins.out_bytes
            # HBM traffic proxy: top-level (non-fused) instructions only
            if cname not in fused and ins.op not in _FREE_OPS \
                    and not ins.op.endswith("-done"):
                opnd_list = [symtab[o].out_bytes for o in ins.operands
                             if o in symtab]
                opnd = sum(opnd_list)
                total = ins.out_bytes + opnd
                name_l = (ins.op + " " + ins.name).lower()
                if "dynamic-update-slice" in name_l or \
                        "dynamic_update_slice" in name_l:
                    # in-place: charge the updated window, not the buffer
                    big = max(opnd_list, default=0)
                    total = max(total - 2 * big, 0)
                elif ins.op == "dynamic-slice":
                    total = 2 * ins.out_bytes
                bytes_ += m * total
    return HloCost(flops=flops, bytes=bytes_, dot_bytes=dot_bytes,
                   collective_bytes=coll)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float            # upper-bound HBM traffic proxy
    dot_bytes: float        # MXU operand/result traffic (memory-term basis)
    collective_bytes: Dict[str, float]

"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run [--quick]`` executes:
  p2p          (paper Figs. 3-5: RMA latency/bandwidth)
  collectives  (paper Fig. 6: OMPCCL vs flat collectives)
  matmul       (paper Fig. 7: Cannon ring matmul scaling)
  minimod      (paper Fig. 8 + Listings 1-2: halo exchange + LOC)
  streams      (paper §3.2: stream-pool policy throughput)
  kvcache      (paper Fig. 2: asymmetric heap / page-table churn)

CSVs land in experiments/bench/.  Set XLA device count before jax imports.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (p2p,collectives,matmul,"
                         "minimod,streams,kvcache)")
    args = ap.parse_args(argv)

    from . import (bench_collectives, bench_kvcache, bench_matmul,
                   bench_minimod, bench_p2p, bench_streams)

    table = {
        "p2p": bench_p2p.run,
        "collectives": bench_collectives.run,
        "matmul": bench_matmul.run,
        "minimod": bench_minimod.run,
        "streams": bench_streams.run,
        "kvcache": bench_kvcache.run,
    }
    only = args.only.split(",") if args.only else list(table)
    t0 = time.time()
    for name in only:
        print(f"\n=== {name} ===")
        table[name](quick=args.quick)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run [--quick] [--json [PATH]]`` executes:
  p2p          (paper Figs. 3-5: RMA latency/bandwidth)
  collectives  (paper Fig. 6: OMPCCL vs flat collectives)
  grad_reduce  (per-param vs bucketed DP gradient reduction; gates the
                shipped bucketed schedule: faster at smoke-CI mesh sizes,
                within 5% at the largest modeled mesh)
  matmul       (paper Fig. 7: Cannon ring matmul scaling, 3 overlap modes)
  minimod      (paper Fig. 8 + Listings 1-2: none/host/fused halo modes,
                asymmetric decomposition, fused-overlap gate + LOC)
  moe          (dropless MoE dispatch: none/a2a/host/fused over EP sizes,
                asymmetric expert regions, fused-overlap + parity gates)
  attention    (fused ring attention: none/allgather/host/fused over ring
                sizes, modeled schedule walk + put-parity gates)
  streams      (paper §3.2: stream-pool policy throughput)
  kvcache      (paper Fig. 2: asymmetric heap / page-table churn)
  faults       (chaos overhead: retry model, seeded recovery smoke,
                rank-death degraded-throughput model)
  overload     (SLO-policed serving vs admit-everything baseline on a
                seeded bursty trace: goodput, p99 TTFT, shed rate,
                deadline violations, decision-log determinism)

CSVs land in experiments/bench/.  ``--json`` (implied by ``--quick``)
additionally writes the consolidated ``BENCH_summary.json`` — the perf
trajectory file CI and the PERF docs read — with every bench's rows plus
run metadata.  Set XLA device count before jax imports.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import platform
import time

SUMMARY_DEFAULT = "BENCH_summary.json"


def write_summary(path: str, results: dict, *, quick: bool,
                  elapsed_s: float) -> str:
    import jax

    summary = {
        "schema": 1,
        "quick": quick,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "unix_time": int(time.time()),
        "elapsed_s": round(elapsed_s, 1),
        "benches": results,
    }
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (p2p,collectives,"
                         "grad_reduce,matmul,minimod,moe,attention,streams,"
                         "kvcache,faults,overload)")
    ap.add_argument("--json", nargs="?", const=SUMMARY_DEFAULT, default=None,
                    metavar="PATH",
                    help="write the consolidated BENCH_summary.json "
                         f"(default path: {SUMMARY_DEFAULT}; --quick "
                         "implies this)")
    args = ap.parse_args(argv)

    from . import (bench_attention, bench_collectives, bench_faults,
                   bench_kvcache, bench_matmul, bench_minimod, bench_moe,
                   bench_overload, bench_p2p, bench_streams)

    table = {
        "p2p": bench_p2p.run,
        "collectives": bench_collectives.run,
        "grad_reduce": bench_collectives.run_grad_reduce,
        "matmul": bench_matmul.run,
        "minimod": bench_minimod.run,
        "moe": bench_moe.run,
        "attention": bench_attention.run,
        "streams": bench_streams.run,
        "kvcache": bench_kvcache.run,
        "faults": bench_faults.run,
        "overload": bench_overload.run,
    }
    only = args.only.split(",") if args.only else list(table)
    t0 = time.time()
    results = {}
    for name in only:
        print(f"\n=== {name} ===")
        rows = table[name](quick=args.quick)
        results[name] = rows if rows is not None else []
    elapsed = time.time() - t0
    json_path = args.json or (SUMMARY_DEFAULT if args.quick else None)
    if json_path:
        path = write_summary(json_path, results, quick=args.quick,
                             elapsed_s=elapsed)
        print(f"\n[summary] -> {path}")
    print(f"\nall benchmarks done in {elapsed:.0f}s")


if __name__ == "__main__":
    main()

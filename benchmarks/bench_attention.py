"""Sequence-parallel attention: none / allgather / host / fused.

The fused ring attention (:mod:`repro.kernels.ring_attention`) swept over
ring sizes for a long causal context, against the baselines it replaces:

* ``none``      — no sequence parallelism: one device scans the whole
                  T x T causal matrix;
* ``allgather`` — the ``moe_block``-style host path ``attention_block``
                  ships by default: K/V all-gathered over the group, then
                  one local flash pass per rank — O(T) memory and a bulk
                  collective strictly BEFORE any compute;
* ``host``      — the one-sided K/V ring serialized (put, fence, fold):
                  same wire bytes, same merge chain, overlap left to the
                  XLA scheduler;
* ``fused``     — the :class:`AttentionRingPlan` overlapped schedule: the
                  stripes feeding step ``s + 1`` fly under step ``s``'s
                  flash block, and causal step skipping drops the FLOPs of
                  fully-future stripes (bitwise sound: their states are
                  the merge identity).

All virtual devices share one physical core, so wall time cannot show the
overlap win; the ``modeled_*`` columns walk each mode's ACTUAL
:meth:`AttentionRingPlan.schedule` at long-context scale (B=1, T=131072,
H=64, KH=8, D=Dv=128, bf16, v5e: 197 TFLOP/s, 50 GB/s per ICI link
direction), rank by rank, taking the slowest rank as the critical path.
The fused mode must never model slower than ``allgather`` or ``host`` at
any swept ring size — asserted here, so the benchmark doubles as a
regression gate — and the fused run's put bytes must equal the OMPCCL
byte log, the RMATracker attention windows, and ``plan.wire_bytes``
exactly.  Both one-sided modes must reproduce the single-device
stripe/merge oracle bit-for-bit.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ompccl
from repro.core.backends import LinkModel, ring_allgather_time
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import attention_window_names
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.plan import AttentionRingPlan, default_planner
from repro.kernels.ring_attention import ring_attention, ring_attention_ref

from .common import timeit, write_csv

# v5e-flavored model constants (per chip / per ICI link direction)
PEAK_FLOPS = 197e12
LINK = LinkModel()           # 50 GB/s per direction, 1 us hop latency
DISPATCH_OVERHEAD = LINK.dispatch_s

# one long-context causal attention layer at paper scale, bf16 on the wire
P_B, P_T, P_H, P_KH, P_D, P_DV = 1, 131072, 64, 8, 128, 128

GROUP = DiompGroup(("x",), name="x")
MODES = ("none", "allgather", "host", "fused")
NS = (2, 4, 8)


def _paper_plan(n: int, mode: str) -> AttentionRingPlan:
    return default_planner().plan_ring_attention(
        P_B, P_T // n, P_T // n, P_H, P_KH, P_D, P_DV, jnp.bfloat16, n,
        causal=True, overlap=(mode == "fused"))


def _stripes_computed(plan: AttentionRingPlan, rank: int) -> int:
    return len(plan.computed_sources(rank))


def _ring_walk(plan: AttentionRingPlan, rank: int) -> float:
    """Critical path of ``rank`` through the plan's ACTUAL step records.

    Puts occupy their link direction only; ``overlap=True`` fences each
    step's forwards after that step's flash blocks, ``False`` (the host
    listing) before them.  Causal step skipping (``plan.computes``) drops
    the flash block but never the send — downstream ranks still need the
    forwarded stripe, so wire bytes are mode-invariant.
    """
    t_stripe = plan.stripe_flops / PEAK_FLOPS
    put_s = plan.stripe_bytes / LINK.bandwidth_Bps
    t = DISPATCH_OVERHEAD
    link_free = {"cw": 0.0, "ccw": 0.0}
    landed = []
    for st in plan.schedule():
        landed = []
        for dirn, send in (("cw", st.send_cw), ("ccw", st.send_ccw)):
            if send:
                start = max(t, link_free[dirn])
                link_free[dirn] = start + put_s
                landed.append(link_free[dirn] + LINK.latency_s)
        if not plan.overlap:            # serialized: land, then fold
            t = max(t, *landed) if landed else t
        if st.compute_cw and plan.computes(rank, (rank - st.index) % plan.n):
            t += t_stripe
        if st.compute_ccw and plan.computes(rank, (rank + st.index) % plan.n):
            t += t_stripe
        if plan.overlap:                # fused: fold first, then fence
            t = max(t, *landed) if landed else t
    return t


def _modeled(n: int, mode: str):
    """(per-layer seconds, wire bytes/rank) at the paper scale."""
    plan = _paper_plan(n, mode)
    if mode == "none":
        # one device, all n*n stripe blocks, causal skipping at stripe
        # granularity (sum over ranks of each rank's visible stripes)
        blocks = sum(_stripes_computed(plan, r) for r in range(n))
        return DISPATCH_OVERHEAD + blocks * plan.stripe_flops / PEAK_FLOPS, 0
    if mode == "allgather":
        # bulk K/V all-gather strictly before compute; the critical path
        # then runs the busiest rank's visible stripes
        kv_full = n * plan.stripe_bytes
        blocks = max(_stripes_computed(plan, r) for r in range(n))
        t = (DISPATCH_OVERHEAD + ring_allgather_time(kv_full, n, LINK)
             + blocks * plan.stripe_flops / PEAK_FLOPS)
        return t, plan.wire_bytes      # same (n-1)/n of the K/V on the wire
    t = max(_ring_walk(plan, r) for r in range(n))
    return t, plan.wire_bytes


# ---------------------------------------------------------------------------
# the tiny real sweep
# ---------------------------------------------------------------------------

B, TQ, H, KH, D, DV = 2, 8, 4, 2, 8, 8


def _tiny_case(n: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    T = n * TQ
    q = rng.randn(B, T, H, D).astype(np.float32)
    k = rng.randn(B, T, KH, D).astype(np.float32)
    v = rng.randn(B, T, KH, DV).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def _ring_fn(mesh, impl):
    def f(q, k, v):
        return ring_attention(q, k, v, GROUP, causal=True, impl=impl)

    spec = P(None, "x")
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(spec,) * 3,
                             out_specs=spec))


def _allgather_fn(mesh, n):
    def f(q, k, v):
        tq = q.shape[1]
        me = jax.lax.axis_index("x")
        k_full = ompccl.allgather(k, GROUP, axis=1)
        v_full = ompccl.allgather(v, GROUP, axis=1)
        return flash_attention_ref(q, k_full, v_full, causal=True,
                                   q_offset=me * tq)

    spec = P(None, "x")
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(spec,) * 3,
                             out_specs=spec))


def _none_fn():
    def f(q, k, v):
        return flash_attention_ref(q, k, v, causal=True)

    return jax.jit(f)


def _oracle_fn(n):
    return jax.jit(lambda q, k, v: ring_attention_ref(q, k, v, n=n,
                                                      causal=True))


def _fused_put_parity(mesh, n, q, k, v):
    """Lower the fused ring under a fresh context; check the books."""
    plan = default_planner().plan_ring_attention(
        B, TQ, TQ, H, KH, D, DV, jnp.float32, n, causal=True)

    dctx = DiompContext()
    with use_default(dctx):
        _ring_fn(mesh, "fused").lower(q, k, v)
    desc = GROUP.descriptor()
    puts = dctx.stats()[desc]["put"]
    put_bytes = dctx.byte_stats()[desc]["put"]
    cw_w, ccw_w = attention_window_names(GROUP, n)
    win_bytes = sum(dctx.rma.window_bytes[w] for w in cw_w + ccw_w)
    # acceptance: OMPCCL byte log == RMA window accounting == the plan
    assert puts == plan.puts_per_rank, (puts, plan.puts_per_rank)
    assert put_bytes == win_bytes == plan.wire_bytes == dctx.rma.put_bytes, \
        (put_bytes, win_bytes, plan.wire_bytes, dctx.rma.put_bytes)
    return puts, put_bytes


def run(quick: bool = False):
    warmup, iters = (1, 2) if quick else (2, 5)
    rows = []
    for n in NS:
        mesh = make_mesh((n,), ("x",), axis_types="auto")
        q, k, v = _tiny_case(n)

        walls, outs = {}, {}
        for impl in ("host", "fused"):
            fn = _ring_fn(mesh, impl)
            outs[impl] = np.asarray(fn(q, k, v))
            walls[impl] = timeit(fn, q, k, v, warmup=warmup, iters=iters)
        # both one-sided modes reproduce the stripe/merge oracle bitwise
        want = np.asarray(_oracle_fn(n)(q, k, v))
        np.testing.assert_array_equal(outs["fused"], want)
        np.testing.assert_array_equal(outs["host"], want)
        ag = _allgather_fn(mesh, n)
        np.testing.assert_allclose(np.asarray(ag(q, k, v)), want,
                                   atol=3e-6, rtol=3e-6)
        walls["allgather"] = timeit(ag, q, k, v, warmup=warmup, iters=iters)
        walls["none"] = timeit(_none_fn(), q, k, v, warmup=warmup,
                               iters=iters)

        puts, put_bytes = _fused_put_parity(mesh, n, q, k, v)
        modeled = {m: _modeled(n, m) for m in MODES}
        base = modeled["allgather"][0]
        for m in MODES:
            step_s, wire = modeled[m]
            rows.append({
                "n": n,
                "mode": m,
                "wall_s": round(walls[m], 4),
                "wall_note": "1-core CPU serializes devices",
                "modeled_layer_s": round(step_s, 6),
                "modeled_speedup_vs_allgather": round(base / step_s, 2),
                "wire_MB_per_rank": round(wire / 2**20, 2),
                "puts": puts if m == "fused" else "-",
                "put_bytes": put_bytes if m == "fused" else "-",
            })
        # the gate: the overlapped ring never models slower than the bulk
        # all-gather or the serialized one-sided listing, at EVERY n
        assert modeled["fused"][0] <= modeled["allgather"][0], (n, modeled)
        assert modeled["fused"][0] <= modeled["host"][0], (n, modeled)

    path = write_csv("attention.csv", rows)
    print(f"[bench_attention] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

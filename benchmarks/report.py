"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from the JSON
records under experiments/dryrun (and the §Perf iterations under
experiments/perf).  ``python -m benchmarks.report > /tmp/tables.md``."""

from __future__ import annotations

import glob
import json
import os
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def load(dirname, tag=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        if tag and not f.endswith(f"__{tag}.json"):
            continue
        recs.append(json.load(open(f)))
    return recs


def dryrun_table(recs):
    print("| arch | shape | mesh | status | HBM GiB/chip | t_compute s | "
          "t_memory s | t_collective s | dominant | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"skip: {r['reason'][:45]} | | | | | | |")
            continue
        if r["status"] == "fail":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | | | | |")
            continue
        mem = sum(v for v in r["memory"].values() if v)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{mem/2**30:.1f} | {r['t_compute_s']:.4f} | "
              f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
              f"{r['dominant']} | {r['useful_flops_fraction']:.2f} |")


def main():
    recs = load("experiments/dryrun", tag="baseline")
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    print(f"## Dry-run summary: {len(ok)} ok, {len(skip)} documented skips, "
          f"{len(recs)-len(ok)-len(skip)} failures\n")
    dryrun_table(recs)
    print("\n\n## Perf iterations\n")
    for r in load("experiments/perf"):
        if r.get("status") != "ok":
            continue
        mem = sum(v for v in r["memory"].values() if v)
        print(f"* {r['arch']} × {r['shape']} × {r['mesh']} "
              f"[{r['knobs']}] -> t=(c {r['t_compute_s']:.3f}, "
              f"m {r['t_memory_s']:.3f}, x {r['t_collective_s']:.3f})s, "
              f"HBM {mem/2**30:.1f} GiB, dominant={r['dominant']}")


if __name__ == "__main__":
    main()

"""Paper Fig. 2 mechanics + serving KV economics (docs/SERVING.md).

Three measurements, written to ``kvcache.csv`` / ``BENCH_summary.json``:

* **alloc churn** — serving-shaped admit/extend/release churn on the PGAS
  heap, old whole-region-realloc design vs the paged page-table allocator.
  CI gate: the paged allocator issues AT MOST one arena page allocation per
  ``extend`` (O(1)), while the realloc baseline's per-extend churn grows
  with the region size (O(pages)).
* **modeled prefill throughput** — engine steps per request for
  token-by-token vs chunked prefill (``ceil(len/chunk) + max_new`` vs
  ``len + max_new``); gate: chunked never takes more steps.
* **wall-clock prefill throughput** — real device calls on a reduced
  model: one ``build_chunk_prefill_step`` call per chunk vs one decode call
  per prompt token, prefill tokens/s both ways.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.serve.kvcache import PagedKVAllocator, ReallocKVAllocator

from .common import write_csv


def _churn(mode: str, n_reqs: int) -> dict:
    """Serving churn: admit, decode with page growth, periodic release."""
    mem = GlobalMemory(8, 1 << 26, allocator="buddy")
    g = DiompGroup(("x",), name="x")
    cls = PagedKVAllocator if mode == "paged" else ReallocKVAllocator
    alloc = cls(mem, g, page_tokens=64, kv_bytes_per_token=256)
    rng = np.random.RandomState(0)
    live, lookups, extend_allocs = [], 0, []
    t0 = time.perf_counter()
    for i in range(n_reqs):
        plen = int(rng.randint(16, 512))
        if len(live) >= 16:                      # steady-state churn: the
            alloc.release(live.pop(0))           # oldest request completes
        r = alloc.admit(plen, plen + 128)
        if r is None:
            for req in live[: len(live) // 2]:   # heap full: drop oldest half
                alloc.release(req)
            live = live[len(live) // 2:]
            r = alloc.admit(plen, plen + 128)
            if r is None:
                continue
        r.pos = plen
        live.append(r)
        # decode 96 tokens with page-table lookups on the home rank
        for _ in range(96):
            a0 = alloc.stats["arena_page_allocs"]
            if not alloc.extend(r):
                break
            extend_allocs.append(alloc.stats["arena_page_allocs"] - a0)
            r.pos += 1
            alloc.lookup(r, r.pos - 1)
            lookups += 1
    wall = time.perf_counter() - t0
    for req in list(live):
        alloc.release(req)
    if mode == "paged":
        alloc.trim()
    mem.check_invariants()
    grew = [d for d in extend_allocs if d > 0]
    return {
        "bench": "churn",
        "mode": mode,
        "requests": n_reqs,
        "wall_s": round(wall, 3),
        "admits_per_s": round(n_reqs / wall),
        "extends": len(extend_allocs),
        "pages_allocated": alloc.stats["pages_allocated"],
        "arena_page_allocs": alloc.stats["arena_page_allocs"],
        "page_reuses": alloc.stats["page_reuses"],
        "alloc_pages_per_extend_max": max(grew, default=0),
        "alloc_pages_per_extend_mean": round(
            sum(grew) / max(len(grew), 1), 2),
        "oom_events": alloc.stats["oom_events"],
        "ptr_cache_hit_rate": round(mem.ptr_cache.hit_rate, 3),
        "lookups": lookups,
    }


def _modeled_prefill(chunk: int = 64, max_new: int = 32) -> list:
    rows = []
    for plen in (128, 512, 2048, 8192):
        legacy = plen + max_new
        chunked = -(-plen // chunk) + max_new
        rows.append({
            "bench": "prefill_model",
            "prompt_len": plen, "chunk": chunk, "max_new": max_new,
            "steps_legacy": legacy, "steps_chunked": chunked,
            "step_speedup": round(legacy / chunked, 2),
        })
    return rows


def _wall_prefill(quick: bool) -> dict:
    """Real device calls: chunked prefill vs token-by-token decode."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import api as model_api
    from repro.models import schema as sch
    from repro.models.config import ParallelCtx
    from repro.serve.step import build_chunk_prefill_step, build_decode_step

    cfg = configs.get_reduced("stablelm-3b")
    mesh = make_smoke_mesh(len(jax.devices()))
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    P, C, S = (48, 16, 96) if quick else (256, 32, 320)
    reps = 1 if quick else 3
    chunk_step = build_chunk_prefill_step(cfg, mesh, ctx, C=C, S_cache=S)
    decode_step = build_decode_step(cfg, mesh, ctx, B=1, S=S, donate=False,
                                    slot_pos=True)
    structs, _ = model_api.cache_structs(cfg, mesh, ctx, 1, S)
    zero = lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                structs)
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, size=P).astype(np.int32)

    def run_chunked():
        cache = zero()
        cache["pos"] = jnp.asarray(0, jnp.int32)
        logits = None
        for f in range(0, P, C):
            toks = jnp.asarray(prompt[None, f:f + C])
            logits, cache = chunk_step(params, toks, cache,
                                       jnp.asarray(C, jnp.int32))
        jax.block_until_ready(logits)

    def run_legacy():
        cache = zero()
        cache["pos"] = jnp.zeros((1,), jnp.int32)
        logits = None
        for t in range(P):
            logits, cache = decode_step(
                params, jnp.asarray(prompt[None, t:t + 1]), cache)
        jax.block_until_ready(logits)

    run_chunked(), run_legacy()                      # compile warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        run_chunked()
    t_chunk = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        run_legacy()
    t_legacy = (time.perf_counter() - t0) / reps
    return {
        "bench": "prefill_wall",
        "prompt_len": P, "chunk": C,
        "wall_s_chunked": round(t_chunk, 4),
        "wall_s_legacy": round(t_legacy, 4),
        "prefill_tok_per_s_chunked": round(P / t_chunk),
        "prefill_tok_per_s_legacy": round(P / t_legacy),
        "wall_speedup": round(t_legacy / t_chunk, 2),
    }


def run(quick: bool = False):
    n_reqs = 200 if quick else 1000
    rows = [_churn("realloc", n_reqs), _churn("paged", n_reqs)]
    realloc, paged = rows
    # -- CI gates: the whole point of the page table -------------------------
    assert paged["alloc_pages_per_extend_max"] <= 1, paged
    assert realloc["alloc_pages_per_extend_mean"] >= 2, realloc
    assert paged["arena_page_allocs"] < realloc["arena_page_allocs"], (
        paged["arena_page_allocs"], realloc["arena_page_allocs"])
    rows += _modeled_prefill()
    for r in rows:
        if r.get("bench") == "prefill_model":
            assert r["steps_chunked"] <= r["steps_legacy"], r
    rows.append(_wall_prefill(quick))
    assert rows[-1]["wall_s_chunked"] <= rows[-1]["wall_s_legacy"] * 1.5, \
        rows[-1]
    keys: list = []
    for r in rows:               # union schema (three bench sections)
        keys += [k for k in r if k not in keys]
    path = write_csv("kvcache.csv", [{k: r.get(k, "") for k in keys}
                                     for r in rows])
    print(f"[bench_kvcache] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

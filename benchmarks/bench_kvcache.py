"""Paper Fig. 2 mechanics: asymmetric allocation + second-level pointers.

Serving-shaped churn on the PGAS heap: admit/extend/release request KV under
the buddy allocator, measuring allocation throughput, fragmentation, and
remote-pointer-cache hit rate (the paper's two-step dereference amortization)
— symmetric (padded) vs asymmetric (second-level pointer) strategies.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.serve.kvcache import PagedKVAllocator

from .common import write_csv


def run(quick: bool = False):
    n_reqs = 200 if quick else 1000
    rng = np.random.RandomState(0)
    rows = []
    for mode in ("asymmetric", "symmetric_padded"):
        mem = GlobalMemory(8, 1 << 26, allocator="buddy")
        g = DiompGroup((), name="world") if False else DiompGroup(("x",),
                                                                  name="x")
        alloc = PagedKVAllocator(mem, g, page_tokens=64,
                                 kv_bytes_per_token=256)
        live = []
        t0 = time.perf_counter()
        lookups = 0
        for i in range(n_reqs):
            plen = 512 if mode == "symmetric_padded" else \
                int(rng.randint(16, 512))
            r = alloc.admit(plen, plen + 64)
            if r is None:
                # heap full: release the oldest half
                for req in live[: len(live) // 2]:
                    alloc.release(req)
                live = live[len(live) // 2:]
                r = alloc.admit(plen, plen + 64)
                if r is None:
                    continue
            live.append(r)
            # decode a few tokens with page-table lookups on a remote rank
            remote = i % 8
            for t in range(8):
                r.pos += 1
                alloc.extend(r)
                # repeated derefs of the same remote rank hit the pointer
                # cache after the first two-step fetch (paper Fig. 2 as-1)
                alloc.lookup(r, r.pos - 1, rank=remote)
                lookups += 1
        wall = time.perf_counter() - t0
        rows.append({
            "mode": mode,
            "requests": n_reqs,
            "wall_s": round(wall, 3),
            "admits_per_s": round(n_reqs / wall),
            "pages_allocated": alloc.stats["pages_allocated"],
            "oom_events": alloc.stats["oom_events"],
            "bytes_in_use_end": alloc.bytes_in_use,
            "ptr_cache_hit_rate": round(mem.ptr_cache.hit_rate, 3),
            "lookups": lookups,
        })
        for req in list(live):
            alloc.release(req)
        mem.check_invariants()
    path = write_csv("kvcache.csv", rows)
    print(f"[bench_kvcache] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 6: collective latency — OMPCCL vs flat-MPI-shaped baselines.

Broadcast and AllReduce across 128 KB..64 MB on the (2,2,2) smoke mesh:
* DiOMP = OMPCCL with the pod-aware hierarchical backend;
* "MPI"  = flat single-phase collective over the whole group.
We report CPU wall medians, the log10(MPI/DiOMP) ratio the paper plots, and
the analytic inter-pod traffic model for the production 2x16x16 mesh (where
the hierarchy's 16x inter-pod reduction actually bites — the smoke mesh has
only fast links, so wall ratios hover near 1).
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro as diomp
from repro.core.compat import shard_map
from repro.core.groups import DiompGroup
from repro.distributed.hierarchical import inter_pod_traffic_bytes

from .common import smoke_mesh, timeit, write_csv

SIZES = [131_072, 1_048_576, 8_388_608, 67_108_864]


def run(quick: bool = False):
    mesh = smoke_mesh()
    dctx = diomp.init(mesh=mesh)
    g = DiompGroup(("pod", "data"), name="dp")
    # one communicator handle per backend: same group, same shared call
    # log, different wire algorithm — the OMPCCL vendor-dispatch claim
    comm_flat = dctx.communicator(g)
    comm_hier = dctx.communicator(g, backend="hierarchical")
    rows = []
    sizes = SIZES[:3] if quick else SIZES
    for nbytes in sizes:
        n = nbytes // 4
        x = np.random.RandomState(0).randn(8, max(n // 8, 1)).astype(np.float32)

        flat_ar = jax.jit(shard_map(
            lambda v: comm_flat.allreduce(v.reshape(-1)).reshape(v.shape),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))
        hier_ar = jax.jit(shard_map(
            lambda v: comm_hier.allreduce(v.reshape(-1)).reshape(v.shape),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))
        flat_bc = jax.jit(shard_map(
            lambda v: comm_flat.bcast(v, root=0),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))

        t_flat = timeit(flat_ar, x) * 1e6
        t_hier = timeit(hier_ar, x) * 1e6
        t_bc = timeit(flat_bc, x) * 1e6
        # production-mesh inter-pod bytes per chip: DP fast domain = the
        # 16-way "data" axis within a pod, slow domain = the 2 pods
        b_flat = inter_pod_traffic_bytes(nbytes, 16, 2, hierarchical=False)
        b_hier = inter_pod_traffic_bytes(nbytes, 16, 2, hierarchical=True)
        rows.append({
            "bytes": nbytes,
            "allreduce_flat_us_cpu": round(t_flat, 1),
            "allreduce_hier_us_cpu": round(t_hier, 1),
            "bcast_us_cpu": round(t_bc, 1),
            "log10_flat_over_hier_cpu": round(
                math.log10(max(t_flat, 1e-9) / max(t_hier, 1e-9)), 3),
            "interpod_bytes_flat_2x256": int(b_flat),
            "interpod_bytes_hier_2x256": int(b_hier),
            "interpod_reduction_x": round(b_flat / max(b_hier, 1), 1),
        })
    path = write_csv("collectives.csv", rows)
    print(f"[bench_collectives] -> {path}")
    for r in rows:
        print("  ", r)
    print("  communicator call log:", dctx.stats())
    return rows


if __name__ == "__main__":
    run()

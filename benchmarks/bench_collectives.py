"""Paper Fig. 6: collective latency — OMPCCL vs flat-MPI-shaped baselines.

Broadcast and AllReduce across 128 KB..64 MB on the (2,2,2) smoke mesh:
* DiOMP = OMPCCL with the pod-aware hierarchical backend;
* "MPI"  = flat single-phase collective over the whole group.
We report CPU wall medians, the log10(MPI/DiOMP) ratio the paper plots, and
the analytic inter-pod traffic model for the production 2x16x16 mesh (where
the hierarchy's 16x inter-pod reduction actually bites — the smoke mesh has
only fast links, so wall ratios hover near 1).

``run_grad_reduce`` (the ``grad_reduce`` bench in ``benchmarks.run``)
compares the two DP gradient-reduction schedules end to end: per-param
issue (one collective per parameter, after the whole backward) vs the
planned flat-bucket schedule of :mod:`repro.distributed.buckets` (whole
buckets, reduce-scatter overlapped with the backward).  Wall + call-log
numbers come from the reduced stablelm-3b pytree on the smoke mesh; the
``modeled_*`` columns run the ``LinkModel`` schedule models over the FULL
stablelm-3b gradient layout at several DP sizes (per-device shard bytes
scaled to each modeled mesh) and gate the shipped bucketed schedule:
strictly faster than per-param issue at the smoke-CI mesh sizes, within
a bounded 5% at the largest modeled mesh (where its extra reduce-scatter
wire volume bites) — the CI regression gate.
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro as diomp
from repro.core.compat import shard_map
from repro.core.groups import DiompGroup
from repro.distributed.hierarchical import inter_pod_traffic_bytes

from .common import smoke_mesh, timeit, write_csv

SIZES = [131_072, 1_048_576, 8_388_608, 67_108_864]

GRAD_ARCH = "stablelm-3b"
PEAK_FLOPS = 197e12          # v5e MXU peak (matches bench_matmul)
TOKENS_PER_DEVICE = 8192     # local microstep: batch 4 x seq 2048
MICROBATCHES = 4             # grad-accumulation factor the overlap models


def run(quick: bool = False):
    mesh = smoke_mesh()
    dctx = diomp.init(mesh=mesh)
    g = DiompGroup(("pod", "data"), name="dp")
    # one communicator handle per backend: same group, same shared call
    # log, different wire algorithm — the OMPCCL vendor-dispatch claim
    comm_flat = dctx.communicator(g)
    comm_hier = dctx.communicator(g, backend="hierarchical")
    rows = []
    sizes = SIZES[:3] if quick else SIZES
    for nbytes in sizes:
        n = nbytes // 4
        x = np.random.RandomState(0).randn(8, max(n // 8, 1)).astype(np.float32)

        flat_ar = jax.jit(shard_map(
            lambda v: comm_flat.allreduce(v.reshape(-1)).reshape(v.shape),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))
        hier_ar = jax.jit(shard_map(
            lambda v: comm_hier.allreduce(v.reshape(-1)).reshape(v.shape),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))
        flat_bc = jax.jit(shard_map(
            lambda v: comm_flat.bcast(v, root=0),
            mesh=mesh, in_specs=P(("pod", "data"), "model"),
            out_specs=P(None, "model")))

        t_flat = timeit(flat_ar, x) * 1e6
        t_hier = timeit(hier_ar, x) * 1e6
        t_bc = timeit(flat_bc, x) * 1e6
        # production-mesh inter-pod bytes per chip: DP fast domain = the
        # 16-way "data" axis within a pod, slow domain = the 2 pods
        b_flat = inter_pod_traffic_bytes(nbytes, 16, 2, hierarchical=False)
        b_hier = inter_pod_traffic_bytes(nbytes, 16, 2, hierarchical=True)
        rows.append({
            "bytes": nbytes,
            "allreduce_flat_us_cpu": round(t_flat, 1),
            "allreduce_hier_us_cpu": round(t_hier, 1),
            "bcast_us_cpu": round(t_bc, 1),
            "log10_flat_over_hier_cpu": round(
                math.log10(max(t_flat, 1e-9) / max(t_hier, 1e-9)), 3),
            "interpod_bytes_flat_2x256": int(b_flat),
            "interpod_bytes_hier_2x256": int(b_hier),
            "interpod_reduction_x": round(b_flat / max(b_hier, 1), 1),
        })
    path = write_csv("collectives.csv", rows)
    print(f"[bench_collectives] -> {path}")
    for r in rows:
        print("  ", r)
    print("  communicator call log:", dctx.stats())
    return rows


def _modeled_rows():
    """LinkModel schedule comparison over the FULL config's gradient
    layout, with the per-device shard sizes scaled to each modeled DP size
    (static shapes only — nothing is allocated)."""
    from repro import configs
    from repro.core.backends import (LinkModel, bucketed_reduce_time,
                                     overlapped_reduce_time,
                                     per_param_reduce_time)
    from repro.distributed import buckets as bk
    from repro.distributed.sharding import rules_for_ctx
    from repro.models import schema as sch
    from repro.models.config import ParallelCtx

    mesh = smoke_mesh()
    cfg = configs.get(GRAD_ARCH)
    ctx = ParallelCtx.from_mesh(mesh)
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    schema = sch.build_schema(cfg)
    link = LinkModel()
    # backward ~= 2x forward ~= 4 * active params * tokens FLOPs
    compute_s = 4 * cfg.active_param_count() * TOKENS_PER_DEVICE / PEAK_FLOPS

    rows = []
    # the sweep is pure static arithmetic, so quick mode models the same
    # mesh sizes — CI exercises every gate branch, including the ndev=128
    # bounded-loss tolerance
    for ndev in [8, 32, 128]:
        # the modeled deployment keeps the smoke mesh's axis roles but
        # grows the "data" axis (the ZeRO/fsdp role) until dp == ndev, so
        # per-device shard bytes match the mesh whose ring is modeled
        sizes = dict(mesh.shape)
        sizes["data"] = ndev // sizes["pod"]
        shapes = {n: bk.local_shape(spec.shape, pspecs[n], sizes)
                  for n, spec in schema.items()}
        # 1/16 MiB sits past the dispatch cliff (tens of thousands of
        # collectives) so the sweep's left edge is visibly worse
        for bucket_mib in [0.0625, 1, 4, 16, 64]:
            planner = bk.BucketPlanner(bucket_bytes=int(bucket_mib * 2**20))
            plan = planner.plan(shapes, pspecs, ctx.dp_group.axes, sizes)
            param_bytes = [
                int(np.prod(plan.shapes[n])) * 4
                for n in plan.shapes if n not in plan.local]
            bucket_bytes = [b.padded_nbytes for b in plan.buckets]
            t_pp = per_param_reduce_time(param_bytes, ndev, link,
                                         compute_s=compute_s)
            t_serial = bucketed_reduce_time(bucket_bytes, ndev, link,
                                            compute_s=compute_s)
            # the SHIPPED default schedule: overlap_grad_reduce with
            # microbatch accumulation — this is "bucketed modeled time"
            t_bk = overlapped_reduce_time(bucket_bytes, ndev, link,
                                          compute_s=compute_s,
                                          microbatches=MICROBATCHES)
            rows.append({
                "arch": cfg.name,
                "ndev": ndev,
                "bucket_mib": bucket_mib,
                "n_params": len(param_bytes),
                "n_buckets": len(plan.buckets),
                "grad_bytes": sum(param_bytes),
                "padded_bytes": sum(bucket_bytes),
                "modeled_perparam_s": round(t_pp, 4),
                "modeled_bucketed_s": round(t_bk, 4),
                "modeled_bucketed_serial_s": round(t_serial, 4),
                "modeled_speedup": round(t_pp / max(t_bk, 1e-12), 3),
            })
    return rows


def run_grad_reduce(quick: bool = False):
    """Per-param vs bucketed DP gradient reduction (wall + calls + model)."""
    from repro import configs
    from repro.core.context import DiompContext, use_default
    from repro.distributed import buckets as bk
    from repro.distributed.sharding import rules_for_ctx
    from repro.models import schema as sch
    from repro.models.config import ParallelCtx
    from repro.train.step import reduce_gradients

    mesh = smoke_mesh()
    cfg = configs.get_reduced(GRAD_ARCH)
    ctx_pp = ParallelCtx.from_mesh(mesh, bucket_bytes=0)
    ctx_bk = ParallelCtx.from_mesh(mesh)
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx_bk))
    plan = bk.plan_for_config(cfg, mesh, ctx_bk)
    schema = sch.build_schema(cfg)
    rng = np.random.RandomState(0)
    grads = {n: rng.randn(*schema[n].shape).astype(np.float32)
             for n in schema}
    gspecs = {n: pspecs[n] for n in grads}

    def timed(ctx, plan_, dctx):
        def red(g):
            with use_default(dctx):
                out, _ = reduce_gradients(g, cfg, ctx, pspecs=pspecs,
                                          plan=plan_)
            return out
        return jax.jit(shard_map(red, mesh=mesh, in_specs=(gspecs,),
                                 out_specs=gspecs))

    dctx_pp = DiompContext(mesh=mesh, segment_bytes=1 << 20)
    dctx_bk = DiompContext(mesh=mesh, segment_bytes=1 << 20)
    t_pp = timeit(timed(ctx_pp, None, dctx_pp), grads) * 1e6
    t_bk = timeit(timed(ctx_bk, plan, dctx_bk), grads) * 1e6

    def n_allreduce(dctx):
        return sum(c.get("allreduce", 0) for c in dctx.stats().values())

    calls_pp, calls_bk = n_allreduce(dctx_pp), n_allreduce(dctx_bk)
    wall_rows = [{
        "arch": cfg.name,
        "wall_perparam_us_cpu": round(t_pp, 1),
        "wall_bucketed_us_cpu": round(t_bk, 1),
        "allreduce_calls_perparam": calls_pp,
        "allreduce_calls_bucketed": calls_bk,
        "call_reduction_x": round(calls_pp / max(calls_bk, 1), 2),
        "bucketed_wire_bytes": sum(
            b.get("allreduce", 0) for b in dctx_bk.byte_stats().values()),
    }]
    # per-partition call-count bound: a (group, dtype, dup) partition with
    # T payload bytes issues exactly ceil(T / bucket_bytes) collectives
    per_part: dict = {}
    for b in plan.buckets:
        part = per_part.setdefault((b.axes, b.dtype, b.dup), [0, 0])
        part[0] += 1
        part[1] += b.nbytes
    for key, (nb, bytes_) in per_part.items():
        bound = -(-bytes_ // plan.bucket_bytes)
        assert nb <= bound, (key, nb, bound)
    assert calls_bk <= calls_pp, (calls_bk, calls_pp)

    modeled = _modeled_rows()
    # the CI gate at the default 4 MiB: the shipped bucketed schedule (the
    # k-RS+AG overlap pipeline) must beat per-param issue at the smoke-CI
    # mesh sizes; it pays (k+1)/2 x the wire volume for its pipelining, so
    # in wire-bound regimes (the largest modeled mesh) it may lose — but
    # only within a bounded few percent; and bucket padding must stay
    # negligible
    for r in modeled:
        if r["bucket_mib"] == 4:
            tol = 1.0 if r["ndev"] <= 32 else 1.05
            assert r["modeled_bucketed_s"] <= tol * r["modeled_perparam_s"], r
            assert r["padded_bytes"] <= 1.05 * r["grad_bytes"], r
    path = write_csv("grad_reduce.csv", wall_rows)
    path_m = write_csv("grad_reduce_modeled.csv", modeled)
    print(f"[bench_grad_reduce] -> {path} ; {path_m}")
    rows = wall_rows + modeled
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()
    run_grad_reduce()

"""MoE dispatch & all-to-all overlap: none / a2a / host / fused.

The dropless expert-parallel dispatch (:mod:`repro.kernels.moe_dispatch`)
swept over EP group sizes under load-imbalanced routing, against the two
baselines it replaces:

* ``none``  — no expert parallelism emulation: allgather every rank's
              tokens, compute the local experts on the full set, allreduce
              the partial outputs back;
* ``a2a``   — the capacity-factor collective (``moe_block``'s host path):
              two serialized ``ompx_alltoall``s of capacity-PADDED buffers,
              the expert GEMMs run on the padding too, overflow drops;
* ``host``  — the one-sided ring serialized (all dispatch puts, fence,
              GEMMs, all combine puts, fence): true asymmetric rows on the
              wire, overlap left to the XLA scheduler;
* ``fused`` — the ``AllToAllPlan`` overlapped schedule: the put feeding
              step s+1 and the combine put of step s-1 both ride under
              step s's GEMMs.

All virtual devices share one physical core, so wall time cannot show the
overlap win; the ``modeled_*`` columns walk each mode's schedule at
DeepSeek-V3 scale (t_loc=8192 tokens, d=7168, k=8, E=256, f=2048, bf16,
v5e: 197 TFLOP/s, 50 GB/s per ICI link direction) with per-expert loads
stretched from the sweep's measured routing skew.  The fused mode must
never model slower than ``a2a`` or ``host`` at any swept EP size —
asserted here, so the benchmark doubles as a regression gate — and the
fused run's put bytes must match the RMATracker dispatch/combine windows
exactly.  Both one-sided modes must reproduce the single-device dropless
oracle bit-for-bit with zero drops.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.backends import (LinkModel, ring_allgather_time,
                                 ring_allreduce_time)
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, default_context, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import dispatch_window_names
from repro.kernels.moe_dispatch import (measure_expert_load, moe_dispatch,
                                        moe_ref, route_topk)
from repro.kernels.plan import default_planner
from repro.models.config import ModelConfig, ParallelCtx
from repro.models.layers import moe_block

from .common import timeit, write_csv

# v5e-flavored model constants (per chip / per ICI link direction)
PEAK_FLOPS = 197e12
LINK = LinkModel()           # 50 GB/s per direction, 1 us hop latency
DISPATCH_OVERHEAD = LINK.dispatch_s        # per host-issued launch

# one DeepSeek-V3 MoE layer at serving scale, bf16 rows on the wire
P_TLOC, P_D, P_K, P_E, P_F = 8192, 7168, 8, 256, 2048
P_ITEM = 2
# the padded collective must over-provision capacity to keep drops
# tolerable (the repo's reduced configs train at cf=2.0; 1.25 is already
# generous to the baseline) — and it wires AND GEMMs the padding
CF_A2A = 1.25

GROUP = DiompGroup(("x",), name="epx")
MODES = ("none", "a2a", "host", "fused")
EPS = (2, 4, 8)
K = 2                        # experts per token in the tiny sweep


def _gemm_t(rows: float) -> float:
    """Three expert GEMMs (gate, up, down) over ``rows`` token rows."""
    return 6.0 * rows * P_D * P_F / PEAK_FLOPS


def _paper_plan(ep: int, frac, overlap: bool):
    """The AllToAllPlan for the paper-scale layer, caps from ``frac``.

    A paper-scale block cannot double-buffer whole in VMEM, so the planner
    degrades to the serialized schedule; the kernel streams each block
    through its staging slots instead (``moe_dispatch`` forces the
    schedule to the impl), so the model walks the requested one.
    """
    rows_all = P_TLOC * P_K
    loads = tuple(int(max(1, np.ceil(f * rows_all))) for f in frac)
    plan = default_planner().plan_alltoall(
        P_TLOC, P_D, P_K, P_E, ep, jnp.bfloat16, loads=loads,
        overlap=overlap)
    return dataclasses.replace(plan, overlap=overlap)


def _modeled(ep: int, mode: str, frac):
    """(per-layer seconds, wire bytes/rank, overlap) at the paper scale."""
    rows_all = P_TLOC * P_K
    if mode == "none":
        tok = P_TLOC * P_D * P_ITEM
        t = (2 * DISPATCH_OVERHEAD
             + ring_allgather_time(tok * ep, ep, LINK)
             + ring_allreduce_time(tok * ep, ep, LINK)
             + _gemm_t(rows_all))
        return t, 3 * (ep - 1) * tok, False
    if mode == "a2a":
        cap = int(np.ceil(rows_all / P_E * CF_A2A))
        buf = P_E * cap * P_D * P_ITEM       # capacity-padded send buffer
        t_x = ((ep - 1) / ep * buf / LINK.bandwidth_Bps
               + (ep - 1) * LINK.latency_s)
        # dispatch a2a, padded GEMMs, return a2a — strictly serialized
        t = 2 * (DISPATCH_OVERHEAD + t_x) + _gemm_t(P_E * cap)
        return t, int(2 * (ep - 1) / ep * buf), False

    plan = _paper_plan(ep, frac, overlap=(mode == "fused"))
    # critical path: the busiest rank's landing block, every ring step
    rows_step = max(plan.block_rows(r) for r in range(ep))
    blk = rows_step * P_D * P_ITEM           # true rows, not the pad
    t_step = _gemm_t(rows_step)
    t, link_free = DISPATCH_OVERHEAD, 0.0
    put_done, ret_done = {}, []
    for phase, s in plan.schedule():
        if phase in ("put", "ret"):          # async: occupies the link only
            start = max(t, link_free)
            link_free = start + blk / LINK.bandwidth_Bps
            if phase == "put":
                put_done[s] = link_free + LINK.latency_s
            else:
                ret_done.append(link_free + LINK.latency_s)
        elif phase == "fence":
            t = max(t, put_done[s])
        elif phase == "gemm":
            t += t_step
        else:                                # fence_ret
            t = max(t, max(ret_done, default=t))
    return t, plan.wire_bytes, plan.overlap


# ---------------------------------------------------------------------------
# the tiny real sweep
# ---------------------------------------------------------------------------

def _tiny_case(ep: int, E=16, t_loc=32, d=32, f=32, skew=1.5, seed=0):
    """Imbalanced-routing case: arrays, load-sized plan, dropless oracle."""
    rng = np.random.RandomState(seed)
    toks = rng.randn(ep * t_loc, d).astype(np.float32)
    router = (rng.randn(d, E) + skew * rng.randn(1, E)).astype(np.float32)
    wg = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wu = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wd = (rng.randn(E, f, d) / np.sqrt(f)).astype(np.float32)
    top_w, top_e = jax.jit(route_topk, static_argnums=2)(toks, router, K)
    loads = measure_expert_load(
        np.asarray(top_e).reshape(ep, t_loc, K), E, sources=ep)
    plan = default_planner().plan_alltoall(t_loc, d, K, E, ep, jnp.float32,
                                           loads=loads)
    want = np.asarray(moe_ref(toks, top_e, top_w, wg, wu, wd))
    return toks, router, (wg, wu, wd), plan, loads, want


def _dispatch_fn(mesh, impl, plan):
    def f(tk, rt, g, u, dn):
        w, e = route_topk(tk, rt, K)
        with default_context().dispatch_stats.collect() as ds:
            out = moe_dispatch(tk, e, w, g, u, dn, GROUP,
                               impl=impl, plan=plan)
        return out, ds["moe_dropped"].reshape(1)

    return jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P("x", None), P(None, None), P("x", None, None),
                  P("x", None, None), P("x", None, None)),
        out_specs=(P("x", None), P("x"))))


def _ref_fn():
    def f(tk, rt, g, u, dn):
        w, e = route_topk(tk, rt, K)
        return moe_ref(tk, e, w, g, u, dn)

    return jax.jit(f)


def _a2a_fn(ep: int, E: int, f_dim: int):
    """The real capacity collective: moe_block's a2a regime, EP = 'model'."""
    cfg = ModelConfig(name="bench-moe", family="moe", num_layers=1,
                      d_model=32, num_heads=4, d_ff=64, vocab_size=128,
                      moe=True, num_experts=E, experts_per_token=K,
                      moe_d_ff=f_dim, capacity_factor=CF_A2A,
                      dtype="float32")
    mesh = make_mesh((1, ep), ("data", "model"), axis_types="auto")
    ctx = ParallelCtx.from_mesh(mesh)
    espec = P("model", None, None)
    lspecs = {"router": P(None, None), "w_gate_e": espec, "w_up_e": espec,
              "w_down_e": espec}

    def f(xx, pp):
        return lax.pmean(moe_block(xx, pp, cfg, ctx), "model")

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), lspecs),
                             out_specs=P()))


def _fused_put_parity(mesh, plan, toks, router, weights):
    """Lower the fused dispatch under a fresh context; check the books."""
    def f(tk, rt, g, u, dn):
        w, e = route_topk(tk, rt, K)
        return moe_dispatch(tk, e, w, g, u, dn, GROUP, impl="fused",
                            plan=plan)

    dctx = DiompContext()
    with use_default(dctx):
        jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P("x", None), P(None, None), P("x", None, None),
                      P("x", None, None), P("x", None, None)),
            out_specs=P("x", None))).lower(toks, router, *weights)
    desc = GROUP.descriptor()
    puts = dctx.stats()[desc]["put"]
    put_bytes = dctx.byte_stats()[desc]["put"]
    dwin, cwin = dispatch_window_names(GROUP, plan.ep)
    win_bytes = sum(dctx.rma.window_bytes[w] for w in dwin + cwin)
    # acceptance: OMPCCL byte log == RMA window accounting, exactly
    assert puts == 2 * (plan.ep - 1), (puts, plan.ep)
    assert put_bytes == 2 * (plan.ep - 1) * plan.block_bytes
    assert put_bytes == win_bytes == dctx.rma.put_bytes
    return puts, put_bytes


def run(quick: bool = False):
    warmup, iters = (1, 2) if quick else (2, 5)
    rows = []
    frac = None
    mesh = plan = None
    for ep in EPS:
        mesh = make_mesh((ep,), ("x",), axis_types="auto")
        toks, router, weights, plan, loads, want = _tiny_case(ep)
        # stretch this sweep's measured skew to the paper's 256 experts
        rep = P_E // len(loads)
        w = np.repeat(np.asarray(loads, float), rep) / rep
        frac = w / w.sum()

        walls, outs = {}, {}
        for impl in ("host", "fused"):
            fn = _dispatch_fn(mesh, impl, plan)
            out, dropped = fn(toks, router, *weights)
            outs[impl] = np.asarray(out)
            assert float(np.asarray(dropped).sum()) == 0.0, impl
            walls[impl] = timeit(fn, toks, router, *weights,
                                 warmup=warmup, iters=iters)
        # dropless: both one-sided modes reproduce the oracle bit-for-bit
        np.testing.assert_array_equal(outs["fused"], want)
        np.testing.assert_array_equal(outs["host"], want)
        walls["none"] = timeit(_ref_fn(), toks, router, *weights,
                               warmup=warmup, iters=iters)
        a2a = _a2a_fn(ep, E=len(loads), f_dim=weights[0].shape[-1])
        x3d = toks.reshape(ep, toks.shape[0] // ep, toks.shape[1])
        lp = {"router": router, "w_gate_e": weights[0],
              "w_up_e": weights[1], "w_down_e": weights[2]}
        walls["a2a"] = timeit(a2a, x3d, lp, warmup=warmup, iters=iters)

        puts, put_bytes = _fused_put_parity(mesh, plan, toks, router,
                                            weights)
        modeled = {m: _modeled(ep, m, frac) for m in MODES}
        base = modeled["a2a"][0]
        for m in MODES:
            step_s, wire, overlap = modeled[m]
            rows.append({
                "ep": ep,
                "mode": m,
                "wall_s": round(walls[m], 4),
                "wall_note": "1-core CPU serializes devices",
                "modeled_layer_s": round(step_s, 6),
                "modeled_speedup_vs_a2a": round(base / step_s, 2),
                "wire_MB_per_rank": round(wire / 2**20, 2),
                "puts": puts if m == "fused" else "-",
                "put_bytes": put_bytes if m == "fused" else "-",
                "modeled_overlap": overlap,
            })
        # the gate: the overlapped dropless schedule never models slower
        # than the padded collective or the serialized one-sided listing
        assert modeled["fused"][0] <= modeled["a2a"][0], (ep, modeled)
        assert modeled["fused"][0] <= modeled["host"][0], (ep, modeled)

    # asymmetric PGAS landing regions for the last sweep's plan: the home
    # rank of expert e registers ep*caps[e] rows, every other rank zero
    dctx = DiompContext(mesh=mesh)
    item, asym_bytes = plan.itemsize, 0
    for e_idx, region_rows in enumerate(plan.region_rows):
        home = e_idx // plan.E_loc
        sizes = [region_rows * plan.d * item if r == home else 0
                 for r in range(plan.ep)]
        dctx.memory.alloc_asymmetric(f"moe.dispatch.e{e_idx}", sizes, GROUP,
                                     dtype="float32")
        asym_bytes += region_rows * plan.d * item
    pad_bytes = plan.E * plan.ep * plan.cap_pad * plan.d * item
    pplan = _paper_plan(EPS[-1], frac, overlap=True)
    p_asym = sum(pplan.region_rows) * P_D * P_ITEM
    p_pad = P_E * pplan.ep * pplan.cap_pad * P_D * P_ITEM
    rows.append({
        "ep": plan.ep,
        "mode": f"regions E={plan.E} asym {asym_bytes}B vs padded "
                f"{pad_bytes}B",
        "wall_s": "-",
        "wall_note": f"paper scale: {round(p_asym / 2**30, 2)} GiB vs "
                     f"{round(p_pad / 2**30, 2)} GiB padded",
        "modeled_layer_s": "-",
        "modeled_speedup_vs_a2a": "-",
        "wire_MB_per_rank": "-",
        "puts": "-", "put_bytes": "-", "modeled_overlap": "-",
    })
    assert asym_bytes <= pad_bytes

    path = write_csv("moe.csv", rows)
    print(f"[bench_moe] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

"""Shared benchmark utilities (timing, CSV, smoke mesh)."""

from __future__ import annotations

import csv
import os
import time
from typing import Callable, Dict, List

import jax

from repro.core.compat import make_mesh

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
    return path


def smoke_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    return make_mesh(shape, axes, axis_types="auto")

"""Paper §3.2 trade-off: MAX_ACTIVE_STREAMS / partial synchronization.

Throughput + responsiveness of the StreamPool under a bursty task mix for
several ``max_active`` bounds, reproducing the paper's claim that bounded
concurrency with partial sync sustains pipeline throughput while limiting
scheduler/memory pressure (unbounded pools thrash; tiny pools stall).
"""

from __future__ import annotations

import time

from repro.core.streams import StreamPool

from .common import write_csv


def _work(us: int):
    t_end = time.perf_counter() + us / 1e6
    while time.perf_counter() < t_end:
        pass
    return us


def run(quick: bool = False):
    n_tasks = 60 if quick else 200
    rows = []
    for max_active in (1, 2, 4, 8, 16):
        pool = StreamPool(max_active=max_active)
        t0 = time.perf_counter()
        futs = [pool.submit(_work, 500 if i % 7 else 5000)
                for i in range(n_tasks)]
        lat = []
        for f in futs:
            f.result()
        wall = time.perf_counter() - t0
        rows.append({
            "max_active": max_active,
            "tasks": n_tasks,
            "wall_s": round(wall, 3),
            "throughput_tasks_s": round(n_tasks / wall, 1),
            "created": pool.stats["created"],
            "reused": pool.stats["reused"],
            "partial_syncs": pool.stats["partial_syncs"],
        })
        pool.close()
    path = write_csv("streams.csv", rows)
    print(f"[bench_streams] -> {path}")
    for r in rows:
        print("  ", r)
    return rows


if __name__ == "__main__":
    run()

"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` of the SPMD-partitioned executable is per-chip;
collective bytes are parsed from the post-partitioning HLO text (operand
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, including their -start async forms).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

__all__ = ["HW", "collective_bytes_from_hlo", "roofline", "RooflineReport"]

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link direction

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# e.g. "bf16[16,512,448]" possibly with layout "{2,1,0}"
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

_OP_LINE_RE = re.compile(
    r"^\s*\S+\s*=\s*(?P<outs>.*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<phase>-start|-done)?\((?P<args>.*)$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum of *output* shape bytes per collective kind (per-chip program).

    ``-done`` ops are skipped (their ``-start`` counterpart was counted).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.match(line)
        if not m or m.group("phase") == "-done":
            continue
        op = m.group("op").lower()
        nbytes = _shape_bytes(m.group("outs"))
        out[op] = out.get(op, 0) + nbytes
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float              # MXU operand/result traffic (dot_bytes)
    coll_bytes_per_chip: Dict[str, int]
    model_flops: float                 # 6·N·D (active params for MoE)
    bytes_upper_per_chip: float = 0.0  # full instruction-level traffic proxy

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes_per_chip.values()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs · chips) — remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline the dominant term allows for useful work:
        (model_flops/chips/peak) / bound_time."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.bound_time if self.bound_time else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.flops_per_chip,
            "hlo_bytes_per_chip": self.bytes_per_chip,
            "hlo_bytes_upper_per_chip": self.bytes_upper_per_chip,
            "coll_bytes_per_chip": dict(self.coll_bytes_per_chip),
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             cost: dict, hlo_text: str, model_flops: float) -> RooflineReport:
    """Build the report from the loop-aware HLO analysis (hlo_analysis.py).

    ``cost`` (compiled.cost_analysis()) is kept for cross-checking but NOT
    used for the terms — XLA's analysis visits while bodies once, which
    under-counts layer scans / grad accumulation by orders of magnitude.
    """
    from .hlo_analysis import analyze_hlo

    hc = analyze_hlo(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=hc.flops,
        bytes_per_chip=hc.dot_bytes,
        coll_bytes_per_chip={k: int(v) for k, v in
                             hc.collective_bytes.items()},
        model_flops=model_flops,
        bytes_upper_per_chip=hc.bytes,
    )

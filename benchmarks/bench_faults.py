"""Chaos overhead + degraded-mode economics (docs/RESILIENCE.md).

Three measurements, written to ``faults.csv`` / ``BENCH_summary.json``:

* **retry overhead model** — expected extra wire time per verb under a
  fault rate ``p``: a transient fault re-issues the op, so the expected
  retries per logical op are the geometric ``p / (1 - p)`` and the chaos
  time is ``t_op + E[r] * (t_op + backoff)``.  The ``modeled_*_s`` columns
  are the CI-gated perf trajectory (tolerance 5%).
* **recovery wall smoke** — an actual seeded ``FaultPlan`` driven through
  ``call_with_retries``: deterministic injected/recovered counts (identity
  columns, so a changed seed or injection order fails the gate loudly) and
  the measured wall cost of the backoff schedule.
* **rank-death degradation model** — serving capacity and drain cost when
  one of ``nranks`` page heaps disappears: graceful drain moves the dead
  rank's pages at the modeled one-sided put bandwidth; abrupt death
  regenerates the lost requests' KV from scratch at prefill cost.
"""

from __future__ import annotations

import time

from repro.core.faults import FaultPlan
from repro.core.resilience import RetryPolicy, call_with_retries

from .common import write_csv

# the modeled wire (matches the LinkModel smoke constants: a PCIe-ish
# 12.5 GB/s one-sided lane with a 2 us verb issue cost)
BW = 12.5e9
LAT = 2e-6


def _op_s(nbytes: int) -> float:
    return LAT + nbytes / BW


def _mean_backoff_s(policy: RetryPolicy, verb: str, n: int = 64) -> float:
    return sum(policy.backoff_s(verb, k % 8 + 1) for k in range(n)) / n


def _retry_rows() -> list:
    # wire-tuned backoff: the default 5 ms cap is for host-visible stalls;
    # per-verb retries back off at the scale of the op itself
    policy = RetryPolicy(base_backoff_s=1e-5, max_backoff_s=1e-4)
    rows = []
    for verb, nbytes in (("put", 1 << 20), ("allreduce", 4 << 20),
                         ("halo_exchange", 256 << 10)):
        for p in (0.01, 0.05, 0.10):
            clean = _op_s(nbytes)
            retries = p / (1.0 - p)
            chaos = clean + retries * (clean + _mean_backoff_s(policy, verb))
            rows.append({
                "bench": "retry_overhead",
                "verb": verb,
                "nbytes": nbytes,
                "fault_p": p,
                "retries_per_op": round(retries, 6),
                "overhead_pct": round(100.0 * (chaos / clean - 1.0), 2),
                "modeled_clean_s": clean,
                "modeled_chaos_s": chaos,
            })
    return rows


def _recovery_row(ops: int) -> dict:
    plan = FaultPlan(7, p=0.05, kinds=("drop", "fail", "timeout"))
    policy = RetryPolicy(max_retries=8, base_backoff_s=1e-5,
                         max_backoff_s=1e-4)

    def one(verb):
        fault = plan.next_fault(verb)
        if fault is not None:
            from repro.core.resilience import TransientFault
            raise TransientFault(f"injected {fault.kind}", fault=fault)
        return True

    t0 = time.perf_counter()
    for i in range(ops):
        verb = ("put", "allreduce")[i % 2]
        call_with_retries(lambda v=verb: one(v), verb, policy)
    wall = time.perf_counter() - t0
    counts = plan.injected_counts()
    return {
        "bench": "recovery_smoke",
        "seed": 7,
        "fault_p": 0.05,
        "ops": ops,
        "injected": len(plan.injected),
        "recovered": len(plan.injected) - len(plan.unrecovered()),
        "kinds": "/".join(f"{k}:{counts[k]}" for k in sorted(counts)),
        "wall_s": round(wall, 4),
    }


def _rank_death_rows() -> list:
    rows = []
    page_bytes = 64 * 256                     # page_tokens * kv_bytes/token
    for nranks in (4, 8):
        pages_per_rank = 256
        reqs_per_rank = 16
        drain_bytes = pages_per_rank * page_bytes
        # serving throughput ~ live KV capacity (slots are page-bound)
        tput = 1000.0
        for mode in ("graceful", "abrupt"):
            if mode == "graceful":
                # one-sided drain of every page homed on the dead rank
                stall = drain_bytes / BW + pages_per_rank * LAT
            else:
                # lost requests re-prefill: model 512 tokens at 1 ms/chunk
                # of 16 tokens per request
                stall = reqs_per_rank * (512 / 16) * 1e-3
            rows.append({
                "bench": "rank_death",
                "nranks": nranks,
                "mode": mode,
                "pages_lost": pages_per_rank if mode == "abrupt" else 0,
                "drain_bytes": drain_bytes if mode == "graceful" else 0,
                "tput_before_rps": tput,
                "tput_after_rps": round(tput * (nranks - 1) / nranks, 1),
                "modeled_stall_s": stall,
            })
    return rows


def run(quick: bool = False) -> list:
    retry = _retry_rows()
    recovery = [_recovery_row(ops=200 if quick else 2000)]
    deaths = _rank_death_rows()
    write_csv("faults_retry.csv", retry)
    write_csv("faults_recovery.csv", recovery)
    write_csv("faults_rank_death.csv", deaths)
    rows = retry + recovery + deaths
    for r in rows:
        if r["bench"] == "recovery_smoke":
            print(f"  recovery: {r['injected']} injected "
                  f"({r['kinds']}), {r['recovered']} recovered "
                  f"over {r['ops']} ops, wall {r['wall_s']}s")
    worst = max((r for r in rows if r["bench"] == "retry_overhead"),
                key=lambda r: r["overhead_pct"])
    print(f"  retry overhead at p={worst['fault_p']}: "
          f"{worst['overhead_pct']}% over clean")
    return rows

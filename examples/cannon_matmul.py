"""Paper §4.4: Cannon's-algorithm matrix multiplication with overlap.

C = A x B on a ring of devices: A row-stripes stay put, B stripes rotate
via ompx_put while the current block GEMM runs — communication is masked by
computation (the paper's 'additional block stripe' trick).

Run:  PYTHONPATH=src python examples/cannon_matmul.py [N]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compat import axis_size, make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.core.rma import ompx_put
from repro.kernels.ring_matmul.ops import matmul


def cannon(a_stripe, b_stripe, g):
    """Each rank holds A[rank] (rows) and B[rank] (row-stripe of B).

    P steps: compute partial product with the currently-held B stripe while
    putting it onward around the ring (paper Listing-1 style: put + fence
    folded into the compiled dataflow).
    """
    n = axis_size(g.axes[0])
    idx = jax.lax.axis_index(g.axes[0])
    ns = b_stripe.shape[0]
    acc = jnp.zeros((a_stripe.shape[0], b_stripe.shape[1]), jnp.float32)
    acc = acc + 0 * a_stripe[0, 0]  # inherit vma
    stripe = b_stripe
    for s in range(n):
        src = (idx - s) % n                      # whose B stripe I hold
        a_block = jax.lax.dynamic_slice_in_dim(a_stripe, src * ns, ns, axis=1)
        acc = acc + matmul(a_block, stripe).astype(jnp.float32)
        if s != n - 1:
            stripe = ompx_put(stripe, g, shift=1)   # overlaps the next GEMM
    return acc.astype(a_stripe.dtype)


def main():
    N = int(sys.argv[1]) if len(sys.argv) > 1 else 768
    ndev = 8
    mesh = make_mesh((ndev,), ("ring",), axis_types="auto")
    g = DiompGroup(("ring",), name="ring")
    rng = np.random.RandomState(0)
    A = rng.randn(N, N).astype(np.float32)
    B = rng.randn(N, N).astype(np.float32)

    f = jax.jit(shard_map(lambda a, b: cannon(a, b, g), mesh=mesh,
                          in_specs=(P("ring", None), P("ring", None)),
                          out_specs=P("ring", None)))
    t0 = time.perf_counter()
    C = np.asarray(jax.block_until_ready(f(A, B)))
    dt = time.perf_counter() - t0
    err = np.abs(C - A @ B).max() / np.abs(A @ B).max()
    print(f"Cannon {N}x{N} on {ndev} devices: {dt*1e3:.1f} ms "
          f"(incl. compile), rel err {err:.2e}")
    assert err < 1e-4
    print("cannon_matmul OK")


if __name__ == "__main__":
    main()

"""Continuous-batching serving example (see repro.launch.serve; the
engine lifecycle, chunked prefill, and every knob are documented in
docs/SERVING.md).

  PYTHONPATH=src python examples/serve_lm.py --arch qwen1-5-110b \\
      --prefill-chunk 16 --temperature 0.7 --top-k 8

Overload controls (docs/SERVING.md "Overload & SLOs") — any of these
arms deadline-aware admission, bounded-queue backpressure, load
shedding, and staged degraded modes:

  PYTHONPATH=src python examples/serve_lm.py --requests 8 \\
      --ttft-deadline-s 5.0 --total-deadline-s 30.0 \\
      --rate-per-s 50 --max-queue 32 --queue-high 8 --queue-low 2
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])

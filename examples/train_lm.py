"""End-to-end driver: train a small LM with the full DiOMP substrate.

This is a thin veneer over the production driver (repro.launch.train): same
step builder, same PGAS registration, same checkpoint/straggler machinery —
scaled to CPU.  ``--arch``/``--steps`` select any assigned architecture's
reduced config; e.g. a few hundred steps of a ~20M-param GLM4 on 8 virtual
devices:

  PYTHONPATH=src python examples/train_lm.py --arch glm4-9b --steps 200
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--reduced" not in argv:
        argv.append("--reduced")
    if not any(a.startswith("--checkpoint-dir") for a in argv):
        argv += ["--checkpoint-dir", "/tmp/diomp_ckpt"]
    main(argv)

"""Quickstart: the DiOMP-JAX runtime in one tour.

Run:  PYTHONPATH=src python examples/quickstart.py

Covers the paper's §3 machinery end to end on an 8-virtual-device CPU mesh:
unified runtime (Fig. 1b), symmetric/asymmetric PGAS allocation (Fig. 2),
one-sided put/get + fence, DiOMP groups, and OMPCCL collectives.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compat import shard_map
from repro.core.groups import DiompGroup, merge
from repro.core.runtime import DiompRuntime
from repro.launch.mesh import make_smoke_mesh


def main():
    mesh = make_smoke_mesh(8)
    rt = DiompRuntime(mesh, segment_bytes=1 << 24)
    ctx = rt.ctx           # the DiompContext: groups + memory + streams +
    #                        the OMPCCL communicator table, one object
    print("== unified runtime (paper Fig. 1b) ==")
    print(rt.report())

    # -- PGAS allocations: symmetric (offset-translated) + asymmetric
    #    (second-level pointer) — paper Fig. 2
    rt.register("weights/w1", (1024, 512), "bfloat16", ("embed_fsdp", "mlp"))
    rt.register("kv_pages", (8, 4096), "bfloat16", (None, None),
                symmetric=False, sizes=[4096 * (i + 1) for i in range(8)])
    w1 = rt.lookup("weights/w1")
    print(f"\nsymmetric region 'w1': remote addr on rank 5 = "
          f"{w1.region.remote_address(5)} (same offset on every rank)")
    kv = rt.lookup("kv_pages")
    print(f"asymmetric region 'kv': dereferenced via 2nd-level ptr -> "
          f"{rt.memory.translate(kv.region, 5)}  "
          f"(cache hit rate {rt.memory.ptr_cache.hit_rate:.0%})")
    rt.memory.translate(kv.region, 5)
    print(f"  after a second lookup: hit rate "
          f"{rt.memory.ptr_cache.hit_rate:.0%}")

    # -- groups: split / merge (paper §3.3)
    world = rt.group("world")
    tp, rest = world.split("model")
    back = merge(rest, tp, name="recomposed")
    print(f"\ngroups: world={world.axes} -> split: tp={tp.axes} "
          f"rest={rest.axes} -> merge: {back.axes}")

    # -- one-sided RMA + OMPCCL collectives through ONE communicator handle:
    #    every op records against the context table and dispatches through
    #    the handle's backend (here the flat XLA vendor path)
    g = DiompGroup(("model",), name="tp")
    comm = ctx.communicator(g)
    x = np.arange(16, dtype=np.float32).reshape(8, 2)

    def demo(v):
        put = comm.put(v, shift=1)                 # one-sided put
        put = comm.fence(put)                      # completion fence
        red = comm.allreduce(v)                    # ompx_allreduce
        bc = comm.bcast(v, root=0)                 # ompx_bcast
        return put, red, bc

    f = jax.jit(shard_map(
        demo, mesh=mesh,
        in_specs=P(("pod", "data"), "model"),
        out_specs=(P(("pod", "data"), "model"),) * 3))
    put, red, bc = f(x)
    print("\nompx_put(shift=1):\n", np.asarray(put))
    print("ompx_allreduce(tp):\n", np.asarray(red))
    print("ompx_bcast(root=0):\n", np.asarray(bc))

    # backend choice is per-handle, and new backends plug in by name — the
    # analytic one logs a link-model cost estimate per traced collective
    acomm = ctx.communicator(g, backend="analytic")
    jax.jit(shard_map(lambda v: acomm.allreduce(v), mesh=mesh,
                      in_specs=P(("pod", "data"), "model"),
                      out_specs=P(("pod", "data"), "model")))(x)
    print("\nanalytic backend estimates:", acomm.backend.estimates)
    print("communicator call log:", ctx.stats())
    rt.fence()
    rt.close()
    print("\nquickstart OK")


if __name__ == "__main__":
    main()

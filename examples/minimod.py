"""Paper §4.5: Minimod — acoustic wave propagation with one-sided halos.

The 25-point (8th-order) acoustic-isotropic kernel, Z-sharded across the
device ring.  Each step: halo exchange via DiOMP one-sided puts + fence
(paper Listing 1 — compare benchmarks/bench_minimod.py for the two-sided
MPI-shaped version at ~4x the lines), then the stencil update (the Pallas
TPU kernel's jnp oracle on CPU; pass --pallas to run the kernel in
interpret mode).

Run:  PYTHONPATH=src python examples/minimod.py [--grid 64] [--steps 10]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compat import make_mesh, shard_map
from repro.core.groups import DiompGroup
from repro.core.rma import halo_exchange
from repro.kernels.stencil.ref import RADIUS, wave_step_ref
from repro.kernels.stencil.ops import wave_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas kernel in interpret mode (slow)")
    args = ap.parse_args()

    ndev = 8
    mesh = make_mesh((ndev,), ("z",), axis_types="auto")
    g = DiompGroup(("z",), name="z")
    G = args.grid
    u0 = np.zeros((G, G, G), np.float32)
    u0[G // 2, G // 2, G // 2] = 1.0          # point source
    up0 = np.zeros_like(u0)
    c2dt2 = 0.1

    def step(u, u_prev):
        # === the paper's Listing 1, DiOMP style: puts + one fence ===
        left, right = halo_exchange(u, g, halo=RADIUS, axis=0)
        upad = jnp.concatenate([left, u, right], axis=0)
        prev = jnp.pad(u_prev, ((RADIUS, RADIUS), (0, 0), (0, 0)))
        if args.pallas:
            nxt = wave_step(upad, prev, c2dt2, impl="pallas", interpret=True)
        else:
            nxt = wave_step_ref(upad, prev, c2dt2)
        return nxt[RADIUS:-RADIUS], u

    def run(u, u_prev):
        def body(carry, _):
            u, u_prev = carry
            return step(u, u_prev), None
        (u, u_prev), _ = jax.lax.scan(body, (u, u_prev), None,
                                      length=args.steps)
        return u

    f = jax.jit(shard_map(run, mesh=mesh, in_specs=(P("z"), P("z")),
                          out_specs=P("z")))
    t0 = time.perf_counter()
    u = np.asarray(jax.block_until_ready(f(u0, up0)))
    dt = time.perf_counter() - t0
    print(f"minimod: grid {G}^3, {args.steps} steps on {ndev} devices "
          f"-> {dt*1e3:.0f} ms (incl. compile)")
    print(f"  wavefield energy {np.square(u).sum():.4e}, "
          f"max |u| {np.abs(u).max():.3e} (finite: "
          f"{np.isfinite(u).all()})")
    assert np.isfinite(u).all() and np.abs(u).max() > 0
    print("minimod OK")


if __name__ == "__main__":
    main()

"""Paper §4.5: Minimod — acoustic wave propagation with one-sided halos.

Thin CLI over the real application driver (:mod:`repro.apps.minimod`):
25-point acoustic stencil, 2-D (Z×Y) domain decomposition with optionally
asymmetric Z extents over heterogeneous ranks (PGAS asymmetric regions),
and three halo modes — ``none`` (two-sided, paper Listing 2), ``host``
(one-sided puts + fence, paper Listing 1) and ``fused`` (in-kernel
one-sided exchange overlapped with the interior stencil; see
docs/PERF.md, "Minimod & halo overlap").

Run:  PYTHONPATH=src python examples/minimod.py [--shape minimod_hetero]
      [--mode fused] [--grid 64] [--steps 10] [--nz 8] [--ny 1]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.minimod import MODES, run_minimod
from repro.launch.shapes import STENCIL_SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", choices=sorted(STENCIL_SHAPES), default=None,
                    help="a predefined Minimod cell (overrides grid/nz/ny)")
    ap.add_argument("--mode", choices=MODES, default="fused")
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--nz", type=int, default=8)
    ap.add_argument("--ny", type=int, default=1)
    ap.add_argument("--weights", type=str, default=None,
                    help="comma-separated per-rank Z proportions, e.g. 3,2,2,1")
    args = ap.parse_args()

    weights = tuple(float(w) for w in args.weights.split(",")) \
        if args.weights else None
    r = run_minimod(grid=(args.grid,) * 3, steps=args.steps, nz=args.nz,
                    ny=args.ny, weights=weights, mode=args.mode,
                    shape=args.shape)
    G = "x".join(str(g) for g in r.grid)
    print(f"minimod[{r.mode}]: grid {G}, {r.steps} steps on "
          f"{r.nz}x{r.ny} ranks -> {r.wall_s * 1e3:.0f} ms (incl. compile)")
    print(f"  decomposition: z_extents={r.z_extents} "
          f"(PGAS region bytes/rank: {r.region_sizes})")
    print(f"  halo plan: overlap={r.plan.overlap} slots={r.plan.slots} "
          f"bz={r.plan.bz} puts/step={r.plan.puts_per_step}")
    print(f"  wire audit: {r.puts} put call sites, {r.put_bytes} B on the "
          f"OMPCCL log; tracker windows {r.tracker_put_bytes} B, "
          f"{r.fences} fences")
    print(f"  wavefield energy {r.energy:.4e}, max |u| "
          f"{np.abs(r.field).max():.3e} "
          f"(finite: {np.isfinite(r.field).all()})")
    assert np.isfinite(r.field).all() and np.abs(r.field).max() > 0
    if r.mode == "fused":
        assert r.put_bytes == r.tracker_put_bytes, "put-traffic parity broken"
    print("minimod OK")


if __name__ == "__main__":
    main()

"""Fused ring collective matmul + OverlapPlanner.

The fused path must (a) match the all-gather reference everywhere the
emulation runs — non-divisible shapes, bf16, group size 1, both ring
directions — (b) finish the bidirectional ring in ``ceil((n - 1) / 2)``
exchange steps, and (c) actually consume ``StreamPool.plan_slots`` through
the planner (the §3.2 contract the seed only documented).
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.streams import StreamPool
from repro.kernels.plan import (OverlapPlanner, RingPlan, default_planner,
                                resolve_interpret, resolve_ring_impl)
from repro.kernels.ring_matmul.fused import fused_ring_allgather_matmul
from repro.kernels.ring_matmul.ops import matmul, ring_allgather_matmul
from repro.kernels.ring_matmul.ref import ring_allgather_matmul_ref

RNG = np.random.RandomState(0)
GROUP = DiompGroup(("x",), name="ring")


def _run(T, K, N, ndev, dtype=np.float32, **kwargs):
    """Fused matmul + reference on an ndev ring; returns (got, want, full)."""
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    A = RNG.randn(T, K).astype(dtype)
    B = RNG.randn(K, N).astype(dtype)
    f = jax.jit(shard_map(
        lambda a, b: ring_allgather_matmul(a, b, GROUP, **kwargs),
        mesh=mesh, in_specs=(P("x", None), P(None, "x")),
        out_specs=P(None, "x")))
    r = jax.jit(shard_map(
        lambda a, b: ring_allgather_matmul_ref(a, b, GROUP),
        mesh=mesh, in_specs=(P("x", None), P(None, "x")),
        out_specs=P(None, "x")))
    return np.asarray(f(A, B)), np.asarray(r(A, B)), (A, B)


# ---------------------------------------------------------------------------
# schedule / plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", list(range(1, 10)))
def test_bidirectional_step_count(n):
    plan = RingPlan(n=n, direction="bidi", slots=2)
    assert plan.exchange_steps == math.ceil((n - 1) / 2)
    # exchange steps counted off the schedule itself, not the formula
    moving = [st for st in plan.schedule() if st.send_cw or st.send_ccw]
    assert len(moving) == plan.exchange_steps


@pytest.mark.parametrize("n", list(range(1, 10)))
@pytest.mark.parametrize("direction", ["bidi", "cw", "ccw"])
def test_schedule_covers_every_stripe_once(n, direction):
    plan = RingPlan(n=n, direction=direction, slots=3)
    if direction != "bidi":
        assert plan.exchange_steps == n - 1
    for rank in range(n):
        srcs = plan.sources(rank)
        assert sorted(srcs) == list(range(n)), (rank, srcs)


def test_schedule_sends_before_they_are_needed():
    """A stripe computed at step s must have been forwarded at step s-1."""
    for n in range(2, 9):
        plan = RingPlan(n=n, direction="bidi", slots=2)
        sched = plan.schedule()
        for prev, cur in zip(sched, sched[1:]):
            if cur.compute_cw:
                assert prev.send_cw
            if cur.compute_ccw:
                assert prev.send_ccw


def test_planner_consumes_plan_slots():
    """The plan's slot count comes from StreamPool.plan_slots (spied)."""
    calls = []

    class SpyPool(StreamPool):
        def plan_slots(self, working_set_bytes, vmem_budget=64 * 2**20):
            calls.append((working_set_bytes, vmem_budget))
            return super().plan_slots(working_set_bytes, vmem_budget)

    planner = OverlapPlanner(pool=SpyPool(max_active=4))
    plan = planner.plan_ring_matmul(8, 32, 16, jnp.float32, 8)
    assert calls, "plan_slots was never queried"
    assert 2 <= plan.slots <= 8
    assert plan.stripe_bytes == 8 * 32 * 4
    # a tighter pool bound means fewer slots
    small = OverlapPlanner(pool=StreamPool(max_active=2))
    assert small.plan_ring_matmul(8, 32, 16, jnp.float32, 8).slots == 2


def test_planner_respects_vmem_budget():
    planner = OverlapPlanner(pool=StreamPool(max_active=8),
                             vmem_budget=2 * 2**20)
    # a huge stripe: slots clamp to double buffering, never overflow count
    plan = planner.plan_ring_matmul(1024, 4096, 256, jnp.float32, 4)
    assert plan.slots == 2
    # tiles shrink under a tiny budget
    bm, bk, bn = planner.plan_matmul_tiles(4096, 4096, 4096, jnp.float32)
    assert (bm * bk + bk * bn) * 4 + bm * bn * 4 < 8 * 2**20


def test_planner_attention_and_stencil_plans():
    planner = default_planner()
    # decode shape: block must track the KV extent, not Tq=1
    assert planner.plan_attention_block(1, 48, 64, 64, jnp.float32) == 48
    assert planner.plan_attention_block(512, 8192, 128, 128,
                                        jnp.bfloat16) >= 128
    assert 1 <= planner.plan_stencil_bz(24, 20, 28, jnp.float32) <= 8


def test_resolvers():
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # on the CPU CI backend, None must resolve to interpret mode
    assert resolve_interpret(None) is (jax.default_backend() != "tpu")
    assert resolve_ring_impl(None) == resolve_ring_impl("auto") == "fused"
    assert resolve_ring_impl("host") == "host"
    with pytest.raises(ValueError):
        resolve_ring_impl("warp")


def test_plan_rejects_bad_direction_and_mismatched_ring():
    with pytest.raises(ValueError):
        RingPlan(n=4, direction="diagonal")
    mesh = make_mesh((4,), ("x",), axis_types="auto")
    A = RNG.randn(8, 16).astype(np.float32)
    B = RNG.randn(16, 8).astype(np.float32)
    bad = RingPlan(n=2, direction="bidi", slots=2)
    f = jax.jit(shard_map(
        lambda a, b: fused_ring_allgather_matmul(a, b, GROUP, plan=bad),
        mesh=mesh, in_specs=(P("x", None), P(None, "x")),
        out_specs=P(None, "x")))
    with pytest.raises(ValueError):
        f(A, B)


# ---------------------------------------------------------------------------
# numerical equivalence (interpret emulation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,K,N,ndev", [
    (64, 64, 64, 8),        # divisible everything
    (24, 33, 40, 8),        # odd t_loc, ragged K, odd N/n
    (8, 17, 8, 4),          # tiny stripes
    (30, 64, 36, 2),        # n = 2: one exchange step
    (16, 32, 16, 1),        # group size 1: no exchange at all
])
def test_fused_matches_reference(T, K, N, ndev):
    got, want, (A, B) = _run(T, K, N, ndev, impl="fused")
    scale = np.abs(A @ B).max()
    assert np.abs(got - want).max() / scale < 1e-4
    assert np.abs(got - A @ B).max() / scale < 1e-4


def test_fused_bf16():
    got, want, (A, B) = _run(24, 48, 32, 8, dtype=jnp.bfloat16, impl="fused")
    ref64 = A.astype(np.float64) @ B.astype(np.float64)
    scale = np.abs(ref64).max()
    assert np.abs(got.astype(np.float64) - want.astype(np.float64)
                  ).max() / scale < 2e-2
    assert np.abs(got.astype(np.float64) - ref64).max() / scale < 2e-2


@pytest.mark.parametrize("direction", ["cw", "ccw"])
def test_unidirectional_rings_both_ways(direction):
    mesh = make_mesh((8,), ("x",), axis_types="auto")
    A = RNG.randn(24, 33).astype(np.float32)
    B = RNG.randn(33, 40).astype(np.float32)
    plan = RingPlan(n=8, direction=direction, slots=2)
    assert plan.exchange_steps == 7
    f = jax.jit(shard_map(
        lambda a, b: fused_ring_allgather_matmul(a, b, GROUP, plan=plan),
        mesh=mesh, in_specs=(P("x", None), P(None, "x")),
        out_specs=P(None, "x")))
    got = np.asarray(f(A, B))
    scale = np.abs(A @ B).max()
    assert np.abs(got - A @ B).max() / scale < 1e-4


def test_host_impl_still_matches():
    got, want, (A, B) = _run(24, 33, 40, 8, impl="host")
    scale = np.abs(A @ B).max()
    assert np.abs(got - want).max() / scale < 1e-4


def test_overlap_false_is_reference():
    got, want, _ = _run(16, 16, 16, 4, overlap=False)
    np.testing.assert_array_equal(got, want)


def test_fused_total_put_traffic_matches_host_ring():
    """Bidirectionality halves the steps, not the bytes: the emulation must
    issue exactly n-1 stripe puts overall (counted off the OMPCCL call log
    at trace time), same as the host ring."""
    mesh = make_mesh((8,), ("x",), axis_types="auto")
    A = RNG.randn(16, 16).astype(np.float32)
    B = RNG.randn(16, 16).astype(np.float32)
    counts = {}
    for impl in ("host", "fused"):
        ctx = DiompContext()
        with use_default(ctx):
            jax.jit(shard_map(
                lambda a, b: ring_allgather_matmul(a, b, GROUP, impl=impl),
                mesh=mesh, in_specs=(P("x", None), P(None, "x")),
                out_specs=P(None, "x"))).lower(A, B)
        counts[impl] = ctx.stats()[GROUP.descriptor()]["put"]
    assert counts == {"host": 7, "fused": 7}


def test_fused_gradients_flow():
    """The emulation is differentiable (it is the TP layers' train path)."""
    mesh = make_mesh((4,), ("x",), axis_types="auto")
    A = RNG.randn(8, 12).astype(np.float32)
    B = RNG.randn(12, 8).astype(np.float32)

    def loss(a, b):
        y = ring_allgather_matmul(a, b, GROUP, impl="fused")
        return (y * y).sum()

    g = jax.jit(shard_map(
        lambda a, b: jax.grad(loss, argnums=(0, 1))(a, b),
        mesh=mesh, in_specs=(P("x", None), P(None, "x")),
        out_specs=(P("x", None), P(None, "x"))))
    ga, gb = g(A, B)
    want_a, want_b = jax.grad(lambda ab: ((ab[0] @ ab[1]) ** 2).sum())((A, B))
    np.testing.assert_allclose(np.asarray(ga), np.asarray(want_a),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(want_b),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# satellite: interpret default resolves from the backend
# ---------------------------------------------------------------------------

def test_matmul_pallas_defaults_resolve():
    """impl='pallas' with no tiles/interpret given: planner tiles + backend-
    resolved interpret mode still match the oracle."""
    x = RNG.randn(100, 130).astype(np.float32)
    w = RNG.randn(130, 70).astype(np.float32)
    got = matmul(x, w, impl="pallas")
    want = x.astype(np.float64) @ w.astype(np.float64)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=1e-4, atol=1e-4 * np.abs(want).max())

"""Fused halo-overlapped Minimod: kernel, planner, app driver.

Tier-1 subset: the fused step must equal the host-loop path AND the
single-device oracle across non-divisible grids, 1-rank groups, bf16, 2-D
decomposition and asymmetric extents; its put traffic must match the
RMATracker halo windows exactly; gradients must flow through it; and the
planner must fall back (never emit an invalid slab plan) on degenerate
grids.  The exhaustive mode×rank sweep is marked ``slow`` (RUN_SLOW=1).
"""

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.apps.minimod import (MODES, pad_shards, run_minimod,
                                split_extents, unpad_shards)
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import RMAError
from repro.core.streams import StreamPool
from repro.kernels.plan import HaloPlan, OverlapPlanner, default_planner
from repro.kernels.stencil import ops as stencil_ops
from repro.kernels.stencil.fused import (Halos, exchange_halos,
                                         fused_wave_step)
from repro.kernels.stencil.ref import RADIUS, wave_step_ref

RNG = np.random.RandomState(0)
ZG = DiompGroup(("z",), name="z")
YG = DiompGroup(("y",), name="y")

slow_sweep = pytest.mark.skipif(
    not os.environ.get("RUN_SLOW"),
    reason="slow sweep; tier-1 runs the equivalence subset (set RUN_SLOW=1)")


def _reference(u, up, c2, steps, dx=1.0):
    for _ in range(steps):
        u, up = np.asarray(wave_step_ref(
            jnp.asarray(u), jnp.asarray(up), c2, dx=dx)), u
    return u


def _run_step(Z, Y, X, nz, ny=1, z_extents=None, dtype=np.float32,
              c2=0.1, ctx=None):
    """One fused step under shard_map; returns (got, want) logical grids."""
    mesh = make_mesh((nz, ny), ("z", "y"), axis_types="auto")
    ext = z_extents or (Z // nz,) * nz
    u = (RNG.randn(Z, Y, X) * 0.1).astype(dtype)
    up = (RNG.randn(Z, Y, X) * 0.1).astype(dtype)
    u_in, up_in = pad_shards(u, ext), pad_shards(up, ext)

    def step(a, b):
        return fused_wave_step(a, b, c2, ZG, YG if ny > 1 else None,
                               z_extents=z_extents)

    f = jax.jit(shard_map(step, mesh=mesh,
                          in_specs=(P("z", "y"), P("z", "y")),
                          out_specs=P("z", "y")))
    with use_default(ctx or DiompContext(mesh=mesh)):
        got = unpad_shards(np.asarray(f(u_in, up_in)), ext)
    want = _reference(u, up, c2, 1)
    return got, want


# ---------------------------------------------------------------------------
# fused == host-loop == single-device reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Z,Y,X,nz,ny,ext", [
    (64, 12, 10, 4, 1, None),            # symmetric 1-D, overlapped
    (32, 12, 10, 4, 1, None),            # no interior: planner fallback
    (16, 8, 8, 1, 1, None),              # 1-rank group: no exchange at all
    (64, 32, 8, 2, 2, None),             # 2-D (Z×Y) decomposition
    (22, 10, 8, 4, 1, (6, 6, 5, 5)),     # non-divisible -> asymmetric
    (44, 10, 8, 4, 1, (14, 10, 10, 10)), # heterogeneous extents
])
def test_fused_step_matches_reference(Z, Y, X, nz, ny, ext):
    got, want = _run_step(Z, Y, X, nz, ny, z_extents=ext)
    np.testing.assert_allclose(got, want, atol=3e-6)


def test_fused_step_bf16():
    got, want = _run_step(64, 12, 8, 4, dtype=jnp.bfloat16)
    scale = np.abs(want.astype(np.float64)).max()
    assert np.abs(got.astype(np.float64)
                  - want.astype(np.float64)).max() / scale < 2e-2


def test_fused_multi_step_all_modes_match_reference():
    """The app driver's time loop (carried halos for fused) == the oracle,
    for every halo mode, including asymmetric extents."""
    grid, steps = (48, 16, 16), 4
    u0 = np.zeros(grid, np.float64)
    u0[24, 8, 8] = 1.0
    want = _reference(u0.astype(np.float32), np.zeros(grid, np.float32),
                      0.1, steps)
    for weights in (None, (3, 2, 2, 1)):
        for mode in MODES:
            r = run_minimod(grid=grid, steps=steps, nz=4, weights=weights,
                            mode=mode)
            np.testing.assert_allclose(
                r.field, want, atol=3e-6,
                err_msg=f"mode={mode} weights={weights}")


def test_fused_2d_app_loop():
    r = run_minimod(shape="minimod_2d", steps=3, mode="fused")
    assert r.plan.overlap and r.plan.ny == 2
    u0 = np.zeros(r.grid, np.float32)
    u0[r.grid[0] // 2, r.grid[1] // 2, r.grid[2] // 2] = 1.0
    want = _reference(u0, np.zeros_like(u0), 0.1, 3)
    np.testing.assert_allclose(r.field, want, atol=3e-6)
    # 2-D exchanges both axes: 2 puts per axis per step (+ prologue)
    assert r.plan.puts_per_step == 4
    assert r.put_bytes == r.tracker_put_bytes


# ---------------------------------------------------------------------------
# put-traffic parity: OMPCCL call log == RMATracker halo windows
# ---------------------------------------------------------------------------

def test_put_traffic_parity_with_tracker():
    r = run_minimod(grid=(64, 12, 10), steps=5, nz=4, mode="fused")
    assert r.plan.overlap
    # 2 put call sites in the carried step + 2 in the prologue exchange
    assert r.puts == r.tracker_puts == 4
    assert r.put_bytes == r.tracker_put_bytes > 0
    # per-window accounting: one lo + one hi window, equal volume
    lo, hi = sorted(w for w in r.window_bytes if w.startswith("halo:z"))
    assert r.window_bytes[lo] == r.window_bytes[hi]
    assert r.window_bytes[lo] + r.window_bytes[hi] == r.put_bytes
    # every put fenced: prologue + carried step each end in one fence
    assert r.fences == 2


def test_asymmetric_pgas_regions_proportional():
    r = run_minimod(grid=(44, 8, 8), steps=2, nz=4,
                    weights=(14, 10, 10, 10), mode="fused")
    assert r.z_extents == (14, 10, 10, 10)
    item = 4
    assert r.region_sizes == tuple(e * 8 * 8 * item for e in r.z_extents)
    assert r.alloc_counts["asymmetric"] == 2      # u and u_prev
    assert r.alloc_counts["free"] == 2            # both released at exit


# ---------------------------------------------------------------------------
# gradients flow through the fused step (it is differentiable end to end)
# ---------------------------------------------------------------------------

def test_fused_gradients_flow():
    Z, Y, X, nz = 48, 8, 6, 4
    mesh = make_mesh((nz, 1), ("z", "y"), axis_types="auto")
    u = (RNG.randn(Z, Y, X) * 0.1).astype(np.float32)
    up = (RNG.randn(Z, Y, X) * 0.1).astype(np.float32)

    def loss(a, b):
        y = fused_wave_step(a, b, 0.1, ZG)
        return (y * y).sum()

    g = jax.jit(shard_map(
        lambda a, b: jax.grad(loss, argnums=(0, 1))(a, b),
        mesh=mesh, in_specs=(P("z", "y"), P("z", "y")),
        out_specs=(P("z", "y"), P("z", "y"))))
    ga, gb = g(u, up)

    def ref_loss(ab):
        y = wave_step_ref(ab[0], ab[1], 0.1)
        return (y * y).sum()

    want_a, want_b = jax.grad(ref_loss)((jnp.asarray(u), jnp.asarray(up)))
    np.testing.assert_allclose(np.asarray(ga), np.asarray(want_a),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(want_b),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# planner: degenerate cases fall back, never an invalid slab plan
# ---------------------------------------------------------------------------

def test_plan_halo_slots_consumes_plan_slots():
    calls = []

    class SpyPool(StreamPool):
        def plan_slots(self, working_set_bytes, vmem_budget=64 * 2**20):
            calls.append(working_set_bytes)
            return super().plan_slots(working_set_bytes, vmem_budget)

    planner = OverlapPlanner(pool=SpyPool(max_active=4))
    plan = planner.plan_halo_slots(32, 16, 16, jnp.float32, 4)
    assert calls, "plan_slots was never queried"
    assert plan.overlap and 2 <= plan.slots <= 4
    assert plan.slab_bytes == RADIUS * 16 * 16 * 4
    assert plan.schedule(carried=True) == ("boundary", "put", "interior",
                                           "fence")
    assert plan.schedule(carried=False) == ("put", "interior", "fence",
                                            "boundary")


def test_plan_halo_slots_degenerate_grid_falls_back():
    planner = default_planner()
    # local extent == 2*R: no interior -> fallback schedule
    plan = planner.plan_halo_slots(2 * RADIUS, 16, 16, jnp.float32, 4)
    assert not plan.overlap
    assert plan.schedule() == ("put", "fence", "all")
    # single rank: nothing to exchange at all
    lone = planner.plan_halo_slots(32, 16, 16, jnp.float32, 1)
    assert not lone.overlap and lone.schedule() == ("all",)
    assert lone.puts_per_step == 0
    # 2-D with a degenerate Y extent also falls back
    flat = planner.plan_halo_slots(32, 2 * RADIUS, 16, jnp.float32, 2, ny=2)
    assert not flat.overlap


def test_plan_halo_slots_tiny_vmem_falls_back():
    planner = OverlapPlanner(pool=StreamPool(max_active=8), vmem_budget=1024)
    plan = planner.plan_halo_slots(64, 64, 64, jnp.float32, 4)
    assert plan.bz == 1                      # slab pipeline bottomed out
    assert not plan.overlap                  # cannot double-buffer: fallback
    assert plan.schedule() == ("put", "fence", "all")


def test_plan_halo_slots_wide_grid_tiles_y():
    """Paper-scale planes exceed VMEM whole; the staging chunk tiles Y so
    the overlap schedule survives instead of falling back."""
    plan = default_planner().plan_halo_slots(128, 1024, 1024, jnp.float32, 8)
    assert plan.overlap
    assert plan.by < plan.y_loc
    # the PINNED pipeline (all slots) must fit the budget, not just one slab
    assert plan.vmem_bytes <= default_planner().vmem_budget


def test_plan_stencil_bz_degenerate():
    planner = default_planner()
    # bz exceeding the Z extent clamps to it
    assert planner.plan_stencil_bz(3, 8, 8, jnp.float32, bz=64) == 3
    # grid smaller than the stencil support still yields a positive slab
    assert planner.plan_stencil_bz(2, 2, 2, jnp.float32) >= 1
    # budget too small for any slab bottoms out at one plane
    tiny = OverlapPlanner(pool=StreamPool(max_active=8), vmem_budget=256)
    assert tiny.plan_stencil_bz(64, 64, 64, jnp.float32) == 1


def test_fused_step_rejects_halo_wider_than_shard():
    mesh = make_mesh((4, 1), ("z", "y"), axis_types="auto")
    u = np.zeros((8, 8, 8), np.float32)    # 2 valid rows/rank < RADIUS

    def step(a, b):
        return fused_wave_step(a, b, 0.1, ZG, z_extents=(2, 2, 2, 2))

    with pytest.raises(RMAError):
        shard_map(step, mesh=mesh, in_specs=(P("z", "y"), P("z", "y")),
                  out_specs=P("z", "y"))(u, u)


def test_fused_step_rejects_mismatched_plan():
    mesh = make_mesh((4, 1), ("z", "y"), axis_types="auto")
    u = (RNG.randn(64, 8, 8) * 0.1).astype(np.float32)
    bad = dataclasses.replace(
        default_planner().plan_halo_slots(16, 8, 8, jnp.float32, 2), nz=2)

    def step(a, b):
        return fused_wave_step(a, b, 0.1, ZG, plan=bad)

    with pytest.raises(ValueError):
        shard_map(step, mesh=mesh, in_specs=(P("z", "y"), P("z", "y")),
                  out_specs=P("z", "y"))(u, u)


def test_split_extents():
    assert split_extents(64, 4) == (16, 16, 16, 16)
    assert split_extents(22, 4) == (6, 6, 5, 5)
    assert sum(split_extents(60, 4, (3, 2, 2, 1))) == 60
    ext = split_extents(60, 4, (30, 1, 1, 1), minimum=RADIUS)
    assert min(ext) >= RADIUS and sum(ext) == 60
    with pytest.raises(ValueError):
        split_extents(8, 4, minimum=RADIUS)   # 4 ranks x 4 rows > 8
    with pytest.raises(ValueError):
        split_extents(16, 4, (1, 1), minimum=1)


# ---------------------------------------------------------------------------
# satellite: interpret=None resolved BEFORE the jit boundary
# ---------------------------------------------------------------------------

def test_wave_step_interpret_resolved_in_jit_key():
    """The jit cache must be keyed on the RESOLVED interpret flag: calling
    with None and with the explicitly resolved value hits ONE entry (the
    silent-interpretation bug class PR 2 fixed for matmul)."""
    from repro.kernels.plan import resolve_interpret

    u = RNG.randn(16, 12, 10).astype(np.float32)
    up = RNG.randn(16, 12, 10).astype(np.float32)
    stencil_ops._wave_step_jit._clear_cache()
    stencil_ops.wave_step(u, up, 0.1, impl="pallas", interpret=None)
    n_after_none = stencil_ops._wave_step_jit._cache_size()
    stencil_ops.wave_step(u, up, 0.1, impl="pallas",
                          interpret=resolve_interpret(None))
    assert stencil_ops._wave_step_jit._cache_size() == n_after_none, \
        "interpret=None leaked into the jit key instead of the resolved flag"


# ---------------------------------------------------------------------------
# slow sweep (excluded from tier-1; the bench covers the modeled gate)
# ---------------------------------------------------------------------------

@slow_sweep
@pytest.mark.slow
@pytest.mark.parametrize("nz", [1, 2, 4, 8])
@pytest.mark.parametrize("mode", MODES)
def test_mode_rank_sweep(nz, mode):
    grid, steps = (64, 16, 16), 5
    u0 = np.zeros(grid, np.float64)
    u0[32, 8, 8] = 1.0
    want = _reference(u0.astype(np.float32), np.zeros(grid, np.float32),
                      0.1, steps)
    r = run_minimod(grid=grid, steps=steps, nz=nz, mode=mode)
    np.testing.assert_allclose(r.field, want, atol=5e-6)

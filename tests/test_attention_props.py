"""Property tests for the ring-attention online-softmax merge monoid.

The ring delivers K/V stripes in a schedule order that depends on the
ring size, the rank, and the direction mix — so the correctness of
:mod:`repro.kernels.ring_attention` rests on algebraic properties of the
``(m, l, acc)`` partial-state fold rather than on any one delivery order:

* ``merge_states`` is **associative** and **permutation-invariant** (up
  to float tolerance) — any arrival order finalizes to the same
  attention;
* the **masked-empty state** is the EXACT bitwise identity of the merge
  (``-0.0`` and ``-inf`` rows included), which is what makes the causal
  step-skip sound: a skipped stripe's state is the identity, so dropping
  its FLOPs leaves the fold chain bit-identical;
* :meth:`AttentionRingPlan.computes` — the static skip predicate — never
  skips a stripe the positional mask oracle says any query attends to.

Runs under real ``hypothesis`` when installed, else the deterministic
``tests/_minihyp.py`` fallback.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                      # pragma: no cover
    from _minihyp import given, settings, st

from repro.kernels.plan import AttentionRingPlan
from repro.kernels.ring_attention import (empty_state, finalize_state,
                                          merge_states, scaled_queries,
                                          stripe_state)
from repro.kernels.ring_attention.kernel import stripe_mask


# ---------------------------------------------------------------------------
# state construction helpers
# ---------------------------------------------------------------------------

B, TQ, KH, G, D, DV = 2, 3, 2, 2, 4, 3
H = KH * G


def _qg(rng):
    q = rng.randn(B, TQ, H, D).astype(np.float32)
    return scaled_queries(jnp.asarray(q), KH, D ** -0.5)


def _stripe(rng, qg, s, *, mask=None):
    """One stripe's partial state; ``mask`` rows control -inf/-0 content."""
    k = rng.randn(B, s, KH, D).astype(np.float32)
    v = rng.randn(B, s, KH, DV).astype(np.float32)
    if mask is None:
        mask = rng.rand(B, TQ, s) < 0.8
    return stripe_state(qg, jnp.asarray(k), jnp.asarray(v),
                        vis=jnp.asarray(mask))


def _final(state):
    return np.asarray(finalize_state(state, jnp.float32))


def _assert_state_bits_equal(a, b):
    """Bitwise equality per component — distinguishes -0.0 from +0.0 and
    matches -inf/-inf, which allclose-style checks cannot."""
    for name, xa, xb in zip(("m", "l", "acc"), a, b):
        ba = np.asarray(xa, np.float32).view(np.uint32)
        bb = np.asarray(xb, np.float32).view(np.uint32)
        np.testing.assert_array_equal(ba, bb, err_msg=name)


# ---------------------------------------------------------------------------
# merge algebra
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 4))
def test_merge_associative(seed, s1, s2, s3):
    rng = np.random.RandomState(seed)
    qg = _qg(rng)
    a, b, c = (_stripe(rng, qg, s) for s in (s1, s2, s3))
    left = merge_states(merge_states(a, b), c)
    right = merge_states(a, merge_states(b, c))
    np.testing.assert_allclose(_final(left), _final(right),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_merge_permutation_invariant(seed, n_stripes):
    rng = np.random.RandomState(seed)
    qg = _qg(rng)
    stripes = [_stripe(rng, qg, int(rng.randint(1, 5)))
               for _ in range(n_stripes)]
    perm = rng.permutation(n_stripes)

    def fold(order):
        state = empty_state(qg, jnp.zeros((B, 1, KH, DV)))
        for i in order:
            state = merge_states(state, stripes[i])
        return _final(state)

    np.testing.assert_allclose(fold(range(n_stripes)), fold(perm),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# the masked-empty state is the EXACT (bitwise) merge identity
# ---------------------------------------------------------------------------


def _adversarial_state(rng):
    """A state with -inf rows (fully masked queries), negative zeros in
    live rows' ``acc``/``l``, and ordinary float content — every case the
    identity pass-through must reproduce verbatim.  Dead rows carry the
    canonical ``(-inf, +0.0, +0.0)`` (the only value :func:`stripe_state`
    / :func:`merge_states` ever produce for them)."""
    m = rng.randn(B, TQ, KH, G).astype(np.float32)
    l = np.abs(rng.randn(B, TQ, KH, G)).astype(np.float32)
    acc = rng.randn(B, TQ, KH, G, DV).astype(np.float32)
    live = rng.rand(B, TQ, KH, G) >= 0.3
    acc[(rng.rand(*acc.shape) < 0.2) & live[..., None]] = -0.0
    l[(rng.rand(*l.shape) < 0.2) & live] = -0.0
    m[~live], l[~live], acc[~live] = -np.inf, 0.0, 0.0
    return jnp.asarray(m), jnp.asarray(l), jnp.asarray(acc)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_empty_state_is_bitwise_merge_identity(seed):
    rng = np.random.RandomState(seed)
    s = _adversarial_state(rng)
    e = (jnp.full((B, TQ, KH, G), -jnp.inf, jnp.float32),
         jnp.zeros((B, TQ, KH, G), jnp.float32),
         jnp.zeros((B, TQ, KH, G, DV), jnp.float32))
    _assert_state_bits_equal(merge_states(e, s), s)
    _assert_state_bits_equal(merge_states(s, e), s)
    _assert_state_bits_equal(merge_states(e, e), e)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_fully_masked_stripe_is_bitwise_empty(seed, s):
    """A stripe no query can see IS the identity — the fact the causal
    step-skip banks on (skipping its FLOPs changes no bits)."""
    rng = np.random.RandomState(seed)
    qg = _qg(rng)
    masked = _stripe(rng, qg, s, mask=np.zeros((B, TQ, s), bool))
    v = jnp.asarray(rng.randn(B, s, KH, DV).astype(np.float32))
    _assert_state_bits_equal(masked, empty_state(qg, v))
    other = _stripe(rng, qg, int(rng.randint(1, 5)))
    _assert_state_bits_equal(merge_states(masked, other), other)
    _assert_state_bits_equal(merge_states(other, masked), other)


# ---------------------------------------------------------------------------
# the causal step-skip predicate vs the positional mask oracle
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 3), st.integers(1, 3),
       st.booleans(), st.booleans(), st.integers(0, 5), st.booleans(),
       st.booleans(), st.integers(1, 24))
def test_skip_predicate_never_skips_visible_stripe(
        n, tq, tk, causal, q_sharded, q_offset, traced_offset,
        has_valid, valid_raw):
    valid_len = min(valid_raw, n * tk) if has_valid else None
    plan = AttentionRingPlan(
        n=n, tq_loc=tq, tk_loc=tk, h=4, kh=2, d=8, dv=8, causal=causal,
        q_sharded=q_sharded, q_offset=None if traced_offset else q_offset,
        valid_len=valid_len)
    for rank in range(n):
        # every stripe is delivered exactly once, whatever the schedule
        assert sorted(plan.sources(rank)) == list(range(n))
        q_lo = q_offset + (rank * tq if q_sharded else 0)
        q_pos = jnp.asarray((q_lo + np.arange(tq)).reshape(1, tq))
        for src in range(n):
            vis = np.asarray(stripe_mask(tk, q_pos=q_pos, k_start=src * tk,
                                         causal=causal, valid_len=valid_len))
            if traced_offset:
                # traced offsets: only valid_len skips are allowed, and
                # soundness still holds (skip => oracle sees nothing)
                if not plan.computes(rank, src):
                    assert not vis.any(), (rank, src)
            else:
                # static offsets: the predicate is EXACT — it skips a
                # stripe iff the oracle mask is empty
                assert plan.computes(rank, src) == bool(vis.any()), \
                    (rank, src, q_lo, valid_len)


def test_skip_predicate_skips_future_stripes():
    # pinned example: rank 0 of a causal 4-ring computes only stripe 0
    plan = AttentionRingPlan(n=4, tq_loc=4, tk_loc=4, h=4, kh=2, d=8, dv=8,
                             causal=True)
    assert plan.computed_sources(0) == (0,)
    assert plan.computed_sources(3) == (3, 2, 0, 1)
    assert plan.flops(0) == plan.stripe_flops
    # sends are never skipped: wire bytes are causal-invariant
    assert plan.wire_bytes == 3 * plan.stripe_bytes
    assert plan.puts_per_rank == 6


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))

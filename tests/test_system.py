"""End-to-end behaviour: the unified runtime (paper Fig. 1b) + property
tests on runtime invariants + the dry-run/roofline toolchain on a small
config."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _minihyp import given, settings, st

from repro.core.groups import DiompGroup
from repro.core.runtime import DiompRuntime
from repro.models import schema as sch
from repro import configs


def test_runtime_unified_table(mesh8):
    rt = DiompRuntime(mesh8, segment_bytes=1 << 22)
    row = rt.register("w", (256, 128), "bfloat16", ("embed_fsdp", "mlp"))
    assert row.symmetric and str(row.spec) == "PartitionSpec('data', 'model')"
    kv = rt.register("kv", (8, 64), "bfloat16", (None, None),
                     symmetric=False, sizes=[64 * (i + 1) for i in range(8)])
    assert not kv.symmetric
    # one mapping table drives placement AND the heap plan (Fig. 1b)
    assert {r.name for r in rt.table()} == {"w", "kv"}
    assert rt.bytes_in_use() > 0
    sh = rt.sharding_for("w")
    assert sh.mesh.shape == mesh8.shape
    rt.release("kv")
    assert {r.name for r in rt.table()} == {"w"}
    rt.fence()
    rt.close()


def test_runtime_rejects_duplicates(mesh8):
    rt = DiompRuntime(mesh8, segment_bytes=1 << 20)
    rt.register("x", (16,), "float32", (None,))
    with pytest.raises(ValueError):
        rt.register("x", (16,), "float32", (None,))
    rt.close()


@given(st.lists(st.integers(1, 1 << 16), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_runtime_heap_accounting(sizes):
    """Register/release cycles never leak arena bytes (property)."""
    from repro.core.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"), axis_types="auto")
    rt = DiompRuntime(mesh, segment_bytes=1 << 22)
    for i, s in enumerate(sizes):
        rt.register(f"t{i}", (s,), "float32", (None,))
    for i in range(len(sizes)):
        rt.release(f"t{i}")
    assert rt.bytes_in_use() == 0
    rt.memory.check_invariants()
    rt.close()


def test_param_counts_match_published():
    expect = {
        "deepseek-v3-671b": (650e9, 700e9),
        "qwen3-moe-235b-a22b": (220e9, 245e9),
        "qwen1-5-110b": (100e9, 120e9),
        "command-r-plus-104b": (95e9, 115e9),
        "glm4-9b": (8e9, 11e9),
        "rwkv6-7b": (6e9, 8.5e9),
        "stablelm-3b": (2e9, 3.5e9),
        "paligemma-3b": (2e9, 3.2e9),
        "zamba2-1-2b": (0.9e9, 1.6e9),
        "hubert-xlarge": (0.8e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_hlo_analyzer_on_known_program():
    """The loop-aware analyzer reproduces a hand-computable program."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(carry, _):
            return carry @ w, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jnp.ones((64, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    hc = analyze_hlo(txt)
    want = 5 * 2 * 64 ** 3           # 5 loop trips x one 64^3 matmul
    assert abs(hc.flops - want) / want < 0.01, hc.flops


@pytest.mark.slow
def test_dryrun_smoke_cell(tmp_path):
    """lower+compile one REAL production cell via the dry-run entry point
    (subprocess: it must own the 512-device XLA_FLAGS before jax init)."""
    import subprocess, sys, os, json
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "stablelm-3b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    files = list(tmp_path.glob("*.json"))
    assert files
    rec = json.loads(files[0].read_text())
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["t_compute_s"] > 0 or rec["t_memory_s"] > 0

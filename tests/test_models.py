"""Model zoo: per-arch smoke tests (reduced configs, one fwd/train step on
the 8-device mesh, shapes + finiteness) and the cross-mesh equivalence and
decode-consistency invariants behind the manual-SPMD implementation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import ompccl
from repro.core.compat import make_mesh, shard_map
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx
from repro.models.transformer import (init_cache, transformer_decode,
                                      transformer_forward, transformer_loss)

MESHES = [((2, 2, 2), ("pod", "data", "model")),
          ((1, 8), ("data", "model")),
          ((4, 2), ("data", "model"))]


def _mesh(shape, axes):
    return make_mesh(shape, axes, axis_types="auto")


def _batch_for(cfg, B=8, S=16, seed=1):
    rng = np.random.RandomState(seed)
    if cfg.family == "audio":
        return {
            "embeds": rng.randn(B, S, cfg.d_model).astype(np.float32),
            "targets": rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32),
            "mask": (rng.rand(B, S) < 0.3).astype(np.float32),
        }
    if cfg.family == "vlm":
        Ptk = cfg.prefix_tokens
        return {
            "tokens": rng.randint(0, cfg.vocab_size, (B, S - Ptk)).astype(
                np.int32),
            "prefix_embeds": rng.randn(B, Ptk, cfg.d_model).astype(
                np.float32) * 0.1,
        }
    return {"tokens": rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)}


def _loss_on(cfg, shape, axes, params, batch):
    mesh = _mesh(shape, axes)
    ctx = ParallelCtx.from_mesh(mesh, remat=True)
    pspecs = sch.partition_specs(cfg, mesh)
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspecs = {k: P(ba) for k in batch}
    loss_fn = model_api.loss_fn(cfg)

    def step(p, b):
        return ompccl.allreduce(loss_fn(p, b, cfg, ctx), ctx.world, op="mean")

    return float(jax.jit(shard_map(step, mesh=mesh,
                                   in_specs=(pspecs, bspecs),
                                   out_specs=P()))(params, batch))


@pytest.mark.parametrize("arch", configs.all_archs())
def test_arch_smoke_train_step(arch):
    """One loss evaluation per reduced arch on (2,2,2): finite + sane."""
    cfg = configs.get_reduced(arch)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss = _loss_on(cfg, *MESHES[0], params, batch)
    assert np.isfinite(loss) and 0.5 < loss < 20.0, (arch, loss)


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-v3-671b", "rwkv6-7b",
                                  "zamba2-1-2b"])
def test_mesh_equivalence(arch):
    """The same global computation on different mesh factorizations."""
    cfg = configs.get_reduced(arch)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    vals = [_loss_on(cfg, s, a, params, batch) for s, a in MESHES]
    assert max(vals) - min(vals) < 0.05, (arch, vals)


def test_full_config_schemas_consistent():
    """Full (published-dim) schemas stay shardable on the production mesh."""
    import os
    for arch in configs.all_archs():
        cfg = configs.get(arch)
        schema = sch.build_schema(cfg)
        for name, spec in schema.items():
            for dim, ax in zip(spec.shape, spec.axes):
                if ax in ("heads", "kv_heads", "mlp", "vocab", "expert"):
                    assert dim % sch.MAX_TP == 0, (arch, name, dim, ax)
                if ax == "embed_fsdp":
                    assert dim % 16 == 0, (arch, name, dim)


def test_decode_matches_forward_glm():
    cfg = configs.get_reduced("glm4-9b")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    tokens = np.random.RandomState(3).randint(0, cfg.vocab_size,
                                              (8, 12)).astype(np.int32)
    mesh = _mesh(*MESHES[0])
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    pspecs = sch.partition_specs(cfg, mesh)

    def full(p, b):
        h, _ = transformer_forward(p, b, cfg, ctx)
        return jnp.dot(h.astype(jnp.float32), p["lm_head"].astype(jnp.float32))

    L_full = np.asarray(jax.jit(shard_map(
        full, mesh=mesh, in_specs=(pspecs, P(("pod", "data"))),
        out_specs=P(("pod", "data"), None, "model")))(params, tokens))

    def serve(p, b):
        cache = init_cache(cfg, ctx, b.shape[0], 12)
        outs = []
        for i in range(12):
            lg, cache = transformer_decode(p, b[:, i:i + 1], cfg, ctx, cache)
            outs.append(lg)
        return jnp.concatenate(outs, axis=1)

    L_serve = np.asarray(jax.jit(shard_map(
        serve, mesh=mesh, in_specs=(pspecs, P(("pod", "data"))),
        out_specs=P(("pod", "data"), None, "model")))(params, tokens))
    err = np.abs(L_serve[:, :-1] - L_full[:, :-1]).max() / \
        np.abs(L_full).max()
    assert err < 2e-2, err


def test_moe_balance_and_capacity():
    """MoE routing: outputs stay finite across capacity factors."""
    base = configs.get_reduced("qwen3-moe-235b-a22b")
    import dataclasses
    for cf in (0.5, 1.0, 2.0):
        cfg = dataclasses.replace(base, capacity_factor=cf)
        params = sch.init_params(cfg, jax.random.PRNGKey(0))
        loss = _loss_on(cfg, *MESHES[0], params, _batch_for(cfg))
        assert np.isfinite(loss), (cf, loss)


def test_expert2d_exact_and_trains():
    """expert2d (2-D expert sharding + combined-group a2a) is numerically
    exact vs the baseline layout, and trains identically."""
    import dataclasses
    from repro.train.optim import adamw, cosine_schedule
    from repro.train.step import build_train_step

    ds = dataclasses.replace(configs.get_reduced("deepseek-v3-671b"),
                             capacity_factor=4.0)  # ample: routing identical
    params = sch.init_params(ds, jax.random.PRNGKey(0))
    toks = np.random.RandomState(1).randint(0, 160, (8, 16)).astype(np.int32)
    mesh = _mesh(*MESHES[0])

    losses = {}
    hists = {}
    for e2d in (False, True):
        ctx = ParallelCtx.from_mesh(mesh, remat=True, expert2d=e2d)
        from repro.distributed.sharding import rules_for_ctx
        pspecs = sch.partition_specs(ds, mesh, rules_for_ctx(ctx))

        def one(p, b, ctx=ctx):
            l = transformer_loss(p, b, ds, ctx)
            return ompccl.allreduce(l, ctx.world, op="mean")

        f = jax.jit(shard_map(one, mesh=mesh,
                              in_specs=(pspecs, {"tokens": P(("pod", "data"))}),
                              out_specs=P()))
        losses[e2d] = float(f(params, {"tokens": toks}))

        opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
        stepf = build_train_step(ds, mesh, ctx, opt, donate=False,
                                 global_batch=8)
        p = jax.tree.map(jnp.copy, params)
        o = jax.jit(opt.init)(p)
        h = []
        for i in range(4):
            p, o, m = stepf(p, o, {"tokens": toks}, jnp.asarray(i))
            h.append(float(m["loss"]))
        hists[e2d] = h
    assert abs(losses[False] - losses[True]) < 1e-3, losses
    np.testing.assert_allclose(hists[False], hists[True], atol=2e-2)
    assert hists[True][-1] < hists[True][0] - 0.1


def test_dp_only_layout_trains():
    """dp_only layout (no TP; batch over every axis) trains a dense arch."""
    from repro.train.optim import adamw, cosine_schedule
    from repro.train.step import build_train_step

    cfg = configs.get_reduced("stablelm-3b")
    mesh = _mesh(*MESHES[0])
    ctx = ParallelCtx.from_mesh(mesh, remat=True, layout="dp_only")
    assert ctx.tp == 1 and ctx.dp == 8
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.RandomState(1).randint(
        0, cfg.vocab_size, (8, 16)).astype(np.int32)
    opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
    stepf = build_train_step(cfg, mesh, ctx, opt, donate=False, global_batch=8)
    p, o = params, jax.jit(opt.init)(params)
    h = []
    for i in range(6):
        p, o, m = stepf(p, o, {"tokens": toks}, jnp.asarray(i))
        h.append(float(m["loss"]))
    assert h[-1] < h[0] - 0.1, h


def test_paligemma_decode_replicated_kv():
    """Non-head-parallel arch (8 heads): decode with fully replicated KV
    matches the full forward."""
    cfg = configs.get_reduced("paligemma-3b")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    mesh = _mesh(*MESHES[0])
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    pspecs = sch.partition_specs(cfg, mesh)
    tokens = np.random.RandomState(5).randint(
        0, cfg.vocab_size, (8, 8)).astype(np.int32)

    def full(p, b):
        h, _ = transformer_forward(p, b, cfg, ctx)
        head = p["embed/table"].T
        return jnp.dot(h.astype(jnp.float32), head.astype(jnp.float32))

    L_full = np.asarray(jax.jit(shard_map(
        full, mesh=mesh, in_specs=(pspecs, P(("pod", "data"))),
        out_specs=P(("pod", "data"), None, "model")))(params, tokens))

    def serve(p, b):
        cache = init_cache(cfg, ctx, b.shape[0], 8)
        outs = []
        for i in range(8):
            lg, cache = transformer_decode(p, b[:, i:i + 1], cfg, ctx, cache)
            outs.append(lg)
        return jnp.concatenate(outs, axis=1)

    L_serve = np.asarray(jax.jit(shard_map(
        serve, mesh=mesh, in_specs=(pspecs, P(("pod", "data"))),
        out_specs=P(("pod", "data"), None, "model")))(params, tokens))
    err = np.abs(L_serve[:, :-1] - L_full[:, :-1]).max() / \
        np.abs(L_full).max()
    assert err < 2e-2, err


def test_zamba_seq_sharded_decode():
    """Context-parallel (S-sharded over 'data') decode for the long-context
    hybrid cells: matches the replicated-cache decode."""
    from repro.models.ssm import zamba_decode, zamba_init_state

    cfg = configs.get_reduced("zamba2-1-2b")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    mesh = _mesh(*MESHES[0])
    ctx = ParallelCtx.from_mesh(mesh, remat=False, inference=True)
    pspecs = sch.partition_specs(cfg, mesh)
    tokens = np.random.RandomState(6).randint(
        0, cfg.vocab_size, (1, 8)).astype(np.int32)  # B=1: batch replicated

    def serve(p, b, seq_sharded):
        st = zamba_init_state(cfg, ctx, 1, 16, seq_sharded=seq_sharded)
        # only the S-sharded KV chunks genuinely vary (over "data")
        vary = ("data",) if seq_sharded else ()
        st = jax.tree.map(lambda a: ompccl.ensure_varying(a, vary), st)
        outs = []
        for i in range(8):
            lg, st = zamba_decode(p, b[:, i:i + 1], cfg, ctx, st,
                                  seq_sharded=seq_sharded)
            outs.append(lg)
        cat = jnp.concatenate(outs, axis=1)
        # value-preserving pmean to certify dp-replication to the checker
        from repro.core.groups import DiompGroup
        return ompccl.allreduce(cat, DiompGroup(("pod", "data")), op="mean")

    outs = {}
    for ss in (False, True):
        f = jax.jit(shard_map(
            lambda p, b, ss=ss: serve(p, b, ss), mesh=mesh,
            in_specs=(pspecs, P(None)),
            out_specs=P(None, None, "model")))
        outs[ss] = np.asarray(f(params, tokens))
    err = np.abs(outs[True] - outs[False]).max() / np.abs(outs[False]).max()
    assert err < 2e-2, err

"""Fused ring attention vs the host listing and the single-device oracle.

The bit contract (docs/ARCHITECTURE.md): the fused CPU emulation, the
serialized host listing, and :func:`ring_attention_ref` all fold the same
exact numpy stripe/merge ops in the same schedule order, so forward AND
gradients must agree ``==`` (not allclose) across ring sizes, GQA ratios,
bf16 inputs, non-divisible (padded) lengths, and traced chunked-prefill
offsets.  The put-side books must match :class:`AttentionRingPlan`
exactly.  ``RUN_SLOW=1`` widens the sweep to every mode x ring size.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ompccl
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import attention_window_names
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.plan import default_planner, resolve_seq_parallel
from repro.kernels.ring_attention import (resolve_attention_impl,
                                          ring_attention, ring_attention_ref)

GROUP = DiompGroup(("x",), name="x")


def _mesh(n):
    return make_mesh((n,), ("x",), axis_types="auto")


def _case(n, *, tq=4, H=4, KH=2, D=8, DV=8, B=2, dtype=jnp.float32, seed=0):
    rng = np.random.RandomState(seed)
    T = n * tq
    q = jnp.asarray(rng.randn(B, T, H, D), jnp.float32).astype(dtype)
    k = jnp.asarray(rng.randn(B, T, KH, D), jnp.float32).astype(dtype)
    v = jnp.asarray(rng.randn(B, T, KH, DV), jnp.float32).astype(dtype)
    return q, k, v


def _ring_fn(mesh, impl, **kw):
    def f(q, k, v):
        return ring_attention(q, k, v, GROUP, impl=impl, **kw)

    spec = P(None, "x")
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(spec,) * 3,
                             out_specs=spec))


# ---------------------------------------------------------------------------
# forward: fused == host == oracle, bitwise
# ---------------------------------------------------------------------------

CASES = [
    ("n2_causal", 2, dict(), dict(dtype=jnp.float32)),
    ("n4_bidi", 4, dict(causal=False), dict()),
    ("n4_bf16", 4, dict(), dict(dtype=jnp.bfloat16)),
    ("n4_mqa", 4, dict(), dict(KH=1)),
    ("n4_mha", 4, dict(), dict(KH=4)),
    ("n4_dv_ne_d", 4, dict(), dict(DV=4)),
    ("n1_group_of_one", 1, dict(), dict()),
    ("n8_causal", 8, dict(), dict(tq=2)),
]


@pytest.mark.parametrize("name,n,kw,ckw", CASES, ids=[c[0] for c in CASES])
def test_fused_host_oracle_bitwise(name, n, kw, ckw):
    q, k, v = _case(n, **ckw)
    causal = kw.get("causal", True)
    want = np.asarray(jax.jit(
        lambda q, k, v: ring_attention_ref(q, k, v, n=n, causal=causal)
    )(q, k, v))
    mesh = _mesh(n)
    for impl in ("host", "fused"):
        got = np.asarray(_ring_fn(mesh, impl, **kw)(q, k, v))
        np.testing.assert_array_equal(got, want, err_msg=impl)
    # and all of it tracks the plain flash oracle to float tolerance
    ref = np.asarray(flash_attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(want.astype(np.float32),
                               ref.astype(np.float32), atol=3e-2 if
                               ckw.get("dtype") == jnp.bfloat16 else 3e-6,
                               rtol=3e-2 if ckw.get("dtype") == jnp.bfloat16
                               else 3e-6)


@pytest.mark.parametrize("impl", ["host", "fused"])
@pytest.mark.parametrize("causal", [True, False])
def test_grad_bitwise(impl, causal):
    n = 4
    q, k, v = _case(n, seed=3)
    ct = jnp.asarray(np.random.RandomState(9).randn(*q.shape[:2], q.shape[2],
                                                    v.shape[-1]), jnp.float32)
    mesh = _mesh(n)
    spec = P(None, "x")

    def g(q, k, v, ct):
        out, vjp = jax.vjp(
            lambda a, b, c: ring_attention(a, b, c, GROUP, causal=causal,
                                           impl=impl), q, k, v)
        return vjp(ct)

    got = jax.jit(shard_map(g, mesh=mesh, in_specs=(spec,) * 4,
                            out_specs=(spec,) * 3))(q, k, v, ct)

    def oracle(q, k, v):
        return ring_attention_ref(q, k, v, n=n, causal=causal)

    _, vjp = jax.vjp(oracle, q, k, v)
    want = vjp(ct)
    for name, a, b in zip("qkv", got, want):
        a = np.asarray(a)
        assert np.isfinite(a).all(), name
        np.testing.assert_array_equal(a, np.asarray(b), err_msg=name)


def test_padded_ragged_length_bitwise():
    """T=20 padded to 24 over n=4 with valid_len=20: fwd + grad bitwise vs
    the oracle, real rows allclose vs unpadded flash."""
    n, T, T_pad = 4, 20, 24
    rng = np.random.RandomState(5)
    B, H, KH, D = 2, 4, 2, 8
    q = jnp.asarray(rng.randn(B, T_pad, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, T_pad, KH, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, T_pad, KH, D), jnp.float32)
    mesh = _mesh(n)
    spec = P(None, "x")
    kw = dict(causal=True, valid_len=T)

    outs = {}
    for impl in ("host", "fused"):
        outs[impl] = np.asarray(_ring_fn(mesh, impl, **kw)(q, k, v))
    want = np.asarray(ring_attention_ref(q, k, v, n=n, **kw))
    np.testing.assert_array_equal(outs["host"], want)
    np.testing.assert_array_equal(outs["fused"], want)
    ref = np.asarray(flash_attention_ref(q[:, :T], k[:, :T], v[:, :T],
                                         causal=True))
    np.testing.assert_allclose(want[:, :T], ref, atol=3e-6, rtol=3e-6)

    ct = jnp.asarray(rng.randn(*want.shape), jnp.float32)

    def g(q, k, v, ct):
        _, vjp = jax.vjp(
            lambda a, b, c: ring_attention(a, b, c, GROUP, impl="fused",
                                           **kw), q, k, v)
        return vjp(ct)

    got = jax.jit(shard_map(g, mesh=mesh, in_specs=(spec,) * 4,
                            out_specs=(spec,) * 3))(q, k, v, ct)
    _, vjp = jax.vjp(lambda a, b, c: ring_attention_ref(a, b, c, n=n, **kw),
                     q, k, v)
    for name, a, b in zip("qkv", got, vjp(ct)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


@pytest.mark.parametrize("impl", ["host", "fused"])
def test_chunked_prefill_traced_offset_bitwise(impl):
    """q replicated (q_sharded=False), K/V striped, TRACED q_offset /
    valid_len — the dynamic chunked-prefill layout the serve step lowers."""
    n, tq, p0 = 4, 8, 8
    rng = np.random.RandomState(7)
    B, H, KH, D = 2, 4, 2, 8
    S = p0 + tq                    # 16 cached rows striped over 4 ranks
    q = jnp.asarray(rng.randn(B, tq, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KH, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KH, D), jnp.float32)
    mesh = _mesh(n)

    def f(q, k, v, off):
        return ring_attention(q, k, v, GROUP, causal=True, q_offset=off,
                              valid_len=off + tq, q_sharded=False, impl=impl)

    fn = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P(None, "x"), P(None, "x"), P()),
        out_specs=P(), check_rep=False))
    got = np.asarray(fn(q, k, v, jnp.asarray(p0, jnp.int32)))
    want = np.asarray(ring_attention_ref(q, k, v, n=n, causal=True,
                                         q_offset=p0, valid_len=p0 + tq,
                                         q_sharded=False))
    np.testing.assert_array_equal(got, want)
    ref = np.asarray(flash_attention_ref(q, k, v, causal=True, q_offset=p0))
    np.testing.assert_allclose(got, ref, atol=3e-6, rtol=3e-6)


# ---------------------------------------------------------------------------
# the put-side books
# ---------------------------------------------------------------------------


def test_fused_put_traffic_matches_plan():
    n = 4
    q, k, v = _case(n)
    B, T, H, D = q.shape
    plan = default_planner().plan_ring_attention(
        B, T // n, T // n, H, k.shape[2], D, v.shape[-1], jnp.float32, n,
        causal=True)
    dctx = DiompContext()
    with use_default(dctx):
        _ring_fn(_mesh(n), "fused").lower(q, k, v)
    desc = GROUP.descriptor()
    assert dctx.stats()[desc]["put"] == plan.puts_per_rank == 2 * (n - 1)
    put_bytes = dctx.byte_stats()[desc]["put"]
    cw_w, ccw_w = attention_window_names(GROUP, n)
    win_bytes = sum(dctx.rma.window_bytes[w] for w in cw_w + ccw_w)
    assert put_bytes == win_bytes == plan.wire_bytes == dctx.rma.put_bytes


def test_host_put_traffic_matches_plan():
    # the serialized listing moves the SAME bytes — overlap changes
    # scheduling, never traffic
    n = 4
    q, k, v = _case(n)
    plan = default_planner().plan_ring_attention(
        q.shape[0], q.shape[1] // n, q.shape[1] // n, q.shape[2], k.shape[2],
        q.shape[-1], v.shape[-1], jnp.float32, n, causal=True, overlap=False)
    dctx = DiompContext()
    with use_default(dctx):
        _ring_fn(_mesh(n), "host").lower(q, k, v)
    desc = GROUP.descriptor()
    assert dctx.stats()[desc]["put"] == plan.puts_per_rank
    assert dctx.byte_stats()[desc]["put"] == plan.wire_bytes


# ---------------------------------------------------------------------------
# API contracts
# ---------------------------------------------------------------------------


def test_resolvers():
    assert resolve_attention_impl(None) == "fused"
    assert resolve_attention_impl("auto") == "fused"
    assert resolve_attention_impl("host") == "host"
    with pytest.raises(ValueError, match="ring attention impl"):
        resolve_attention_impl("bogus")
    assert resolve_seq_parallel(None) == "allgather"
    assert resolve_seq_parallel("auto") == "allgather"
    assert resolve_seq_parallel("ring") == "ring"
    with pytest.raises(ValueError, match="seq_parallel"):
        resolve_seq_parallel("bogus")


def test_flash_attention_ring_impl_contract():
    q, k, v = _case(1)
    with pytest.raises(ValueError, match="DiompGroup"):
        flash_attention(q, k, v, impl="ring")
    with pytest.raises(ValueError, match="prefix_len"):
        flash_attention(q, k, v, impl="ring", group=GROUP, prefix_len=4)


def test_pallas_traced_offsets_raise():
    """Satellite regression: traced q_offset/valid_len into the pallas
    kernel must fail loudly at the API boundary, naming the contract."""
    q, k, v = _case(1)

    def f_off(off):
        return flash_attention(q, k, v, impl="pallas", q_offset=off)

    with pytest.raises(ValueError, match="static-offsets contract"):
        jax.jit(f_off)(jnp.asarray(3, jnp.int32))

    def f_vl(vl):
        return flash_attention(q, k, v, impl="pallas", valid_len=vl)

    with pytest.raises(ValueError, match="static-offsets contract"):
        jax.jit(f_vl)(jnp.asarray(3, jnp.int32))


# ---------------------------------------------------------------------------
# the model-layer knob (ctx.seq_parallel = "ring")
# ---------------------------------------------------------------------------


def test_attention_block_seq_parallel_ring_matches_allgather():
    """ctx.seq_parallel='ring' swaps the token-parallel flash for the ring
    without changing the block's numerics (bf16-quantized params)."""
    import dataclasses

    from repro.models import schema as sch
    from repro.models.config import ModelConfig, ParallelCtx
    from repro.models.layers import attention_block

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=8, kv_heads=2, d_ff=128, vocab_size=32,
                      dtype="float32")
    mesh = make_mesh((4, 1), ("model", "data"), axis_types="auto")
    ctx = ParallelCtx.from_mesh(mesh)
    assert not sch.head_parallel(cfg)      # 8 heads -> token-parallel path
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    lp = {kk.split("/")[1]: vv[0] for kk, vv in params.items()
          if kk.startswith("layers/")}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)

    def run(seq_parallel):
        c = dataclasses.replace(ctx, seq_parallel=seq_parallel)

        def f(x):
            out, _ = attention_block(x, lp, cfg, c)
            return out

        return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(),),
                                 out_specs=P(), check_rep=False))(x)

    a, r = run("allgather"), run("ring")
    np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RUN_SLOW=1: the full mode x ring-size sweep
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SLOW"),
                    reason="slow sweep; tier-1 runs the equivalence subset "
                           "(set RUN_SLOW=1)")
@pytest.mark.parametrize("impl", ["host", "fused"])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("n", [2, 3, 4, 5, 6, 8])
def test_sweep_bitwise(n, causal, impl):
    q, k, v = _case(n, tq=3, seed=n)
    got = np.asarray(_ring_fn(_mesh(n), impl, causal=causal)(q, k, v))
    want = np.asarray(ring_attention_ref(q, k, v, n=n, causal=causal))
    np.testing.assert_array_equal(got, want)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))

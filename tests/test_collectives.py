"""OMPCCL collectives + RMA verbs + hierarchical/compressed backends on the
8-virtual-device mesh — numerical equivalence against plain numpy."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import ompccl, rma
from repro.core.compat import shard_map
from repro.core.groups import DiompGroup
from repro.distributed import compression, hierarchical

WORLD = DiompGroup(("pod", "data", "model"), name="world")
DP = DiompGroup(("pod", "data"), name="dp")
TP = DiompGroup(("model",), name="tp")
RING = DiompGroup(("x",), name="x")


def _run(mesh, fn, x, in_spec, out_spec):
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec))(x))


def test_allreduce_ops(mesh8):
    x = np.random.RandomState(0).randn(8, 4).astype(np.float32)
    for op, ref in [("sum", np.sum), ("max", np.max), ("min", np.min)]:
        got = _run(mesh8, lambda v, op=op: ompccl.allreduce(v, WORLD, op=op),
                   x, P(("pod", "data", "model")), P(("pod", "data", "model")))
        want = np.repeat(ref(x, axis=0, keepdims=True), 8, axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_bcast_and_reduce(mesh8):
    x = np.random.RandomState(1).randn(8, 4).astype(np.float32)
    got = _run(mesh8, lambda v: ompccl.bcast(v, WORLD, root=3), x,
               P(("pod", "data", "model")), P(("pod", "data", "model")))
    np.testing.assert_allclose(got, np.tile(x[3], (8, 1)), rtol=1e-6)
    got = _run(mesh8, lambda v: ompccl.reduce(v, WORLD, root=2), x,
               P(("pod", "data", "model")), P(("pod", "data", "model")))
    want = np.zeros_like(x)
    want[2] = x.sum(0)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_allgather_reducescatter_roundtrip(mesh8):
    x = np.random.RandomState(2).randn(8, 6).astype(np.float32)

    def f(v):
        full = ompccl.allgather(v, DP, axis=0)       # (4*2, 6) per shard
        return ompccl.reducescatter(full, DP, axis=0) / 4.0

    got = _run(mesh8, f, x, P(("pod", "data"), "model"),
               P(("pod", "data"), "model"))
    np.testing.assert_allclose(got, x, rtol=1e-5)


def test_put_get_inverse(ring8):
    x = np.arange(32, dtype=np.float32).reshape(8, 4)

    def f(v):
        return rma.ompx_get(rma.ompx_put(v, RING, shift=3), RING, shift=3)

    got = _run(ring8, f, x, P("x"), P("x"))
    np.testing.assert_allclose(got, x)


def test_put_shift_semantics(ring8):
    x = np.arange(8, dtype=np.float32).reshape(8, 1)
    got = _run(ring8, lambda v: rma.ompx_put(v, RING, shift=2), x,
               P("x"), P("x"))
    np.testing.assert_allclose(got[:, 0], np.roll(np.arange(8), 2))


def test_halo_exchange_edges(ring8):
    x = np.arange(24, dtype=np.float32).reshape(24, 1)

    def f(v):
        l, r = rma.halo_exchange(v, RING, halo=1, axis=0)
        return jnp.concatenate([l, r], axis=0)

    got = _run(ring8, f, x, P("x"), P("x"))
    lr = got.reshape(8, 2)
    assert lr[0, 0] == 0.0 and lr[7, 1] == 0.0       # non-periodic edges
    np.testing.assert_allclose(lr[1:, 0], x.reshape(8, 3)[:-1, 2])
    np.testing.assert_allclose(lr[:-1, 1], x.reshape(8, 3)[1:, 0])


def test_hierarchical_equals_flat(mesh8):
    x = np.random.RandomState(3).randn(8, 16).astype(np.float32)
    flat = _run(mesh8, lambda v: ompccl.allreduce(v, DP), x,
                P(("pod", "data"), "model"), P(None, "model"))
    hier = _run(mesh8,
                lambda v: ompccl.allreduce(v, DP, backend="hierarchical"),
                x, P(("pod", "data"), "model"), P(None, "model"))
    np.testing.assert_allclose(flat, hier, rtol=1e-5)


def test_hierarchical_pad_path_bf16(mesh8):
    """Non-divisible payload (5 elems/device, fast size 2) exercises the
    pad/reshape round-trip with bf16 inputs."""
    x = np.random.RandomState(6).randn(4, 10).astype(np.float32)
    xb = np.asarray(jnp.asarray(x, jnp.bfloat16), np.float32)

    def f(v):
        h = ompccl.allreduce(v.astype(jnp.bfloat16), DP,
                             backend="hierarchical")
        return h.astype(jnp.float32)

    got = _run(mesh8, f, x, P(("pod", "data"), "model"),
               P(("pod", "data"), "model"))
    want = np.tile(xb.sum(0), (4, 1))
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


def test_hierarchical_max_falls_back_flat(mesh8):
    """op="max" does not decompose through a scatter: the hierarchical
    backend must fall back to the flat algorithm, exactly."""
    x = np.random.RandomState(7).randn(4, 10).astype(np.float32)
    got = _run(mesh8,
               lambda v: ompccl.allreduce(v, DP, op="max",
                                          backend="hierarchical"),
               x, P(("pod", "data"), "model"), P(("pod", "data"), "model"))
    np.testing.assert_allclose(got, np.tile(x.max(0), (4, 1)), rtol=1e-6)


def test_hierarchical_flat_fastpath_matches_general(mesh8):
    """A 1-D fast-size-divisible payload (the gradient-bucket layout) takes
    the no-pad/no-reshape fast path and must agree with the general path
    and the flat psum."""
    x = np.random.RandomState(8).randn(4, 12).astype(np.float32)

    def f1d(v):  # local (1, 6) -> flat (6,), divisible by fast size 2
        return ompccl.allreduce(v.reshape(-1), DP,
                                backend="hierarchical").reshape(v.shape)

    spec = P(("pod", "data"), "model")
    got_fast = _run(mesh8, f1d, x, spec, spec)
    got_gen = _run(mesh8,
                   lambda v: ompccl.allreduce(v, DP, backend="hierarchical"),
                   x, spec, spec)
    got_flat = _run(mesh8, lambda v: ompccl.allreduce(v, DP), x, spec, spec)
    np.testing.assert_allclose(got_fast, got_gen, rtol=1e-6)
    np.testing.assert_allclose(got_fast, got_flat, rtol=1e-5)


def test_hierarchical_rs_ag_pair_roundtrip(mesh8):
    """The hierarchical backend's reduce-scatter (fast-axes-first, so the
    slow link only carries the 1/F shard) and invariant all-gather are
    mutually inverse through one handle: RS -> AG == the flat psum — the
    contract the bucketed backward-overlap path relies on."""
    x = np.random.RandomState(9).randn(4, 8).astype(np.float32)

    def f(v):
        flat = v.reshape(-1)                      # (8,): 4-way group divides
        sh = ompccl.reducescatter(flat, DP, backend="hierarchical")
        full = ompccl.allgather(sh, DP, invariant=True,
                                backend="hierarchical")
        return full.reshape(v.shape)

    spec = P(("pod", "data"), "model")
    got = _run(mesh8, f, x, spec, spec)
    np.testing.assert_allclose(got, np.tile(x.sum(0), (4, 1)), rtol=1e-5)


def test_compressed_allreduce_accuracy(mesh8):
    x = np.random.RandomState(4).randn(4, 64).astype(np.float32)
    out, err = jax.jit(shard_map(
        lambda v: compression.compressed_allreduce(v, DP),
        mesh=mesh8, in_specs=P(("pod", "data"), "model"),
        out_specs=(P(("pod", "data"), "model"),) * 2))(x)
    want = np.tile(x.mean(0), (4, 1))
    rel = np.abs(np.asarray(out) - want).max() / np.abs(want).max()
    assert rel < 0.02                       # int8 quantization error bound
    # error feedback residual bounded by a quantization step
    assert np.abs(np.asarray(err)).max() <= np.abs(x).max() / 127 + 1e-6


def test_error_feedback_converges(mesh8):
    """Repeated compressed reductions of the SAME gradient with error
    feedback must converge to the true mean (Karimireddy et al.)."""
    x = np.random.RandomState(5).randn(4, 32).astype(np.float32)

    def f(v):
        err = jnp.zeros_like(v)
        acc = jnp.zeros_like(v)
        for _ in range(8):
            out, err = compression.compressed_allreduce(v + err - err, DP,
                                                        error=err)
            acc = acc + out
        return acc / 8

    got = np.asarray(jax.jit(shard_map(
        f, mesh=mesh8, in_specs=P(("pod", "data"), "model"),
        out_specs=P(("pod", "data"), "model")))(x))
    want = np.tile(x.mean(0), (4, 1))
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 5e-3


def test_wire_bytes_model():
    assert compression.wire_bytes(1000, codec="int8") == 1004
    assert compression.wire_bytes(1000, codec="f32") == 4000
    assert compression.wire_bytes(1000, codec="topk", k=10) == 80


def test_interpod_traffic_model():
    flat = hierarchical.inter_pod_traffic_bytes(1 << 20, 16, 2,
                                                hierarchical=False)
    hier = hierarchical.inter_pod_traffic_bytes(1 << 20, 16, 2,
                                                hierarchical=True)
    # flat: 2B·(31/32) on every link; hier inter-pod: 2·(B/16)·(1/2) = B/16
    assert flat / hier == pytest.approx(31.0, rel=1e-6)


def test_ompx_api_surface(ring8):
    """The paper's verbatim ompx_* API (core/ompx.py) works end to end."""
    from repro.core import ompx

    g = ompx.ompx_group_t(("x",), name="ring")
    x = np.arange(16, dtype=np.float32).reshape(8, 2)

    def listing1(v):
        moved = ompx.ompx_put(v, g, shift=1)          # paper Listing 1
        moved = ompx.ompx_fence(moved)
        total = ompx.ompx_allreduce(v, g)
        root = ompx.ompx_bcast(v, g, root=2)
        return moved, total, root

    moved, total, root = jax.jit(shard_map(
        listing1, mesh=ring8, in_specs=P("x"),
        out_specs=(P("x"),) * 3))(x)
    np.testing.assert_allclose(np.asarray(moved)[:, 0],
                               np.roll(x[:, 0], 1))
    np.testing.assert_allclose(np.asarray(total),
                               np.tile(x.sum(0), (8, 1)))
    np.testing.assert_allclose(np.asarray(root), np.tile(x[2], (8, 1)))
    w = ompx.ompx_group_world(ring8)
    assert ompx.ompx_group_merge(
        *w.split("x")[::-1]).axes == ("x",)

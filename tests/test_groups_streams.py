"""DiOMP groups (split/merge/descriptors) + stream-pool policy + RMA rules."""

import threading
import time

import pytest

from repro.core.groups import DiompGroup, GroupError, merge, world_group
from repro.core.rma import RMAError, RMATracker
from repro.core.streams import HybridPoller, Stream, StreamPool


def test_group_split_merge(mesh8):
    w = world_group(mesh8)
    tp, rest = w.split("model")
    assert tp.axes == ("model",) and rest.axes == ("pod", "data")
    assert merge(rest, tp).axes == ("pod", "data", "model")
    with pytest.raises(GroupError):
        merge(tp, tp)
    with pytest.raises(GroupError):
        w.split("nonexistent")
    assert w.axis_size(mesh8) == 8 and tp.axis_size(mesh8) == 2


def test_group_descriptor_stable(mesh8):
    a = DiompGroup(("model",))
    b = DiompGroup(("model",))
    assert a.descriptor() == b.descriptor()      # UniqueID handshake agrees
    assert a.descriptor() != DiompGroup(("data",)).descriptor()


def test_group_duplicate_axis_rejected():
    with pytest.raises(GroupError):
        DiompGroup(("model", "model"))


def test_stream_pool_reuse_and_bound():
    pool = StreamPool(max_active=2)
    futs = [pool.submit(lambda i=i: i * i) for i in range(20)]
    assert [f.result() for f in futs] == [i * i for i in range(20)]
    # bounded: lazily created streams never exceeded the cap by much
    assert pool.stats["created"] <= 2 + pool.stats["partial_syncs"]
    assert pool.stats["reused"] > 0
    pool.close()


def test_stream_pool_partial_sync_under_pressure():
    pool = StreamPool(max_active=2)
    blocker = threading.Event()
    slow = pool.submit(lambda: blocker.wait(5))
    for _ in range(4):
        pool.submit(time.sleep, 0.001)
    assert pool.stats["partial_syncs"] >= 1
    blocker.set()
    pool.close()


def test_stream_ids_unique_under_concurrent_creation():
    """Stream._ids is shared class state: racing constructors must never
    mint duplicate sids (regression for the unguarded counter)."""
    streams, lock = [], threading.Lock()

    def mk():
        mine = [Stream() for _ in range(25)]
        with lock:
            streams.extend(mine)

    ts = [threading.Thread(target=mk) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    try:
        sids = [s.sid for s in streams]
        assert len(set(sids)) == len(sids)
    finally:
        for s in streams:
            s.close()


def test_stream_pool_concurrent_submit_release():
    """Hammer acquire/submit/release from many threads: the partial-sync
    path drops the pool lock mid-flight, and a concurrent release() used to
    be able to pull the synced stream out from under it."""
    pool = StreamPool(max_active=2)
    errs = []

    def worker(k):
        try:
            futs = [pool.submit(lambda i=i: i * i + k) for i in range(30)]
            assert [f.result() for f in futs] == [i * i + k for i in range(30)]
        except BaseException as e:  # noqa: BLE001 - surfaced via errs
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs
    pool.synchronize_all()
    with pool._lock:
        # invariants survived the stampede: disjoint lists, bound respected
        assert not set(pool._active) & set(pool._idle)
    pool.close()


def test_stream_pool_release_during_partial_sync():
    """Directed race: thread A blocks in partial sync on the oldest stream
    while thread B releases that very stream; A must neither crash nor
    corrupt the pool."""
    pool = StreamPool(max_active=1)
    gate = threading.Event()
    s = pool.acquire()
    fut = s.submit(gate.wait, 5)
    errs = []

    def acquirer():
        try:
            s2 = pool.acquire()      # bound hit -> partial sync on ``s``
            pool.release(s2)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    t = threading.Thread(target=acquirer)
    t.start()
    time.sleep(0.05)                 # let A block inside the sync
    gate.set()                       # s finishes...
    fut.result()
    pool.release(s)                  # ...and B releases it concurrently
    t.join(timeout=5)
    assert not t.is_alive()
    assert not errs, errs
    with pool._lock:
        assert not set(pool._active) & set(pool._idle)
    pool.close()


def test_hybrid_poller_fence():
    done = {"a": False, "b": False}
    p = HybridPoller(interval_s=1e-4)
    p.register(lambda: done["a"])
    p.register(lambda: done["b"])
    threading.Timer(0.02, lambda: done.update(a=True)).start()
    threading.Timer(0.04, lambda: done.update(b=True)).start()
    p.fence(timeout_s=2)
    assert p.polls >= 2


def test_hybrid_poller_timeout():
    p = HybridPoller(interval_s=1e-4)
    p.register(lambda: False)
    with pytest.raises(TimeoutError):
        p.fence(timeout_s=0.05)


def test_rma_tracker_discipline():
    t = RMATracker()
    t.register("win")
    t.on_put("win")
    with pytest.raises(RMAError):
        t.on_read("win")             # read before fence: the bug class
    t.on_fence("win")
    t.on_read("win")                 # fine after the fence
    with pytest.raises(RMAError):
        t.on_put("nope")

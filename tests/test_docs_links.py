"""Tier-1 wrapper around the CI docs link checker: a dead relative link in
docs/*.md, the root *.md files, or an example/serve docstring fails here
before it fails the CI "Docs link check" step."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))

import check_docs_links  # noqa: E402


def test_no_dead_doc_links():
    assert check_docs_links.check() == []


def test_required_docs_exist():
    root = pathlib.Path(check_docs_links.ROOT)
    for name in ("docs/ARCHITECTURE.md", "docs/SERVING.md", "docs/API.md",
                 "docs/PERF.md", "README.md"):
        assert (root / name).exists(), name

"""tools/check_bench_regression.py — the bench-smoke CI gate's logic."""

import sys

sys.path.insert(0, "tools")

from check_bench_regression import compare, row_key  # noqa: E402


def _rows(step):
    return [{"devices": 8, "mode": "fused", "wall_s": 0.5,
             "modeled_step_s": step, "modeled_overlap": True}]


def test_identical_summaries_pass():
    regs, notes = compare({"m": _rows(0.01)}, {"m": _rows(0.01)}, 0.05)
    assert regs == [] and notes == []


def test_wall_noise_is_ignored():
    fresh = _rows(0.01)
    fresh[0]["wall_s"] = 99.0                  # machine noise: not identity,
    regs, _ = compare({"m": _rows(0.01)}, {"m": fresh}, 0.05)
    assert regs == []                          # not a comparison target


def test_modeled_regression_beyond_tol_fails():
    regs, _ = compare({"m": _rows(0.010)}, {"m": _rows(0.012)}, 0.05)
    assert len(regs) == 1 and "modeled_step_s" in regs[0]
    # within tolerance (and any speedup) passes
    regs, _ = compare({"m": _rows(0.010)}, {"m": _rows(0.0104)}, 0.05)
    assert regs == []
    regs, _ = compare({"m": _rows(0.010)}, {"m": _rows(0.002)}, 0.05)
    assert regs == []


def test_higher_is_better_rates_fail_on_decrease():
    def rows(rps):
        return [{"mode": "slo", "seed": 17, "wall_s": 0.5,
                 "modeled_goodput_rps": rps}]
    # a >tol drop in a rate field is a regression
    regs, _ = compare({"o": rows(10.0)}, {"o": rows(9.0)}, 0.05)
    assert len(regs) == 1 and "modeled_goodput_rps" in regs[0]
    # within tolerance, and any increase, passes
    regs, _ = compare({"o": rows(10.0)}, {"o": rows(9.6)}, 0.05)
    assert regs == []
    regs, _ = compare({"o": rows(10.0)}, {"o": rows(14.0)}, 0.05)
    assert regs == []


def test_rate_fields_are_compared_not_identity():
    a = {"mode": "slo", "modeled_goodput_rps": 10.0}
    b = dict(a, modeled_goodput_rps=3.0)
    assert row_key(a) == row_key(b)


def test_new_rows_and_benches_note_but_pass():
    fresh = {"m": _rows(0.01) + [{"devices": 16, "mode": "fused",
                                  "modeled_step_s": 1.0}],
             "new_bench": _rows(5.0)}
    regs, notes = compare({"m": _rows(0.01), "gone": _rows(0.1)}, fresh, 0.05)
    assert regs == []
    assert len(notes) == 3                     # new row, new bench, gone bench


def test_row_key_excludes_volatile_and_compared_fields():
    a = _rows(0.01)[0]
    b = dict(a, wall_s=123.0, modeled_step_s=9.9)
    assert row_key(a) == row_key(b)
    assert row_key(a) != row_key(dict(a, mode="host"))

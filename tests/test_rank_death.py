"""Rank death: serving degradation + elastic training restore.

docs/RESILIENCE.md lifecycle under test:

* **graceful** (the rank announces eviction): every page homed there is
  drained to survivors over the one-sided migrate path; all in-flight
  requests complete with outputs identical to an undisturbed engine;
* **abrupt** (the rank vanishes): its pages are gone — active requests
  requeue and regenerate from scratch, deterministically reproducing the
  undisturbed outputs (temperature-0 decode); the page ledger stays
  balanced (lost pages are accounted, never leaked);
* the scheduler's rank set shrinks and latency stats keep flowing;
* the trainer survives a mid-run death: the straggler monitor escalates,
  the driver checkpoints, shrinks the mesh, restores, and the final loss
  matches the uninterrupted run.
"""

import numpy as np
import jax
import pytest

from repro import configs
from repro.core.context import DiompContext
from repro.core.faults import FaultPlan
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine

CFG = configs.get_reduced("stablelm-3b")
LENGTHS = (5, 9, 13)
MAX_NEW = 6


@pytest.fixture(scope="module")
def params():
    return sch.init_params(CFG, jax.random.PRNGKey(0))


def _engine(mesh8, params, fault_plan=None, **kw):
    pctx = ParallelCtx.from_mesh(mesh8, remat=False, inference=True)
    dctx = DiompContext(mesh=mesh8, segment_bytes=1 << 26, allocator="buddy",
                        fault_plan=fault_plan or FaultPlan(0, p=0.0))
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 4)
    return ServeEngine(CFG, mesh8, pctx, params, context=dctx, **kw)


def _prompts():
    rng = np.random.RandomState(7)
    return [rng.randint(0, CFG.vocab_size, size=n).astype(np.int32)
            for n in LENGTHS]


def _reference_outs(mesh8, params):
    eng = _engine(mesh8, params)
    reqs = [eng.submit(p, max_new=MAX_NEW) for p in _prompts()]
    eng.run()
    return [r.out for r in reqs]


def test_graceful_death_drains_pages_and_completes(mesh8, params):
    # the plan schedules the controller rank's death mid-decode; active
    # requests home their pages on rank 0, so the drain path is exercised
    plan = FaultPlan(0, p=0.0).kill_rank(6, rank=0, graceful=True)
    eng = _engine(mesh8, params, fault_plan=plan)
    reqs = [eng.submit(p, max_new=MAX_NEW) for p in _prompts()]
    eng.run()

    assert all(r.done and len(r.out) == MAX_NEW for r in reqs)
    assert [r.out for r in reqs] == _reference_outs(mesh8, params)

    st = eng.latency_stats()
    assert st["rank_deaths"] == 1
    assert st["live_ranks"] == eng.memory.nranks - 1
    (step, rank, graceful, drained, lost), = eng.rank_death_log
    assert step == 6 and rank == 0 and graceful
    assert drained > 0 and lost == 0           # pages moved, nothing dropped
    # ledger balanced: every allocated page was freed, none leaked
    kv = eng.kv_stats
    assert kv["pages_allocated"] == kv["pages_freed"] > 0
    assert kv["pages_lost"] == 0
    assert plan.deaths_at(6) == []             # the death fired exactly once


def test_abrupt_death_requeues_and_reproduces_outputs(mesh8, params):
    eng = _engine(mesh8, params)
    reqs = [eng.submit(p, max_new=MAX_NEW) for p in _prompts()]
    for _ in range(5):
        eng.step()
    homed = [r for r in eng.active.values()
             if r.kv is not None and r.kv.home_rank == 0 and r.kv.page_table]
    assert homed                               # the death actually costs us

    eng.on_rank_death(0, graceful=False)
    eng.run()

    assert all(r.done and len(r.out) == MAX_NEW for r in reqs)
    # requeued requests regenerate from scratch — deterministically
    assert [r.out for r in reqs] == _reference_outs(mesh8, params)
    st = eng.latency_stats()
    assert st["requeued"] >= len(homed)
    assert st["rank_deaths"] == 1
    assert st["live_ranks"] == eng.memory.nranks - 1
    kv = eng.kv_stats
    assert kv["pages_lost"] > 0                # the loss is visible...
    assert kv["pages_allocated"] == kv["pages_freed"]   # ...and accounted


def test_dead_rank_leaves_scheduling_rotation(mesh8, params):
    eng = _engine(mesh8, params)
    n = eng.memory.nranks
    eng.on_rank_death(2)
    assert eng._live_ranks() == [r for r in range(n) if r != 2]
    assert eng._home(0) == 0
    eng.on_rank_death(0)
    assert eng._home(0) == 1                   # controller moves to lowest live
    eng.on_rank_death(2)                       # idempotent: already dead
    assert eng.latency_stats()["rank_deaths"] == 2


def test_last_live_rank_is_protected(mesh8, params):
    eng = _engine(mesh8, params)
    for r in range(eng.memory.nranks - 1):
        eng.on_rank_death(r)
    with pytest.raises(RuntimeError, match="last live rank"):
        eng.on_rank_death(eng.memory.nranks - 1)


# ---------------------------------------------------------------------------
# training: death -> escalate -> checkpoint -> shrink -> restore
# ---------------------------------------------------------------------------

def test_elastic_restore_matches_uninterrupted_loss(tmp_path):
    from repro.launch.train import main
    common = ["--arch", "stablelm-3b", "--reduced", "--steps", "6",
              "--batch", "4", "--seq", "16", "--checkpoint-every", "2"]
    want = main(common + ["--checkpoint-dir", str(tmp_path / "a")])
    got = main(common + ["--checkpoint-dir", str(tmp_path / "b"),
                         "--chaos-seed", "5", "--chaos-p", "0.0",
                         "--kill-rank-step", "3", "--max-restarts", "1"])
    # the restored run replays the same data from the checkpoint on the
    # shrunken mesh; only reduction order differs
    assert np.isclose(got, want, atol=5e-2), (got, want)

"""Property-based halo-exchange tests (via tests/_minihyp.py).

``rma.halo_exchange`` over random (halo, axis, shard-size, backend) tuples:

* interior ranks receive exactly the neighbors' boundary slabs;
* edge ranks receive zeros (non-periodic boundaries);
* ``halo`` exceeding the local shard raises a clear ``RMAError`` instead
  of silently wrapping neighbor-of-neighbor data;
* every put is fenced before read — the RMATracker's epoch discipline
  holds after each exchange, and the misuse (read with an un-fenced put
  outstanding) raises.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _minihyp import given, settings, st

from repro.core import rma
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import RMAError, RMATracker, halo_window_names

NDEV = 4
GROUP = DiompGroup(("x",), name="halo-ring")
BACKENDS = ("xla", "hierarchical")


def _run_exchange(per: int, halo: int, axis: int, backend: str):
    """Returns (left, right, local shards, ctx) of one jitted exchange."""
    mesh = make_mesh((NDEV,), ("x",), axis_types="auto")
    shape = [3, 5]
    shape.insert(axis, NDEV * per)
    x = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    spec = [None, None]
    spec.insert(axis, "x")

    def ex(a):
        return rma.halo_exchange(a, GROUP, halo=halo, axis=axis,
                                 backend=backend)

    ctx = DiompContext(mesh=mesh)
    with use_default(ctx):
        f = jax.jit(shard_map(ex, mesh=mesh, in_specs=(P(*spec),),
                              out_specs=(P(*spec), P(*spec))))
        left, right = f(x)
    shards = np.split(x, NDEV, axis=axis)
    return np.asarray(left), np.asarray(right), shards, ctx


@settings(max_examples=15, deadline=None)
@given(st.tuples(st.integers(1, 8), st.integers(0, 1),
                 st.integers(1, 6), st.integers(0, len(BACKENDS) - 1)))
def test_halo_exchange_properties(case):
    halo, axis, per, bidx = case
    backend = BACKENDS[bidx]
    if halo > per:
        # over-wide halo must fail loudly, not wrap around the ring
        with pytest.raises(RMAError):
            _run_exchange(per, halo, axis, backend)
        return
    left, right, shards, ctx = _run_exchange(per, halo, axis, backend)
    lefts = np.split(left, NDEV, axis=axis)
    rights = np.split(right, NDEV, axis=axis)
    for r in range(NDEV):
        if r == 0:     # edge ranks receive zeros (the paper's rank guards)
            assert not lefts[r].any()
        else:          # interior: exactly the left neighbor's hi slab
            want = np.take(shards[r - 1], range(per - halo, per), axis=axis)
            np.testing.assert_array_equal(lefts[r], want)
        if r == NDEV - 1:
            assert not rights[r].any()
        else:
            want = np.take(shards[r + 1], range(0, halo), axis=axis)
            np.testing.assert_array_equal(rights[r], want)
    # epoch discipline: both windows saw a put, a fence, then the read —
    # nothing left dirty, and the byte accounting matches the slab size
    lo_w, hi_w = halo_window_names(GROUP, axis)
    slab = shards[0].size // per * halo * 4
    assert ctx.rma.puts == 2 and ctx.rma.fences == 1
    assert ctx.rma.window_bytes[lo_w] == ctx.rma.window_bytes[hi_w] == slab
    for w in (lo_w, hi_w):
        ctx.rma.on_read(w)      # a clean window reads without raising


def test_unfenced_read_raises():
    """The discipline the windows enforce: put -> read without a fence is
    exactly the bug class ompx_fence exists to prevent."""
    tr = RMATracker()
    tr.ensure("w")
    tr.on_put("w", 128)
    with pytest.raises(RMAError):
        tr.on_read("w")
    tr.on_fence("w")
    tr.on_read("w")             # fenced: fine
    assert tr.put_bytes == 128


def test_halo_exchange_validates_before_any_put():
    """A rejected exchange must not leave dirty windows behind."""
    mesh = make_mesh((NDEV,), ("x",), axis_types="auto")
    x = np.zeros((NDEV * 2, 3), np.float32)
    ctx = DiompContext(mesh=mesh)
    with use_default(ctx):
        with pytest.raises(RMAError):
            shard_map(lambda a: rma.halo_exchange(a, GROUP, halo=5, axis=0),
                      mesh=mesh, in_specs=(P("x", None),),
                      out_specs=(P("x", None), P("x", None)))(x)
    assert ctx.rma.puts == 0    # validation fired before any recording


def test_halo_zero_raises():
    mesh = make_mesh((NDEV,), ("x",), axis_types="auto")
    x = np.zeros((NDEV * 2, 3), np.float32)
    with use_default(DiompContext(mesh=mesh)):
        with pytest.raises(RMAError):
            shard_map(lambda a: rma.halo_exchange(a, GROUP, halo=0, axis=0),
                      mesh=mesh, in_specs=(P("x", None),),
                      out_specs=(P("x", None), P("x", None)))(x)

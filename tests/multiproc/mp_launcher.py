"""Subprocess launcher + tmpdir result rendezvous for the harness.

``launch(cases, num_processes, ndev_per_proc)`` spawns ``num_processes``
copies of :mod:`mp_worker` (each a REAL operating-system process with its
own jax runtime and device visibility), pointed at a freshly-bound
coordinator port on localhost.  Results rendezvous through per-process
JSON files in a scratch directory; the launcher reaps every worker, maps
the exit-code protocol (77 = infrastructure unavailable -> the caller
skips) and returns the parsed, process-indexed result list.

Environment contract:

* each worker gets its own ``XLA_FLAGS`` (the launcher strips any
  inherited forced-device-count so a worker only ever sees
  ``ndev_per_proc`` devices) and ``PYTHONPATH=src``;
* ambient ``DIOMP_CHAOS_*`` is stripped — the harness arms chaos
  explicitly via ``chaos_seed`` so calm runs stay calm even under a
  chaos-armed outer CI job;
* ``DIOMP_MULTIPROC=0`` is the kill switch (everything skips);
* ``DIOMP_MULTIPROC_ARTIFACTS`` redirects scratch dirs somewhere
  CI can upload on failure.
"""

import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
WORKER = Path(__file__).with_name("mp_worker.py")
INFRA_EXIT = 77
DEFAULT_TIMEOUT_S = 600


class MultiprocUnavailable(Exception):
    """Multi-process execution can't run here; tests should skip."""


class WorkerFailure(AssertionError):
    """A worker failed for real (nonzero, non-77 exit or timeout)."""


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _scratch_dir(tag):
    root = os.environ.get("DIOMP_MULTIPROC_ARTIFACTS")
    if root:
        d = Path(root) / tag
        d.mkdir(parents=True, exist_ok=True)
        return d
    return Path(tempfile.mkdtemp(prefix=f"diomp-mp-{tag}-"))


def _worker_env(chaos_seed):
    env = os.environ.copy()
    # device visibility is the worker's own: never inherit a forced count
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for k in ("DIOMP_CHAOS_SEED", "DIOMP_CHAOS_P", "DIOMP_CHAOS_KINDS",
              "DIOMP_CHAOS_VERBS"):
        env.pop(k, None)
    if chaos_seed is not None:
        env["DIOMP_CHAOS_SEED"] = str(chaos_seed)
        env["DIOMP_CHAOS_P"] = os.environ.get("DIOMP_MP_CHAOS_P", "0.15")
        env["DIOMP_CHAOS_KINDS"] = "drop,fail"
    return env


def _tail(path, lines=40):
    try:
        text = Path(path).read_text(errors="replace").splitlines()
        return "\n".join(text[-lines:])
    except OSError:
        return "<no log>"


def launch(cases, num_processes, ndev_per_proc, *, chaos_seed=None,
           timeout=DEFAULT_TIMEOUT_S, tag=None):
    """Run ``cases`` under ``num_processes`` x ``ndev_per_proc`` devices.

    Returns ``[result_0, ..., result_{n-1}]`` (one parsed JSON dict per
    process).  Raises :class:`MultiprocUnavailable` when the run cannot
    happen here (caller skips) and :class:`WorkerFailure` with the log
    tails when a worker genuinely failed.
    """
    if os.environ.get("DIOMP_MULTIPROC", "1") == "0":
        raise MultiprocUnavailable("disabled via DIOMP_MULTIPROC=0")
    tag = tag or (f"{num_processes}x{ndev_per_proc}"
                  + ("-chaos" if chaos_seed is not None else ""))
    outdir = _scratch_dir(tag)
    port = _free_port()
    env = _worker_env(chaos_seed)
    procs, logs = [], []
    for pid in range(num_processes):
        log_path = outdir / f"proc{pid}.log"
        log = open(log_path, "w")
        procs.append(subprocess.Popen(
            [sys.executable, str(WORKER),
             "--coordinator", f"127.0.0.1:{port}",
             "--num-processes", str(num_processes),
             "--process-id", str(pid),
             "--ndev-per-proc", str(ndev_per_proc),
             "--cases", ",".join(cases),
             "--out", str(outdir / f"result{pid}.json")],
            env=env, cwd=str(REPO), stdout=log,
            stderr=subprocess.STDOUT))
        logs.append((log, log_path))

    deadline = time.monotonic() + timeout
    rcs = []
    try:
        for p in procs:
            rcs.append(p.wait(timeout=max(1.0,
                                          deadline - time.monotonic())))
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for p in procs:
            p.wait()
        tails = "\n".join(f"--- proc{i} ---\n{_tail(lp)}"
                          for i, (_, lp) in enumerate(logs))
        raise WorkerFailure(
            f"harness run {tag} timed out after {timeout}s\n{tails}")
    finally:
        for log, _ in logs:
            log.close()

    if any(rc == INFRA_EXIT for rc in rcs):
        raise MultiprocUnavailable(
            f"run {tag}: workers reported infra-unavailable "
            f"(exit codes {rcs}); last log:\n{_tail(logs[0][1])}")
    if any(rc != 0 for rc in rcs):
        tails = "\n".join(f"--- proc{i} (exit {rcs[i]}) ---\n{_tail(lp)}"
                          for i, (_, lp) in enumerate(logs))
        raise WorkerFailure(f"harness run {tag} failed\n{tails}")

    results = []
    for pid in range(num_processes):
        path = outdir / f"result{pid}.json"
        if not path.exists():
            raise WorkerFailure(
                f"run {tag}: proc{pid} exited 0 without writing {path}")
        with open(path) as fh:
            results.append(json.load(fh))
    return results

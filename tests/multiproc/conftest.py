"""Session fixtures: one harness launch per topology, shared by tests.

Each fixture is a full multi-process run (real OS processes, each with
its own jax runtime) of the same SPMD worker program; tests then diff
the per-process JSON results.  Launches are cached for the session and
an infra-unavailable outcome (worker exit 77, e.g. a sandbox that
forbids localhost sockets) turns into a skip, so the tier-1 suite
degrades gracefully instead of failing on machines that cannot fork
a jax.distributed job.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))

import mp_launcher  # noqa: E402

COMPUTE_CASES = ["pgas", "ring_matmul", "minimod", "moe_dispatch",
                 "ring_attention", "grad_buckets", "determinism"]
CHAOS_SEED = 1234

_cache = {}


def _run(key, **kw):
    if key not in _cache:
        try:
            _cache[key] = mp_launcher.launch(**kw)
        except mp_launcher.MultiprocUnavailable as e:
            _cache[key] = e
    val = _cache[key]
    if isinstance(val, mp_launcher.MultiprocUnavailable):
        pytest.skip(f"multi-process harness unavailable: {val}")
    return val


@pytest.fixture(scope="session")
def baseline():
    """Single process, 4 virtual devices — today's tier-1 topology."""
    return _run("1x4", cases=COMPUTE_CASES, num_processes=1,
                ndev_per_proc=4, tag="1x4")


@pytest.fixture(scope="session")
def two_proc():
    """2 real processes x 2 devices each (same 4 global devices)."""
    return _run("2x2", cases=COMPUTE_CASES, num_processes=2,
                ndev_per_proc=2, tag="2x2")


@pytest.fixture(scope="session")
def four_proc():
    """4 real processes x 1 device each — every rank a separate host."""
    return _run("4x1", cases=COMPUTE_CASES, num_processes=4,
                ndev_per_proc=1, tag="4x1")


@pytest.fixture(scope="session")
def chaos_two():
    """2x2 with DIOMP_CHAOS_* armed in the workers' environment."""
    return _run("2x2-chaos", cases=["chaos_ring"], num_processes=2,
                ndev_per_proc=2, chaos_seed=CHAOS_SEED, tag="2x2-chaos")

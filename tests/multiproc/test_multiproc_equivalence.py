"""Cross-process equivalence: the tier-1 suites re-run under REAL
multi-process SPMD must agree bitwise with the single-process run.

The contract (ISSUE PR 10 / paper §3): DiOMP programs are written once
and run at any process count — so ring matmul, the Minimod halo stencil,
MoE dispatch and ring attention must produce byte-for-byte identical
outputs at 1x4, 2x2 and 4x1 (processes x devices), the PGAS mapping
table must be globally consistent, and the per-process OMPCCL/RMA logs
must agree rank-against-rank within a run AND hold the same logical
content across runs (``logical_digest``).
"""

import json

import pytest

pytestmark = pytest.mark.multiproc

# suites whose outputs the paper-contract pins BITWISE across topologies
BITWISE_CASES = ["ring_matmul", "moe_dispatch", "ring_attention"]


def _cases(results, pid=0):
    return results[pid]["cases"]


def _strip_pid(result):
    return json.dumps({k: v for k, v in result.items()
                       if k != "process_id"}, sort_keys=True)


# ---------------------------------------------------------------------------
# the job really is multi-process
# ---------------------------------------------------------------------------


def test_topology(baseline, two_proc, four_proc):
    for results, procs, local in ((baseline, 1, 4), (two_proc, 2, 2),
                                  (four_proc, 4, 1)):
        assert len(results) == procs
        for r in results:
            assert r["num_processes"] == procs
            assert r["ndev_per_proc"] == local   # per-process visibility
            assert r["global_devices"] == 4      # same global machine


def test_every_process_reports_identical_results(two_proc, four_proc):
    """SPMD: modulo its process id, every process's full result blob —
    digests, logs, mapping tables — must be byte-identical."""
    for results in (two_proc, four_proc):
        blobs = {_strip_pid(r) for r in results}
        assert len(blobs) == 1


# ---------------------------------------------------------------------------
# bitwise output equivalence across process counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", BITWISE_CASES)
def test_two_process_bitwise(baseline, two_proc, case):
    assert _cases(two_proc)[case]["digests"] == \
        _cases(baseline)[case]["digests"]


@pytest.mark.parametrize("case", BITWISE_CASES)
def test_four_process_bitwise(baseline, four_proc, case):
    assert _cases(four_proc)[case]["digests"] == \
        _cases(baseline)[case]["digests"]


def test_minimod_bitwise(baseline, two_proc, four_proc):
    base = _cases(baseline)["minimod"]
    for results in (two_proc, four_proc):
        got = _cases(results)["minimod"]
        for tag in base:
            assert got[tag]["digest"] == base[tag]["digest"], tag
            assert got[tag]["z_extents"] == base[tag]["z_extents"], tag
            assert got[tag]["region_sizes"] == base[tag]["region_sizes"]


def test_in_run_oracle_agreement(baseline, two_proc, four_proc):
    """Within every run the fused/host impls match their oracles exactly
    (the tier-1 bit contracts survive the process split)."""
    for results in (baseline, two_proc, four_proc):
        c = _cases(results)
        assert c["ring_matmul"]["fused_eq_ref"]
        assert c["ring_matmul"]["digests"]["host"] == \
            c["ring_matmul"]["digests"]["ref"]
        assert c["moe_dispatch"]["fused_eq_ref"]
        assert c["moe_dispatch"]["host_eq_ref"]
        assert c["moe_dispatch"]["fused_dropped"] == 0.0
        assert c["ring_attention"]["fused_eq_ref"]
        assert c["ring_attention"]["host_eq_ref"]
        assert c["minimod"]["fused"]["digest"] == \
            c["minimod"]["host"]["digest"]


# ---------------------------------------------------------------------------
# log parity: rank-vs-rank within a run, logical across runs
# ---------------------------------------------------------------------------


def test_rank_vs_rank_log_parity(baseline, two_proc, four_proc):
    """ctx.gather_stats() rows must be identical on every rank: same
    call counts, byte counts, tracker totals, PGAS regions."""
    for results in (baseline, two_proc, four_proc):
        for case, c in _cases(results).items():
            if "rank_parity" in c:
                assert c["rank_parity"], case


def test_logical_logs_identical_across_process_counts(
        baseline, two_proc, four_proc):
    base = _cases(baseline)
    for results in (two_proc, four_proc):
        got = _cases(results)
        for case in base:
            if "logical_digest" in base[case]:
                assert got[case]["logical_digest"] == \
                    base[case]["logical_digest"], case


def test_ompccl_vs_tracker_byte_parity(baseline, two_proc, four_proc):
    """The OMPCCL put byte log equals the RMATracker window totals for
    every windowed suite, in every topology.  Minimod pins parity on the
    fused paths (tier-1's contract; the serialized host listing keeps
    separate books) — and every parity flag, true or false, must agree
    across process counts."""
    base_flags = None
    for results in (baseline, two_proc, four_proc):
        c = _cases(results)
        for case in ("moe_dispatch", "ring_attention", "grad_buckets",
                     "pgas"):
            assert c[case]["byte_parity"], case
        for tag in ("fused", "weighted"):
            assert c["minimod"][tag]["byte_parity"], tag
        flags = {case: r.get("byte_parity") for case, r in c.items()}
        flags["minimod"] = {t: r["byte_parity"]
                            for t, r in c["minimod"].items()}
        if base_flags is None:
            base_flags = flags
        assert flags == base_flags


# ---------------------------------------------------------------------------
# PGAS mapping table + bucketed reduce
# ---------------------------------------------------------------------------


def test_pgas_mapping_table_globally_consistent(baseline, two_proc,
                                                four_proc):
    base = _cases(baseline)["pgas"]
    assert base["sym_b_offsets_identical"]
    assert base["oversize_raises"]
    for results in (two_proc, four_proc):
        got = _cases(results)["pgas"]
        # coordinated allocation lands the identical table at any scale:
        # same regions, same per-rank extents, same offsets
        assert got["table"] == base["table"]
        assert got["table_digest"] == base["table_digest"]
        assert got["alloc_counts"] == base["alloc_counts"]
        assert got["sym_b_offsets_identical"]
        assert got["oversize_raises"]


def test_grad_buckets_match_across_process_counts(baseline, two_proc,
                                                  four_proc):
    base = _cases(baseline)["grad_buckets"]
    assert base["bk_matches_perparam"]
    assert base["n_allreduce_bk"] == base["n_buckets"]
    assert base["n_allreduce_bk"] < base["n_allreduce_pp"]
    for results in (two_proc, four_proc):
        got = _cases(results)["grad_buckets"]
        assert got["bk_matches_perparam"]
        # identical collective schedule at any process count
        assert got["n_allreduce_bk"] == base["n_allreduce_bk"]
        assert got["n_allreduce_pp"] == base["n_allreduce_pp"]
        # reduced grads agree to the bit on this stack (and at minimum to
        # fp32 tolerance, which the sums re-check if digests ever drift)
        assert got["digest"] == base["digest"]
        for name, want in base["sums"].items():
            assert got["sums"][name] == pytest.approx(want, rel=1e-6,
                                                      abs=1e-4), name

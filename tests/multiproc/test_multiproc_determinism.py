"""Seeded substrates are process-count invariant.

FaultPlan schedules, the sha256-derived RNG streams and the serving
arrival traces all feed "deterministic" claims elsewhere in the repo;
here we pin that determinism ACROSS PROCESS BOUNDARIES: every process of
every topology derives the identical streams (no reliance on Python
hash randomization, process ids, or time).
"""

import pytest

pytestmark = pytest.mark.multiproc

DIGESTS = ("fault_digest", "rng_digest", "trace_digest")


def test_determinism_digests_match_across_runs(baseline, two_proc,
                                               four_proc):
    base = baseline[0]["cases"]["determinism"]
    for results in (two_proc, four_proc):
        got = results[0]["cases"]["determinism"]
        for key in DIGESTS:
            assert got[key] == base[key], key
        assert got["injected_counts"] == base["injected_counts"]


def test_determinism_digests_match_across_ranks(two_proc, four_proc):
    for results in (two_proc, four_proc):
        rows = [r["cases"]["determinism"] for r in results]
        for key in DIGESTS:
            assert len({row[key] for row in rows}) == 1, key


def test_fault_plan_actually_fired(baseline):
    """p=0.3 over 240 dispatches: the schedule must inject faults (the
    digest would trivially 'agree' on an empty stream)."""
    counts = baseline[0]["cases"]["determinism"]["injected_counts"]
    assert sum(counts.values()) > 0

"""SPMD worker: one process of the cross-process equivalence harness.

Launched by :mod:`mp_launcher` as ``python mp_worker.py --coordinator
host:port --num-processes N --process-id I ...`` — never imported by
pytest.  It joins the multi-controller job via
``diomp.init(coordinator=...)``, runs the requested equivalence cases
over the *global* device set, and writes a JSON result file whose
digests the host-side tests diff bitwise across runs with different
process counts (1x4 vs 2x2 vs 4x1).

Every case follows the same discipline:

* inputs are seeded numpy, built identically on every process (SPMD);
* outputs are materialized with
  :func:`repro.core.coordination.fetch_global` (bit-identical on every
  process even when the sharded array is not fully addressable) and
  reduced to sha256 digests;
* the OMPCCL call/byte logs, retry logs, RMA tracker counters and the
  PGAS mapping table are snapshotted via ``ctx.gather_stats()`` — a
  collective — and checked rank-against-rank (``rank_parity``), then
  digested for cross-run comparison (``logs_digest`` /
  ``logical_digest``, the latter excluding retry traffic so it is
  chaos-invariant).

Exit codes: 0 = all cases ran; 77 = the multi-process infrastructure is
unavailable (tests skip); anything else is a real failure.
"""

import argparse
import hashlib
import json
import os
import sys
import traceback

INFRA_EXIT = 77


# ---------------------------------------------------------------------------
# digest + log-snapshot helpers
# ---------------------------------------------------------------------------


def _digest(arr):
    import numpy as np

    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(repr(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _obj_digest(obj):
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True).encode("utf-8")).hexdigest()


def _log_report(ctx):
    """Collective log snapshot -> parity flag + cross-run digests."""
    rows = ctx.gather_stats()
    canon = json.loads(json.dumps(
        [{k: v for k, v in r.items() if k != "process_id"} for r in rows]))
    parity = all(r == canon[0] for r in canon)
    mine = canon[0]
    logical = {k: mine[k] for k in ("stats", "byte_stats", "rma", "pgas")}
    rma = {k: v for k, v in logical["rma"].items()
           if k not in ("retry_puts", "retry_bytes")}
    logical = dict(logical, rma=rma)
    ompccl_put_bytes = sum(
        int(d.get("put", 0)) for d in mine["byte_stats"].values())
    return {
        "rank_parity": parity,
        "logs_digest": _obj_digest(canon),
        "logical_digest": _obj_digest(logical),
        "retry_total": sum(sum(d.values()) for d in
                           mine["retry_stats"].values()),
        "ompccl_put_bytes": ompccl_put_bytes,
        "tracker_put_bytes": int(mine["rma"]["put_bytes"]),
        "byte_parity": ompccl_put_bytes == int(mine["rma"]["put_bytes"]),
    }


def _ring_mesh():
    import jax

    from repro.launch.mesh import make_process_mesh

    return make_process_mesh(shape=(jax.device_count(),), axes=("x",))


# ---------------------------------------------------------------------------
# cases
# ---------------------------------------------------------------------------


def _ring_matmul_payload(report_chaos):
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.context import DiompContext, use_default
    from repro.core.coordination import fetch_global
    from repro.core.groups import DiompGroup
    from repro.kernels.ring_matmul.ops import ring_allgather_matmul
    from repro.kernels.ring_matmul.ref import ring_allgather_matmul_ref

    n = jax.device_count()
    mesh = _ring_mesh()
    group = DiompGroup(("x",), name="ring")
    ctx = DiompContext(mesh=mesh, segment_bytes=1 << 20)
    rng = np.random.RandomState(0)
    A = rng.randn(4 * n, 24).astype(np.float32)
    B = rng.randn(24, 8 * n).astype(np.float32)
    out = {}
    with use_default(ctx):
        for impl in ("fused", "host"):
            f = jax.jit(shard_map(
                lambda a, b, impl=impl: ring_allgather_matmul(
                    a, b, group, impl=impl),
                mesh=mesh, in_specs=(P("x", None), P(None, "x")),
                out_specs=P(None, "x")))
            out[impl] = fetch_global(f(A, B))
        r = jax.jit(shard_map(
            lambda a, b: ring_allgather_matmul_ref(a, b, group),
            mesh=mesh, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        out["ref"] = fetch_global(r(A, B))
    rep = {"digests": {k: _digest(v) for k, v in out.items()},
           "fused_eq_ref": bool(np.array_equal(out["fused"], out["ref"])),
           **_log_report(ctx)}
    if report_chaos:
        fp = ctx.fault_plan
        rep["chaos"] = {
            "armed": fp is not None,
            "injected": dict(fp.injected_counts()) if fp else {},
            "injected_total": len(fp.injected) if fp else 0,
            "unrecovered": len(fp.unrecovered()) if fp else -1,
        }
    return rep


def case_ring_matmul():
    return _ring_matmul_payload(report_chaos=False)


def case_chaos_ring():
    """Same program as ring_matmul, run with DIOMP_CHAOS_* armed by the
    launcher; the host test diffs ``logical_digest`` against the calm
    run and asserts every injected fault was recovered."""
    return _ring_matmul_payload(report_chaos=True)


def case_minimod():
    import jax

    from repro.apps.minimod import run_minimod

    n = jax.device_count()
    runs = {
        "fused": dict(grid=(8 * n, 8, 16), mode="fused"),
        "host": dict(grid=(8 * n, 8, 16), mode="host"),
        # asymmetric decomposition: rank 0 owns a double-weight slab
        "weighted": dict(grid=(10 * n, 8, 16), mode="fused",
                         weights=tuple(2.0 if r == 0 else 1.0
                                       for r in range(n))),
    }
    out = {}
    for tag, kw in runs.items():
        grid = kw.pop("grid")
        r = run_minimod(grid=grid, steps=2, nz=n, ny=1, **kw)
        out[tag] = {
            "digest": _digest(r.field),
            "energy": float(r.energy),
            "z_extents": list(r.z_extents),
            "puts": int(r.puts),
            "put_bytes": int(r.put_bytes),
            "byte_parity": (r.puts == r.tracker_puts
                            and r.put_bytes == r.tracker_put_bytes),
            "region_sizes": list(r.region_sizes),
            "alloc_counts": dict(r.alloc_counts),
        }
    return out


def case_moe_dispatch():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.context import (DiompContext, default_context,
                                    use_default)
    from repro.core.coordination import fetch_global
    from repro.core.groups import DiompGroup
    from repro.kernels.moe_dispatch import (measure_expert_load,
                                            moe_dispatch, moe_ref,
                                            route_topk)
    from repro.kernels.plan import default_planner

    n = jax.device_count()
    mesh = _ring_mesh()
    group = DiompGroup(("x",), name="epx")
    ctx = DiompContext(mesh=mesh, segment_bytes=1 << 22)
    rng = np.random.RandomState(1)
    E, t_loc, d, f, k = 8, 8, 16, 16, 2
    toks = rng.randn(n * t_loc, d).astype(np.float32)
    router = (rng.randn(d, E) + 2.0 * rng.randn(1, E)).astype(np.float32)
    wg = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wu = (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wd = (rng.randn(E, f, d) / np.sqrt(f)).astype(np.float32)
    rep = {}
    with use_default(ctx):
        top_w, top_e = jax.jit(route_topk, static_argnums=2)(toks, router, k)
        loads = measure_expert_load(
            np.asarray(top_e).reshape(n, t_loc, k), E, sources=n)
        plan = default_planner().plan_alltoall(t_loc, d, k, E, n,
                                               jnp.float32, loads=loads)
        want = np.asarray(moe_ref(jnp.asarray(toks), top_e, top_w,
                                  jnp.asarray(wg), jnp.asarray(wu),
                                  jnp.asarray(wd)))
        rep["loads"] = [int(x) for x in loads]
        rep["digests"] = {"ref": _digest(want)}
        for impl in ("fused", "host"):
            def fn(tk, rt, g, u, dn, impl=impl):
                w, e = route_topk(tk, rt, k)
                with default_context().dispatch_stats.collect() as ds:
                    o = moe_dispatch(tk, e, w, g, u, dn, group,
                                     impl=impl, plan=plan)
                return o, ds["moe_dropped"].reshape(1)

            jf = jax.jit(shard_map(
                fn, mesh=mesh,
                in_specs=(P("x", None), P(None, None), P("x", None, None),
                          P("x", None, None), P("x", None, None)),
                out_specs=(P("x", None), P("x"))))
            o, dropped = jf(toks, router, wg, wu, wd)
            o = fetch_global(o)
            rep["digests"][impl] = _digest(o)
            rep[f"{impl}_eq_ref"] = bool(np.array_equal(o, want))
            rep[f"{impl}_dropped"] = float(
                np.asarray(fetch_global(dropped)).sum())
    rep.update(_log_report(ctx))
    return rep


def case_ring_attention():
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.context import DiompContext, use_default
    from repro.core.coordination import fetch_global
    from repro.core.groups import DiompGroup
    from repro.kernels.ring_attention import ring_attention, \
        ring_attention_ref

    n = jax.device_count()
    mesh = _ring_mesh()
    group = DiompGroup(("x",), name="x")
    ctx = DiompContext(mesh=mesh, segment_bytes=1 << 22)
    rng = np.random.RandomState(2)
    tq, H, KH, D, DV, B = 4, 4, 2, 8, 8, 2
    T = n * tq
    q = rng.randn(B, T, H, D).astype(np.float32)
    kk = rng.randn(B, T, KH, D).astype(np.float32)
    v = rng.randn(B, T, KH, DV).astype(np.float32)
    spec = P(None, "x")
    rep = {"digests": {}}
    with use_default(ctx):
        want = np.asarray(jax.jit(
            lambda q, k, v: ring_attention_ref(q, k, v, n=n))(q, kk, v))
        rep["digests"]["ref"] = _digest(want)
        for impl in ("fused", "host"):
            jf = jax.jit(shard_map(
                lambda q, k, v, impl=impl: ring_attention(
                    q, k, v, group, impl=impl),
                mesh=mesh, in_specs=(spec,) * 3, out_specs=spec))
            o = fetch_global(jf(q, kk, v))
            rep["digests"][impl] = _digest(o)
            rep[f"{impl}_eq_ref"] = bool(np.array_equal(o, want))
    rep.update(_log_report(ctx))
    return rep


def case_grad_buckets():
    import jax
    import numpy as np

    from repro import configs
    from repro.core.compat import shard_map
    from repro.core.context import DiompContext, use_default
    from repro.core.coordination import fetch_global
    from repro.distributed import buckets as bk
    from repro.distributed.sharding import rules_for_ctx
    from repro.launch.mesh import make_process_mesh
    from repro.models import schema as sch
    from repro.models.config import ParallelCtx
    from repro.train.step import reduce_gradients

    n = jax.device_count()
    if n < 4 or n % 2:
        return {"skipped": True}
    mesh = make_process_mesh(shape=(2, n // 2), axes=("data", "model"))
    cfg = configs.get_reduced("glm4-9b")
    ctx_bk = ParallelCtx.from_mesh(mesh)
    ctx_pp = ParallelCtx.from_mesh(mesh, bucket_bytes=0)
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx_bk))
    plan = bk.plan_for_config(cfg, mesh, ctx_bk)
    rng = np.random.RandomState(0)
    grads = {name: rng.randn(*s.shape).astype(np.float32)
             for name, s in sch.build_schema(cfg).items()}
    gspecs = {name: pspecs[name] for name in sch.build_schema(cfg)}

    def traced(pctx, plan_, dctx):
        def red(g):
            with use_default(dctx):
                out, _ = reduce_gradients(g, cfg, pctx, pspecs=pspecs,
                                          plan=plan_)
            return out

        return jax.jit(shard_map(red, mesh=mesh, in_specs=(gspecs,),
                                 out_specs=gspecs))

    d_bk = DiompContext(mesh=mesh, segment_bytes=1 << 20)
    d_pp = DiompContext(mesh=mesh, segment_bytes=1 << 20)
    out_bk = traced(ctx_bk, plan, d_bk)(grads)
    out_pp = traced(ctx_pp, None, d_pp)(grads)
    f_bk = {name: fetch_global(v) for name, v in sorted(out_bk.items())}
    f_pp = {name: fetch_global(v) for name, v in sorted(out_pp.items())}
    match = all(np.allclose(f_bk[name], f_pp[name], rtol=1e-5, atol=1e-6)
                for name in f_bk)

    def n_allreduce(d):
        return sum(c.get("allreduce", 0) for c in d.stats().values())

    # psum order may differ legally across process layouts, so the
    # cross-run comparison uses float64 sums (tolerance), not digests —
    # the digest is still recorded for the within-run rank-parity story.
    return {
        "digest": _obj_digest({name: _digest(v) for name, v in f_bk.items()}),
        "sums": {name: float(np.float64(v).sum()) for name, v in
                 f_bk.items()},
        "bk_matches_perparam": bool(match),
        "n_allreduce_bk": int(n_allreduce(d_bk)),
        "n_allreduce_pp": int(n_allreduce(d_pp)),
        "n_buckets": len(plan.buckets),
        **_log_report(d_bk),
    }


def case_pgas():
    import jax

    from repro.core.context import DiompContext
    from repro.core.groups import DiompGroup
    from repro.core.pgas import AllocError

    n = jax.device_count()
    mesh = _ring_mesh()
    group = DiompGroup(("x",), name="x")
    ctx = DiompContext(mesh=mesh, segment_bytes=1 << 16)
    mem = ctx.memory
    r1 = mem.alloc_symmetric("sym-a", 2048, group)
    # global-vector asymmetric: every process passes the same sizes
    slp = mem.alloc_asymmetric("rag", [256 * (r + 1) for r in range(n)],
                               group)
    # per-process contribution: each process speaks only for its ranks
    slp2 = mem.alloc_asymmetric(
        "rag-local", group=group,
        local_sizes=[384 * (r + 1) for r in mem.local_ranks])
    # churn, then a symmetric alloc that must coordinate: after the ragged
    # allocs the arenas have diverged, so the common offset comes from the
    # free-extent intersection protocol, not the local fast path
    mem.free(r1)
    r2 = mem.alloc_symmetric("sym-b", 1024, group)
    mem.check_invariants()
    oversize_raises = False
    try:
        mem.alloc_symmetric("too-big", 1 << 20, group)
    except AllocError:
        oversize_raises = True
    table = [
        [row["name"], bool(row["symmetric"]), list(row["bytes"]),
         list(row["offsets"])]
        for row in sorted(mem.mapping_table(), key=lambda r: r["rid"])
    ]
    return {
        "table": table,
        "table_digest": _obj_digest(table),
        "sym_b_offsets_identical": len(set(r2.offsets)) == 1,
        "rag_offsets": list(slp.region.offsets),
        "rag_local_sizes": list(slp2.region.sizes),
        "oversize_raises": oversize_raises,
        "alloc_counts": dict(mem.alloc_counts),
        **_log_report(ctx),
    }


def case_determinism():
    """Seeded substrates must be process-invariant: the fault schedule,
    the sha256-derived RNG streams, and the serving arrival trace."""
    from repro.core.faults import FaultPlan
    from repro.core.resilience import derive_rng
    from repro.serve.trace import bursty_trace

    plan = FaultPlan(seed=1234, p=0.3, kinds=("drop", "fail", "timeout"))
    stream = []
    for i in range(240):
        f = plan.next_fault(("put", "get", "allreduce")[i % 3])
        stream.append(None if f is None else [f.verb, f.call_index, f.kind])
    rngs = [[round(derive_rng("halo", i, tag).random(), 17)
             for tag in ("x", "y")] for i in range(32)]
    trace = [repr(r) for r in bursty_trace(seed=7, n=48)]
    return {
        "fault_digest": _obj_digest(stream),
        "rng_digest": _obj_digest(rngs),
        "trace_digest": _obj_digest(trace),
        "injected_counts": plan.injected_counts(),
    }


CASES = {
    "pgas": case_pgas,
    "ring_matmul": case_ring_matmul,
    "minimod": case_minimod,
    "moe_dispatch": case_moe_dispatch,
    "ring_attention": case_ring_attention,
    "grad_buckets": case_grad_buckets,
    "determinism": case_determinism,
    "chaos_ring": case_chaos_ring,
}


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", required=True)
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--ndev-per-proc", type=int, required=True)
    ap.add_argument("--cases", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args(argv)

    names = [c for c in args.cases.split(",") if c]
    unknown = [c for c in names if c not in CASES]
    if unknown:
        print(f"unknown cases: {unknown}", file=sys.stderr)
        return 2

    try:
        import repro as diomp

        diomp.init(coordinator=args.coordinator,
                   num_processes=args.num_processes,
                   process_id=args.process_id,
                   local_device_count=args.ndev_per_proc)
        import jax

        if jax.process_count() != args.num_processes:
            raise RuntimeError(
                f"joined as {jax.process_count()} processes, "
                f"asked for {args.num_processes}")
    except Exception:
        traceback.print_exc()
        print("multi-process bootstrap unavailable; exiting 77",
              file=sys.stderr)
        return INFRA_EXIT

    result = {
        "process_id": int(jax.process_index()),
        "num_processes": int(jax.process_count()),
        "ndev_per_proc": int(jax.local_device_count()),
        "global_devices": int(jax.device_count()),
        "cases": {},
    }
    for name in names:
        print(f"[proc {args.process_id}] case {name} ...", flush=True)
        result["cases"][name] = CASES[name]()

    tmp = args.out + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(result, fh, indent=1, sort_keys=True)
    os.replace(tmp, args.out)

    # all processes finish before the launcher reaps anyone (a process
    # exiting early would poison its peers' pending collectives)
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices("diomp-harness-done")
    return 0


if __name__ == "__main__":
    sys.exit(main())

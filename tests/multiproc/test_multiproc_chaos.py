"""Chaos under real multi-process SPMD: injected wire faults recover,
and recovery is invisible to the logical books and the outputs.

The 2x2 chaos run re-executes the ring-matmul program with
``DIOMP_CHAOS_SEED`` armed in every worker's environment (ambient chaos,
no test-body changes — the FaultPlan.from_env path).  The assertions are
the repo's chaos contract, now cross-process: faults WERE injected, all
recovered via retries, and the outputs + logical call/byte logs are
bit-identical to the calm run.
"""

import json

import pytest

pytestmark = pytest.mark.multiproc


def _chaos(chaos_two):
    return chaos_two[0]["cases"]["chaos_ring"]


def test_chaos_armed_and_recovered(chaos_two):
    c = _chaos(chaos_two)
    assert c["chaos"]["armed"]
    assert c["chaos"]["injected_total"] > 0      # the dice actually rolled
    assert c["chaos"]["unrecovered"] == 0        # every fault retried out
    assert c["retry_total"] > 0                  # retries hit the books


def test_chaos_outputs_bitwise_equal_calm_run(chaos_two, two_proc):
    c = _chaos(chaos_two)
    calm = two_proc[0]["cases"]["ring_matmul"]
    assert c["digests"] == calm["digests"]
    assert c["fused_eq_ref"]


def test_chaos_invariant_logical_logs(chaos_two, two_proc):
    """Retry traffic lands in the retry books only: the logical OMPCCL
    call/byte log and RMA tracker totals match the calm run exactly."""
    c = _chaos(chaos_two)
    calm = two_proc[0]["cases"]["ring_matmul"]
    assert c["logical_digest"] == calm["logical_digest"]
    assert calm["retry_total"] == 0


def test_chaos_rank_parity(chaos_two):
    """Deterministic injection: every process rolls the same faults at
    the same call indices, so the full result blob agrees rank-vs-rank."""
    c = _chaos(chaos_two)
    assert c["rank_parity"]
    blobs = {json.dumps({k: v for k, v in r.items() if k != "process_id"},
                        sort_keys=True) for r in chaos_two}
    assert len(blobs) == 1

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.linear_scan.ops import linear_scan
from repro.kernels.ring_matmul.ops import matmul
from repro.kernels.stencil.ops import wave_step

RNG = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _naive_attn(q, k, v, causal, q_offset, prefix_len):
    B, Tq, H, D = q.shape
    Tk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    kx = np.repeat(k, G, axis=2).astype(np.float64)
    vx = np.repeat(v, G, axis=2).astype(np.float64)
    s = np.einsum("bqhd,bkhd->bhqk", q.astype(np.float64), kx) * D ** -0.5
    qp = q_offset + np.arange(Tq)[:, None]
    kp = np.arange(Tk)[None, :]
    vis = np.ones((Tq, Tk), bool)
    if causal:
        vis = (kp <= qp) | ((kp < prefix_len) & (qp < prefix_len))
    s = np.where(vis[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = np.where(vis[None, None], p, 0)
    return np.einsum("bhqk,bkhd->bqhd", p / p.sum(-1, keepdims=True), vx)


SWEEP = [
    # B, Tq, Tk, H, KH, D, Dv, causal, off, pfx, dtype
    (2, 16, 16, 4, 2, 64, 64, True, 0, 0, np.float32),
    (1, 8, 24, 4, 1, 32, 32, True, 16, 0, np.float32),
    (2, 12, 12, 6, 6, 64, 64, False, 0, 0, np.float32),
    (1, 20, 20, 8, 2, 64, 64, True, 0, 5, np.float32),
    (1, 1, 33, 4, 2, 64, 64, True, 32, 0, np.float32),
    (1, 16, 16, 4, 2, 32, 16, True, 0, 0, np.float32),   # MLA: Dv != D
    (2, 16, 16, 4, 4, 64, 64, True, 0, 0, np.float16),
]


@pytest.mark.parametrize("case", SWEEP, ids=[str(i) for i in range(len(SWEEP))])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_flash_attention_sweep(case, impl):
    B, Tq, Tk, H, KH, D, Dv, causal, off, pfx, dt = case
    q = RNG.randn(B, Tq, H, D).astype(dt)
    k = RNG.randn(B, Tk, KH, D).astype(dt)
    v = RNG.randn(B, Tk, KH, Dv).astype(dt)
    want = _naive_attn(q, k, v, causal, off, pfx)
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          prefix_len=pfx, impl=impl, block=8, interpret=True)
    tol = 2e-2 if dt == np.float16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float64), want, atol=tol,
                               rtol=tol)


def test_flash_vector_positions():
    """Per-slot decode offsets (continuous batching)."""
    B, Tk, H, D = 3, 16, 4, 32
    q = RNG.randn(B, 1, H, D).astype(np.float32)
    k = RNG.randn(B, Tk, H, D).astype(np.float32)
    v = RNG.randn(B, Tk, H, D).astype(np.float32)
    pos = np.array([3, 7, 15])
    got = flash_attention(q, k, v, causal=True, q_offset=pos,
                          valid_len=pos + 1, impl="ref", block=8)
    for b in range(B):
        want = _naive_attn(q[b:b + 1], k[b:b + 1, : pos[b] + 1],
                           v[b:b + 1, : pos[b] + 1], True, int(pos[b]), 0)
        np.testing.assert_allclose(np.asarray(got)[b:b + 1], want, atol=2e-5)


# ---------------------------------------------------------------------------
# linear scan (rwkv6 / mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,T,M,N,pre,chunk", [
    (3, 64, 16, 8, True, 16),
    (2, 128, 32, 32, False, 64),
    (1, 32, 8, 24, True, 32),
    (4, 96, 64, 64, False, 32),
    (2, 64, 64, 16, True, 64),
])
def test_linear_scan_sweep(BH, T, M, N, pre, chunk):
    p = RNG.randn(BH, T, M).astype(np.float32) * 0.5
    q = RNG.randn(BH, T, N).astype(np.float32) * 0.5
    a = RNG.uniform(0.7, 0.999, (BH, T, N)).astype(np.float32)
    r = RNG.randn(BH, T, N).astype(np.float32) * 0.5
    y_ref, s_ref = linear_scan(p, q, a, r, readout_pre=pre, impl="ref")
    y_pal, s_pal = linear_scan(p, q, a, r, readout_pre=pre, impl="pallas",
                               chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               atol=2e-4, rtol=2e-4)


def test_linear_scan_state_carry():
    """Chunked prefill: state from chunk 1 feeds chunk 2 == one long scan."""
    BH, T, M, N = 2, 64, 8, 8
    p = RNG.randn(BH, T, M).astype(np.float32)
    q = RNG.randn(BH, T, N).astype(np.float32)
    a = RNG.uniform(0.8, 0.99, (BH, T, N)).astype(np.float32)
    r = RNG.randn(BH, T, N).astype(np.float32)
    y_full, s_full = linear_scan(p, q, a, r, impl="ref")
    h = T // 2
    y1, s1 = linear_scan(p[:, :h], q[:, :h], a[:, :h], r[:, :h], impl="ref")
    y2, s2 = linear_scan(p[:, h:], q[:, h:], a[:, h:], r[:, h:], s1,
                         impl="ref")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full)[:, h:],
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bk,bn,dt", [
    (64, 96, 48, 32, 32, 32, np.float32),
    (100, 130, 70, 32, 64, 32, np.float32),
    (256, 512, 256, 128, 128, 128, np.float32),
    (64, 64, 64, 32, 32, 32, np.float16),
    (33, 65, 17, 32, 32, 32, np.float32),       # ragged padding
])
def test_matmul_sweep(M, K, N, bm, bk, bn, dt):
    x = RNG.randn(M, K).astype(dt)
    w = RNG.randn(K, N).astype(dt)
    got = matmul(x, w, impl="pallas", bm=bm, bk=bk, bn=bn, interpret=True)
    want = x.astype(np.float64) @ w.astype(np.float64)
    tol = 2e-2 if dt == np.float16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=tol, atol=tol * np.abs(want).max())


# ---------------------------------------------------------------------------
# stencil
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Z,Y,X,bz", [
    (24, 20, 28, 8),
    (16, 16, 16, 16),
    (17, 12, 20, 8),        # ragged Z
])
def test_stencil_sweep(Z, Y, X, bz):
    u = RNG.randn(Z, Y, X).astype(np.float32)
    up = RNG.randn(Z, Y, X).astype(np.float32)
    got = wave_step(u, up, 0.1, impl="pallas", bz=bz, interpret=True)
    want = wave_step(u, up, 0.1, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_stencil_velocity_model():
    """Spatially-varying c^2·dt^2 (the Minimod subsurface model)."""
    u = RNG.randn(16, 16, 16).astype(np.float32)
    up = RNG.randn(16, 16, 16).astype(np.float32)
    c2 = RNG.uniform(0.05, 0.2, (16, 16, 16)).astype(np.float32)
    got = wave_step(u, up, c2, impl="pallas", bz=8, interpret=True)
    want = wave_step(u, up, c2, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

"""Production serving engine: chunked prefill, paged KV, preemption.

Covers the docs/SERVING.md contracts: chunked prefill output-equivalence
with the token-by-token baseline, the per-request engine-step bound,
O(1)-page ``extend`` (call-log asserted), free-list reuse (no arena growth
across request churn), OOM -> preempt -> resume round-trips, and migration
byte accounting against the OMPCCL/RMA call logs.
"""

import numpy as np
import jax
import pytest

from repro import configs
from repro.core.context import DiompContext
from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import PagedKVAllocator

CFG = configs.get_reduced("stablelm-3b")


@pytest.fixture(scope="module")
def params():
    return sch.init_params(CFG, jax.random.PRNGKey(0))


def _engine(mesh8, params, **kw):
    ctx = ParallelCtx.from_mesh(mesh8, remat=False, inference=True)
    return ServeEngine(CFG, mesh8, ctx, params, **kw)


def _kv_bpt():
    return 2 * 2 * max(CFG.kv_heads, 1) * max(CFG.head_dim, 1) \
        * CFG.num_layers


def _serve(eng, lengths, max_new=4):
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in lengths]
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run()
    assert all(r.done and len(r.out) == max_new for r in reqs), \
        [(len(r.prompt), len(r.out), r.done) for r in reqs]
    return reqs


# -- chunked prefill -------------------------------------------------------

def test_chunked_equals_token_by_token(mesh8, params):
    """Mixed prompt lengths, continuous batching: the chunked engine's
    outputs match the token-by-token (prefill_chunk=1) baseline exactly."""
    lengths = (3, 9, 17, 5, 26)
    base = _serve(_engine(mesh8, params, slots=2, max_len=64,
                          prefill_chunk=1), lengths)
    fast = _serve(_engine(mesh8, params, slots=2, max_len=64,
                          prefill_chunk=8), lengths)
    for b, f in zip(base, fast):
        assert b.out == f.out, (len(b.prompt), b.out, f.out)
    # the chunked engine spends ceil(len/chunk) prefill device calls
    for f, n in zip(fast, lengths):
        assert f.prefill_steps == -(-n // 8)


def test_step_bound_mixed_batch(mesh8, params):
    """A mixed batch (prompt lengths 8..512) prefills in ceil(len/chunk)
    chunk calls and finishes within ceil(len/chunk) + max_new + O(1)
    engine steps per request."""
    chunk, max_new = 64, 4
    lengths = (8, 40, 230, 512)
    eng = _engine(mesh8, params, slots=len(lengths), max_len=544,
                  prefill_chunk=chunk)
    reqs = _serve(eng, lengths, max_new=max_new)
    for r, n in zip(reqs, lengths):
        assert r.prefill_steps == -(-n // chunk), (n, r.prefill_steps)
        assert r.decode_steps <= max_new
        resident = r.finish_step - r.admit_step
        assert resident <= -(-n // chunk) + max_new + 2, (n, resident)
    st = eng.kv_stats
    assert st["pages_allocated"] == st["pages_freed"] > 0
    assert st["oom_events"] == 0


def test_released_slot_keeps_no_stale_state(mesh8, params):
    """Seed-engine regression: a freed slot must not keep teacher-forcing
    its stale pending token / advancing the device position.  A request
    admitted into a previously used slot generates exactly what a fresh
    engine generates."""
    rng = np.random.RandomState(3)
    long_p = rng.randint(0, CFG.vocab_size, size=9).astype(np.int32)
    short_p = rng.randint(0, CFG.vocab_size, size=2).astype(np.int32)
    late_p = rng.randint(0, CFG.vocab_size, size=6).astype(np.int32)

    eng = _engine(mesh8, params, slots=2, max_len=64, prefill_chunk=4)
    eng.submit(short_p, max_new=2)           # finishes early, frees its slot
    eng.submit(long_p, max_new=12)           # keeps the engine running
    eng.run()
    late = eng.submit(late_p, max_new=4)     # reuses the churned slot
    eng.run()

    fresh = _engine(mesh8, params, slots=2, max_len=64, prefill_chunk=4)
    ref = fresh.submit(late_p, max_new=4)
    fresh.run()
    assert late.done and late.out == ref.out, (late.out, ref.out)


# -- paged allocator -------------------------------------------------------

def _alloc(page_tokens=16, nranks=4, segment=1 << 22):
    mem = GlobalMemory(nranks, segment, allocator="buddy")
    g = DiompGroup(("x",), name="x")
    return PagedKVAllocator(mem, g, page_tokens=page_tokens,
                            kv_bytes_per_token=64), mem


def test_extend_is_one_page_alloc():
    """Every ``extend`` that grows performs EXACTLY one page allocation
    (arena or free-list) — call-log asserted."""
    alloc, _ = _alloc()
    r = alloc.admit(10, 200)
    mark = len(alloc.call_log)
    grown = 0
    for _ in range(100):
        r.pos += 1
        before = len(alloc.call_log)
        assert alloc.extend(r)
        events = alloc.call_log[before:]
        allocs = [e for e in events if e[0] in ("arena_alloc", "page_reuse")]
        grows = [e for e in events if e[0] == "extend"]
        assert len(allocs) <= 1
        if grows:
            assert len(allocs) == 1 and grows[0][2] == 1
            grown += 1
    assert grown == len(r.page_table) - 2  # admit covered prompt + 1 page
    assert all(e[2] == 1 for e in alloc.call_log[mark:] if e[0] == "extend")
    alloc.release(r)


def test_free_list_reuse_no_arena_growth():
    """Steady-state request churn re-uses released pages: the arena sees no
    new allocations after the first request's working set exists."""
    alloc, mem = _alloc()
    def one_request():
        r = alloc.admit(20, 60)
        assert r is not None
        for _ in range(40):
            r.pos += 1
            assert alloc.extend(r)
        alloc.release(r)
    one_request()
    arena_after_first = alloc.stats["arena_page_allocs"]
    asym_after_first = mem.alloc_counts["asymmetric"]
    for _ in range(25):
        one_request()
    assert alloc.stats["arena_page_allocs"] == arena_after_first
    assert mem.alloc_counts["asymmetric"] == asym_after_first
    assert alloc.stats["page_reuses"] > 0
    assert alloc.stats["pages_allocated"] == alloc.stats["pages_freed"]
    # trim returns the pool to the arena cleanly
    alloc.trim()
    assert mem.bytes_in_use(0) == 0
    mem.check_invariants()


def test_lookup_resolves_through_page_table():
    alloc, mem = _alloc(page_tokens=16)
    r = alloc.admit(40, 80, home_rank=2)
    # token 20 lives on page 1 at within-page offset 4
    rank, off = alloc.lookup(r, 20)
    assert rank == 2
    p1_rank, p1_base = mem.translate(r.page_table[1], 2)
    assert (rank, off) == (p1_rank, p1_base + 4 * alloc.token_bytes)
    # repeated remote lookups hit the pointer cache after the first deref
    h0 = mem.ptr_cache.hits
    alloc.lookup(r, 21)
    alloc.lookup(r, 22)
    assert mem.ptr_cache.hits >= h0 + 2
    alloc.release(r)


def test_migrate_moves_pages_and_accounts_bytes():
    alloc, _ = _alloc(page_tokens=16)
    r = alloc.admit(30, 60, home_rank=0)
    npages = len(r.page_table)

    class _Rec:
        def __init__(self):
            self.calls, self.nbytes = {}, {}
        def record(self, op, payload=None):
            self.calls[op] = self.calls.get(op, 0) + 1
            if payload is not None:
                self.nbytes[op] = self.nbytes.get(op, 0) + payload.nbytes

    from repro.core.rma import RMATracker
    comm, tr = _Rec(), RMATracker()
    tr.register("w")
    moved = alloc.migrate(r, 3, comm=comm, tracker=tr, window="w")
    assert r.home_rank == 3 and len(r.page_table) == npages
    assert moved == npages * alloc.page_bytes
    assert comm.calls == {"get": npages, "put": npages}
    assert comm.nbytes["put"] == moved            # leaf-op byte convention
    assert tr.put_bytes == moved and tr.window_bytes["w"] == moved
    assert tr.fences == 1
    alloc.release(r)


# -- preemption / migration in the engine ----------------------------------

PAGE_TOKENS = 16
OOM_LENGTHS, OOM_MAX_NEW = (20, 21), 42   # both grow 3 -> 4 pages at pos 48


def _pressured_engine(mesh8, params):
    """2 slots, arena of exactly 8 pages minus 1 page of ballast: admits
    take 3 + 3 (+1 ballast), the first page-boundary extend fits (8/8),
    the second hard-OOMs.  Watermark preemption is disabled so the hard-OOM
    path itself is exercised (test_watermark_preemption covers the soft
    path)."""
    page_bytes = PAGE_TOKENS * _kv_bpt()
    ctx = DiompContext(mesh=mesh8, segment_bytes=8 * page_bytes,
                       allocator="buddy")
    eng = _engine(mesh8, params, slots=2, max_len=64, prefill_chunk=8,
                  page_tokens=PAGE_TOKENS, high_watermark=10.0, context=ctx)
    sizes = [page_bytes if r == 0 else 0 for r in range(eng.memory.nranks)]
    eng.memory.alloc_asymmetric("ballast", sizes, eng._group)
    return eng


def test_oom_preempt_resume_roundtrip(mesh8, params):
    """Decode growth past the arena forces preemption; the victim swaps its
    pages to a spill heap over RMA, resumes later, and ends with exactly
    the unpressured run's output."""
    ref = _serve(_engine(mesh8, params, slots=2, max_len=64,
                         prefill_chunk=8, page_tokens=PAGE_TOKENS),
                 OOM_LENGTHS, max_new=OOM_MAX_NEW)
    eng = _pressured_engine(mesh8, params)
    got = _serve(eng, OOM_LENGTHS, max_new=OOM_MAX_NEW)
    assert sum(r.preemptions for r in got) >= 1
    assert eng.alloc.stats["migrations"] >= 2      # swap out + swap home
    assert eng.alloc.stats["oom_events"] >= 1
    for a, b in zip(ref, got):
        assert a.out == b.out, (a.out, b.out)


def test_engine_migration_bytes_match_rma_log(mesh8, params):
    eng = _pressured_engine(mesh8, params)
    world = eng._group.descriptor()
    put0 = eng.dctx.byte_stats().get(world, {}).get("put", 0)
    _serve(eng, OOM_LENGTHS, max_new=OOM_MAX_NEW)
    moved = eng.alloc.stats["bytes_migrated"]
    assert moved > 0
    put1 = eng.dctx.byte_stats()[world]["put"]
    assert put1 - put0 == moved            # OMPCCL wire-volume log
    assert eng.dctx.rma.put_bytes == moved  # RMA tracker window accounting
    assert eng.dctx.stats()[world]["get"] == moved // eng.alloc.page_bytes


def test_watermark_preemption_still_correct(mesh8, params):
    """An aggressive high watermark serializes execution through preemption
    without changing any output (greedy sampling)."""
    lengths = (9, 14, 5)
    ref = _serve(_engine(mesh8, params, slots=3, max_len=64,
                         prefill_chunk=8), lengths, max_new=6)
    eng = _engine(mesh8, params, slots=3, max_len=64, prefill_chunk=8,
                  high_watermark=1e-4, low_watermark=5e-5)
    got = _serve(eng, lengths, max_new=6)
    assert sum(r.preemptions for r in got) >= 1
    for a, b in zip(ref, got):
        assert a.out == b.out


# -- sampling / scheduling --------------------------------------------------

def test_sampling_deterministic_and_nongreedy(mesh8, params):
    kw = dict(slots=2, max_len=64, prefill_chunk=8, temperature=0.9,
              top_k=8, seed=11)
    a = _serve(_engine(mesh8, params, **kw), (7, 12), max_new=6)
    b = _serve(_engine(mesh8, params, **kw), (7, 12), max_new=6)
    greedy = _serve(_engine(mesh8, params, slots=2, max_len=64,
                            prefill_chunk=8), (7, 12), max_new=6)
    for x, y in zip(a, b):
        assert x.out == y.out              # seeded sampling is reproducible
    assert any(x.out != g.out for x, g in zip(a, greedy))


def test_submit_rejects_unservable_chunk_span(mesh8, params):
    """The padded final chunk must fit the cache (a clamped device write
    would corrupt live rows): ceil(len/chunk)*chunk > max_len is rejected
    at submit, even when len + max_new fits."""
    eng = _engine(mesh8, params, slots=1, max_len=96, prefill_chunk=64)
    with pytest.raises(ValueError, match="chunked prefill"):
        eng.submit(np.ones(89, np.int32), max_new=5)   # 2*64 = 128 > 96
    eng.submit(np.ones(60, np.int32), max_new=4)       # 64 <= 96: fine
    eng.run()


def test_priority_admission(mesh8, params):
    eng = _engine(mesh8, params, slots=1, max_len=64, prefill_chunk=8)
    rng = np.random.RandomState(0)
    lo = eng.submit(rng.randint(0, CFG.vocab_size, 5), max_new=3, priority=0)
    hi = eng.submit(rng.randint(0, CFG.vocab_size, 5), max_new=3, priority=5)
    eng.run()
    assert lo.done and hi.done
    assert hi.admit_step < lo.admit_step   # higher priority admits first
    st = eng.latency_stats()
    assert st["requests_done"] == 2 and st["preemptions"] == 0

"""Chaos-engineered communicator: deterministic fault injection + recovery.

Contract under test (docs/RESILIENCE.md):

* a ``FaultPlan`` is a pure function of its seed — two plans with the same
  seed inject the identical (verb, call, kind) stream, so any chaos run
  reproduces bit-for-bit;
* every injected *transient* fault is absorbed by the communicator's retry
  layer: results are bit-identical to the fault-free run, logical call/byte
  logs are untouched, and the re-issued wire traffic lands in the separate
  retry logs (the OMPCCL-log == RMATracker parity audits survive chaos);
* the fused equivalence paths (ring matmul, Minimod wave step, MoE
  dispatch) run unchanged under an injecting default context;
* RMA checksum validation catches a corrupted page migration and repairs
  it by re-putting — or raises once the retry budget is spent, never
  silently absorbing garbage.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, use_default
from repro.core.faults import (INJECTABLE_VERBS, ChaosBackend, FaultPlan,
                               FaultSpec)
from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.core.resilience import (RetryError, RetryPolicy, TransientFault,
                                   call_with_retries, content_digest,
                                   corrupt_digest)
from repro.core.rma import RMAError, RMATracker
from repro.serve.kvcache import PagedKVAllocator

RNG = np.random.RandomState(0)
WORLD = DiompGroup(("pod", "data", "model"), name="world")
RING = DiompGroup(("x",), name="x")

# tests drive many injected retries; don't actually sleep the backoffs
FAST = RetryPolicy(sleep=False)


def _clean_plan():
    """Explicitly inert plan: keeps 'clean' runs fault-free even when the
    chaos-smoke CI job exports DIOMP_CHAOS_SEED into the environment."""
    return FaultPlan(0, p=0.0)


def _chaos_ctx(mesh, seed=7, p=0.25, kinds=("drop", "fail", "timeout"),
               specs=(), **kw):
    plan = FaultPlan(seed, p=p, kinds=kinds, specs=tuple(specs))
    return DiompContext(mesh=mesh, segment_bytes=1 << 20,
                        fault_plan=plan, retry_policy=FAST, **kw), plan


def _total(stats):
    return sum(sum(ops.values()) for ops in stats.values())


# ---------------------------------------------------------------------------
# the plan is deterministic
# ---------------------------------------------------------------------------

def _stream(plan, verbs, n):
    out = []
    for verb in verbs:
        for _ in range(n):
            f = plan.next_fault(verb)
            out.append(None if f is None
                       else (f.verb, f.call_index, f.kind))
    return out


def test_fault_plan_same_seed_same_stream():
    a = _stream(FaultPlan(7, p=0.5, kinds=("drop", "fail", "timeout")),
                INJECTABLE_VERBS, 8)
    b = _stream(FaultPlan(7, p=0.5, kinds=("drop", "fail", "timeout")),
                INJECTABLE_VERBS, 8)
    assert a == b
    assert any(f is not None for f in a)          # p=0.5 over 88 rolls


def test_fault_plan_seed_changes_stream():
    a = _stream(FaultPlan(7, p=0.5), INJECTABLE_VERBS, 16)
    b = _stream(FaultPlan(8, p=0.5), INJECTABLE_VERBS, 16)
    assert a != b


def test_fault_spec_targets_exact_call():
    plan = FaultPlan(0, specs=(FaultSpec("put", 2, "corrupt"),))
    hits = _stream(plan, ("put",), 5)
    assert hits == [None, None, ("put", 2, "corrupt"), None, None]
    assert plan.injected_counts() == {"corrupt": 1}


def test_fault_plan_max_faults_cap():
    plan = FaultPlan(3, p=1.0, kinds=("drop",), max_faults=4)
    hits = [f for f in _stream(plan, ("allreduce",), 10) if f]
    assert len(hits) == 4


def test_fault_plan_from_env():
    env = {"DIOMP_CHAOS_SEED": "42", "DIOMP_CHAOS_P": "0.9",
           "DIOMP_CHAOS_KINDS": "drop,timeout",
           "DIOMP_CHAOS_VERBS": "put,allreduce"}
    plan = FaultPlan.from_env(env)
    assert plan.seed == 42 and plan.p == 0.9
    assert plan.kinds == ("drop", "timeout")
    assert plan.verbs == ("put", "allreduce")
    assert plan.next_fault("bcast") is None       # verb not opted in
    assert FaultPlan.from_env({}) is None         # no seed: chaos off


def test_kill_rank_fires_once():
    plan = FaultPlan(0).kill_rank(5, rank=3, graceful=True)
    assert plan.deaths_at(4) == []
    first = plan.deaths_at(5)
    assert [(d.rank, d.graceful) for d in first] == [(3, True)]
    assert plan.deaths_at(5) == []                # already fired


# ---------------------------------------------------------------------------
# retry policy + driver
# ---------------------------------------------------------------------------

def test_backoff_capped_and_deterministic():
    pol = RetryPolicy(base_backoff_s=1e-4, max_backoff_s=5e-4, jitter=0.5)
    waits = [pol.backoff_s("put", k) for k in range(1, 10)]
    assert all(w <= 5e-4 * 1.25 + 1e-12 for w in waits)
    assert waits == [pol.backoff_s("put", k) for k in range(1, 10)]
    assert pol.backoff_s("put", 3) != pol.backoff_s("allreduce", 3)


def test_retry_budget_per_verb_override():
    pol = RetryPolicy(max_retries=8, per_verb={"put": 2})
    assert pol.budget("put") == 2 and pol.budget("barrier") == 8


def test_call_with_retries_recovers():
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] <= 3:
            raise TransientFault(f"boom {state['n']}")
        return "ok"

    seen = []
    out = call_with_retries(flaky, "put", FAST,
                            on_retry=lambda k, tf: seen.append(k))
    assert out == "ok" and seen == [1, 2, 3]


def test_call_with_retries_exhausts_budget():
    pol = RetryPolicy(max_retries=2, sleep=False)

    def always():
        raise TransientFault("down")

    with pytest.raises(RetryError):
        call_with_retries(always, "put", pol)


# ---------------------------------------------------------------------------
# the whole verb surface, bit-identical under chaos
# ---------------------------------------------------------------------------

def _verb_sweep(ctx, mesh):
    comm = ctx.communicator(RING)
    x = np.arange(32, dtype=np.float32).reshape(8, 4)

    def fn(v):
        y = comm.allreduce(v)
        y = y + comm.bcast(v, root=1)
        y = y + comm.permute(v, shift=1)
        y = y + comm.put(v, shift=2)
        lo, hi = comm.halo_exchange(v, halo=1, axis=0)
        y = y + lo + hi
        y = y + comm.reducescatter(comm.allgather(v, axis=0), axis=0)
        return y + 0 * jnp.asarray(comm.barrier(), y.dtype)

    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x))


def test_verbs_bit_identical_under_chaos(ring8):
    clean_ctx = DiompContext(mesh=ring8, segment_bytes=1 << 20,
                             fault_plan=_clean_plan())
    chaos_ctx, plan = _chaos_ctx(ring8, seed=11, p=0.3)

    want = _verb_sweep(clean_ctx, ring8)
    got = _verb_sweep(chaos_ctx, ring8)

    assert np.array_equal(got, want)              # bit-identical recovery
    assert len(plan.injected) > 0                 # chaos actually fired
    assert plan.unrecovered() == []               # ...and was absorbed
    # logical logs are chaos-invariant; retries live in their own log
    assert chaos_ctx.stats() == clean_ctx.stats()
    assert chaos_ctx.byte_stats() == clean_ctx.byte_stats()
    assert clean_ctx.retry_stats() == {}
    assert _total(chaos_ctx.retry_stats()) == len(plan.injected)


def test_retry_budget_exhaustion_surfaces(ring8):
    # every roll faults and the budget is tiny: the failure must surface
    # as RetryError, not hang or silently drop the op
    plan = FaultPlan(1, p=1.0, kinds=("drop",))
    ctx = DiompContext(mesh=ring8, segment_bytes=1 << 20, fault_plan=plan,
                       retry_policy=RetryPolicy(max_retries=2, sleep=False))
    comm = ctx.communicator(RING)
    x = np.ones((8, 4), np.float32)
    with pytest.raises(RetryError):
        jax.jit(shard_map(lambda v: comm.allreduce(v), mesh=ring8,
                          in_specs=P("x"), out_specs=P("x")))(x)


def test_chaos_backend_wraps_any_registered_backend(ring8):
    # ChaosBackend must delegate each verb directly (never through the
    # base-class fallbacks, which would double-inject via allreduce)
    from repro.core.backends import XlaBackend
    plan = FaultPlan(5, specs=(FaultSpec("bcast", 0, "fail"),))
    cb = ChaosBackend(XlaBackend(), plan)
    assert cb.name == "chaos:xla"
    x = np.arange(8, dtype=np.float32)

    def fn(v):
        return cb.bcast(v, RING, root=2)

    with pytest.raises(TransientFault):
        jax.jit(shard_map(fn, mesh=ring8, in_specs=P("x"),
                          out_specs=P("x")))(x)
    # only the bcast roll fired — delegation never touched allreduce
    assert [f.verb for f in plan.injected] == ["bcast"]


# ---------------------------------------------------------------------------
# fused equivalence paths survive an injecting default context
# ---------------------------------------------------------------------------

def test_ring_matmul_bit_identical_under_chaos():
    from repro.kernels.ring_matmul.ops import ring_allgather_matmul
    ndev = 8
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    A = RNG.randn(16, 24).astype(np.float32)
    B = RNG.randn(24, 16).astype(np.float32)

    def run(ctx):
        f = jax.jit(shard_map(
            lambda a, b: ring_allgather_matmul(a, b, RING),
            mesh=mesh, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        with use_default(ctx):
            return np.asarray(f(A, B))

    want = run(DiompContext(mesh=mesh, fault_plan=_clean_plan()))
    chaos_ctx, plan = _chaos_ctx(mesh, seed=13, p=0.3)
    got = run(chaos_ctx)
    assert np.array_equal(got, want)
    assert len(plan.injected) > 0 and plan.unrecovered() == []
    assert _total(chaos_ctx.retry_stats()) == len(plan.injected)


def test_minimod_step_bit_identical_under_chaos():
    from repro.apps.minimod import pad_shards, unpad_shards
    from repro.kernels.stencil.fused import fused_wave_step
    ZG = DiompGroup(("z",), name="z")
    Z, Y, X, nz = 32, 8, 8, 4
    mesh = make_mesh((nz, 1), ("z", "y"), axis_types="auto")
    ext = (Z // nz,) * nz
    u = (RNG.randn(Z, Y, X) * 0.1).astype(np.float32)
    up = (RNG.randn(Z, Y, X) * 0.1).astype(np.float32)
    u_in, up_in = pad_shards(u, ext), pad_shards(up, ext)

    def run(ctx):
        f = jax.jit(shard_map(
            lambda a, b: fused_wave_step(a, b, 0.1, ZG, None),
            mesh=mesh, in_specs=(P("z", "y"), P("z", "y")),
            out_specs=P("z", "y")))
        with use_default(ctx):
            return unpad_shards(np.asarray(f(u_in, up_in)), ext)

    want = run(DiompContext(mesh=mesh, fault_plan=_clean_plan()))
    chaos_ctx, plan = _chaos_ctx(mesh, seed=17, p=0.3)
    got = run(chaos_ctx)
    assert np.array_equal(got, want)
    assert len(plan.injected) > 0 and plan.unrecovered() == []


def test_moe_dispatch_bit_identical_under_chaos():
    from repro.kernels.moe_dispatch import (measure_expert_load,
                                            moe_dispatch, route_topk)
    from repro.kernels.plan import default_planner
    ndev, E, t_loc, d, f, k = 4, 8, 8, 16, 32, 2
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    toks = RNG.randn(ndev * t_loc, d).astype(np.float32)
    router = (RNG.randn(d, E) + 2.0 * RNG.randn(1, E)).astype(np.float32)
    wg = (RNG.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wu = (RNG.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wd = (RNG.randn(E, f, d) / np.sqrt(f)).astype(np.float32)
    _, top_e = jax.jit(route_topk, static_argnums=2)(toks, router, k)
    loads = measure_expert_load(
        np.asarray(top_e).reshape(ndev, t_loc, k), E, sources=ndev)
    plan = default_planner().plan_alltoall(t_loc, d, k, E, ndev,
                                          jnp.float32, loads=loads)

    def run(ctx):
        def fn(tk, rt, g, u, dn):
            w, e = route_topk(tk, rt, k)
            return moe_dispatch(tk, e, w, g, u, dn, RING,
                                impl="host", plan=plan)
        fjit = jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(P("x", None), P(None, None), P("x", None, None),
                      P("x", None, None), P("x", None, None)),
            out_specs=P("x", None)))
        with use_default(ctx):
            return np.asarray(fjit(toks, router, wg, wu, wd))

    want = run(DiompContext(mesh=mesh, fault_plan=_clean_plan()))
    chaos_ctx, fplan = _chaos_ctx(mesh, seed=19, p=0.25)
    got = run(chaos_ctx)
    assert np.array_equal(got, want)
    assert len(fplan.injected) > 0 and fplan.unrecovered() == []


# ---------------------------------------------------------------------------
# RMA checksum validation: corruption detected and repaired, never absorbed
# ---------------------------------------------------------------------------

def _kv(page_tokens=16):
    mem = GlobalMemory(4, 1 << 22, allocator="buddy")
    g = DiompGroup(("x",), name="x")
    return PagedKVAllocator(mem, g, page_tokens=page_tokens,
                            kv_bytes_per_token=64)


class _Rec:
    def __init__(self):
        self.calls, self.nbytes = {}, {}
        self.retries, self.retry_nbytes = {}, {}

    def record(self, op, payload=None):
        self.calls[op] = self.calls.get(op, 0) + 1
        if payload is not None:
            self.nbytes[op] = self.nbytes.get(op, 0) + payload.nbytes

    def record_retry(self, op, payload=None):
        self.retries[op] = self.retries.get(op, 0) + 1
        if payload is not None:
            self.retry_nbytes[op] = self.retry_nbytes.get(op, 0) \
                + payload.nbytes


def test_migrate_checksum_detects_and_repairs_corruption():
    alloc = _kv()
    r = alloc.admit(30, 60, home_rank=0)
    npages = len(r.page_table)
    comm, tr = _Rec(), RMATracker()
    tr.register("w")
    plan = FaultPlan(0, specs=(FaultSpec("migrate", 0, "corrupt"),))
    moved = alloc.migrate(r, 3, comm=comm, tracker=tr, window="w",
                          faults=plan, policy=FAST, validate=True)
    assert moved == npages * alloc.page_bytes
    assert r.home_rank == 3
    # logical logs exactly as the fault-free path...
    assert comm.calls == {"get": npages, "put": npages}
    assert comm.nbytes["put"] == moved
    assert tr.put_bytes == moved
    # ...and the repair visible only in the retry logs
    assert alloc.stats["retried_page_puts"] >= 1
    assert comm.retries.get("put", 0) >= 1
    assert tr.retry_bytes == comm.retry_nbytes["put"]
    assert plan.injected[0].kind == "corrupt" and plan.injected[0].recovered


def test_migrate_validation_exhausts_budget_raises():
    alloc = _kv()
    r = alloc.admit(20, 40, home_rank=0)
    comm, tr = _Rec(), RMATracker()
    tr.register("w")
    # corrupt EVERY attempt on page 0: the budget must be spent and the
    # error surfaced — garbage never lands silently
    specs = tuple(FaultSpec("migrate", i, "corrupt") for i in range(16))
    plan = FaultPlan(0, specs=specs)
    pol = RetryPolicy(max_retries=2, sleep=False)
    with pytest.raises(RMAError):
        alloc.migrate(r, 2, comm=comm, tracker=tr, window="w",
                      faults=plan, policy=pol, validate=True)


def test_validate_rejects_unfenced_and_mismatched():
    tr = RMATracker()
    tr.register("w")
    buf = np.arange(16, dtype=np.uint8)
    good = content_digest(buf)
    tr.on_put("w", buf.nbytes, checksum=good)
    with pytest.raises(RMAError):
        tr.validate("w", good)                    # unfenced epoch
    tr.on_fence("w")
    tr.validate("w", good)                        # clean pass
    tr.on_put("w", buf.nbytes, checksum=corrupt_digest(good, 1))
    tr.on_fence("w")
    with pytest.raises(RMAError, match="checksum mismatch"):
        tr.validate("w", good)

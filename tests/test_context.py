"""DiompContext + communicator-handle API + pluggable OMPCCL backends.

Covers the redesign invariants: every collective/RMA verb dispatches through
a CclBackend instance obtained from a context communicator handle; backend
choice propagates to every op (including reduce/bcast, which the free-
function API used to silently flatten); plugins register without touching
call sites; and the paper-verbatim ompx_* compat layer produces identical
results and per-op call counts to the handle API.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

import repro as diomp
from repro.core import backends, ompccl, ompx, rma
from repro.core.compat import shard_map
from repro.core.context import DiompContext, default_context
from repro.core.groups import DiompGroup

WORLD = DiompGroup(("pod", "data", "model"), name="world")
DP = DiompGroup(("pod", "data"), name="dp")
RING = DiompGroup(("x",), name="x")


def _run(mesh, fn, x, in_spec, out_spec):
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec))(x))


# ---------------------------------------------------------------------------
# the handle API end to end
# ---------------------------------------------------------------------------


def test_handle_collectives_numerics(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    comm = ctx.communicator(WORLD)
    x = np.random.RandomState(0).randn(8, 4).astype(np.float32)
    spec = P(("pod", "data", "model"))

    got = _run(mesh8, lambda v: comm.allreduce(v), x, spec, spec)
    np.testing.assert_allclose(
        got, np.repeat(x.sum(0, keepdims=True), 8, axis=0), rtol=1e-5)

    got = _run(mesh8, lambda v: comm.bcast(v, root=3), x, spec, spec)
    np.testing.assert_allclose(got, np.tile(x[3], (8, 1)), rtol=1e-6)

    got = _run(mesh8, lambda v: comm.reduce(v, root=2), x, spec, spec)
    want = np.zeros_like(x)
    want[2] = x.sum(0)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_handle_rma_verbs(ring8):
    ctx = DiompContext(mesh=ring8, segment_bytes=1 << 20)
    comm = ctx.communicator(RING)
    x = np.arange(32, dtype=np.float32).reshape(8, 4)

    got = _run(ring8,
               lambda v: comm.get(comm.fence(comm.put(v, shift=3)), shift=3),
               x, P("x"), P("x"))
    np.testing.assert_allclose(got, x)

    def halo(v):
        l, r = comm.halo_exchange(v, halo=1, axis=0)
        return jnp.concatenate([l, r], axis=0)

    got = _run(ring8, halo, np.arange(24, dtype=np.float32).reshape(24, 1),
               P("x"), P("x"))
    lr = got.reshape(8, 2)
    assert lr[0, 0] == 0.0 and lr[7, 1] == 0.0
    # exactly one halo_exchange + one put + one get recorded on the group
    calls = ctx.stats()[RING.descriptor()]
    assert calls["halo_exchange"] == 1
    assert calls["get"] == 1 and calls["put"] == 2  # get records its put


def test_group_lookup_by_name(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    comm = ctx.communicator("world")
    assert comm.group.axes == tuple(mesh8.axis_names)


# ---------------------------------------------------------------------------
# backend propagation — the dropped-backend bug class
# ---------------------------------------------------------------------------


class _SpyBackend(backends.XlaBackend):
    """Counts which verbs were dispatched through it."""

    name = "spy"

    def __init__(self):
        self.ops = []

    def allreduce(self, x, group, *, op="sum"):
        self.ops.append("allreduce")
        return super().allreduce(x, group, op=op)

    def bcast(self, x, group, *, root=0):
        self.ops.append("bcast")
        return super().bcast(x, group, root=root)


backends.register_backend(_SpyBackend)


def test_backend_propagates_to_reduce_and_bcast(mesh8):
    """reduce/bcast run through the handle's backend — previously both
    silently fell back to the flat path whatever the caller asked for."""
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    comm = ctx.communicator(WORLD, backend="spy")
    x = np.random.RandomState(1).randn(8, 4).astype(np.float32)
    spec = P(("pod", "data", "model"))

    _run(mesh8, lambda v: comm.reduce(v, root=2), x, spec, spec)
    _run(mesh8, lambda v: comm.bcast(v, root=1), x, spec, spec)
    # reduce routes through the backend's allreduce; bcast dispatches and
    # then routes its masked contribution through allreduce too
    assert comm.backend.ops == ["allreduce", "bcast", "allreduce"]


def test_free_function_backend_propagates(mesh8):
    """The compat free functions honor backend= for every op too."""
    spy = default_context().communicator(WORLD, backend="spy").backend
    before = len(spy.ops)
    x = np.random.RandomState(2).randn(8, 4).astype(np.float32)
    spec = P(("pod", "data", "model"))
    _run(mesh8, lambda v: ompccl.reduce(v, WORLD, root=0, backend="spy"),
         x, spec, spec)
    _run(mesh8, lambda v: ompccl.bcast(v, WORLD, root=0, backend="spy"),
         x, spec, spec)
    assert spy.ops[before:] == ["allreduce", "bcast", "allreduce"]


def test_hierarchical_backend_handles_match_flat(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    flat = ctx.communicator(DP)
    hier = ctx.communicator(DP, backend="hierarchical")
    x = np.random.RandomState(3).randn(8, 16).astype(np.float32)
    a = _run(mesh8, lambda v: flat.allreduce(v), x,
             P(("pod", "data"), "model"), P(None, "model"))
    b = _run(mesh8, lambda v: hier.allreduce(v), x,
             P(("pod", "data"), "model"), P(None, "model"))
    np.testing.assert_allclose(a, b, rtol=1e-5)
    c = _run(mesh8, lambda v: hier.bcast(v, root=1), x,
             P(("pod", "data"), "model"), P(None, "model"))
    d = _run(mesh8, lambda v: flat.bcast(v, root=1), x,
             P(("pod", "data"), "model"), P(None, "model"))
    np.testing.assert_allclose(c, d, rtol=1e-5)


def test_backend_registry_plugin_and_errors():
    assert set(backends.available_backends()) >= {
        "xla", "flat", "hierarchical", "compressed", "analytic", "spy"}
    with pytest.raises(backends.BackendError):
        backends.get_backend("no-such-backend")
    with pytest.raises(backends.BackendError):
        backends.register_backend(object)  # not a CclBackend

    class Custom(backends.XlaBackend):
        name = "custom-plugin"

    backends.register_backend(Custom, aliases=("cp",))
    assert backends.get_backend("cp") is Custom
    # a fresh context resolves it by name with zero call-site changes
    ctx = DiompContext(segment_bytes=1 << 20)
    assert ctx.communicator(RING, backend="cp").backend_name == "custom-plugin"


def test_analytic_backend_cost_log(ring8):
    ctx = DiompContext(mesh=ring8, segment_bytes=1 << 20)
    comm = ctx.communicator(RING, backend="analytic")
    x = np.random.RandomState(4).randn(8, 128).astype(np.float32)
    got = _run(ring8, lambda v: comm.allreduce(v), x, P("x"), P("x"))
    np.testing.assert_allclose(
        got, np.repeat(x.sum(0, keepdims=True), 8, axis=0), rtol=1e-5)
    (est,) = comm.backend.estimates
    assert est["op"] == "allreduce" and est["ndev"] == 8
    assert est["bytes"] == 128 * 4  # local shard bytes
    assert est["est_s"] > 0


# ---------------------------------------------------------------------------
# context plumbing
# ---------------------------------------------------------------------------


def test_shared_call_log_across_backends(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    flat = ctx.communicator(DP)
    hier = ctx.communicator(DP, backend="hierarchical")
    assert flat is not hier and flat.calls is hier.calls
    flat.record("allreduce")
    hier.record("allreduce")
    assert ctx.stats()[DP.descriptor()] == {"allreduce": 2}
    ctx.reset_stats()
    assert ctx.stats() == {}


def test_default_context_init_and_runtime_share_table(mesh8):
    from repro.core.runtime import DiompRuntime

    rt = DiompRuntime(mesh8, segment_bytes=1 << 22)
    assert rt.ctx is default_context()
    assert rt.communicator(WORLD).group is WORLD
    assert rt.ccl is rt.ctx.comms
    rt.close()
    # restore an un-meshed default for whatever test runs next
    diomp.reset_default_context()


def test_use_default_scopes_and_restores():
    prev = default_context()
    tmp = DiompContext(segment_bytes=1 << 20)
    with diomp.use_default(tmp) as active:
        assert active is tmp and default_context() is tmp
        inner = DiompContext(segment_bytes=1 << 20)
        with diomp.use_default(inner):
            assert default_context() is inner
        assert default_context() is tmp
    assert default_context() is prev


def test_use_default_is_thread_scoped():
    """A scope open on one thread never leaks into another, and overlapping
    scopes on two threads cannot clobber the process default."""
    import threading

    prev = default_context()
    a, b = DiompContext(segment_bytes=1 << 20), \
        DiompContext(segment_bytes=1 << 20)
    seen = {}
    gate_a, gate_b = threading.Event(), threading.Event()

    def worker(name, ctx, my_gate, other_gate):
        with diomp.use_default(ctx):
            my_gate.set()
            other_gate.wait(5)           # both scopes open concurrently
            seen[name] = default_context()

    ta = threading.Thread(target=worker, args=("a", a, gate_a, gate_b))
    tb = threading.Thread(target=worker, args=("b", b, gate_b, gate_a))
    ta.start(); tb.start(); ta.join(); tb.join()
    assert seen == {"a": a, "b": b}
    assert default_context() is prev


def test_compressed_backend_honors_sum_contract(mesh8):
    """allreduce(op='sum') through the compressed handle matches the flat
    sum within int8 tolerance; unsupported ops fail loudly."""
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    comm = ctx.communicator(DP, backend="compressed")
    x = np.random.RandomState(7).randn(4, 64).astype(np.float32)
    got = _run(mesh8, lambda v: comm.allreduce(v), x,
               P(("pod", "data"), "model"), P(("pod", "data"), "model"))
    want = np.tile(x.sum(0), (4, 1))
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 0.02
    with pytest.raises(ValueError, match="sum"):
        _run(mesh8, lambda v: comm.allreduce(v, op="max"), x,
             P(("pod", "data"), "model"), P(("pod", "data"), "model"))


def test_reset_keeps_live_handles_recording(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    comm = ctx.communicator(DP)
    comm.record("allreduce")
    ctx.reset_stats()
    comm.record("allreduce")   # handle must keep feeding the same table
    assert ctx.stats()[DP.descriptor()] == {"allreduce": 1}


def test_instance_backend_not_aliased_by_name():
    """Two differently configured instances of one backend class get their
    own handles; a registry-name handle never shadows a passed instance."""
    ctx = DiompContext(segment_bytes=1 << 20)
    by_name = ctx.communicator(RING, backend="analytic")
    mine = backends.AnalyticBackend(backends.LinkModel(bandwidth_Bps=1.0))
    by_inst = ctx.communicator(RING, backend=mine)
    assert by_inst.backend is mine and by_name.backend is not mine
    # same group -> still one shared call log
    assert by_inst.calls is by_name.calls


def test_registry_proxy_is_default_table(mesh8):
    diomp.reset_default_context()
    c1 = ompccl.registry.communicator(RING)
    c2 = default_context().communicator(RING)
    assert c1 is c2
    c1.record("allreduce")
    assert ompccl.registry.stats()[RING.descriptor()] == {"allreduce": 1}
    ompccl.registry.reset()
    assert ompccl.registry.stats() == {}


# ---------------------------------------------------------------------------
# ompx_* compat layer: identical results + per-op call counts
# ---------------------------------------------------------------------------


def test_ompx_results_match_handles(ring8):
    g = DiompGroup(("x",), name="ring")
    x = np.arange(16, dtype=np.float32).reshape(8, 2)

    def via_ompx(v):
        moved = ompx.ompx_fence(ompx.ompx_put(v, g, shift=1))
        return moved, ompx.ompx_allreduce(v, g), ompx.ompx_bcast(v, g, root=2)

    comm = DiompContext(mesh=ring8, segment_bytes=1 << 20).communicator(g)

    def via_handle(v):
        moved = comm.fence(comm.put(v, shift=1))
        return moved, comm.allreduce(v), comm.bcast(v, root=2)

    outs_a = jax.jit(shard_map(via_ompx, mesh=ring8, in_specs=P("x"),
                               out_specs=(P("x"),) * 3))(x)
    outs_b = jax.jit(shard_map(via_handle, mesh=ring8, in_specs=P("x"),
                               out_specs=(P("x"),) * 3))(x)
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_ompx_call_counts_match_seed_semantics(ring8):
    """The seed API recorded: reduce -> reduce+allreduce, get -> get+put,
    put_perm -> put; the compat layer must keep those counts exactly."""
    diomp.reset_default_context()
    g = DiompGroup(("x",), name="ring")
    x = np.arange(8, dtype=np.float32).reshape(8, 1)

    def ops(v):
        a = ompccl.allreduce(v, g)
        r = ompccl.reduce(v, g, root=0)
        b = ompccl.bcast(v, g, root=0)
        ag = ompccl.allgather(v, g, axis=0)
        rs = ompccl.reducescatter(ag, g, axis=0)
        a2a = ompccl.alltoall(v * 0 + ag, g, split_axis=0, concat_axis=0)
        pm = ompccl.permute(v, g, shift=1)
        bar = ompccl.barrier_value(g)
        p = rma.ompx_put(v, g, shift=1)
        gq = rma.ompx_get(v, g, shift=1)
        pp = rma.ompx_put_perm(v, g, [(i, i) for i in range(8)])
        h0, h1 = rma.halo_exchange(v, g, halo=1, axis=0)
        acc = (a + r + b + rs + pm + p + gq + pp + h0 + h1
               + a2a[:1] + 0 * bar)
        return acc

    jax.jit(shard_map(ops, mesh=ring8, in_specs=P("x"),
                      out_specs=P("x")))(x)
    calls = default_context().stats()[g.descriptor()]
    assert calls == {
        "allreduce": 2,       # allreduce + the one reduce() routes through
        "reduce": 1,
        "bcast": 1,
        "allgather": 1,
        "reducescatter": 1,
        "alltoall": 1,
        "permute": 1,
        "barrier": 1,
        "put": 3,             # put + put_perm + the one get() routes through
        "get": 1,
        "halo_exchange": 1,
    }
    diomp.reset_default_context()

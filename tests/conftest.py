"""Shared fixtures.  8 virtual CPU devices for the multi-device tests —
set BEFORE jax initializes (pytest imports conftest first)."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402,F401
import pytest  # noqa: E402

from repro.core.compat import make_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return make_mesh((2, 2, 2), ("pod", "data", "model"), axis_types="auto")


@pytest.fixture(scope="session")
def ring8():
    return make_mesh((8,), ("x",), axis_types="auto")

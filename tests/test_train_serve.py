"""Training + serving integration: optimizer descent, explicit-vs-implicit
DP equivalence, grad compression training, checkpoint round-trip with
elastic re-shard, straggler monitor, data determinism, serve engine."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import (adafactor, adafactor_dim_axes, adamw,
                               cosine_schedule)
from repro.train.step import build_train_step
from repro.train.straggler import StragglerMonitor

CFG = configs.get_reduced("glm4-9b")


def _setup(mesh8, **knobs):
    params = sch.init_params(CFG, jax.random.PRNGKey(0))
    ctx = ParallelCtx.from_mesh(mesh8, remat=True, **knobs)
    opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
    step = build_train_step(CFG, mesh8, ctx, opt, donate=False,
                            global_batch=8)
    ostate = jax.jit(opt.init)(params)
    batch = {"tokens": np.random.RandomState(1).randint(
        0, CFG.vocab_size, (8, 16)).astype(np.int32)}
    return params, ostate, step, batch


def _run_steps(params, ostate, step, batch, n=8):
    hist = []
    for i in range(n):
        params, ostate, m = step(params, ostate, batch, jnp.asarray(i))
        hist.append(float(m["loss"]))
    return hist


def test_loss_descends(mesh8):
    hist = _run_steps(*_setup(mesh8))
    assert hist[-1] < hist[0] - 0.1, hist


def test_explicit_equals_implicit_dp(mesh8):
    h1 = _run_steps(*_setup(mesh8, explicit_dp=True), n=5)
    h2 = _run_steps(*_setup(mesh8, explicit_dp=False), n=5)
    np.testing.assert_allclose(h1, h2, atol=2e-2)


def test_int8_grad_compression_trains(mesh8):
    hist = _run_steps(*_setup(mesh8, grad_codec="int8"), n=8)
    assert hist[-1] < hist[0] - 0.05, hist


def test_microbatch_matches(mesh8):
    h1 = _run_steps(*_setup(mesh8, microbatch=1), n=5)
    h2 = _run_steps(*_setup(mesh8, microbatch=4), n=5)
    np.testing.assert_allclose(h1, h2, atol=5e-2)


def test_ring_matmul_step(mesh8):
    hist = _run_steps(*_setup(mesh8, use_ring_matmul=True), n=4)
    base = _run_steps(*_setup(mesh8, use_ring_matmul=False), n=4)
    np.testing.assert_allclose(hist, base, atol=2e-2)


def test_adafactor_big_model_path(mesh8):
    params = sch.init_params(CFG, jax.random.PRNGKey(0))
    ctx = ParallelCtx.from_mesh(mesh8, remat=True)
    opt = adafactor(cosine_schedule(5e-3, warmup=2, total=40),
                    dim_axes=adafactor_dim_axes(CFG, mesh8))
    step = build_train_step(CFG, mesh8, ctx, opt, optimizer_name="adafactor",
                            donate=False, global_batch=8)
    ostate = jax.jit(opt.init)(params)
    batch = {"tokens": np.random.RandomState(1).randint(
        0, CFG.vocab_size, (8, 16)).astype(np.int32)}
    hist = _run_steps(params, ostate, step, batch, n=8)
    assert hist[-1] < hist[0] - 0.05, hist


def test_checkpoint_roundtrip_and_reshard(tmp_path, mesh8):
    params, ostate, step, batch = _setup(mesh8)
    params, ostate, _ = step(params, ostate, batch, jnp.asarray(0))
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    ckpt.save(1, jax.device_get(params), jax.device_get(ostate),
              blocking=True)
    s, p2, o2, _ = ckpt.restore()
    assert s == 1
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k], np.float32),
                                      np.asarray(p2[k], np.float32))
    # elastic: restore onto a DIFFERENT mesh via shard_fn
    from repro.core.compat import make_mesh
    mesh2 = make_mesh((4, 2), ("data", "model"), axis_types="auto")
    from repro.distributed.sharding import logical_to_spec
    from jax.sharding import NamedSharding
    schema = sch.build_schema(CFG)

    def shard_fn(name, arr):
        key = name.split("|")[-1] if "|" in name else name
        return jnp.asarray(arr)

    s, p3, _, _ = ckpt.restore(shard_fn=shard_fn)
    assert s == 1


def test_checkpoint_corruption_detected(tmp_path, mesh8):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"w": np.ones((4, 4), np.float32)}, {"v": np.zeros(3)},
              blocking=True)
    d = os.path.join(str(tmp_path), "step_00000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        ckpt.restore()


def test_checkpoint_gc_keeps_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"w": np.full((2,), s, np.float32)}, {}, blocking=True)
    assert ckpt.steps() == [3, 4]


def test_straggler_monitor_boost_and_evict():
    boosts, evicts = [], []
    m = StragglerMonitor(threshold=2.0, evict_after=3,
                         on_prefetch_boost=boosts.append,
                         on_evict=lambda: evicts.append(1))
    for i in range(5):
        m.step_end(i, dt=1.0)
    m.step_end(5, dt=5.0)
    m.step_end(6, dt=5.0)
    assert boosts == [1, 2]
    m.step_end(7, dt=6.0)
    assert evicts == [1]
    m.step_end(8, dt=1.0)     # recovery resets the streak
    assert m.consecutive == 0


def test_data_pipeline_deterministic_and_sharded():
    cfg = configs.get_reduced("stablelm-3b")
    a = SyntheticLM(cfg, 4, 8, seed=7, shard=0)
    b = SyntheticLM(cfg, 4, 8, seed=7, shard=0)
    c = SyntheticLM(cfg, 4, 8, seed=7, shard=1)
    np.testing.assert_array_equal(a.batch_at(5)["tokens"],
                                  b.batch_at(5)["tokens"])
    assert not np.array_equal(a.batch_at(5)["tokens"],
                              c.batch_at(5)["tokens"])
    pf = Prefetcher(a, depth=3)
    steps = [pf.get()[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]        # resumable order
    pf.boost(2)
    assert pf.depth == 5


def test_serve_engine_continuous_batching(mesh8):
    cfg = configs.get_reduced("stablelm-3b")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    ctx = ParallelCtx.from_mesh(mesh8, remat=False, inference=True)
    eng = ServeEngine(cfg, mesh8, ctx, params, slots=2, max_len=48)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(0, cfg.vocab_size, size=n), max_new=4)
            for n in (3, 2, 5, 1)]
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    st = eng.kv_stats
    assert st["pages_allocated"] == st["pages_freed"] > 0
    assert st["oom_events"] == 0


def test_int8_weight_gathers_track_exact(mesh8):
    """gather_codec=int8 (custom_vjp: int8 wire fwd, exact RS bwd) trains
    within 2e-3/step of the exact gather."""
    h_none = _run_steps(*_setup(mesh8, gather_codec="none"), n=6)
    h_q8 = _run_steps(*_setup(mesh8, gather_codec="int8"), n=6)
    np.testing.assert_allclose(h_q8, h_none, atol=5e-2)
    assert h_q8[-1] < h_q8[0] - 0.1

"""Minimal stand-in for the hypothesis API used by this suite.

The container may not ship ``hypothesis``; property tests fall back to this
deterministic random-sampling harness (seeded per test name) implementing
just the surface we use: ``given``, ``settings``, and the ``lists`` /
``tuples`` / ``booleans`` / ``integers`` strategies.  With real hypothesis
installed the import sites prefer it and this module is inert.
"""

from __future__ import annotations

import inspect
import random
import zlib
from typing import Any, Callable, List


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class st:  # noqa: N801 - mimics `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def tuples(*parts: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(p.draw(rng) for p in parts))

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elem.draw(rng) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._minihyp_max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        all_params = list(inspect.signature(fn).parameters)
        drawn_names = all_params[len(all_params) - len(strategies):]

        def wrapper(*args, **kwargs):
            n = getattr(fn, "_minihyp_max_examples",
                        getattr(wrapper, "_minihyp_max_examples", 50))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strategies)}
                fn(*args, **kwargs, **drawn)

        # expose only the non-drawn leading params (fixtures) to pytest;
        # the drawn trailing params are filled here, like hypothesis does
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: max(len(params) - len(strategies), 0)]
        wrapper.__signature__ = inspect.Signature(keep)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper._minihyp_max_examples = getattr(fn, "_minihyp_max_examples",
                                                50)
        return wrapper

    return deco

"""PGAS heap: allocators, symmetric/asymmetric regions, pointer cache.

Property tests (hypothesis) assert the allocator invariants the paper's
runtime depends on: free+live extents tile the arena exactly, symmetric
offsets stay identical across ranks, second-level pointers resolve to the
right payloads, and frees invalidate cached remote pointers.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _minihyp import given, settings, st

from repro.core.groups import DiompGroup
from repro.core.pgas import (AllocError, BuddyAllocator, GlobalMemory,
                             LinearAllocator)

G = DiompGroup(("x",), name="x")


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5000)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_linear_allocator_invariants(ops):
    a = LinearAllocator(1 << 16)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            try:
                live.append(a.alloc(size))
            except AllocError:
                pass
        else:
            a.free(live.pop(len(live) // 2))
        a.check_invariants()
    assert a.bytes_in_use + a.bytes_free == a.capacity


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4096)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_buddy_allocator_invariants(ops):
    a = BuddyAllocator(1 << 16)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            try:
                live.append(a.alloc(size))
            except AllocError:
                pass
        else:
            a.free(live.pop(0))
        a.check_invariants()


def test_buddy_coalescing_full_cycle():
    a = BuddyAllocator(1 << 12)
    offs = [a.alloc(256) for _ in range(16)]
    for o in offs:
        a.free(o)
    # after freeing everything, one max-order block must be available again
    assert a.alloc(1 << 12) == 0


def test_symmetric_offsets_identical():
    gm = GlobalMemory(4, 1 << 16)
    r1 = gm.alloc_symmetric("a", 1000, G)
    r2 = gm.alloc_symmetric("b", 500, G)
    assert len(set(r1.offsets)) == 1 and len(set(r2.offsets)) == 1
    assert r1.remote_address(3) == (3, r1.offsets[0])


def test_asymmetric_requires_slp():
    gm = GlobalMemory(4, 1 << 16)
    slp = gm.alloc_asymmetric("kv", [100, 200, 300, 400], G)
    with pytest.raises(AllocError):
        slp.region.remote_address(2)     # direct offset translation forbidden
    assert gm.translate(slp, 2) == (2, slp.region.offsets[2])


def test_remote_ptr_cache_hits_and_invalidation():
    gm = GlobalMemory(4, 1 << 16)
    slp = gm.alloc_asymmetric("kv", [64, 128, 256, 512], G)
    gm.translate(slp, 1)
    gm.translate(slp, 1)
    gm.translate(slp, 2)
    assert gm.ptr_cache.hits == 1 and gm.ptr_cache.misses == 2
    gm.free(slp)
    assert not gm.ptr_cache._cache          # invalidated with the region
    with pytest.raises(AllocError):
        gm.free(slp)                        # double free


def test_alloc_rollback_on_oom():
    gm = GlobalMemory(2, 4096)
    gm.alloc_symmetric("big", 3500, G)
    before = gm.bytes_in_use()
    with pytest.raises(AllocError):
        gm.alloc_symmetric("too-big", 3000, G)
    assert gm.bytes_in_use() == before      # nothing leaked
    gm.check_invariants()


def test_mapping_table_contents():
    gm = GlobalMemory(2, 1 << 16)
    gm.alloc_symmetric("w", 128, G, logical_axes=("embed", "mlp"),
                       dtype="bfloat16")
    (row,) = gm.mapping_table()
    assert row["name"] == "w" and row["symmetric"]
    assert row["logical_axes"] == ("embed", "mlp")


# ---------------------------------------------------------------------------
# allocator churn + collective-alloc rollback + pointer-cache lifetime
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("allocator", ["linear", "buddy"])
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 3000)),
                min_size=1, max_size=50))
@settings(max_examples=40, deadline=None)
def test_global_memory_randomized_churn(allocator, ops):
    """Mixed symmetric/asymmetric alloc/free churn keeps every arena's
    invariants (free+live extents tile the segment; symmetric offsets stay
    in lockstep) and leaks nothing once everything is freed."""
    gm = GlobalMemory(4, 1 << 15, allocator=allocator)
    live = []
    for i, (kind, size) in enumerate(ops):
        if kind == 0 or not live:          # symmetric alloc
            try:
                live.append(gm.alloc_symmetric(f"s{i}", size, G))
            except AllocError:
                pass
        elif kind == 1:                    # asymmetric alloc
            try:
                live.append(gm.alloc_asymmetric(
                    f"a{i}", [size, size // 2 + 1, size * 2, 1], G))
            except AllocError:
                pass
        else:                              # free the middle handle
            gm.free(live.pop(len(live) // 2))
        gm.check_invariants()
        # symmetric regions must keep identical offsets on every rank
        for r in gm.regions():
            if r.symmetric:
                assert len(set(r.offsets)) == 1, r
    for h in live:
        gm.free(h)
        gm.check_invariants()
    assert all(gm.bytes_in_use(r) == 0 for r in range(4))


def test_asymmetric_rollback_on_mid_collective_alloc_error():
    """If one rank's arena cannot satisfy its share of a collective
    asymmetric allocation, every already-placed shard AND the second-level
    pointer slot roll back — no rank leaks (paper: 'all participating
    nodes coordinate')."""
    gm = GlobalMemory(4, 4096)
    # diverge the arenas: rank 2 nearly full, others roomy
    keep = gm.alloc_asymmetric("warm", [256, 256, 3328, 256], G)
    before_use = [gm.bytes_in_use(r) for r in range(4)]
    before_slp = gm._slp_arena.bytes_in_use
    with pytest.raises(AllocError):
        # ranks 0..1 succeed, rank 2 cannot fit 2048 -> mid-collective abort
        gm.alloc_asymmetric("boom", [128, 128, 2048, 128], G)
    assert [gm.bytes_in_use(r) for r in range(4)] == before_use
    assert gm._slp_arena.bytes_in_use == before_slp
    gm.check_invariants()
    # the arena still serves what actually fits
    ok = gm.alloc_asymmetric("ok", [128, 128, 256, 128], G)
    gm.free(ok)
    gm.free(keep)
    assert all(gm.bytes_in_use(r) == 0 for r in range(4))


def test_remote_ptr_cache_scoped_invalidation_on_free():
    """Freeing one region invalidates exactly its cached remote pointers;
    other regions' entries keep their validity (and their hits)."""
    gm = GlobalMemory(4, 1 << 16)
    a = gm.alloc_asymmetric("a", [64, 128, 256, 512], G)
    b = gm.alloc_asymmetric("b", [32, 32, 32, 32], G)
    for r in range(4):
        gm.translate(a, r)
        gm.translate(b, r)
    assert gm.ptr_cache.misses == 8
    gm.free(a)
    # b's entries survived: all four hits, no new misses
    hits0 = gm.ptr_cache.hits
    for r in range(4):
        gm.translate(b, r)
    assert gm.ptr_cache.hits == hits0 + 4 and gm.ptr_cache.misses == 8
    # a is gone from the cache; a fresh region re-misses (new rid)
    a2 = gm.alloc_asymmetric("a2", [64, 64, 64, 64], G)
    gm.translate(a2, 0)
    assert gm.ptr_cache.misses == 9
    gm.free(a2)
    gm.free(b)

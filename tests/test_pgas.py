"""PGAS heap: allocators, symmetric/asymmetric regions, pointer cache.

Property tests (hypothesis) assert the allocator invariants the paper's
runtime depends on: free+live extents tile the arena exactly, symmetric
offsets stay identical across ranks, second-level pointers resolve to the
right payloads, and frees invalidate cached remote pointers.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.groups import DiompGroup
from repro.core.pgas import (AllocError, BuddyAllocator, GlobalMemory,
                             LinearAllocator)

G = DiompGroup(("x",), name="x")


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5000)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_linear_allocator_invariants(ops):
    a = LinearAllocator(1 << 16)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            try:
                live.append(a.alloc(size))
            except AllocError:
                pass
        else:
            a.free(live.pop(len(live) // 2))
        a.check_invariants()
    assert a.bytes_in_use + a.bytes_free == a.capacity


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 4096)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_buddy_allocator_invariants(ops):
    a = BuddyAllocator(1 << 16)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            try:
                live.append(a.alloc(size))
            except AllocError:
                pass
        else:
            a.free(live.pop(0))
        a.check_invariants()


def test_buddy_coalescing_full_cycle():
    a = BuddyAllocator(1 << 12)
    offs = [a.alloc(256) for _ in range(16)]
    for o in offs:
        a.free(o)
    # after freeing everything, one max-order block must be available again
    assert a.alloc(1 << 12) == 0


def test_symmetric_offsets_identical():
    gm = GlobalMemory(4, 1 << 16)
    r1 = gm.alloc_symmetric("a", 1000, G)
    r2 = gm.alloc_symmetric("b", 500, G)
    assert len(set(r1.offsets)) == 1 and len(set(r2.offsets)) == 1
    assert r1.remote_address(3) == (3, r1.offsets[0])


def test_asymmetric_requires_slp():
    gm = GlobalMemory(4, 1 << 16)
    slp = gm.alloc_asymmetric("kv", [100, 200, 300, 400], G)
    with pytest.raises(AllocError):
        slp.region.remote_address(2)     # direct offset translation forbidden
    assert gm.translate(slp, 2) == (2, slp.region.offsets[2])


def test_remote_ptr_cache_hits_and_invalidation():
    gm = GlobalMemory(4, 1 << 16)
    slp = gm.alloc_asymmetric("kv", [64, 128, 256, 512], G)
    gm.translate(slp, 1)
    gm.translate(slp, 1)
    gm.translate(slp, 2)
    assert gm.ptr_cache.hits == 1 and gm.ptr_cache.misses == 2
    gm.free(slp)
    assert not gm.ptr_cache._cache          # invalidated with the region
    with pytest.raises(AllocError):
        gm.free(slp)                        # double free


def test_alloc_rollback_on_oom():
    gm = GlobalMemory(2, 4096)
    gm.alloc_symmetric("big", 3500, G)
    before = gm.bytes_in_use()
    with pytest.raises(AllocError):
        gm.alloc_symmetric("too-big", 3000, G)
    assert gm.bytes_in_use() == before      # nothing leaked
    gm.check_invariants()


def test_mapping_table_contents():
    gm = GlobalMemory(2, 1 << 16)
    gm.alloc_symmetric("w", 128, G, logical_axes=("embed", "mlp"),
                       dtype="bfloat16")
    (row,) = gm.mapping_table()
    assert row["name"] == "w" and row["symmetric"]
    assert row["logical_axes"] == ("embed", "mlp")

"""Bucketed gradient reduction (repro.distributed.buckets): pack/unpack
round-trips over every config schema, plan determinism, the call-log ceil
bound, int8 error-feedback equivalence, and the backward-overlap schedule."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.compat import shard_map
from repro.core.context import DiompContext, use_default
from repro.core.groups import group_for_axes
from repro.distributed import buckets as bk
from repro.distributed.sharding import rules_for_ctx
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.train.step import build_train_step, reduce_gradients

CFG = configs.get_reduced("glm4-9b")
SMALL_BUCKET = 1 << 14          # force multi-bucket plans on reduced configs


def _plan(cfg, mesh, ctx, **kw):
    return bk.plan_for_config(cfg, mesh, ctx, **kw)


def _rand_grads(plan, seed=0):
    rng = np.random.RandomState(seed)
    return {n: rng.randn(*shp).astype(np.float32)
            for n, shp in plan.shapes.items()}


# ---------------------------------------------------------------------------
# pack / unpack index maps
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_all_configs(mesh8):
    """The pack->unpack index maps are exact inverses for every assigned
    architecture's (reduced) schema, including params split across
    bucket boundaries."""
    split_seen = False
    for arch in configs.all_archs():
        cfg = configs.get_reduced(arch)
        ctx = ParallelCtx.from_mesh(mesh8)
        plan = _plan(cfg, mesh8, ctx, bucket_bytes=SMALL_BUCKET)
        grads = _rand_grads(plan)
        bufs = bk.pack_buckets({n: jnp.asarray(g) for n, g in grads.items()},
                               plan)
        out = bk.unpack_buckets(bufs, plan)
        assert set(out) | set(plan.local) == set(plan.shapes), arch
        for name, got in out.items():
            np.testing.assert_array_equal(np.asarray(got), grads[name],
                                          err_msg=f"{arch}:{name}")
        split_seen |= any(len({s.name for s in b.slices}) > 1
                          or s.start > 0
                          for b in plan.buckets for s in b.slices)
    assert split_seen  # at 16 KiB some param crosses a bucket boundary


def test_every_gradient_covered_exactly_once(mesh8):
    """Schedule coverage: each param is either local (no collective needed)
    or its flattened payload is tiled exactly once by bucket slices; each
    bucket is gap-free and padded to its layout multiple."""
    sizes = dict(mesh8.shape)
    for arch in configs.all_archs():
        cfg = configs.get_reduced(arch)
        ctx = ParallelCtx.from_mesh(mesh8)
        plan = _plan(cfg, mesh8, ctx, bucket_bytes=SMALL_BUCKET)
        covered = {}
        for b in plan.buckets:
            pos = 0
            for s in sorted(b.slices, key=lambda s: s.offset):
                assert s.offset == pos, (arch, b.key, s)
                pos += s.size
                covered.setdefault(s.name, []).append((s.start, s.size))
            assert pos == b.size
            assert b.padded_size >= b.size
            assert b.padded_size % b.group_size(sizes) == 0
        for name, runs in covered.items():
            assert name not in plan.local
            pos = 0
            for start, size in sorted(runs):
                assert start == pos, (arch, name, runs)
                pos += size
            assert pos == int(np.prod(plan.shapes[name])), (arch, name)
        for name in plan.local:
            assert name not in covered


def test_plan_determinism_across_traces(mesh8):
    ctx = ParallelCtx.from_mesh(mesh8)
    plan = _plan(CFG, mesh8, ctx, bucket_bytes=SMALL_BUCKET)
    # the cache hands every trace the same object; a fresh planner over the
    # same static shapes reproduces it field for field
    assert _plan(CFG, mesh8, ctx, bucket_bytes=SMALL_BUCKET) is plan
    pspecs = sch.partition_specs(CFG, mesh8, rules_for_ctx(ctx))
    planner = bk.BucketPlanner(bucket_bytes=SMALL_BUCKET)
    seen = []

    def f(g):
        p = planner.plan_from_arrays(g, pspecs, ctx.dp_group.axes,
                                     dict(mesh8.shape))
        seen.append(p)
        return {k: v for k, v in bk.pack_buckets(g, p).items()}

    grads = _rand_grads(plan)
    gspecs = {n: P() for n in grads}
    for _ in range(2):  # two independent traces
        jax.jit(shard_map(f, mesh=mesh8, in_specs=(gspecs,),
                          out_specs={b.key: P() for b in plan.buckets})
                )(grads)
    assert seen[0] == seen[1] == planner.plan(
        plan.shapes, pspecs, ctx.dp_group.axes, dict(mesh8.shape))
    assert seen[0].bucket_count() == plan.bucket_count()


# ---------------------------------------------------------------------------
# the call-log bound (the acceptance criterion)
# ---------------------------------------------------------------------------


def _traced_reduce(mesh, cfg, ctx, plan, pspecs, dctx):
    def red(g):
        with use_default(dctx):
            out, _ = reduce_gradients(g, cfg, ctx, pspecs=pspecs, plan=plan)
        return out

    gspecs = {n: pspecs[n] for n in sch.build_schema(cfg)}
    return jax.jit(shard_map(red, mesh=mesh, in_specs=(gspecs,),
                             out_specs=gspecs))


def _global_grads(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return {n: rng.randn(*s.shape).astype(np.float32)
            for n, s in sch.build_schema(cfg).items()}


def test_bucketed_call_log_ceil_bound(mesh8):
    """Per (group, backend): the bucketed reduction issues exactly the
    plan's bucket count of collectives, which is ceil(partition_bytes /
    bucket_bytes) per (group, dtype, dup) partition — verified against the
    communicator call log, alongside the wire-byte log."""
    ctx = ParallelCtx.from_mesh(mesh8, bucket_bytes=SMALL_BUCKET)
    pspecs = sch.partition_specs(CFG, mesh8, rules_for_ctx(ctx))
    plan = _plan(CFG, mesh8, ctx)
    assert len(plan.buckets) > len(plan.bucket_count())  # multi-bucket run
    dctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    _traced_reduce(mesh8, CFG, ctx, plan, pspecs, dctx)(_global_grads(CFG))

    stats, bstats = dctx.stats(), dctx.byte_stats()
    want_calls, want_bytes, part_bytes = {}, {}, {}
    for b in plan.buckets:
        d = group_for_axes(b.axes).descriptor()
        want_calls[d] = want_calls.get(d, 0) + 1
        want_bytes[d] = want_bytes.get(d, 0) + b.padded_nbytes
        part_bytes.setdefault((b.axes, b.dtype, b.dup), 0)
        part_bytes[(b.axes, b.dtype, b.dup)] += b.nbytes
    # per-partition ceil bound, exactly met by the plan
    counts = {}
    for b in plan.buckets:
        counts[(b.axes, b.dtype, b.dup)] = \
            counts.get((b.axes, b.dtype, b.dup), 0) + 1
    for key, n in counts.items():
        assert n == -(-part_bytes[key] // plan.bucket_bytes), (key, n)
    # the call log agrees with the plan, group by group
    for d, n in want_calls.items():
        assert stats[d].get("allreduce", 0) == n, (d, stats[d])
        assert bstats[d].get("allreduce", 0) == want_bytes[d], (d, bstats[d])


def test_default_bucketing_reduces_calls_and_matches_perparam(mesh8):
    """At the default 4 MiB bucket size every partition fits one bucket:
    strictly fewer collectives than per-param issue, identical result."""
    ctx_bk = ParallelCtx.from_mesh(mesh8)
    ctx_pp = ParallelCtx.from_mesh(mesh8, bucket_bytes=0)
    pspecs = sch.partition_specs(CFG, mesh8, rules_for_ctx(ctx_bk))
    plan = _plan(CFG, mesh8, ctx_bk)
    grads = _global_grads(CFG)
    d_bk = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    d_pp = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    out_bk = _traced_reduce(mesh8, CFG, ctx_bk, plan, pspecs, d_bk)(grads)
    out_pp = _traced_reduce(mesh8, CFG, ctx_pp, None, pspecs, d_pp)(grads)

    def n_allreduce(d):
        return sum(c.get("allreduce", 0) for c in d.stats().values())

    n_bk, n_pp = n_allreduce(d_bk), n_allreduce(d_pp)
    parts = {(b.axes, b.dtype, b.dup) for b in plan.buckets}
    assert n_bk == len(plan.buckets) == len(parts)  # one bucket/partition
    assert n_bk < n_pp
    for name in out_bk:
        np.testing.assert_allclose(np.asarray(out_bk[name]),
                                   np.asarray(out_pp[name]),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# int8 error feedback, one state per bucket
# ---------------------------------------------------------------------------


def test_int8_error_feedback_equivalence(mesh8):
    """Bucketed int8 (per-block scales, ONE error-feedback state per
    bucket) stays within quantization tolerance of the per-param codec and
    of the exact f32 mean, with the residual carried across rounds."""
    ctx_ex = ParallelCtx.from_mesh(mesh8, bucket_bytes=0)
    ctx_pp = ParallelCtx.from_mesh(mesh8, bucket_bytes=0, grad_codec="int8")
    ctx_bk = ParallelCtx.from_mesh(mesh8, grad_codec="int8")
    pspecs = sch.partition_specs(CFG, mesh8, rules_for_ctx(ctx_bk))
    plan = _plan(CFG, mesh8, ctx_bk)
    assert plan.bucket_bytes == ctx_bk.bucket_bytes
    grads = _global_grads(CFG, seed=3)
    gspecs = {n: pspecs[n] for n in grads}

    def iterated(ctx, plan_):
        def f(g):
            errors, acc = {}, None
            for _ in range(4):
                out, errors = reduce_gradients(g, CFG, ctx, errors=errors,
                                               pspecs=pspecs, plan=plan_)
                acc = out if acc is None else \
                    {n: acc[n] + out[n] for n in out}
            return {n: a / 4 for n, a in acc.items()}
        return jax.jit(shard_map(f, mesh=mesh8, in_specs=(gspecs,),
                                 out_specs=gspecs))(grads)

    exact = iterated(ctx_ex, None)
    pp = iterated(ctx_pp, None)
    bks = iterated(ctx_bk, plan)
    for name in exact:
        e = np.asarray(exact[name])
        scale = max(np.abs(e).max(), 1e-3)
        # both codecs within the int8 bound of the exact mean...
        assert np.abs(np.asarray(pp[name]) - e).max() / scale < 0.02, name
        assert np.abs(np.asarray(bks[name]) - e).max() / scale < 0.02, name
        # ...and of each other
        assert (np.abs(np.asarray(bks[name]) - np.asarray(pp[name])).max()
                / scale < 0.04), name


# ---------------------------------------------------------------------------
# backward overlap: RS inside the scan, AG after it
# ---------------------------------------------------------------------------


def _run_step(mesh8, n=5, **knobs):
    from repro.train.optim import adamw, cosine_schedule

    params = sch.init_params(CFG, jax.random.PRNGKey(0))
    ctx = ParallelCtx.from_mesh(mesh8, remat=True, **knobs)
    opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
    step = build_train_step(CFG, mesh8, ctx, opt, donate=False,
                            global_batch=8)
    ostate = jax.jit(opt.init)(params)
    batch = {"tokens": np.random.RandomState(1).randint(
        0, CFG.vocab_size, (8, 16)).astype(np.int32)}
    hist = []
    for i in range(n):
        params, ostate, m = step(params, ostate, batch, jnp.asarray(i))
        hist.append(float(m["loss"]))
    return hist


def test_overlap_equals_nonoverlap(mesh8):
    """The RS-in-scan + trailing-AG pipeline is the same psum, split and
    pipelined: training trajectories match the unoverlapped bucket path."""
    h_ov = _run_step(mesh8, microbatch=4, overlap_grad_reduce=True)
    h_no = _run_step(mesh8, microbatch=4, overlap_grad_reduce=False)
    np.testing.assert_allclose(h_ov, h_no, atol=2e-2)


def test_overlap_schedule_call_log(mesh8):
    """In overlap mode every bucket reduce-scatters once inside the scan
    body and all-gathers once after it — no whole-bucket allreduce left."""
    from repro.train.optim import adamw, cosine_schedule

    params = sch.init_params(CFG, jax.random.PRNGKey(0))
    ctx = ParallelCtx.from_mesh(mesh8, remat=True, microbatch=4)
    plan = _plan(CFG, mesh8, ctx)
    assert plan.buckets
    opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
    step = build_train_step(CFG, mesh8, ctx, opt, donate=False,
                            global_batch=8)
    ostate = jax.jit(opt.init)(params)
    batch = {"tokens": np.random.RandomState(1).randint(
        0, CFG.vocab_size, (8, 16)).astype(np.int32)}
    dctx = DiompContext(mesh=mesh8, segment_bytes=1 << 20)
    with use_default(dctx):  # collective sites resolve at trace time
        step(params, ostate, batch, jnp.asarray(0))
    stats = dctx.stats()
    per_group = {}
    for b in plan.buckets:
        d = group_for_axes(b.axes).descriptor()
        per_group[d] = per_group.get(d, 0) + 1
    for d, n in per_group.items():
        ops = stats.get(d, {})
        assert ops.get("reducescatter", 0) == n, (d, ops)
        assert ops.get("allgather", 0) == n, (d, ops)
        assert ops.get("allreduce", 0) == 0, (d, ops)

"""Checkpoint crash recovery: torn writes, corrupt manifests, orphan GC.

The durability contract (train/checkpoint.py): a checkpoint is either
complete-and-verified or it does not exist.  ``latest()`` must skip a
damaged step and fall back to the newest intact one; ``restore`` must
refuse garbage with a clear error naming the damage; a crashed writer's
``step_XXXX.tmp`` must be reclaimed on the next startup, never promoted.
"""

import json
import os
import shutil

import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _params(seed=0):
    rng = np.random.RandomState(seed)
    return ({"w": rng.randn(8, 4).astype(np.float32),
             "b": rng.randn(4).astype(np.float32)},
            {"m": np.zeros((8, 4), np.float32)})


def _save_steps(ckpt, steps):
    for s in steps:
        params, opt = _params(s)
        ckpt.save(s, params, opt, blocking=True)


def _step_dir(d, step):
    return os.path.join(d, f"step_{step:08d}")


def _shard_files(d, step):
    sd = _step_dir(d, step)
    return [os.path.join(sd, f) for f in os.listdir(sd) if f.endswith(".npy")]


def test_truncated_shard_is_skipped_by_latest(tmp_path):
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [1, 2])
    assert ckpt.latest() == 2

    # tear the newest step mid-file, as a crash between write and fsync would
    victim = _shard_files(d, 2)[0]
    size = os.path.getsize(victim)
    with open(victim, "r+b") as f:
        f.truncate(size // 2)

    assert ckpt.verify_step(2) is False
    assert ckpt.verify_step(1) is True
    assert ckpt.latest() == 1          # damaged step 2 skipped, not fatal
    assert ckpt.latest(verify=False) == 2   # the unverified view still sees it

    step, params, _opt, _extra = ckpt.restore()
    want, _ = _params(1)
    assert step == 1
    np.testing.assert_array_equal(params["w"], want["w"])


def test_restore_damaged_step_raises_clear_error(tmp_path):
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [3])

    # flip bits in a shard: the manifest checksum no longer matches
    victim = _shard_files(d, 3)[0]
    with open(victim, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")

    with pytest.raises(IOError, match="damaged.*checksum mismatch"):
        ckpt.restore(step=3)


def test_restore_unreadable_manifest_raises(tmp_path):
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [4])
    with open(os.path.join(_step_dir(d, 4), "manifest.json"), "w") as f:
        f.write("{ not json")
    with pytest.raises(IOError, match="unreadable manifest"):
        ckpt.restore(step=4)
    assert ckpt.latest() is None       # nothing restorable left


def test_tampered_manifest_checksum_detected(tmp_path):
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [5])
    mpath = os.path.join(_step_dir(d, 5), "manifest.json")
    with open(mpath) as f:
        meta = json.load(f)
    name = next(iter(meta["files"]))
    meta["files"][name]["sha256"] = "0" * 64
    with open(mpath, "w") as f:
        json.dump(meta, f)
    assert ckpt.verify_step(5) is False
    with pytest.raises(IOError, match="refusing to load garbage"):
        ckpt.restore(step=5)


def test_orphaned_tmp_dirs_reclaimed_on_startup(tmp_path):
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [1])
    # a writer that died mid-save leaves a .tmp that must never be promoted
    orphan = os.path.join(d, "step_00000009.tmp")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "junk.npy"), "wb") as f:
        f.write(b"partial")

    fresh = CheckpointManager(d, keep=5)
    assert not os.path.exists(orphan)
    assert fresh.steps() == [1]        # the committed step untouched
    assert fresh.latest() == 1


def test_crash_between_saves_falls_back_across_gap(tmp_path):
    # steps 1..3 saved; 3 torn AND 2 removed wholesale (disk died mid-GC):
    # latest() must walk back to 1 rather than give up
    d = str(tmp_path)
    ckpt = CheckpointManager(d, keep=5)
    _save_steps(ckpt, [1, 2, 3])
    victim = _shard_files(d, 3)[0]
    with open(victim, "r+b") as f:
        f.truncate(1)
    shutil.rmtree(_step_dir(d, 2))
    assert ckpt.latest() == 1
    step, params, _o, _e = ckpt.restore()
    assert step == 1

"""Fused dropless MoE dispatch (kernels/moe_dispatch) + AllToAllPlan.

Contract under test:

* the fused one-sided dispatch is DROPLESS — bit-equivalent to the
  single-device oracle under load-imbalanced routing when the plan's
  asymmetric capacities come from measured load;
* the serialized ``host`` mode issues the identical traffic and numbers;
* gradients flow through the fenced schedule (it is the MoE train path);
* the OMPCCL byte log and the RMATracker's dispatch/combine window bytes
  agree exactly (the PGAS accounting the paper's asymmetric story needs);
* ``moe_capacity`` is the true ceiling (the old ``int(q + 1)`` overshot
  exact products), and the host capacity paths surface their overflow
  drops through ``DispatchStats`` while the dropless path records zero.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import ompccl
from repro.core.compat import make_mesh, shard_map
from repro.core.context import DiompContext, default_context, use_default
from repro.core.groups import DiompGroup
from repro.core.rma import dispatch_window_names
from repro.kernels.moe_dispatch import (measure_expert_load, moe_dispatch,
                                        moe_ref, route_topk)
from repro.kernels.plan import (AllToAllPlan, default_planner,
                                resolve_dispatch_impl)
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx
from repro.models.layers import moe_block, moe_capacity

RNG = np.random.RandomState(0)
GROUP = DiompGroup(("x",), name="epx")


# ---------------------------------------------------------------------------
# satellite: the capacity formula is the true ceiling
# ---------------------------------------------------------------------------

def test_moe_capacity_exact_products_do_not_overshoot():
    # exactly integral quotients: the old int(q + 1) returned 17 / 21 / 16
    assert moe_capacity(64, 2, 8, 1.0) == 16
    assert moe_capacity(64, 2, 8, 1.25) == 20
    assert moe_capacity(60, 2, 8, 1.0) == 15


def test_moe_capacity_non_exact_still_ceils():
    assert moe_capacity(50, 2, 8, 1.0) == 13      # ceil(12.5)
    assert moe_capacity(7, 2, 4, 1.1) == 4        # ceil(3.85)
    assert moe_capacity(1, 1, 64, 1.0) == 1       # floor clamp


def test_resolve_dispatch_impl():
    assert resolve_dispatch_impl(None) == "a2a"
    assert resolve_dispatch_impl("auto") == "a2a"
    assert resolve_dispatch_impl("fused") == "fused"
    assert resolve_dispatch_impl("host") == "host"
    with pytest.raises(ValueError):
        resolve_dispatch_impl("warp")


# ---------------------------------------------------------------------------
# plan: asymmetric capacities from measured load
# ---------------------------------------------------------------------------

def test_plan_caps_reproduce_measured_load():
    loads = (6, 5, 8, 6, 7, 6, 3, 5)
    plan = default_planner().plan_alltoall(16, 32, 2, 8, 4, jnp.float32,
                                           loads=loads)
    # slack = 1.0: the largest-remainder split reproduces the loads exactly
    assert plan.caps == loads
    assert plan.cap_pad == 8
    assert plan.region_rows == tuple(4 * c for c in loads)
    assert plan.block_bytes == plan.E_loc * 8 * 32 * 4
    # true (asymmetric) rows per destination vs the padded wire block
    assert plan.block_rows(0) == 6 + 5 and plan.block_rows(2) == 7 + 6


def test_plan_slack_grows_caps_but_never_below_load():
    loads = (6, 5, 8, 6, 7, 6, 3, 5)
    plan = default_planner().plan_alltoall(16, 32, 2, 8, 4, jnp.float32,
                                           loads=loads, slack=1.5)
    assert sum(plan.caps) >= int(np.ceil(sum(loads) * 1.5))
    assert all(c >= l for c, l in zip(plan.caps, loads))


def test_plan_zero_load_experts_keep_a_slot():
    plan = default_planner().plan_alltoall(32, 16, 2, 8, 4, jnp.float32,
                                           loads=(32, 0, 0, 0, 0, 0, 0, 0))
    assert plan.caps[0] >= 32 and all(c >= 1 for c in plan.caps)


def test_plan_fallback_is_worst_case():
    plan = default_planner().plan_alltoall(16, 32, 2, 8, 4, jnp.float32)
    assert plan.caps == (16,) * 8          # no measurement: t_loc everywhere
    assert plan.slots >= 2


def test_plan_validation():
    with pytest.raises(ValueError):
        default_planner().plan_alltoall(16, 32, 2, 6, 4, jnp.float32)
    with pytest.raises(ValueError):
        AllToAllPlan(ep=4, E=8, t_loc=8, k=2, d=16, caps=(2,) * 7)
    with pytest.raises(ValueError):
        AllToAllPlan(ep=4, E=8, t_loc=8, k=2, d=16, caps=(0,) * 8)


def test_schedule_overlap_order():
    plan = AllToAllPlan(ep=4, E=8, t_loc=8, k=2, d=16, caps=(2,) * 8)
    sched = plan.schedule()
    for s in range(1, 4):
        # the put feeding step s is issued before step s-1's GEMM (overlap),
        # its landing is fenced before its own GEMM, and the combine put
        # rides after the GEMM that produced it
        assert sched.index(("put", s)) < sched.index(("gemm", s - 1))
        assert sched.index(("fence", s)) < sched.index(("gemm", s))
        assert sched.index(("ret", s)) > sched.index(("gemm", s))
    assert sched[-1] == ("fence_ret", 0)
    host = dataclasses.replace(plan, overlap=False).schedule()
    assert sorted(host) == sorted(sched)   # same traffic, serialized
    last_put = max(i for i, (p, _) in enumerate(host) if p == "put")
    first_gemm = min(i for i, (p, _) in enumerate(host) if p == "gemm")
    assert last_put < first_gemm
    one = AllToAllPlan(ep=1, E=4, t_loc=8, k=2, d=16, caps=(2,) * 4)
    assert one.schedule() == (("gemm", 0),)


# ---------------------------------------------------------------------------
# numerical equivalence vs the single-device oracle
# ---------------------------------------------------------------------------

def _dispatch_case(ndev, E, t_loc, d, f, k=2, skew=2.0):
    """Imbalanced-routing test case: full arrays + a load-sized plan."""
    toks = RNG.randn(ndev * t_loc, d).astype(np.float32)
    router = (RNG.randn(d, E) + skew * RNG.randn(1, E)).astype(np.float32)
    wg = (RNG.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wu = (RNG.randn(E, d, f) / np.sqrt(d)).astype(np.float32)
    wd = (RNG.randn(E, f, d) / np.sqrt(f)).astype(np.float32)
    top_w, top_e = jax.jit(route_topk, static_argnums=2)(toks, router, k)
    loads = measure_expert_load(
        np.asarray(top_e).reshape(ndev, t_loc, k), E, sources=ndev)
    plan = default_planner().plan_alltoall(t_loc, d, k, E, ndev,
                                           jnp.float32, loads=loads)
    want = np.asarray(moe_ref(toks, top_e, top_w, wg, wu, wd))
    return toks, router, (wg, wu, wd), plan, loads, want


def _run_dispatch(mesh, impl, plan, toks, router, weights, k=2):
    def f(tk, rt, g, u, dn):
        w, e = route_topk(tk, rt, k)
        with default_context().dispatch_stats.collect() as ds:
            out = moe_dispatch(tk, e, w, g, u, dn, GROUP,
                               impl=impl, plan=plan)
        return out, ds["moe_dropped"].reshape(1)

    fn = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P("x", None), P(None, None), P("x", None, None),
                  P("x", None, None), P("x", None, None)),
        out_specs=(P("x", None), P("x"))))
    out, dropped = fn(toks, router, *weights)
    return np.asarray(out), float(np.asarray(dropped).sum())


def test_fused_and_host_match_oracle_under_imbalance():
    ndev = 8
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    toks, router, weights, plan, loads, want = _dispatch_case(
        ndev, E=16, t_loc=12, d=16, f=24)
    assert max(loads) > min(loads)         # the skew actually skewed
    fused, d_fused = _run_dispatch(mesh, "fused", plan, toks, router, weights)
    host, d_host = _run_dispatch(mesh, "host", plan, toks, router, weights)
    # dropless by construction: zero drops, bit-equal to the oracle
    assert d_fused == 0.0 and d_host == 0.0
    np.testing.assert_array_equal(fused, want)
    np.testing.assert_array_equal(host, want)


def test_undersized_plan_records_drops():
    """Starved capacities (caps == 1) must surface as a positive drop count
    — the stat the dropless path pins to zero."""
    ndev = 4
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    toks, router, weights, plan, _, want = _dispatch_case(
        ndev, E=8, t_loc=8, d=16, f=16)
    starved = dataclasses.replace(plan, caps=(1,) * 8)
    out, dropped = _run_dispatch(mesh, "fused", starved, toks, router, weights)
    assert dropped > 0
    assert np.abs(out - want).max() > 0    # and it is a real quality tax


def test_fused_gradients_match_oracle():
    ndev = 4
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    toks, router, weights, plan, _, _ = _dispatch_case(
        ndev, E=8, t_loc=8, d=12, f=16)
    router_c = jnp.asarray(router)

    def dist_loss(tk, wgt):
        # per-rank local loss: AD of the SPMD program sums the seeds, so
        # the grads are those of the GLOBAL loss (the train-step pattern)
        w, e = route_topk(tk, router_c, 2)
        y = moe_dispatch(tk, e, w, *wgt, GROUP, impl="fused", plan=plan)
        return (y.astype(jnp.float32) ** 2).sum()

    g = jax.jit(shard_map(
        lambda tk, wgt: jax.grad(dist_loss, argnums=(0, 1))(tk, wgt),
        mesh=mesh,
        in_specs=(P("x", None), (P("x", None, None),) * 3),
        out_specs=(P("x", None), (P("x", None, None),) * 3)))
    gt, gw = g(toks, tuple(map(jnp.asarray, weights)))

    def ref_loss(tk, wgt):
        w, e = route_topk(tk, router_c, 2)
        return (moe_ref(tk, e, w, *wgt).astype(jnp.float32) ** 2).sum()

    rt, rw = jax.jit(jax.grad(ref_loss, argnums=(0, 1)))(
        jnp.asarray(toks), tuple(map(jnp.asarray, weights)))
    np.testing.assert_allclose(np.asarray(gt), np.asarray(rt),
                               rtol=1e-4, atol=1e-5)
    for got, ref in zip(gw, rw):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# PGAS accounting: OMPCCL byte log == RMATracker window bytes
# ---------------------------------------------------------------------------

def test_put_byte_parity_with_tracker_windows():
    ndev = 4
    mesh = make_mesh((ndev,), ("x",), axis_types="auto")
    toks, router, weights, plan, _, _ = _dispatch_case(
        ndev, E=8, t_loc=8, d=16, f=16)

    def f(tk, rt, g, u, dn):
        w, e = route_topk(tk, rt, 2)
        return moe_dispatch(tk, e, w, g, u, dn, GROUP, impl="fused",
                            plan=plan)

    dctx = DiompContext()
    with use_default(dctx):
        jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P("x", None), P(None, None), P("x", None, None),
                      P("x", None, None), P("x", None, None)),
            out_specs=P("x", None))).lower(toks, router, *weights)
    desc = GROUP.descriptor()
    # (ep-1) dispatch puts + (ep-1) combine puts, one padded block each
    assert dctx.stats()[desc]["put"] == 2 * (ndev - 1)
    put_bytes = dctx.byte_stats()[desc]["put"]
    assert put_bytes == 2 * (ndev - 1) * plan.block_bytes
    dwin, cwin = dispatch_window_names(GROUP, ndev)
    win_bytes = sum(dctx.rma.window_bytes[w] for w in dwin + cwin)
    assert put_bytes == win_bytes == dctx.rma.put_bytes


# ---------------------------------------------------------------------------
# satellite: moe_block regime coverage (a2a / replicated / local) vs oracle
# ---------------------------------------------------------------------------

def _moe_cfg(E, shared=0, cf=8.0):
    return ModelConfig(name="tiny-moe", family="moe", num_layers=1,
                       d_model=32, num_heads=4, d_ff=64, vocab_size=128,
                       moe=True, num_experts=E, experts_per_token=2,
                       moe_d_ff=24, shared_experts=shared,
                       capacity_factor=cf, dtype="float32")


def _moe_lp(cfg, seed=0):
    rng = np.random.RandomState(seed)
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    lp = {
        "router": rng.randn(d, E).astype(np.float32) * 2.0,
        "w_gate_e": (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32),
        "w_up_e": (rng.randn(E, d, f) / np.sqrt(d)).astype(np.float32),
        "w_down_e": (rng.randn(E, f, d) / np.sqrt(f)).astype(np.float32),
    }
    if cfg.shared_experts:
        fs = cfg.moe_d_ff * cfg.shared_experts
        lp["w_gate_s"] = (rng.randn(d, fs) / np.sqrt(d)).astype(np.float32)
        lp["w_up_s"] = (rng.randn(d, fs) / np.sqrt(d)).astype(np.float32)
        lp["w_down_s"] = (rng.randn(fs, d) / np.sqrt(fs)).astype(np.float32)
    return lp


def _moe_oracle(x, lp, cfg):
    """Dropless reference for an ample-capacity moe_block call."""
    B, T, d = x.shape
    flat = jnp.asarray(x.reshape(B * T, d))
    top_w, top_e = route_topk(flat, jnp.asarray(lp["router"]),
                              cfg.experts_per_token)
    out = moe_ref(flat, top_e, top_w, jnp.asarray(lp["w_gate_e"]),
                  jnp.asarray(lp["w_up_e"]), jnp.asarray(lp["w_down_e"]))
    if cfg.shared_experts:
        h = jax.nn.silu(flat @ lp["w_gate_s"]) * (flat @ lp["w_up_s"])
        out = out + h @ lp["w_down_s"]
    return np.asarray(out).reshape(B, T, d)


def _run_moe_block(mesh, cfg, lp, x, sharded_experts, **knobs):
    ctx = ParallelCtx.from_mesh(mesh, **knobs)
    espec = (P("model", None, None) if sharded_experts
             else P(None, None, None))
    lspecs = {"router": P(None, None), "w_gate_e": espec, "w_up_e": espec,
              "w_down_e": espec}
    if "w_gate_s" in lp:
        lspecs.update({"w_gate_s": P(None, "model"),
                       "w_up_s": P(None, "model"),
                       "w_down_s": P("model", None)})

    def f(xx, pp):
        out = moe_block(xx, pp, cfg, ctx)
        return lax.pmean(out, "model")     # ranks agree; make it invariant

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), lspecs),
                           out_specs=P()))
    return np.asarray(fn(x, lp))


@pytest.mark.parametrize("case", ["a2a", "a2a_shared", "replicated", "local"])
def test_moe_block_regimes_match_dropless_oracle(case):
    """With ample capacity every dispatch regime equals the dropless oracle:
    a2a (tokens sliced over the EP ring), replicated (decode-shaped B*T <
    tp), and the non-divisible-E local fallback."""
    mesh = make_mesh((1, 8), ("data", "model"), axis_types="auto")
    E, shared = (8, 0)
    B, T = 2, 32                           # B*T = 64: a2a regime
    sharded = True
    if case == "a2a_shared":
        shared = 1
    elif case == "replicated":
        B, T = 1, 4                        # B*T < tp: replicated regime
    elif case == "local":
        E, sharded = 6, False              # E % ep != 0: local fallback
    cfg = _moe_cfg(E, shared=shared)
    lp = _moe_lp(cfg)
    x = RNG.randn(B, T, cfg.d_model).astype(np.float32)
    got = _run_moe_block(mesh, cfg, lp, x, sharded)
    want = _moe_oracle(x, lp, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("impl", ["fused", "host"])
def test_moe_block_dropless_impls_match_oracle(impl):
    """dispatch_impl='fused'/'host' swap the a2a collective for the one-sided
    ring inside moe_block itself — same dropless numbers, shared experts
    included."""
    mesh = make_mesh((1, 8), ("data", "model"), axis_types="auto")
    cfg = _moe_cfg(8, shared=1, cf=1.0)    # tight capacity: a2a would drop
    lp = _moe_lp(cfg)
    x = RNG.randn(2, 32, cfg.d_model).astype(np.float32)
    got = _run_moe_block(mesh, cfg, lp, x, True, dispatch_impl=impl)
    want = _moe_oracle(x, lp, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# model level: both MoE configs, every dispatch_impl
# ---------------------------------------------------------------------------

def _model_loss(cfg, mesh, params, batch, **knobs):
    ctx = ParallelCtx.from_mesh(mesh, remat=False, **knobs)
    pspecs = sch.partition_specs(cfg, mesh)
    bspecs = {k: P("data") for k in batch}
    loss_fn = model_api.loss_fn(cfg)

    def step(p, b):
        return ompccl.allreduce(loss_fn(p, b, cfg, ctx), ctx.world,
                                op="mean")

    return float(jax.jit(shard_map(step, mesh=mesh,
                                   in_specs=(pspecs, bspecs),
                                   out_specs=P()))(params, batch))


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "qwen3-moe-235b-a22b"])
def test_model_loss_across_dispatch_impls(arch):
    """The dropless modes agree with each other exactly (same schedule, same
    numerics) and sit within routing-drop distance of the capacity a2a."""
    cfg = configs.get_reduced(arch)
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": np.random.RandomState(1).randint(
        0, cfg.vocab_size, (8, 16)).astype(np.int32)}
    mesh = make_mesh((1, 8), ("data", "model"), axis_types="auto")
    losses = {impl: _model_loss(cfg, mesh, params, batch,
                                dispatch_impl=impl)
              for impl in ("a2a", "fused", "host")}
    assert np.isfinite(losses["a2a"])
    assert abs(losses["fused"] - losses["host"]) < 1e-6, losses
    assert abs(losses["fused"] - losses["a2a"]) < 0.1, losses


# ---------------------------------------------------------------------------
# satellite: drop stats surface in the train step's metrics
# ---------------------------------------------------------------------------

def test_train_step_moe_drop_metrics():
    from repro.train.optim import adamw, cosine_schedule
    from repro.train.step import build_train_step

    cfg = configs.get_reduced("qwen3-moe-235b-a22b")
    mesh = make_mesh((4, 2), ("data", "model"), axis_types="auto")
    params = sch.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(cosine_schedule(5e-3, warmup=2, total=40))
    ostate = jax.jit(opt.init)(params)
    batch = {"tokens": np.random.RandomState(1).randint(
        0, cfg.vocab_size, (8, 16)).astype(np.int32)}

    # capacity a2a, overlapped-reduction scan branch: real drops surface
    ctx = ParallelCtx.from_mesh(mesh, remat=True, microbatch=2)
    _, _, m = build_train_step(cfg, mesh, ctx, opt, donate=False,
                               global_batch=8)(params, ostate, batch,
                                               jnp.asarray(0))
    assert float(m["moe_dropped"]) > 0
    assert 0.0 < float(m["moe_drop_rate"]) < 1.0
    # dropless fused dispatch, plain accumulation scan branch: exactly zero
    ctx = ParallelCtx.from_mesh(mesh, remat=True, microbatch=2,
                                overlap_grad_reduce=False,
                                dispatch_impl="fused")
    _, _, m = build_train_step(cfg, mesh, ctx, opt, donate=False,
                               global_batch=8)(params, ostate, batch,
                                               jnp.asarray(0))
    assert float(m["moe_dropped"]) == 0.0
    assert float(m["moe_drop_rate"]) == 0.0
    assert np.isfinite(float(m["loss"]))

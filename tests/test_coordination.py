"""Single-process unit tests for the multi-controller bootstrap layer.

The cross-process behavior is exercised for real by tests/multiproc;
here we pin the pieces that must hold in ANY topology: LocalCoordinator
semantics (the identity exchange every pre-PR-10 test now runs on),
process-local arena guards in GlobalMemory, the local_sizes/sizes
contract of the extent exchange, mesh validation, the ``diomp.init``
argument contract, and the single-process shape of ``gather_stats``.
"""

import numpy as np
import pytest

from repro.core.context import DiompContext, init, reset_default_context
from repro.core.coordination import (JaxCoordinator, LocalCoordinator,
                                     coordinator_for, fetch_global,
                                     is_distributed, process_local_ranks)
from repro.core.groups import DiompGroup
from repro.core.pgas import AllocError, GlobalMemory
from repro.launch.mesh import make_process_mesh, make_smoke_mesh

G = DiompGroup(("x",), name="x")


# ---------------------------------------------------------------------------
# coordinators
# ---------------------------------------------------------------------------


def test_local_coordinator_identity():
    c = LocalCoordinator()
    assert c.process_id == 0 and c.num_processes == 1
    assert c.allgather({"a": (1, 2)}) == [{"a": [1, 2]}]  # JSON round-trip
    assert c.broadcast("x") == "x"
    assert c.agree(["anything"])
    c.barrier("tag")  # no-op, no jax


def test_coordinator_for_single_process(mesh8):
    assert isinstance(coordinator_for(mesh8), LocalCoordinator)
    assert not is_distributed()


def test_jax_coordinator_single_process_roundtrip():
    # a 1-process "distributed" job degenerates to the identity exchange
    c = JaxCoordinator()
    assert c.num_processes == 1
    assert c.allgather_bytes(b"payload") == [b"payload"]
    assert c.allgather([1, "two"]) == [[1, "two"]]


def test_fetch_global_is_plain_numpy_locally():
    x = np.arange(12.0).reshape(3, 4)
    got = fetch_global(x)
    np.testing.assert_array_equal(got, x)


def test_process_local_ranks_covers_mesh(mesh8):
    ranks = process_local_ranks(mesh8)
    assert ranks == list(range(mesh8.devices.size))


# ---------------------------------------------------------------------------
# GlobalMemory: process-local arenas + extent exchange contract
# ---------------------------------------------------------------------------


def test_remote_rank_arena_is_guarded():
    gm = GlobalMemory(4, 1 << 12, local_ranks=[0, 1])
    slp = gm.alloc_asymmetric("kv", [64, 64, 0, 0], G)
    assert slp.region.offsets[2] == -1
    assert gm.bytes_in_use(0) > 0
    with pytest.raises(AllocError, match="not process-local"):
        gm.bytes_in_use(3)
    with pytest.raises(AllocError, match="outside"):
        gm.bytes_in_use(7)


def test_alloc_asymmetric_exactly_one_of_sizes_and_local_sizes():
    gm = GlobalMemory(2, 1 << 12)
    with pytest.raises(ValueError, match="exactly one"):
        gm.alloc_asymmetric("both", [8, 8], G, local_sizes=[8, 8])
    with pytest.raises(ValueError, match="exactly one"):
        gm.alloc_asymmetric("neither", group=G)


def test_alloc_asymmetric_local_sizes_must_cover_local_ranks():
    gm = GlobalMemory(4, 1 << 12, local_ranks=[1, 2])
    with pytest.raises(ValueError, match="local sizes"):
        gm.alloc_asymmetric("short", group=G, local_sizes=[64])
    # partial visibility without peer processes: the assembled size
    # vector cannot cover every rank, and the exchange says so
    with pytest.raises(AllocError, match="covered ranks"):
        gm.alloc_asymmetric("uncovered", group=G, local_sizes=[64, 128])


def test_local_sizes_equals_global_sizes_table():
    """One process owning every rank: the contribution path must build
    the identical region the global-vector path builds."""
    gm_a = GlobalMemory(4, 1 << 12)
    gm_b = GlobalMemory(4, 1 << 12)
    a = gm_a.alloc_asymmetric("kv", [32, 64, 0, 128], G)
    b = gm_b.alloc_asymmetric("kv", group=G, local_sizes=[32, 64, 0, 128])
    assert a.region.sizes == b.region.sizes
    assert a.region.offsets == b.region.offsets


# ---------------------------------------------------------------------------
# meshes
# ---------------------------------------------------------------------------


def test_make_smoke_mesh_validates_ndev():
    with pytest.raises(ValueError, match="positive"):
        make_smoke_mesh(0)
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_smoke_mesh(4096)


def test_make_process_mesh_single_process_defaults():
    import jax

    mesh = make_process_mesh()
    assert mesh.devices.size == jax.device_count()


def test_make_process_mesh_explicit_ring():
    import jax

    n = jax.device_count()
    mesh = make_process_mesh(shape=(n,), axes=("x",))
    assert dict(mesh.shape) == {"x": n}
    with pytest.raises(ValueError, match="explicit axes"):
        make_process_mesh(shape=(n,))
    with pytest.raises(ValueError, match="covers"):
        make_process_mesh(shape=(n + 1,), axes=("x",))
    with pytest.raises(ValueError, match="rank mismatch"):
        make_process_mesh(shape=(n, 1), axes=("x",))


def test_make_process_mesh_validates_claimed_topology():
    import jax

    with pytest.raises(ValueError, match="local devices"):
        make_process_mesh(ndev_per_proc=jax.local_device_count() + 1)
    with pytest.raises(ValueError, match="processes"):
        make_process_mesh(num_processes=jax.process_count() + 1)


# ---------------------------------------------------------------------------
# diomp.init + gather_stats
# ---------------------------------------------------------------------------


def test_init_topology_args_require_coordinator():
    with pytest.raises(ValueError, match="coordinator"):
        init(num_processes=2)
    with pytest.raises(ValueError, match="coordinator"):
        init(process_id=0)
    reset_default_context()


def test_init_accepts_coordinator_instance(mesh8):
    ctx = init(mesh=mesh8, coordinator=LocalCoordinator())
    try:
        assert ctx.process_id == 0 and ctx.num_processes == 1
        assert not ctx.multiprocess
    finally:
        reset_default_context()


def test_gather_stats_single_process_shape(mesh8):
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 16)
    ctx.memory.alloc_symmetric("a", 512, G)
    rows = ctx.gather_stats()
    assert len(rows) == 1
    (row,) = rows
    assert row["process_id"] == 0
    assert row["pgas"]["alloc_counts"]["symmetric"] == 1
    names = [r[0] for r in row["pgas"]["regions"]]
    assert "a" in names
    for key in ("stats", "byte_stats", "retry_stats", "rma"):
        assert key in row

"""Overload-resilient serving: SLO admission, shedding, degraded modes,
and spill-rank circuit breakers.

Covers the docs/SERVING.md "Overload & SLOs" contracts: the percentile
math behind ``latency_stats()``, the token-bucket/admission state machine
and degraded-mode ladder on a deterministic clock, the seeded bursty
trace generator, the ``CircuitBreaker`` lifecycle, the migrate-failure
ledger rollback, and — end to end on the engine — explicit
admit/reject/backpressure decisions, deadline shedding with KV pages
freed, degraded-mode caps with recovery, identical-seed decision-log
replay, and the acceptance scenario: a chaos-injected flaky spill rank is
quarantined (open observed), migrations reroute around it, and the rank
is readmitted through a half-open probe.
"""

import numpy as np
import jax
import pytest

from repro import configs
from repro.core.context import DiompContext
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.core.resilience import CircuitBreaker, RetryPolicy
from repro.core.rma import RMAError, RMATracker
from repro.models import schema as sch
from repro.models.config import ParallelCtx
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.kvcache import PagedKVAllocator
from repro.serve.slo import (AdmissionController, ManualClock, SLOPolicy,
                             TierPolicy, TokenBucket, percentile, percentiles)
from repro.serve.trace import bursty_trace

CFG = configs.get_reduced("stablelm-3b")


@pytest.fixture(scope="module")
def params():
    return sch.init_params(CFG, jax.random.PRNGKey(0))


def _engine(mesh8, params, **kw):
    ctx = ParallelCtx.from_mesh(mesh8, remat=False, inference=True)
    return ServeEngine(CFG, mesh8, ctx, params, **kw)


def _prompts(lengths, seed=7):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, CFG.vocab_size, size=n).astype(np.int32)
            for n in lengths]


# -- percentile math (satellite: latency_stats aggregation) -----------------

def test_percentile_math_pinned():
    """Linear-interpolation percentiles, numpy's default convention."""
    assert percentile([], 50) is None
    assert percentile([3.0], 99) == 3.0
    assert percentile([1, 2, 3, 4], 50) == 2.5
    assert percentile(list(range(1, 101)), 99) == pytest.approx(99.01)
    assert percentile(list(range(1, 101)), 50) == pytest.approx(50.5)
    assert percentile([4, 1, 3, 2], 0) == 1.0       # order-independent
    assert percentile([4, 1, 3, 2], 100) == 4.0
    ps = percentiles([1, 2, 3, 4], (50, 95, 99))
    assert ps == {"p50": 2.5,
                  "p95": pytest.approx(3.85),
                  "p99": pytest.approx(3.97)}
    assert percentiles([], (50,)) is None


def test_token_bucket_deterministic_refill():
    clk = ManualClock()
    tb = TokenBucket(rate_per_s=2.0, burst=2.0, clock=clk)
    assert tb.try_take() and tb.try_take() and not tb.try_take()
    clk.advance(0.5)                       # +1 token
    assert tb.try_take() and not tb.try_take()
    clk.advance(100.0)                     # refill caps at burst
    assert tb.peek() == 2.0


# -- admission state machine -------------------------------------------------

def _controller(**kw):
    clk = kw.pop("clock", ManualClock())
    pol = SLOPolicy(**kw)
    return AdmissionController(pol, clk), clk


def test_admission_decision_order_and_reasons():
    ctl, _ = _controller(
        default_tier=TierPolicy(rate_per_s=1.0, burst=2.0),
        max_queue=4, queue_high=2, queue_low=1, min_step_s=0.01)
    kw = dict(priority=0, prompt_len=8, max_new=4, chunk=8,
              ttft_deadline_s=None, total_deadline_s=None)
    # infeasible beats everything: min service 5 steps * 0.01 > 0.01
    d = ctl.decide(queue_depth=0, **{**kw, "total_deadline_s": 0.01})
    assert (d.action, d.reason) == ("reject", "infeasible")
    assert not d.admitted
    # a ttft deadline below one chunk's floor is equally infeasible
    d = ctl.decide(queue_depth=0, **{**kw, "ttft_deadline_s": 0.005})
    assert (d.action, d.reason) == ("reject", "infeasible")
    # queue bound
    d = ctl.decide(queue_depth=4, **kw)
    assert (d.action, d.reason) == ("reject", "queue_full")
    # rate limit: burst of 2, no refill on a manual clock
    assert ctl.decide(queue_depth=0, **kw).action == "admit"
    assert ctl.decide(queue_depth=0, **kw).action == "admit"
    d = ctl.decide(queue_depth=0, **kw)
    assert (d.action, d.reason) == ("reject", "rate_limited")


def test_backpressure_hysteresis():
    ctl, _ = _controller(max_queue=16, queue_high=3, queue_low=1)
    kw = dict(priority=0, prompt_len=4, max_new=2, chunk=4,
              ttft_deadline_s=None, total_deadline_s=None)
    assert ctl.decide(queue_depth=0, **kw).action == "admit"
    d = ctl.decide(queue_depth=3, **kw)       # crosses high watermark
    assert (d.action, d.reason) == ("backpressure", "queue_high")
    assert d.admitted                         # backpressure still queues
    # stays latched between the watermarks...
    assert ctl.decide(queue_depth=2, **kw).action == "backpressure"
    # ...and clears only at/below the low watermark
    assert ctl.decide(queue_depth=1, **kw).action == "admit"


def test_degrade_ladder_sustain_and_recover():
    ctl, _ = _controller(max_queue=64, queue_high=4, queue_low=1,
                         degrade_sustain_steps=3, degrade_recover_steps=2)
    step = 0
    for _ in range(3):                        # 3 sustained steps -> L1
        step += 1
        lvl = ctl.update_pressure(10, step)
    assert lvl == 1
    for _ in range(6):                        # keeps climbing, capped at 3
        step += 1
        lvl = ctl.update_pressure(10, step)
    assert lvl == 3
    step += 1
    assert ctl.update_pressure(3, step) == 3  # between watermarks: hold
    for _ in range(2):                        # 2 calm steps -> one level back
        step += 1
        lvl = ctl.update_pressure(0, step)
    assert lvl == 2
    for _ in range(4):
        step += 1
        lvl = ctl.update_pressure(0, step)
    assert lvl == 0
    # every move is on the decision log, one level at a time
    assert [t[1:] for t in ctl.transitions] == \
        [(0, 1), (1, 2), (2, 3), (3, 2), (2, 1), (1, 0)]


# -- bursty trace ------------------------------------------------------------

def test_bursty_trace_deterministic_and_shaped():
    a = bursty_trace(13, 200)
    b = bursty_trace(13, 200)
    assert a == b                             # same seed, same trace
    assert bursty_trace(14, 200) != a         # seed actually matters
    assert len(a) == 200
    arrivals = [t.arrival_s for t in a]
    assert arrivals == sorted(arrivals)
    assert all(4 <= t.prompt_len <= 96 for t in a)
    assert all(t.priority in (0, 1, 2) for t in a)
    assert len({t.priority for t in a}) == 3  # all tiers represented
    # bursts: many identical arrival times (same-burst requests)
    assert len(set(arrivals)) < len(arrivals)


# -- circuit breaker ---------------------------------------------------------

def test_breaker_lifecycle():
    clk = ManualClock()
    cb = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                        half_open_probes=1, clock=clk)
    key = ("migrate", 3)
    assert cb.allow(key) and cb.state(key) == "closed"
    assert cb.record_failure(key) == "closed"     # 1 of 2
    cb.record_success(key)                        # success resets the count
    assert cb.record_failure(key) == "closed"
    assert cb.record_failure(key) == "open"       # threshold reached
    assert not cb.allow(key)                      # quarantined
    assert cb.open_keys() == [key]
    clk.advance(0.5)
    assert not cb.allow(key)                      # cooldown not elapsed
    clk.advance(0.6)
    assert cb.allow(key)                          # half-open probe granted
    assert cb.state(key) == "half_open"
    assert not cb.allow(key)                      # only one probe slot
    assert cb.record_failure(key) == "open"       # failed probe re-opens
    clk.advance(1.1)
    assert cb.allow(key)
    assert cb.record_success(key) == "closed"     # clean probe closes
    assert cb.allow(key)
    assert cb.stats["opened"] == 1 and cb.stats["reopened"] == 1
    assert cb.stats["closed"] == 1 and cb.stats["denied"] == 3
    assert (key, "open", "half_open") in cb.transitions
    assert (key, "half_open", "closed") in cb.transitions
    # other keys are independent
    assert cb.state(("migrate", 4)) == "closed"


# -- migrate failure rollback (ledger safety for the breaker path) ----------

def test_migrate_budget_exhaustion_rolls_back_ledger():
    """When migrate raises after its retry budget, the destination pages
    it allocated must return to the free list — the caller (the engine's
    breaker path) catches the error, so the allocated-freed==live ledger
    has to stay balanced."""
    mem = GlobalMemory(4, 1 << 22, allocator="buddy")
    alloc = PagedKVAllocator(mem, DiompGroup(("x",), name="x"),
                             page_tokens=16, kv_bytes_per_token=64)
    r = alloc.admit(20, 40, home_rank=0)
    npages = len(r.page_table)
    tr = RMATracker()
    tr.register("w")
    specs = tuple(FaultSpec("migrate", i, "corrupt") for i in range(16))
    with pytest.raises(RMAError):
        alloc.migrate(r, 2, tracker=tr, window="w",
                      faults=FaultPlan(0, specs=specs),
                      policy=RetryPolicy(max_retries=2, sleep=False),
                      validate=True)
    # source intact, destination rolled back, ledger balanced
    assert r.home_rank == 0 and len(r.page_table) == npages
    assert alloc.stats["pages_allocated"] - alloc.stats["pages_freed"] \
        == alloc.live_pages()
    assert alloc.free_list_pages(2) == npages
    assert ("migrate_failed", r.rid, 2) in alloc.call_log
    alloc.release(r)


# -- engine: SLO wiring ------------------------------------------------------

def test_slo_engine_unconstrained_matches_plain(mesh8, params):
    """A permissive SLO policy changes nothing: identical outputs to the
    plain engine, every decision an explicit admit."""
    lengths = (3, 9, 12)
    ref = _engine(mesh8, params, slots=2, max_len=64, prefill_chunk=8)
    for p in _prompts(lengths):
        ref.submit(p, max_new=4)
    ref.run()
    clk = ManualClock()
    eng = _engine(mesh8, params, slots=2, max_len=64, prefill_chunk=8,
                  slo=SLOPolicy(), clock=clk)
    reqs = [eng.submit(p, max_new=4) for p in _prompts(lengths)]
    while eng.active or eng.queue or eng.preempted:
        eng.step()
        clk.advance(0.01)
    for a, b in zip(ref._all, reqs):
        assert b.done and a.out == b.out
        assert b.decision.action == "admit" and b.shed_reason is None
    st = eng.latency_stats()
    assert st["goodput"] == len(lengths) and st["shed_total"] == 0
    assert st["deadline_violations"] == 0 and st["tokens_late"] == 0
    assert st["ttft_s"]["p99"] >= st["ttft_s"]["p50"] > 0


def test_submit_rejections_explicit_and_not_queued(mesh8, params):
    clk = ManualClock()
    slo = SLOPolicy(default_tier=TierPolicy(rate_per_s=1.0, burst=2.0),
                    max_queue=3, queue_high=3, queue_low=1, min_step_s=0.01)
    eng = _engine(mesh8, params, slots=1, max_len=64, prefill_chunk=8,
                  slo=slo, clock=clk)
    p = _prompts([6])[0]
    # infeasible: 1 prefill chunk + 4 decode steps * 0.01 > deadline
    r = eng.submit(p, max_new=4, total_deadline_s=0.02)
    assert (r.decision.action, r.shed_reason) == ("reject", "infeasible")
    # bucket burst 2: two admits, then rate_limited
    a, b = eng.submit(p, max_new=2), eng.submit(p, max_new=2)
    assert a.decision.admitted and b.decision.admitted
    c = eng.submit(p, max_new=2)
    assert (c.decision.action, c.decision.reason) == ("reject",
                                                      "rate_limited")
    # a refilled token admits the next one, filling the queue to max_queue
    clk.advance(1.0)
    d = eng.submit(p, max_new=2)
    assert d.decision.admitted
    # queue at max_queue (3): queue_full outranks the rate limiter
    clk.advance(1.0)
    e = eng.submit(p, max_new=2)
    assert (e.decision.action, e.decision.reason) == ("reject", "queue_full")
    assert len(eng.queue) == 3 and len(eng._all) == 6
    st = eng.latency_stats()
    assert st["shed"] == {"infeasible": 1, "rate_limited": 1,
                          "queue_full": 1}
    # rejected requests never run
    while eng.active or eng.queue or eng.preempted:
        eng.step()
        clk.advance(0.001)
    assert a.done and b.done and d.done
    assert not (r.done or c.done or e.done)
    assert r.out == c.out == e.out == []


def test_queue_shedding_and_midflight_cancellation(mesh8, params):
    """Expired queued requests shed without binding resources; a mid-flight
    request past its total deadline is cancelled with pages freed and its
    tokens counted as wasted — and no token is ever served late."""
    clk = ManualClock()
    eng = _engine(mesh8, params, slots=1, max_len=64, prefill_chunk=8,
                  slo=SLOPolicy(min_step_s=0.01), clock=clk)
    pa, pb, pc = _prompts((6, 6, 10))
    a = eng.submit(pa, max_new=30, total_deadline_s=1.0)   # will expire
    b = eng.submit(pb, max_new=2, ttft_deadline_s=0.5)     # starves in queue
    c = eng.submit(pc, max_new=2, total_deadline_s=0.9)    # becomes hopeless
    for _ in range(4):            # a admits and makes some progress
        eng.step()
        clk.advance(0.2)
    assert a.slot >= 0 and len(a.out) > 0
    # t=0.8: b's ttft deadline (0.5) passed while queued -> queue_expired;
    # c needs >= 2 chunks + 2 decodes = 0.04 but only 0.1 remains... still
    # feasible; at t>=0.9 it is hopeless/expired too
    eng.step()
    assert b.shed_reason == "queue_expired" and not b.done
    clk.advance(0.3)              # t=1.1: a's total deadline (1.0) passed
    eng.step()
    assert a.shed_reason == "expired" and not a.done
    assert a.slot == -1 and a.kv is None
    assert c.shed_reason in ("hopeless", "queue_expired", "expired")
    assert eng.active == {} and eng.queue == []
    st = eng.latency_stats()
    assert st["tokens_wasted"] == len(a.out) > 0
    assert st["tokens_late"] == 0          # nothing served past a deadline
    assert st["shed_total"] == 3
    # allocator ledger balanced after the cancellation freed a's pages
    kv = eng.kv_stats                      # (asserts the ledger internally)
    assert kv["live_pages"] == 0
    # shed events are on the decision log
    kinds = [e[0] for e in eng.slo_log]
    assert kinds.count("shed") == 3


def test_degraded_modes_cap_work_and_recover(mesh8, params):
    """Sustained queue pressure walks the ladder (max_new capped at L1),
    and draining the queue recovers to L0."""
    clk = ManualClock()
    slo = SLOPolicy(max_queue=64, queue_high=2, queue_low=1,
                    degrade_sustain_steps=2, degrade_recover_steps=2,
                    degraded_max_new=2, degraded_chunk=4)
    eng = _engine(mesh8, params, slots=1, max_len=64, prefill_chunk=8,
                  slo=slo, clock=clk)
    busy = eng.submit(_prompts([6])[0], max_new=8)
    waiters = [eng.submit(p, max_new=6)
               for p in _prompts((4, 4, 4, 4), seed=9)]
    while eng.active or eng.queue or eng.preempted:
        eng.step()
        clk.advance(0.01)
    assert busy.done and len(busy.out) == 8      # admitted pre-degrade
    assert eng.slo_ctl.transitions, "ladder never engaged"
    assert max(t[2] for t in eng.slo_ctl.transitions) >= 1
    # at least one waiter was admitted under L1+ and got the capped budget
    assert any(w.done and len(w.out) == 2 for w in waiters), \
        [(w.done, len(w.out)) for w in waiters]
    # queue drained: recovery steps bring the level back down
    for _ in range(3 * slo.degrade_recover_steps + 2):
        eng.step()
        clk.advance(0.01)
    assert eng.slo_ctl.level == 0
    assert eng.latency_stats()["degrade_level"] == 0


def test_identical_seeds_identical_decision_logs(mesh8, params):
    """The whole decision sequence (submit verdicts, sheds, degrades) is a
    pure function of (trace, policy, clock) — two runs replay exactly."""
    def drive():
        clk = ManualClock()
        slo = SLOPolicy(default_tier=TierPolicy(ttft_deadline_s=0.4,
                                                total_deadline_s=1.2),
                        max_queue=6, queue_high=2, queue_low=1,
                        min_step_s=0.01, degrade_sustain_steps=2,
                        degrade_recover_steps=2, degraded_max_new=2)
        eng = _engine(mesh8, params, slots=1, max_len=64, prefill_chunk=8,
                      slo=slo, clock=clk)
        trace = bursty_trace(21, 10, max_prompt=12,
                             max_new_choices=(2, 4), burst_rate_per_s=8.0)
        pending = list(trace)
        rng = np.random.RandomState(5)
        prompts = {id(t): rng.randint(0, CFG.vocab_size, t.prompt_len)
                   .astype(np.int32) for t in pending}
        for _ in range(60):
            while pending and pending[0].arrival_s <= clk.now():
                t = pending.pop(0)
                eng.submit(prompts[id(t)], max_new=t.max_new,
                           priority=t.priority)
            eng.step()
            clk.advance(0.05)
            if not (pending or eng.active or eng.queue or eng.preempted):
                break
        return eng
    a, b = drive(), drive()
    assert a.slo_log == b.slo_log and len(a.slo_log) > 0
    assert a.shed == b.shed
    assert [r.out for r in a._all] == [r.out for r in b._all]


# -- acceptance: flaky spill rank quarantined by the breaker -----------------

def test_flaky_spill_rank_quarantined_and_recovers(mesh8, params):
    """A spill rank whose migrations exhaust the retry budget is opened by
    the breaker within that budget, further migrations reroute around it,
    outputs stay correct, and after the cooldown a half-open probe
    readmits it."""
    lengths, max_new = (9, 14, 5), 6
    ref = _engine(mesh8, params, slots=3, max_len=64, prefill_chunk=8)
    for p in _prompts(lengths):
        ref.submit(p, max_new=max_new)
    ref.run()

    # corrupt the first migrate put AND its retry: with a budget of 1 the
    # first spill spends its whole budget and surfaces RMAError; every
    # later transfer is clean
    plan = FaultPlan(0, specs=(FaultSpec("migrate", 0, "corrupt"),
                               FaultSpec("migrate", 1, "corrupt")))
    clk = ManualClock()
    cb = CircuitBreaker(failure_threshold=1, cooldown_s=50.0,
                        half_open_probes=1, clock=clk)
    ctx = DiompContext(mesh=mesh8, segment_bytes=1 << 26, allocator="buddy",
                       fault_plan=plan,
                       retry_policy=RetryPolicy(per_verb={"migrate": 1},
                                                sleep=False))
    eng = _engine(mesh8, params, slots=3, max_len=64, prefill_chunk=8,
                  high_watermark=1e-4, low_watermark=5e-5,
                  context=ctx, clock=clk, breaker=cb)
    reqs = [eng.submit(p, max_new=max_new) for p in _prompts(lengths)]
    while eng.active or eng.queue or eng.preempted:
        eng.step()
        clk.advance(0.01)

    # correctness survived the flaky rank (recompute-preemption fallback)
    for a, b in zip(ref._all, reqs):
        assert b.done and a.out == b.out, (a.out, b.out)
    # the breaker opened on exactly the rank that spent the budget...
    assert cb.stats["opened"] == 1
    open_keys = [k for k in cb.open_keys() if cb.state(k) == "open"]
    assert len(open_keys) == 1 and open_keys[0][0] == "migrate"
    flaky = open_keys[0][1]
    assert any(e[0] == "breaker" and e[2] == flaky and e[4] == "open"
               for e in eng.slo_log)
    # ...while migrations rerouted and succeeded elsewhere
    assert eng.alloc.stats["migrations"] >= 1
    migrated_to = {e[3] for e in eng.alloc.call_log if e[0] == "migrate"}
    assert flaky not in migrated_to
    # ledger balanced despite the failed migration (rollback path)
    assert eng.kv_stats["live_pages"] == 0

    # half-open recovery: after the cooldown one probe is granted; a clean
    # migrate to the formerly-flaky rank closes the breaker
    clk.advance(60.0)
    key = ("migrate", flaky)
    assert cb.allow(key) and cb.state(key) == "half_open"
    kv = eng.alloc.admit(4, 8, home_rank=eng._home(0))
    probe = GenRequest(prompt=np.ones(4, np.int32), max_new=1, kv=kv)
    eng.dctx.rma.register(eng._win(probe))
    assert eng._migrate(probe, flaky) > 0
    assert cb.state(key) == "closed"
    assert (key, "open", "half_open") in cb.transitions
    assert (key, "half_open", "closed") in cb.transitions
    eng.alloc.release(kv)
    assert eng.kv_stats["live_pages"] == 0

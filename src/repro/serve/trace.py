"""Seeded bursty request traces for overload experiments.

ROADMAP item 1 gates disaggregated serving on "simulated
millions-of-users request traces (bursty arrivals, mixed prompt lengths,
priority tiers)" — this module is that trace source, scaled down to CI.
``bursty_trace`` models the canonical serving workload shape:

* **Poisson bursts**: arrivals come in bursts whose inter-burst gaps are
  exponential (a Poisson process over bursts) and whose sizes are
  geometric — long quiet stretches punctuated by pile-ups, the pattern
  that actually overloads an admission queue (uniform arrivals never do).
* **Heavy-tail prompt lengths**: log-normal, clamped to the engine's
  cache bounds — most prompts are short, a few are huge (the huge ones
  are what trip watermark preemption and spill migration).
* **Priority tiers**: each request draws a tier from a weighted
  distribution; the tier index is passed straight through as the engine
  ``priority`` (higher wins at admission), and the SLO policy maps it
  to per-tier deadlines and rate limits.  The weights only set the mix.

Determinism: all draws go through :func:`repro.core.resilience.derive_rng`
(sha256-seeded ``random.Random``), NOT numpy Generators, because Python's
``random`` distribution algorithms are stable across versions/platforms —
the same seed must produce the same trace on every CI machine, since
``bench_overload``'s decision-log digest is computed over it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.resilience import derive_rng

__all__ = ["TraceRequest", "bursty_trace"]


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival in a trace (engine-agnostic: modeled seconds)."""

    arrival_s: float       # modeled arrival time
    prompt_len: int
    max_new: int
    priority: int          # engine priority (higher wins)


def bursty_trace(seed: int, n: int, *,
                 burst_rate_per_s: float = 4.0,
                 mean_burst: float = 3.0,
                 prompt_mu: float = 2.6,
                 prompt_sigma: float = 0.6,
                 min_prompt: int = 4,
                 max_prompt: int = 96,
                 max_new_choices: Sequence[int] = (8, 16, 24),
                 tier_weights: Sequence[float] = (0.2, 0.5, 0.3),
                 ) -> List[TraceRequest]:
    """``n`` seeded arrivals: Poisson bursts, log-normal prompts, tiers.

    ``tier_weights[i]`` is the probability a request lands in priority
    tier ``i`` (passed straight through as the engine ``priority`` —
    the SLO policy maps it to deadlines; by repo convention HIGHER is
    more urgent, so put the premium tier's weight LAST).
    """
    if n < 1:
        return []
    rng = derive_rng("trace", seed, n)
    cum, acc = [], 0.0
    for w in tier_weights:
        acc += float(w)
        cum.append(acc)
    out: List[TraceRequest] = []
    t = 0.0
    while len(out) < n:
        # next burst: exponential gap, geometric size (>= 1)
        t += rng.expovariate(burst_rate_per_s)
        burst = 1
        while rng.random() < 1.0 - 1.0 / max(mean_burst, 1.0):
            burst += 1
        for _ in range(burst):
            if len(out) >= n:
                break
            plen = int(round(rng.lognormvariate(prompt_mu, prompt_sigma)))
            plen = max(min_prompt, min(max_prompt, plen))
            u = rng.random() * acc
            tier = next(i for i, c in enumerate(cum) if u <= c)
            out.append(TraceRequest(
                arrival_s=t,
                prompt_len=plen,
                max_new=max_new_choices[
                    rng.randrange(len(max_new_choices))],
                priority=tier))
    return out

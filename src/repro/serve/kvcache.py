"""Paged KV-cache allocator on the DiOMP PGAS heap.

This is the paper's *asymmetric allocation* machinery doing real work (the
serving design is documented in docs/SERVING.md; the layer map in
docs/ARCHITECTURE.md): every KV **page** is an asymmetric region (the
request's bytes live on its *home rank*; other ranks hold only the region
metadata), the per-request ``page_table`` is the second-level-pointer table
of paper Fig. 2 (uniformly allocated wrappers whose values point at ragged
payloads), and the remote-pointer cache amortizes repeated lookups — the
Fig. 2 (as-1) mechanism, reused as a vLLM-style page table.

Key properties (the whole point of this allocator vs the old
whole-region-realloc design, kept below as :class:`ReallocKVAllocator` for
the benchmark baseline):

* ``extend`` performs exactly ONE page allocation (call-log asserted in
  tests) instead of re-allocating the whole region — O(1) churn per token
  of growth instead of O(pages);
* ``release`` returns pages to a per-home-rank **free list**, so steady-
  state request churn causes ZERO arena traffic (audited against
  ``GlobalMemory.alloc_counts``);
* ``lookup`` resolves token -> (rank, byte offset) through the page table
  (one cached second-level-pointer dereference per page);
* ``migrate`` moves a request's pages to another rank's heap with
  one-sided RMA get/put semantics — the engine's preemption/swap path.

The allocator plans *addresses*; the device-side cache tensor is dense per
slot (the serve step's layout) and its bytes live in XLA buffers.  What the
plan buys at scale: KV for a preempted/migrated request is addressed on a
remote device's heap by (rank, offset) — one-sided, no registration
handshake.  The migration helper therefore records its page transfers
against the OMPCCL communicator call log (count under ``get``, payload
bytes under ``put`` — the same leaf-op byte accounting every delegating
verb uses) and the :class:`~repro.core.rma.RMATracker` window of the
request, which is exactly where a TPU deployment's compiled
collective-permutes would be logged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.groups import DiompGroup
from repro.core.pgas import AllocError, GlobalMemory, SecondLevelPtr
from repro.core.rma import RMAError

__all__ = ["PagedKVAllocator", "ReallocKVAllocator", "Request"]


@dataclasses.dataclass
class Request:
    """One serving request's KV plan: a page table over the PGAS heap."""

    rid: int
    prompt_len: int
    max_len: int
    home_rank: int = 0
    page_table: List[SecondLevelPtr] = dataclasses.field(default_factory=list)
    pos: int = 0                # tokens written so far (engine-driven)
    done: bool = False
    # legacy field kept for the realloc baseline
    handle: Optional[SecondLevelPtr] = None

    @property
    def pages(self) -> List[int]:
        """Page indices (legacy surface; the table itself is page_table)."""
        return list(range(len(self.page_table)))


class PagedKVAllocator:
    """Page-granular KV planning over GlobalMemory's buddy arena.

    Every page is one ``page_bytes`` asymmetric region homed on
    ``home_rank`` (other ranks carry only the 32-byte second-level-pointer
    wrapper + minimal metadata), tracked in the request's ``page_table``.
    Released pages park on a per-home-rank free list and are handed out
    again before the arena is ever touched.
    """

    def __init__(self, memory: GlobalMemory, group: DiompGroup, *,
                 page_tokens: int = 128, kv_bytes_per_token: int = 2 * 2 * 128):
        self.memory = memory
        self.group = group
        self.page_tokens = page_tokens
        self.token_bytes = kv_bytes_per_token
        self.page_bytes = page_tokens * kv_bytes_per_token
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._free_pages: Dict[int, List[SecondLevelPtr]] = {}
        # (event, ...) tuples; tests assert the per-op allocation counts
        self.call_log: List[Tuple] = []
        self.dead_ranks: set = set()
        self.stats = {
            "pages_allocated": 0,   # pages handed to requests (incl. reuse)
            "pages_freed": 0,       # pages returned (free list or rollback)
            "arena_page_allocs": 0,  # actual GlobalMemory allocations
            "page_reuses": 0,       # free-list hits
            "oom_events": 0,
            "migrations": 0,
            "bytes_migrated": 0,
            "pages_lost": 0,        # pages on a dead rank (subset of freed)
            "retried_page_puts": 0,  # re-issued page transfers (faults)
        }
        # watermark-pressure denominator; the buddy allocator rounds each
        # page up to a power-of-two block, so size pages accordingly for an
        # exact capacity (docs/SERVING.md "knobs")
        self.capacity_pages = max(
            1, memory.segment_bytes // max(self.page_bytes, 1))

    # -- page pool ------------------------------------------------------------
    def _alloc_page(self, home: int, rid: int, idx: int) -> Optional[SecondLevelPtr]:
        if home in self.dead_ranks:
            return None
        free = self._free_pages.get(home)
        if free:
            slp = free.pop()
            self.stats["page_reuses"] += 1
            self.call_log.append(("page_reuse", home))
        else:
            sizes = [self.page_bytes if r == home else 0
                     for r in range(self.memory.nranks)]
            try:
                slp = self.memory.alloc_asymmetric(
                    f"kv/r{rid}/p{idx}", sizes, self.group)
            except AllocError:
                return None
            self.stats["arena_page_allocs"] += 1
            self.call_log.append(("arena_alloc", home))
        self.stats["pages_allocated"] += 1
        return slp

    def _release_page(self, slp: SecondLevelPtr, home: int) -> None:
        self._free_pages.setdefault(home, []).append(slp)
        self.stats["pages_freed"] += 1

    # -- request lifecycle ----------------------------------------------------
    def admit(self, prompt_len: int, max_len: int, *,
              home_rank: int = 0) -> Optional[Request]:
        """Allocate pages for the prompt + one growth page; None if OOM."""
        rid = self._next_rid
        pages_needed = -(-max(prompt_len, 1) // self.page_tokens) + 1
        table: List[SecondLevelPtr] = []
        for i in range(pages_needed):
            page = self._alloc_page(home_rank, rid, i)
            if page is None:
                for p in table:          # rollback to the free list
                    self._release_page(p, home_rank)
                self.stats["oom_events"] += 1
                self.call_log.append(("admit_oom", rid))
                return None
            table.append(page)
        req = Request(rid=rid, prompt_len=prompt_len, max_len=max_len,
                      home_rank=home_rank, page_table=table, pos=0)
        self.requests[rid] = req
        self._next_rid += 1
        self.call_log.append(("admit", rid, pages_needed))
        return req

    def extend(self, req: Request) -> bool:
        """Ensure capacity for ``req.pos + 1`` tokens — AT MOST one page
        allocation (the O(1) growth the page table exists for)."""
        if req.pos < len(req.page_table) * self.page_tokens:
            return True
        page = self._alloc_page(req.home_rank, req.rid, len(req.page_table))
        if page is None:
            self.stats["oom_events"] += 1
            self.call_log.append(("extend_oom", req.rid))
            return False
        req.page_table.append(page)
        self.call_log.append(("extend", req.rid, 1))
        return True

    def reserve(self, req: Request, tokens: int) -> bool:
        """Grow the page table to cover ``tokens`` rows (the resume path
        after a recompute-style preemption dropped the pages)."""
        while len(req.page_table) * self.page_tokens < tokens:
            page = self._alloc_page(req.home_rank, req.rid,
                                    len(req.page_table))
            if page is None:
                self.stats["oom_events"] += 1
                self.call_log.append(("reserve_oom", req.rid))
                return False
            req.page_table.append(page)
            self.call_log.append(("reserve", req.rid, 1))
        return True

    def drop_pages(self, req: Request) -> int:
        """Return a live request's pages to the free list WITHOUT releasing
        the request (recompute-style preemption: the engine holds the row
        snapshot and re-``reserve``s pages at resume).  Returns the count."""
        n = len(req.page_table)
        for page in req.page_table:
            self._release_page(page, req.home_rank)
        req.page_table = []
        self.call_log.append(("drop_pages", req.rid, n))
        return n

    def release(self, req: Request) -> None:
        for page in req.page_table:
            self._release_page(page, req.home_rank)
        self.call_log.append(("release", req.rid, len(req.page_table)))
        req.page_table = []
        req.done = True
        del self.requests[req.rid]

    # -- preemption / migration ----------------------------------------------
    def migrate(self, req: Request, dst_rank: int, *, comm=None,
                tracker=None, window: Optional[str] = None,
                faults=None, policy=None, validate: bool = False) -> int:
        """Move every page of ``req`` to ``dst_rank``'s heap; returns bytes.

        Per page: allocate a destination page, issue a one-sided transfer
        (dst-side ``get`` of page_bytes — recorded on the OMPCCL
        communicator handle and the RMA tracker window, see module
        docstring), then return the source page to its free list.  One
        fence completes the epoch.

        ``validate=True`` turns on get-side integrity checking: each page
        transfer carries a content digest, is fenced and validated through
        the tracker, and a digest mismatch (an injected ``corrupt``/
        ``drop`` from ``faults``) is repaired by re-putting the page —
        retried wire traffic lands in the tracker/communicator *retry*
        logs, so the logical byte-parity audits still hold.  The default
        path (no validation) is byte-for-byte the historical one: N puts,
        one fence.
        """
        import numpy as np

        from repro.core.resilience import content_digest, corrupt_digest

        if dst_rank == req.home_rank:
            return 0
        name = window or f"kv/req{req.rid}"
        pagebuf = np.zeros((self.page_bytes,), np.uint8)
        new_table: List[SecondLevelPtr] = []
        for i, _old in enumerate(req.page_table):
            page = self._alloc_page(dst_rank, req.rid, i)
            if page is None:
                # roll the partial destination back; caller keeps the source
                # and NOTHING is recorded (no bytes moved on a failed swap)
                for p in new_table:
                    self._release_page(p, dst_rank)
                self.stats["oom_events"] += 1
                self.call_log.append(("migrate_oom", req.rid, dst_rank))
                return 0
            new_table.append(page)
        digest = content_digest(pagebuf) if validate else None
        budget = policy.budget("migrate") if policy is not None else 3
        try:
            for _ in new_table:
                attempt = 0
                pending = []      # faults hit on this page, not yet repaired
                while True:
                    fault = faults.next_fault("migrate") \
                        if faults is not None else None
                    wire = digest
                    if fault is not None:
                        if fault.kind == "delay":
                            fault.recovered = True
                        elif validate:
                            # damaged in flight: a wrong digest lands
                            wire = corrupt_digest(digest, fault.call_index)
                            pending.append(fault)
                    if comm is not None:
                        if attempt == 0:
                            # one-sided read of the page: count under "get",
                            # payload bytes under the leaf "put" (the
                            # communicator's delegating-op convention, so wire
                            # volume is never double-counted)
                            comm.record("get")
                            comm.record("put", pagebuf)
                        else:
                            comm.record_retry("put", pagebuf)
                    if tracker is not None:
                        tracker.on_put(name, self.page_bytes,
                                       checksum=wire, retry=attempt > 0)
                    if not validate or tracker is None:
                        break
                    tracker.on_fence(name)
                    try:
                        tracker.validate(name, digest)
                    except RMAError:
                        attempt += 1
                        self.stats["retried_page_puts"] += 1
                        if attempt > budget:
                            raise
                        continue
                    for hit in pending:   # a clean re-put repaired these
                        hit.recovered = True
                    break
        except RMAError:
            # budget exhausted mid-migration: the source pages are intact
            # (nothing released yet), so roll the destination table back to
            # its free list — otherwise the allocated-minus-freed == live
            # ledger breaks the moment a caller catches this error.  The
            # caller (engine/circuit-breaker) decides whether dst is sick.
            for p in new_table:
                self._release_page(p, dst_rank)
            self.call_log.append(("migrate_failed", req.rid, dst_rank))
            raise
        for old in req.page_table:
            self._release_page(old, req.home_rank)
        if tracker is not None and not validate:
            tracker.on_fence(name)
        moved = len(new_table) * self.page_bytes
        self.call_log.append(
            ("migrate", req.rid, req.home_rank, dst_rank, len(new_table)))
        req.page_table = new_table
        req.home_rank = dst_rank
        self.stats["migrations"] += 1
        self.stats["bytes_migrated"] += moved
        return moved

    # -- rank death -----------------------------------------------------------
    def forget_pages(self, req: Request) -> int:
        """A request's pages are GONE (their home rank died): unmap them
        without recycling.  Lost pages count under ``pages_lost`` AND
        ``pages_freed`` so the allocated-minus-freed == live ledger stays
        balanced.  Returns the count."""
        n = len(req.page_table)
        if n == 0:
            return 0
        for slp in req.page_table:
            self.memory.free(slp)
        req.page_table = []
        self.stats["pages_freed"] += n
        self.stats["pages_lost"] += n
        self.call_log.append(("forget_pages", req.rid, n))
        return n

    def forget(self, req: Request) -> None:
        """Drop a request whose pages were forgotten (no release recycling)."""
        req.page_table = []
        self.requests.pop(req.rid, None)
        self.call_log.append(("forget", req.rid))

    def forget_rank(self, rank: int) -> int:
        """Rank ``rank`` died abruptly: purge its free list, forget every
        tracked request's pages homed there, and refuse future allocations
        on it.  Returns pages lost from live requests (the engine decides
        what to do with their owners)."""
        self.dead_ranks.add(rank)
        for slp in self._free_pages.pop(rank, []):
            self.memory.free(slp)
        lost = 0
        for req in list(self.requests.values()):
            if req.home_rank == rank and req.page_table:
                lost += self.forget_pages(req)
        self.call_log.append(("rank_death", rank, lost))
        return lost

    # -- addressing -----------------------------------------------------------
    def lookup(self, req: Request, token_pos: int,
               rank: Optional[int] = None) -> Tuple[int, int]:
        """(rank, byte offset) of a token's KV — page-table indirection via
        the cached second-level pointer (paper Fig. 2 (as-1))."""
        page_idx, within = divmod(token_pos, self.page_tokens)
        slp = req.page_table[page_idx]
        r, base = self.memory.translate(
            slp, req.home_rank if rank is None else rank)
        return r, base + within * self.token_bytes

    # -- pressure / introspection ---------------------------------------------
    def live_pages(self, rank: Optional[int] = None) -> int:
        return sum(
            len(r.page_table) for r in self.requests.values()
            if rank is None or r.home_rank == rank)

    def free_list_pages(self, rank: Optional[int] = None) -> int:
        return sum(
            len(v) for k, v in self._free_pages.items()
            if rank is None or k == rank)

    def pressure(self, ranks=None) -> float:
        """max over ``ranks`` (default: all live) of live-KV-page
        utilization — the engine's watermark-preemption signal.  Dead
        ranks are excluded: their heaps no longer exist."""
        ranks = range(self.memory.nranks) if ranks is None else ranks
        util = [self.live_pages(r) / self.capacity_pages
                for r in ranks if r not in self.dead_ranks]
        return max(util, default=0.0)

    def trim(self) -> int:
        """Return every free-list page to the arena; returns pages trimmed."""
        n = 0
        for home, pages in self._free_pages.items():
            for slp in pages:
                self.memory.free(slp)
                n += 1
            pages.clear()
        return n

    @property
    def bytes_in_use(self) -> int:
        return self.memory.bytes_in_use(0)


class ReallocKVAllocator:
    """The pre-page-table design (whole-region realloc on every growth).

    Kept as the measured baseline for ``benchmarks/bench_kvcache.py``:
    ``extend`` re-allocates the ENTIRE region one page larger and frees the
    old one — O(pages) bytes of churn per page-boundary crossing, O(pages²)
    over a request's life — which is exactly the churn the page table
    eliminates.  Same stats surface as :class:`PagedKVAllocator` so the
    bench compares rows directly.
    """

    def __init__(self, memory: GlobalMemory, group: DiompGroup, *,
                 page_tokens: int = 128, kv_bytes_per_token: int = 2 * 2 * 128):
        self.memory = memory
        self.group = group
        self.page_tokens = page_tokens
        self.token_bytes = kv_bytes_per_token
        self.page_bytes = page_tokens * kv_bytes_per_token
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._npages: Dict[int, int] = {}
        self.stats = {
            "pages_allocated": 0, "pages_freed": 0, "arena_page_allocs": 0,
            "page_reuses": 0, "oom_events": 0, "migrations": 0,
            "bytes_migrated": 0,
        }

    def admit(self, prompt_len: int, max_len: int, *,
              home_rank: int = 0) -> Optional[Request]:
        rid = self._next_rid
        pages = -(-max(prompt_len, 1) // self.page_tokens) + 1
        sizes = [pages * self.page_bytes] * self.memory.nranks
        try:
            handle = self.memory.alloc_asymmetric(
                f"kv/req{rid}", sizes, self.group)
        except AllocError:
            self.stats["oom_events"] += 1
            return None
        req = Request(rid=rid, prompt_len=prompt_len, max_len=max_len,
                      home_rank=home_rank, pos=0, handle=handle)
        self.requests[rid] = req
        self._npages[rid] = pages
        self._next_rid += 1
        self.stats["pages_allocated"] += pages
        self.stats["arena_page_allocs"] += pages
        return req

    def extend(self, req: Request) -> bool:
        pages = self._npages[req.rid]
        if req.pos < pages * self.page_tokens:
            return True
        sizes = [(pages + 1) * self.page_bytes] * self.memory.nranks
        try:
            new = self.memory.alloc_asymmetric(
                f"kv/req{req.rid}p{pages}", sizes, self.group)
        except AllocError:
            self.stats["oom_events"] += 1
            return False
        self.memory.free(req.handle)
        req.handle = new
        self._npages[req.rid] = pages + 1
        # the realloc moves the whole region: pages+1 pages of fresh
        # allocation (and pages of copy+free) for ONE page of growth
        self.stats["pages_allocated"] += 1
        self.stats["arena_page_allocs"] += pages + 1
        return True

    def release(self, req: Request) -> None:
        if req.handle is not None:
            self.memory.free(req.handle)
            self.stats["pages_freed"] += self._npages.pop(req.rid)
            req.handle = None
        req.done = True
        del self.requests[req.rid]

    def lookup(self, req: Request, token_pos: int,
               rank: Optional[int] = None) -> Tuple[int, int]:
        r, base = self.memory.translate(
            req.handle, req.home_rank if rank is None else rank)
        return r, base + token_pos * self.token_bytes

    @property
    def bytes_in_use(self) -> int:
        return self.memory.bytes_in_use(0)

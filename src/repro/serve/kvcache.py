"""Paged KV-cache allocator on the DiOMP PGAS heap.

This is the paper's *asymmetric allocation* machinery doing real work
(DESIGN.md §4): every request's KV pages are an asymmetric region (request
lengths differ per rank), the page table is the second-level-pointer table
(uniformly allocated, values point at ragged payloads), and the remote
pointer cache amortizes repeated lookups — exactly the Fig. 2 (as-1)
mechanism, reused as a vLLM-style page table.

The allocator plans *addresses*; the device-side cache tensor is dense per
slot (the serve step's layout).  What the plan buys at scale: KV for a
preempted/migrated request can be fetched from a remote device's heap by
(rank, offset) — one-sided, no registration handshake.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.groups import DiompGroup
from repro.core.pgas import AllocError, GlobalMemory, SecondLevelPtr

__all__ = ["PagedKVAllocator", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_len: int
    pages: List[int] = dataclasses.field(default_factory=list)
    handle: Optional[SecondLevelPtr] = None
    pos: int = 0
    done: bool = False


class PagedKVAllocator:
    """Page-granular KV planning over GlobalMemory's buddy arena."""

    def __init__(self, memory: GlobalMemory, group: DiompGroup, *,
                 page_tokens: int = 128, kv_bytes_per_token: int = 2 * 2 * 128):
        self.memory = memory
        self.group = group
        self.page_tokens = page_tokens
        self.page_bytes = page_tokens * kv_bytes_per_token
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.stats = {"pages_allocated": 0, "pages_freed": 0, "oom_events": 0}

    # -- request lifecycle ----------------------------------------------------
    def admit(self, prompt_len: int, max_len: int) -> Optional[Request]:
        """Allocate pages for the prompt + one growth page; None if OOM."""
        rid = self._next_rid
        pages_needed = -(-prompt_len // self.page_tokens) + 1
        sizes = [pages_needed * self.page_bytes] * self.memory.nranks
        try:
            handle = self.memory.alloc_asymmetric(
                f"kv/req{rid}", sizes, self.group)
        except AllocError:
            self.stats["oom_events"] += 1
            return None
        req = Request(rid=rid, prompt_len=prompt_len, max_len=max_len,
                      pages=list(range(pages_needed)), handle=handle,
                      pos=prompt_len)
        self.requests[rid] = req
        self._next_rid += 1
        self.stats["pages_allocated"] += pages_needed
        return req

    def extend(self, req: Request) -> bool:
        """Grow by one page when decode crosses a page boundary."""
        have = len(req.pages) * self.page_tokens
        if req.pos < have:
            return True
        old = req.handle
        sizes = [(len(req.pages) + 1) * self.page_bytes] * self.memory.nranks
        try:
            new = self.memory.alloc_asymmetric(
                f"kv/req{req.rid}p{len(req.pages)}", sizes, self.group)
        except AllocError:
            self.stats["oom_events"] += 1
            return False
        self.memory.free(old)
        req.handle = new
        req.pages.append(len(req.pages))
        self.stats["pages_allocated"] += 1
        return True

    def release(self, req: Request) -> None:
        if req.handle is not None:
            self.memory.free(req.handle)
            self.stats["pages_freed"] += len(req.pages)
            req.handle = None
        req.done = True
        del self.requests[req.rid]

    # -- addressing -------------------------------------------------------------
    def lookup(self, req: Request, token_pos: int, rank: int) -> Tuple[int, int]:
        """(rank, byte offset) of a token's KV — via the 2nd-level pointer
        (cached after first remote fetch)."""
        base_rank, base_off = self.memory.translate(req.handle, rank)
        page, within = divmod(token_pos, self.page_tokens)
        return base_rank, base_off + page * self.page_bytes + within * (
            self.page_bytes // self.page_tokens)

    @property
    def bytes_in_use(self) -> int:
        return self.memory.bytes_in_use(0)

"""Serving substrate: paged KV cache on the PGAS heap + batching engine."""

"""Serve-step builders: shard_map'd prefill and decode steps per family.

The decode step is THE unit the decode_32k / long_500k dry-run cells lower:
one new token against a full KV cache, with the cache sharded per the
runtime's placement rules (heads over "model"; batch over DP axes; the S
axis over "data" for the context-parallel long shapes).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import ompccl
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx

__all__ = ["build_decode_step", "build_prefill_step",
           "build_chunk_prefill_step"]


def build_decode_step(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx, *,
                      B: int, S: int, seq_sharded: bool = False,
                      donate: bool = True, slot_pos: bool = False):
    """jitted (params, tokens (B,1), cache) -> (logits (B,1,V), cache').

    ``slot_pos=True`` (the serving engine) declares ``cache["pos"]`` as a
    per-slot (B,) vector sharded like the batch, so a slot count divisible
    by the DP axes keeps positions aligned with their cache rows.
    """
    import dataclasses

    from repro.distributed.sharding import rules_for_ctx
    from repro.kernels.plan import (resolve_dispatch_impl, resolve_ring_impl,
                                    resolve_seq_parallel)

    ctx = dataclasses.replace(
        ctx, inference=True, remat=False,
        ring_impl=resolve_ring_impl(ctx.ring_impl),
        dispatch_impl=resolve_dispatch_impl(ctx.dispatch_impl),
        seq_parallel=resolve_seq_parallel(ctx.seq_parallel))
    decode = model_api.decode_fn(cfg)
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    _, cspecs = model_api.cache_structs(cfg, mesh, ctx, B, S,
                                        seq_sharded=seq_sharded)
    ba = model_api._batch_axes(mesh, B)
    bpart = ba if ba else None
    if slot_pos:
        cspecs = dict(cspecs)
        cspecs["pos"] = P(bpart)
    vs = "model" if sch.vocab_sharded(cfg) else None

    def step(params, tokens, cache):
        logits, cache = decode(params, tokens, cfg, ctx, cache,
                               seq_sharded=seq_sharded)
        return logits, cache

    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(bpart), cspecs),
        out_specs=(P(bpart, None, vs), cspecs),
    )
    kwargs = {"donate_argnums": (2,)} if donate else {}
    return jax.jit(mapped, **kwargs)


def build_chunk_prefill_step(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx,
                             *, C: int, S_cache: int, B: int = 1,
                             donate: bool = False):
    """jitted (params, tokens (B,C), cache, rlen ()) -> (logits (B,1,V), cache').

    The serving engine's chunked-prefill unit (docs/SERVING.md): ``cache``
    is the engine cache sliced to one slot (B=1) with a *scalar* ``pos``;
    the chunk is appended at ``pos`` and the logits of the last real token
    (``rlen - 1``) come back — ONE device call per prompt chunk instead of
    one per prompt token.  Transformer families only (attention caches
    address by position; recurrent-state families prefill token-by-token
    through the decode step).
    """
    import dataclasses

    from repro.distributed.sharding import rules_for_ctx
    from repro.kernels.plan import (resolve_dispatch_impl, resolve_ring_impl,
                                    resolve_seq_parallel)
    from repro.models.transformer import transformer_chunk_prefill

    if cfg.family not in model_api.TRANSFORMER_FAMILIES:
        raise ValueError(
            f"chunked prefill supports transformer families only, "
            f"got {cfg.family!r}")
    ctx = dataclasses.replace(
        ctx, inference=True, remat=False,
        ring_impl=resolve_ring_impl(ctx.ring_impl),
        dispatch_impl=resolve_dispatch_impl(ctx.dispatch_impl),
        seq_parallel=resolve_seq_parallel(ctx.seq_parallel))
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    _, cspecs = model_api.cache_structs(cfg, mesh, ctx, B, S_cache)
    vs = "model" if sch.vocab_sharded(cfg) else None

    def step(params, tokens, cache, rlen):
        return transformer_chunk_prefill(params, tokens, cfg, ctx, cache,
                                         rlen)

    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(None), cspecs, P()),
        out_specs=(P(None, None, vs), cspecs),
    )
    kwargs = {"donate_argnums": (2,)} if donate else {}
    return jax.jit(mapped, **kwargs)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx, *,
                       B: int, S_prompt: int, S_cache: int,
                       seq_sharded: bool = False, donate: bool = True):
    """jitted (params, tokens (B,Sp), cache) -> (last logits, cache')."""
    import dataclasses

    import jax.numpy as jnp

    from repro.models.transformer import transformer_prefill
    from repro.models.rwkv import rwkv_forward
    from repro.models.ssm import zamba_forward

    from repro.distributed.sharding import rules_for_ctx
    from repro.kernels.plan import (resolve_dispatch_impl, resolve_ring_impl,
                                    resolve_seq_parallel)

    ctx = dataclasses.replace(
        ctx, inference=True, remat=False,
        ring_impl=resolve_ring_impl(ctx.ring_impl),
        dispatch_impl=resolve_dispatch_impl(ctx.dispatch_impl),
        seq_parallel=resolve_seq_parallel(ctx.seq_parallel))
    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    _, cspecs = model_api.cache_structs(cfg, mesh, ctx, B, S_cache,
                                        seq_sharded=seq_sharded)
    ba = model_api._batch_axes(mesh, B)
    bpart = ba if ba else None
    vs = "model" if sch.vocab_sharded(cfg) else None

    if cfg.family in model_api.TRANSFORMER_FAMILIES:
        def step(params, tokens, cache):
            logits, cache = transformer_prefill(
                params, tokens, cfg, ctx, cache, seq_sharded=seq_sharded)
            return logits, cache
    elif cfg.family == "ssm":
        def step(params, tokens, cache):
            h, cache = rwkv_forward(params, tokens, cfg, ctx, cache)
            logits = jnp.dot(h[:, -1:].astype(jnp.float32),
                             params["lm_head"].astype(jnp.float32))
            return logits, cache
    elif cfg.family == "hybrid":
        def step(params, tokens, cache):
            h, cache = zamba_forward(params, tokens, cfg, ctx, cache,
                                     seq_sharded=seq_sharded)
            logits = jnp.dot(h[:, -1:].astype(jnp.float32),
                             params["lm_head"].astype(jnp.float32))
            return logits, cache
    else:
        raise ValueError(cfg.family)

    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P(bpart), cspecs),
        out_specs=(P(bpart, None, vs), cspecs),
    )
    kwargs = {"donate_argnums": (2,)} if donate else {}
    return jax.jit(mapped, **kwargs)

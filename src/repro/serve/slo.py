"""SLO layer for the serving engine: deadlines, admission, degraded modes.

The engine's scheduler (engine.py) decides *which* admitted request runs
next; this module decides *whether a request should be admitted at all*
and *how hard the engine should work* under sustained pressure — the
request-level robustness layer on top of PR 7's wire-level resilience
(docs/SERVING.md "Overload & SLOs" is the design doc).

Everything here is evaluated on an **injectable clock** (any
``() -> float`` callable; :class:`ManualClock` for tests and the
deterministic ``bench_overload`` runs, ``time.perf_counter`` in
production), so admission, shedding, and degraded-mode decisions replay
bit-identically for a fixed seed and trace.

Pieces:

* :class:`TierPolicy` / :class:`SLOPolicy` — per-priority-tier TTFT and
  total-latency deadlines, a token-bucket rate limit per tier, a bounded
  queue with high/low depth watermarks, and the degraded-mode knobs.
* :class:`TokenBucket` — the rate limiter, refilled from clock deltas.
* :class:`AdmissionController` — turns a submit into an explicit
  :class:`AdmissionDecision` (``admit`` / ``reject`` / ``backpressure``)
  and runs the degraded-mode ladder (level 0..3) off sustained queue
  pressure with hysteresis.
* :func:`percentile` / :func:`percentiles` — the latency-aggregation
  math ``latency_stats()`` reports (pinned by ``tests/test_overload.py``).

Admission state machine (evaluated in ``decide`` order)::

     submit ──► infeasible deadline? ──► REJECT "infeasible"
                │ queue at max_queue? ─► REJECT "queue_full"
                │ tier bucket empty? ──► REJECT "rate_limited"
                │ depth ≥ queue_high ──► BACKPRESSURE (queued, slow down)
                ▼
              ADMIT "ok" (queued)

Degraded-mode ladder (one level per ``degrade_sustain_steps`` of queue
depth above ``queue_high``; one level back per ``degrade_recover_steps``
at-or-below ``queue_low``)::

     L0 normal ─► L1 cap max_new ─► L2 cap prefill chunk ─► L3 suspend
                                                            spill
                                                            migration
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence

__all__ = [
    "ManualClock",
    "TokenBucket",
    "TierPolicy",
    "SLOPolicy",
    "AdmissionDecision",
    "AdmissionController",
    "percentile",
    "percentiles",
]


class ManualClock:
    """A clock the caller advances explicitly — the deterministic time
    base for SLO tests and ``bench_overload`` (one fixed ``dt`` per
    engine step models a serving tick)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    __call__ = now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += float(dt)
        return self._t


class TokenBucket:
    """Token-bucket rate limiter on an injectable clock.

    Refill is computed from clock deltas (``rate_per_s`` tokens/second,
    capped at ``burst``), so behavior is a pure function of the take
    times — deterministic under :class:`ManualClock`.
    """

    def __init__(self, rate_per_s: float, burst: float,
                 clock: Callable[[], float]):
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError("rate_per_s and burst must be positive")
        self.rate = float(rate_per_s)
        self.burst = float(burst)
        self.clock = clock
        self.tokens = float(burst)          # starts full
        self._last = clock()

    def _refill(self) -> None:
        now = self.clock()
        self.tokens = min(self.burst,
                          self.tokens + (now - self._last) * self.rate)
        self._last = now

    def try_take(self, n: float = 1.0) -> bool:
        self._refill()
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def peek(self) -> float:
        self._refill()
        return self.tokens


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Per-priority-tier SLO targets.  ``None`` disables a limit."""

    ttft_deadline_s: Optional[float] = None    # submit -> first token
    total_deadline_s: Optional[float] = None   # submit -> finish
    rate_per_s: Optional[float] = None         # admission rate limit
    burst: float = 8.0                         # bucket depth for the limiter


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """The engine-wide SLO configuration (knob table: docs/SERVING.md).

    ``tiers`` maps a ``submit(priority=...)`` value to its
    :class:`TierPolicy`; unlisted priorities use ``default_tier``.
    ``min_step_s`` is the modeled floor of one engine step — it powers the
    admission feasibility check (a request whose minimal service time
    cannot fit its deadline is rejected at the door, never admitted to
    violate); ``0`` disables feasibility checking.
    """

    tiers: Mapping[int, TierPolicy] = dataclasses.field(default_factory=dict)
    default_tier: TierPolicy = dataclasses.field(default_factory=TierPolicy)
    max_queue: int = 64                 # hard bound: beyond it, reject
    queue_high: int = 16                # backpressure + degrade watermark
    queue_low: int = 4                  # hysteresis: clears both
    min_step_s: float = 0.0             # modeled engine-step floor
    # degraded-mode ladder
    degrade_sustain_steps: int = 4      # steps above high before escalating
    degrade_recover_steps: int = 8      # steps at/below low before recovering
    degraded_max_new: Optional[int] = None   # L1: cap admissions' max_new
    degraded_chunk: Optional[int] = None     # L2: cap prefill tokens/call

    def __post_init__(self):
        if not (0 <= self.queue_low <= self.queue_high <= self.max_queue):
            raise ValueError(
                f"need queue_low <= queue_high <= max_queue, got "
                f"{self.queue_low}/{self.queue_high}/{self.max_queue}")

    def tier(self, priority: int) -> TierPolicy:
        return self.tiers.get(priority, self.default_tier)

    def min_service_s(self, prompt_remaining: int, max_new: int,
                      chunk: int) -> float:
        """Modeled lower bound on serving time: one step per prefill chunk
        plus one per generated token, at the ``min_step_s`` floor."""
        if self.min_step_s <= 0.0:
            return 0.0
        steps = -(-max(prompt_remaining, 0) // max(chunk, 1)) + max(max_new, 0)
        return steps * self.min_step_s

    def min_ttft_s(self, prompt_remaining: int, chunk: int) -> float:
        """Modeled lower bound on TTFT: the prefill chunks alone (the
        final chunk commits the first token)."""
        if self.min_step_s <= 0.0:
            return 0.0
        return -(-max(prompt_remaining, 1) // max(chunk, 1)) * self.min_step_s


@dataclasses.dataclass
class AdmissionDecision:
    """The explicit result of a ``submit`` under an SLO policy.

    ``action`` is ``"admit"`` (queued), ``"backpressure"`` (queued, but
    the caller should slow down — queue depth crossed ``queue_high`` and
    has not fallen back to ``queue_low``), or ``"reject"`` (NOT queued;
    ``reason`` says why: ``infeasible`` / ``queue_full`` /
    ``rate_limited``).
    """

    action: str
    reason: str
    tier: int = 0
    queue_depth: int = 0

    @property
    def admitted(self) -> bool:
        return self.action != "reject"


class AdmissionController:
    """Evaluates :class:`SLOPolicy` for one engine.

    Owns the per-tier token buckets, the backpressure flag (watermark
    hysteresis), and the degraded-mode ladder.  Every decision and ladder
    transition is appended to ``log`` (the engine's ``slo_log``), which is
    the deterministic decision record ``bench_overload`` replays and
    diffs across seeds.
    """

    def __init__(self, policy: SLOPolicy, clock: Callable[[], float],
                 log: Optional[List[tuple]] = None):
        self.policy = policy
        self.clock = clock
        self.log = log if log is not None else []
        self._buckets: Dict[int, TokenBucket] = {}
        self.backpressure = False
        self.level = 0                       # degraded-mode ladder level
        self._above = 0
        self._below = 0
        self.transitions: List[tuple] = []   # (step, old_level, new_level)

    def bucket(self, priority: int) -> Optional[TokenBucket]:
        tier = self.policy.tier(priority)
        if tier.rate_per_s is None:
            return None
        if priority not in self._buckets:
            self._buckets[priority] = TokenBucket(
                tier.rate_per_s, tier.burst, self.clock)
        return self._buckets[priority]

    # -- admission ----------------------------------------------------------
    def decide(self, *, priority: int, prompt_len: int, max_new: int,
               chunk: int, queue_depth: int,
               ttft_deadline_s: Optional[float],
               total_deadline_s: Optional[float]) -> AdmissionDecision:
        p = self.policy
        d = lambda action, reason: AdmissionDecision(
            action, reason, tier=priority, queue_depth=queue_depth)
        # 1. a deadline that cannot be met even unqueued is never admitted
        if ttft_deadline_s is not None \
                and p.min_ttft_s(prompt_len, chunk) > ttft_deadline_s:
            return d("reject", "infeasible")
        if total_deadline_s is not None \
                and p.min_service_s(prompt_len, max_new,
                                    chunk) > total_deadline_s:
            return d("reject", "infeasible")
        # 2. hard queue bound
        if queue_depth >= p.max_queue:
            return d("reject", "queue_full")
        # 3. per-tier rate limit
        bucket = self.bucket(priority)
        if bucket is not None and not bucket.try_take(1.0):
            return d("reject", "rate_limited")
        # 4. watermark backpressure (queued, with a slow-down signal)
        if queue_depth >= p.queue_high:
            self.backpressure = True
        elif queue_depth <= p.queue_low:
            self.backpressure = False
        if self.backpressure:
            return d("backpressure", "queue_high")
        return d("admit", "ok")

    # -- degraded-mode ladder ----------------------------------------------
    def update_pressure(self, queue_depth: int, step: int) -> int:
        """One engine step's pressure sample; returns the ladder level."""
        p = self.policy
        if queue_depth > p.queue_high:
            self._above += 1
            self._below = 0
            if self._above >= p.degrade_sustain_steps and self.level < 3:
                self._above = 0
                self._move(step, self.level + 1, queue_depth)
        elif queue_depth <= p.queue_low:
            self._below += 1
            self._above = 0
            if self._below >= p.degrade_recover_steps and self.level > 0:
                self._below = 0
                self._move(step, self.level - 1, queue_depth)
            if queue_depth <= p.queue_low:
                self.backpressure = False
        else:
            self._above = 0
            self._below = 0
        return self.level

    def _move(self, step: int, new: int, depth: int) -> None:
        self.transitions.append((step, self.level, new))
        self.log.append(("degrade", step, self.level, new, depth))
        self.level = new


# -- latency aggregation -----------------------------------------------------

def percentile(xs: Sequence[float], q: float) -> Optional[float]:
    """Linear-interpolation percentile (numpy's default convention): the
    value at fractional rank ``q/100 * (n-1)`` between order statistics.
    ``None`` on empty input."""
    if not xs:
        return None
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (float(q) / 100.0) * (len(s) - 1)
    lo = min(int(rank), len(s) - 2)
    frac = rank - lo
    return float(s[lo] + (s[lo + 1] - s[lo]) * frac)


def percentiles(xs: Sequence[float],
                qs: Sequence[float] = (50, 95, 99)) -> Optional[dict]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` or ``None`` on empty."""
    if not xs:
        return None
    return {f"p{q:g}": percentile(xs, q) for q in qs}


def wall_clock() -> float:
    """The default engine clock (monotonic wall seconds)."""
    return time.perf_counter()

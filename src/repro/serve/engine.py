"""Continuous-batching serving engine (slot-based, vLLM-shaped).

A fixed pool of B slots; requests admit into free slots via the PagedKV
allocator (PGAS asymmetric regions — the paper's second-level-pointer
machinery as a page table), every engine step advances *all* active slots
by one token (per-slot ``pos`` vector in the cache), finished slots release
their pages and refill from the queue.  Prompts stream through the decode
path token-by-token (teacher-forced prefill), so a newly admitted request
coexists with slots that are mid-generation — continuous batching.

The engine is single-controller host code: the paper's "single-process
multi-GPU" deployment — the host orchestrates, OMPCCL moves data, and host
threads (StreamPool) stay free for tokenize/detokenize work.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.models.config import ModelConfig, ParallelCtx
from repro.models.transformer import init_cache
from .kvcache import PagedKVAllocator, Request
from .step import build_decode_step

__all__ = ["ServeEngine", "GenRequest"]


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    fed: int = 0                # prompt tokens consumed so far
    kv: Optional[Request] = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, ctx: ParallelCtx, params, *,
                 slots: int = 4, max_len: int = 256,
                 memory: Optional[GlobalMemory] = None,
                 context: Optional[DiompContext] = None):
        self.cfg, self.mesh, self.ctx = cfg, mesh, ctx
        self.params = params
        self.B, self.S = slots, max_len
        # the engine runs on a DiompContext: the KV-page arena is its PGAS
        # memory, the world group its communicator domain.  A caller-provided
        # `memory` (legacy) still wins for the arena.
        if context is None:
            context = DiompContext(mesh=mesh, segment_bytes=1 << 26,
                                   allocator="buddy")
        self.dctx = context
        self.memory = memory or context.memory
        kv_bpt = 2 * 2 * max(cfg.kv_heads, 1) * max(cfg.head_dim, 1) \
            * cfg.num_layers
        self.alloc = PagedKVAllocator(
            self.memory,
            context.groups.get("world",
                               DiompGroup(tuple(mesh.axis_names),
                                          name="world")),
            page_tokens=64, kv_bytes_per_token=max(kv_bpt, 64))
        self.decode_step = build_decode_step(cfg, mesh, ctx, B=slots,
                                             S=max_len, donate=False)
        # global-view cache (cache_structs shapes); in_specs shard it
        from repro.models import api as model_api
        structs, _ = model_api.cache_structs(cfg, mesh, ctx, self.B, self.S)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)
        cache["pos"] = jnp.zeros((self.B,), jnp.int32)
        self.cache = cache
        self.queue: Deque[GenRequest] = deque()
        self.active: Dict[int, GenRequest] = {}
        self.free_slots = list(range(slots))
        self.pending = np.zeros((slots, 1), np.int32)
        self.steps = 0

    # -- API --------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32) -> GenRequest:
        r = GenRequest(prompt=np.asarray(prompt, np.int32), max_new=max_new)
        self.queue.append(r)
        return r

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self._admit()
            if not self.active:
                if not self.queue:
                    break
                continue
            self._set_inputs()
            logits = self._device_step()
            self._harvest(logits)
        return self

    # -- internals ----------------------------------------------------------
    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue[0]
            kv = self.alloc.admit(len(req.prompt),
                                  len(req.prompt) + req.max_new)
            if kv is None:
                break                      # KV OOM — wait for a release
            self.queue.popleft()
            req.kv = kv
            req.slot = self.free_slots.pop()
            kv.pos = 0
            self.active[req.slot] = req

    def _set_inputs(self):
        for slot, req in self.active.items():
            if req.fed < len(req.prompt):
                self.pending[slot, 0] = req.prompt[req.fed]
            else:
                self.pending[slot, 0] = req.out[-1]

    def _device_step(self):
        # the decode step's collectives resolve the process-default context
        # at trace time; scope it to the engine's own context so its
        # communicator table records this engine's traffic
        with use_default(self.dctx):
            logits, self.cache = self.decode_step(
                self.params, jnp.asarray(self.pending), self.cache)
        self.steps += 1
        return np.asarray(jax.device_get(logits))

    def _harvest(self, logits):
        for slot, req in list(self.active.items()):
            req.kv.pos += 1
            self.alloc.extend(req.kv)
            if req.fed < len(req.prompt):
                req.fed += 1
                if req.fed < len(req.prompt):
                    continue               # still prefilling: ignore logits
            req.out.append(int(logits[slot, 0].argmax()))
            if len(req.out) >= req.max_new:
                req.done = True
                self.alloc.release(req.kv)
                del self.active[slot]
                self.free_slots.append(slot)
                # reset this slot's device position for the next request
                self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    @property
    def kv_stats(self):
        s = dict(self.alloc.stats)
        s["ptr_cache_hit_rate"] = self.memory.ptr_cache.hit_rate
        return s

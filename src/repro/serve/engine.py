"""Continuous-batching serving engine (slot-based, vLLM-shaped).

The production serving loop documented in docs/SERVING.md (layer map:
docs/ARCHITECTURE.md).  A fixed pool of B slots; requests admit into free
slots via the PagedKV allocator (PGAS page tables — the paper's second-
level-pointer machinery), prompts stream in through **chunked prefill**
(one device call per ``prefill_chunk`` prompt tokens, interleaved with
decode in the same engine loop), every decode step advances all decode-
ready slots by one sampled token (per-slot ``pos`` vector in the cache),
finished slots release their pages to the allocator free list and refill
from the queue.

Scheduling: the queue is priority-ordered (then FIFO); when KV pressure
crosses the high watermark — or a page allocation fails mid-decode — the
lowest-priority / latest-arrived victim is **preempted**: its device rows
are snapshotted host-side and its KV pages migrate to a spill rank's heap
via one-sided RMA (recorded on the OMPCCL call log and the request's
RMATracker window); preempted requests resume into the next free slot by
migrating their pages home again.  Slots that are free or mid-prefill are
*parked* during decode steps (their device write lands on the reserved
scratch row S-1, and the engine re-asserts the authoritative per-slot
positions afterwards), which fixes the seed engine's leak of stale pending
tokens / phantom position advances on released slots.

The engine is single-controller host code: the paper's "single-process
multi-GPU" deployment — the host orchestrates, OMPCCL moves data, and host
threads (StreamPool) stay free for tokenize/detokenize work.

Overload behavior (docs/SERVING.md "Overload & SLOs"): with an
``SLOPolicy`` attached, ``submit()`` returns an explicit admit / reject /
backpressure decision (``req.decision``) instead of queueing
unconditionally; each ``step()`` sheds queued requests whose deadlines
expired (or can no longer be met) and cancels mid-flight expired requests
with their KV pages freed and accounted; sustained queue pressure walks a
staged degraded-mode ladder (cap ``max_new`` → cap prefill chunk →
suspend spill migration) with hysteretic recovery.  All timestamps come
from an **injectable clock** (wall clock by default), so the whole
decision sequence replays deterministically under a ``ManualClock``.
Spill-target selection runs through a per-``(verb, rank)``
``CircuitBreaker``: a spill rank that keeps exhausting migrate retry
budgets is quarantined (open), routed around, probed after cooldown
(half-open), and readmitted on a clean success.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import DiompContext, use_default
from repro.core.groups import DiompGroup
from repro.core.pgas import GlobalMemory
from repro.core.resilience import CircuitBreaker
from repro.core.rma import RMAError
from repro.models import api as model_api
from repro.models.config import ModelConfig, ParallelCtx
from .kvcache import PagedKVAllocator, Request
from .slo import AdmissionController, AdmissionDecision, SLOPolicy, percentiles
from .step import build_chunk_prefill_step, build_decode_step

__all__ = ["ServeEngine", "GenRequest"]


@dataclasses.dataclass(eq=False)       # identity semantics: requests are
class GenRequest:                      # scheduled objects, not values
    prompt: np.ndarray          # (len,) int32
    max_new: int
    priority: int = 0           # higher wins at admission / survives preemption
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    fed: int = 0                # prompt tokens consumed so far
    kv: Optional[Request] = None
    done: bool = False
    arrival: int = 0
    # per-request accounting (docs/SERVING.md "measurement")
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    admit_step: int = -1
    finish_step: int = -1
    prefill_steps: int = 0      # chunk-prefill device calls for this request
    decode_steps: int = 0       # decode steps this request participated in
    preemptions: int = 0
    # SLO surface (docs/SERVING.md "Overload & SLOs"): deadlines are
    # ABSOLUTE clock times (submit_t + the relative deadline); `decision`
    # is the explicit admission verdict, `shed_reason` is set when the
    # engine rejected/shed/cancelled this request instead of finishing it
    ttft_deadline: Optional[float] = None
    total_deadline: Optional[float] = None
    decision: Optional[AdmissionDecision] = None
    shed_reason: Optional[str] = None
    _snapshot: Optional[dict] = None  # host copy of device rows while swapped
    _rng: Optional[np.random.Generator] = None

    def deadline_met(self) -> bool:
        """Did this request meet every deadline it carried?  (Vacuously
        true with no deadlines; requires the respective timestamp.)"""
        if self.ttft_deadline is not None and (
                self.first_token_t is None
                or self.first_token_t > self.ttft_deadline):
            return False
        if self.total_deadline is not None and (
                self.finish_t is None or self.finish_t > self.total_deadline):
            return False
        return True

    def stats(self) -> dict:
        ttft = (self.first_token_t - self.submit_t
                if self.first_token_t else None)
        total = (self.finish_t - self.submit_t) if self.finish_t else None
        return {
            "prompt_len": int(len(self.prompt)), "generated": len(self.out),
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "preemptions": self.preemptions,
            "ttft_s": ttft, "total_s": total,
            "shed_reason": self.shed_reason,
            "deadline_met": self.deadline_met(),
        }


class ServeEngine:
    """See module docstring; knob reference in docs/SERVING.md."""

    def __init__(self, cfg: ModelConfig, mesh, ctx: ParallelCtx, params, *,
                 slots: int = 4, max_len: int = 256,
                 prefill_chunk: int = 16, page_tokens: int = 64,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 high_watermark: float = 0.92, low_watermark: float = 0.80,
                 memory: Optional[GlobalMemory] = None,
                 context: Optional[DiompContext] = None,
                 slo: Optional[SLOPolicy] = None,
                 clock=None,
                 breaker: Optional[CircuitBreaker] = None):
        if cfg.family not in model_api.TRANSFORMER_FAMILIES \
                or not model_api.has_decode(cfg):
            raise ValueError(
                f"ServeEngine supports decode-capable transformer families "
                f"(positional KV caches); got family {cfg.family!r}")
        self.cfg, self.mesh, self.ctx = cfg, mesh, ctx
        self.params = params
        self.B, self.S = slots, max_len
        self.chunk = max(int(prefill_chunk), 1)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.seed = int(seed)
        self.high_watermark = float(high_watermark)
        self.low_watermark = float(low_watermark)
        # the engine runs on a DiompContext: the KV-page arena is its PGAS
        # memory, the world group its communicator domain.  A caller-provided
        # `memory` (legacy) still wins for the arena.
        if context is None:
            context = DiompContext(mesh=mesh, segment_bytes=1 << 26,
                                   allocator="buddy")
        self.dctx = context
        self.memory = memory or context.memory
        self._group = context.groups.get(
            "world", DiompGroup(tuple(mesh.axis_names), name="world"))
        self._comm = self.dctx.communicator(self._group)
        kv_bpt = 2 * 2 * max(cfg.kv_heads, 1) * max(cfg.head_dim, 1) \
            * cfg.num_layers
        self.alloc = PagedKVAllocator(
            self.memory, self._group,
            page_tokens=page_tokens, kv_bytes_per_token=max(kv_bpt, 64))
        self.decode_step = build_decode_step(cfg, mesh, ctx, B=slots,
                                             S=max_len, donate=False,
                                             slot_pos=True)
        # chunked prefill: one (B=1, C) step reused for every slot; chunk=1
        # falls back to the token-by-token teacher-forced path (the
        # equivalence baseline in tests)
        self.chunk_step = (
            build_chunk_prefill_step(cfg, mesh, ctx, C=self.chunk,
                                     S_cache=max_len)
            if self.chunk > 1 else None)
        # global-view cache (cache_structs shapes); in_specs shard it
        structs, _ = model_api.cache_structs(cfg, mesh, ctx, self.B, self.S)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)
        cache["pos"] = jnp.zeros((self.B,), jnp.int32)
        self.cache = cache
        self.queue: List[GenRequest] = []
        self.preempted: List[GenRequest] = []
        self.active: Dict[int, GenRequest] = {}
        self.free_slots = list(range(slots))
        self.pending = np.zeros((slots, 1), np.int32)
        # authoritative per-slot device positions (rows written); the device
        # copy is re-asserted from this after every decode step
        self.host_pos = np.zeros((slots,), np.int32)
        self.steps = 0
        self.device_calls = 0
        self._arrival = 0
        self._all: List[GenRequest] = []
        # rank-death recovery (docs/RESILIENCE.md): deaths scheduled on the
        # context's FaultPlan fire in step(); dead ranks leave the scheduling
        # set, their pages drain (graceful) or their requests requeue
        self.faults = context.fault_plan
        self.dead_ranks: set = set()
        self.rank_death_log: List[tuple] = []
        self.requeued = 0
        # SLO layer (docs/SERVING.md "Overload & SLOs"): injectable clock
        # (every timestamp in the engine reads it), optional admission
        # controller, spill-rank circuit breaker.  With slo=None behavior
        # is identical to the pre-SLO engine except that timestamps come
        # from `clock` and explicit per-submit deadlines are *recorded*
        # (never enforced) — that is the bench's admit-everything baseline.
        self.clock = clock if clock is not None else time.perf_counter
        self._now = self.clock()
        self.slo_log: List[tuple] = []   # (event, ...) decision record
        self.shed: Dict[str, int] = {}   # per-reason shed counters
        self.tokens_wasted = 0           # tokens generated for cancelled reqs
        self.tokens_late = 0             # tokens committed past total deadline
        self.slo_ctl = (AdmissionController(slo, self.clock,
                                            log=self.slo_log)
                        if slo is not None else None)
        # one exhausted migrate budget marks a spill rank sick: quarantine
        # immediately, probe again after the cooldown
        self.breaker = breaker if breaker is not None else CircuitBreaker(
            failure_threshold=1, cooldown_s=0.5, clock=self.clock)

    # -- API --------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32, *, priority: int = 0,
               ttft_deadline_s: Optional[float] = None,
               total_deadline_s: Optional[float] = None) -> GenRequest:
        """Submit a request.  Returns the :class:`GenRequest` either way;
        with an SLO policy attached its ``decision`` field carries the
        explicit admit / backpressure / reject verdict, and a rejected
        request is NOT queued (``done`` stays False, ``shed_reason`` set).

        ``ttft_deadline_s`` / ``total_deadline_s`` are RELATIVE deadlines
        (seconds from now); omitted ones fall back to the request's SLO
        tier.  Without an SLO policy, explicit deadlines are recorded for
        measurement but never enforced — the admit-everything baseline.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.S - 1:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new} exceeds the "
                f"cache ({self.S} rows, one reserved for slot parking)")
        if self.chunk_step is not None \
                and -(-len(prompt) // self.chunk) * self.chunk > self.S:
            # the final chunk is padded to full width and written in place:
            # its whole span must fit the cache or the device write would
            # clamp and corrupt live rows
            raise ValueError(
                f"prompt {len(prompt)} needs "
                f"{-(-len(prompt) // self.chunk) * self.chunk} cache rows "
                f"for chunked prefill (chunk {self.chunk}, cache {self.S}); "
                f"lower prefill_chunk or raise max_len")
        now = self.clock()
        if self.slo_ctl is not None:
            tier = self.slo_ctl.policy.tier(priority)
            if ttft_deadline_s is None:
                ttft_deadline_s = tier.ttft_deadline_s
            if total_deadline_s is None:
                total_deadline_s = tier.total_deadline_s
        r = GenRequest(prompt=prompt, max_new=max_new, priority=priority,
                       arrival=self._arrival, submit_t=now)
        if ttft_deadline_s is not None:
            r.ttft_deadline = now + float(ttft_deadline_s)
        if total_deadline_s is not None:
            r.total_deadline = now + float(total_deadline_s)
        r._rng = np.random.default_rng(self.seed * 1_000_003 + self._arrival)
        self._arrival += 1
        self._all.append(r)
        if self.slo_ctl is not None:
            dec = self.slo_ctl.decide(
                priority=priority, prompt_len=len(prompt), max_new=max_new,
                chunk=self.chunk, queue_depth=len(self.queue),
                ttft_deadline_s=ttft_deadline_s,
                total_deadline_s=total_deadline_s)
            r.decision = dec
            self.slo_log.append(("submit", r.arrival, dec.action, dec.reason,
                                 priority, int(len(prompt)), int(max_new)))
            if not dec.admitted:
                r.shed_reason = dec.reason
                self.shed[dec.reason] = self.shed.get(dec.reason, 0) + 1
                return r
        self.queue.append(r)
        return r

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not (self.active or self.queue or self.preempted):
                break
            self.step()
        return self

    def step(self) -> None:
        """One engine iteration: shed/cancel expired work, update the
        degraded-mode ladder, preempt-on-pressure, admit/resume, chunked
        prefill for filling slots, one decode step for decode-ready slots."""
        self.steps += 1
        self._now = self.clock()
        if self.faults is not None:
            for death in self.faults.deaths_at(self.steps):
                self.on_rank_death(death.rank, graceful=death.graceful)
        if self.slo_ctl is not None:
            self._shed_expired()
            self.slo_ctl.update_pressure(len(self.queue), self.steps)
        self._maybe_preempt()
        self._admit()
        if not self.active:
            return
        self._prefill_chunks()
        self._decode()

    # -- deadline shedding / cancellation (SLO layer) -----------------------
    def _shed(self, req: GenRequest, reason: str) -> None:
        req.shed_reason = reason
        self.shed[reason] = self.shed.get(reason, 0) + 1
        self.slo_log.append(("shed", self.steps, req.arrival, reason))

    def _cancel(self, req: GenRequest, reason: str) -> None:
        """Cancel an admitted (active or preempted) request: free its slot,
        release its KV pages back to the allocator (accounted in the
        ledger), unregister its RMA window, count its generated tokens as
        wasted work."""
        slot = req.slot
        if slot >= 0 and self.active.get(slot) is req:
            del self.active[slot]
            self.free_slots.append(slot)
            self.pending[slot, 0] = 0
            self.host_pos[slot] = 0
            self.cache["pos"] = jnp.asarray(self.host_pos.copy())
        elif req in self.preempted:
            self.preempted.remove(req)
        if req.kv is not None:
            try:
                self.dctx.rma.unregister(self._win(req))
            except RMAError:
                pass
            self.alloc.release(req.kv)
            req.kv = None
        req.slot = -1
        req._snapshot = None
        self.tokens_wasted += len(req.out)
        self._shed(req, reason)

    def _shed_expired(self) -> None:
        """Deadline enforcement, once per step BEFORE admission: expired
        queued requests are shed (no resources were ever bound); queued
        requests that can no longer make their deadline even if admitted
        this instant are shed as hopeless; admitted requests past their
        deadline are cancelled with pages freed."""
        now = self._now
        p = self.slo_ctl.policy
        for req in list(self.queue):
            reason = None
            if req.ttft_deadline is not None and now > req.ttft_deadline:
                reason = "queue_expired"
            elif req.total_deadline is not None and now + p.min_service_s(
                    len(req.prompt), req.max_new,
                    self.chunk) > req.total_deadline:
                reason = "hopeless"
            elif req.ttft_deadline is not None and now + p.min_ttft_s(
                    len(req.prompt), self.chunk) > req.ttft_deadline:
                reason = "hopeless"
            if reason is not None:
                self.queue.remove(req)
                self._shed(req, reason)
        for req in list(self.active.values()) + list(self.preempted):
            if req.total_deadline is not None and now > req.total_deadline:
                self._cancel(req, "expired")
            elif req.first_token_t is None \
                    and req.ttft_deadline is not None \
                    and now > req.ttft_deadline:
                self._cancel(req, "ttft_expired")

    # -- scheduling ---------------------------------------------------------
    @staticmethod
    def _order(reqs: List[GenRequest]) -> List[GenRequest]:
        return sorted(reqs, key=lambda r: (-r.priority, r.arrival))

    def _live_ranks(self) -> List[int]:
        return [r for r in range(self.memory.nranks)
                if r not in self.dead_ranks]

    def _home(self, slot: int) -> int:
        # every ACTIVE request's pages live on the controller heap (the
        # lowest LIVE rank; rank 0 until it dies), so freeing a victim's
        # pages always relieves the rank the OOM'd request allocates from;
        # preempted requests park on spill ranks
        del slot
        live = self._live_ranks()
        return live[0] if live else 0

    def _spill(self, req: GenRequest) -> int:
        # round-robin over the live non-home ranks so swapped-out requests
        # spread across the remote heaps; ranks whose migrate breaker is
        # open are routed around (returning home_rank makes the preemption
        # recompute-style: migrate is a no-op, pages drop, snapshot holds)
        live = [r for r in self._live_ranks() if r != req.kv.home_rank]
        if not live:
            return req.kv.home_rank
        if self.slo_ctl is not None and self.slo_ctl.level >= 3:
            return req.kv.home_rank     # L3 degraded: spill suspended
        start = req.kv.rid % len(live)
        for r in live[start:] + live[:start]:
            if self.breaker.allow(("migrate", r)):
                return r
        return req.kv.home_rank         # every spill target quarantined

    def _migrate(self, req: GenRequest, dst: int) -> int:
        """``alloc.migrate`` with circuit-breaker accounting: an exhausted
        retry budget (RMAError; the allocator already rolled the
        destination pages back) records a breaker failure for
        ``("migrate", dst)`` and reports 0 bytes moved; a successful move
        records a success with the retry-ledger delta it cost."""
        if req.kv is None or dst == req.kv.home_rank:
            return 0
        key = ("migrate", dst)
        before = self.alloc.stats["retried_page_puts"]
        try:
            moved = self.alloc.migrate(req.kv, dst, **self._migrate_kw(req))
        except RMAError:
            state = self.breaker.record_failure(key)
            self.slo_log.append(
                ("breaker", self.steps, dst, "failure", state))
            return 0
        if moved:
            self.breaker.record_success(
                key, retries=self.alloc.stats["retried_page_puts"] - before)
        return moved

    def _win(self, req: GenRequest) -> str:
        return f"kv/req{req.kv.rid}"

    def _migrate_kw(self, req: GenRequest) -> dict:
        kw = dict(comm=self._comm, tracker=self.dctx.rma,
                  window=self._win(req))
        if self.faults is not None:
            # chaos active: validate every page transfer get-side so an
            # injected corrupt/drop is detected and re-put, never absorbed
            kw.update(faults=self.faults, policy=self.dctx.retry_policy,
                      validate=True)
        return kw

    def _admit(self) -> None:
        # resumptions first: preempted requests hold committed progress
        for req in self._order(list(self.preempted)):
            if not self.free_slots:
                break
            slot = self.free_slots[-1]
            home = self._home(slot)
            if req.kv.page_table:
                if req.kv.home_rank != home \
                        and self._migrate(req, home) == 0:
                    continue        # spill heap -> home heap OOM: wait
            else:
                req.kv.home_rank = home
                if not self.alloc.reserve(req.kv, req.kv.pos + 1):
                    continue
            self.free_slots.pop()
            self.preempted.remove(req)
            self._restore(slot, req)
        for req in self._order(self.queue):
            if not self.free_slots:
                break
            slot = self.free_slots[-1]
            if self.slo_ctl is not None and self.slo_ctl.level >= 1 \
                    and self.slo_ctl.policy.degraded_max_new is not None:
                # L1 degraded: fresh admissions get a capped token budget
                # (shed load by finishing sooner, not by rejecting more)
                req.max_new = min(req.max_new,
                                  self.slo_ctl.policy.degraded_max_new)
            kv = self.alloc.admit(len(req.prompt),
                                  len(req.prompt) + req.max_new,
                                  home_rank=self._home(slot))
            if kv is None:
                break                      # KV OOM — wait for a release
            self.free_slots.pop()
            self.queue.remove(req)
            req.kv = kv
            req.slot = slot
            req.admit_t = self.clock()
            req.admit_step = self.steps
            self.dctx.rma.register(self._win(req))
            self.pending[slot, 0] = 0
            self.host_pos[slot] = 0
            self.active[slot] = req

    def _restore(self, slot: int, req: GenRequest) -> None:
        if req._snapshot is not None:
            for k, v in req._snapshot.items():
                self.cache[k] = self.cache[k].at[:, slot:slot + 1].set(v)
            req._snapshot = None
        req.slot = slot
        self.active[slot] = req
        self.host_pos[slot] = req.kv.pos
        self.pending[slot, 0] = 0

    # -- preemption (RMA swap to a spill rank) ------------------------------
    def _pick_victim(self, exclude: Optional[int] = None) -> Optional[int]:
        cands = [s for s in self.active if s != exclude]
        if not cands:
            return None
        return max(cands, key=lambda s: (-self.active[s].priority,
                                         self.active[s].arrival))

    def _preempt(self, slot: int) -> None:
        req = self.active.pop(slot)
        # the swap payload: this slot's device rows, snapshotted host-side
        # (on real hardware the same rows are what the one-sided page
        # transfers below move between heaps)
        req._snapshot = {
            k: jax.device_get(v[:, slot:slot + 1])
            for k, v in self.cache.items() if k != "pos"}
        moved = self._migrate(req, self._spill(req))
        if moved == 0 and req.kv.page_table:
            # spill heap full (or single-rank deployment): the swap moved
            # nothing, so drop the page plan instead — the snapshot above
            # holds the rows and resume re-reserves pages.  Either way a
            # preemption always relieves home-rank pressure.
            self.alloc.drop_pages(req.kv)
        req.preemptions += 1
        req.slot = -1
        self.free_slots.append(slot)
        self.pending[slot, 0] = 0
        self.host_pos[slot] = 0
        self.preempted.append(req)

    def _maybe_preempt(self) -> None:
        while len(self.active) > 1:
            homes = {req.kv.home_rank for req in self.active.values()}
            if self.alloc.pressure(homes) <= self.high_watermark:
                break
            self._preempt(self._pick_victim())
            homes = {req.kv.home_rank for req in self.active.values()}
            if self.alloc.pressure(homes) <= self.low_watermark:
                break

    # -- rank death (docs/RESILIENCE.md lifecycle) --------------------------
    def on_rank_death(self, rank: int, *, graceful: bool = False) -> None:
        """Remove ``rank`` from the serving set.

        ``graceful`` (the rank announced eviction): its requests' paged KV
        drains to surviving ranks over the one-sided ``migrate`` path
        first.  Abrupt: pages homed there are gone — preempted requests
        survive on their host row snapshots (resume re-reserves pages);
        active requests requeue from scratch.  Either way the scheduler's
        rank set shrinks and latency stats keep flowing.
        """
        if rank in self.dead_ranks or not (0 <= rank < self.memory.nranks):
            return
        live_after = [r for r in self._live_ranks() if r != rank]
        if not live_after:
            raise RuntimeError("cannot remove the last live rank")
        holders = [r for r in (list(self.active.values())
                               + list(self.preempted))
                   if r.kv is not None and r.kv.home_rank == rank
                   and r.kv.page_table]
        drained, lost = 0, []
        if graceful:
            for req in holders:
                dst = live_after[req.kv.rid % len(live_after)]
                moved = self._migrate(req, dst)
                if moved:
                    drained += moved
                else:
                    lost.append(req)    # surviving heaps full: treat as lost
        else:
            lost = holders
        self.dead_ranks.add(rank)
        # purge the free list, forget remaining page tables homed there
        self.alloc.forget_rank(rank)
        for req in lost:
            if req in self.preempted:
                # pages gone, but the host snapshot holds the rows:
                # recompute-style resume (reserve at re-admission)
                continue
            self._requeue(req)
        self.rank_death_log.append(
            (self.steps, rank, graceful, drained, len(lost)))

    def _requeue(self, req: GenRequest) -> None:
        """An active request lost its KV pages: reset all generation
        progress and put it back on the arrival queue (priority kept)."""
        slot = req.slot
        if slot >= 0 and self.active.get(slot) is req:
            del self.active[slot]
            self.free_slots.append(slot)
            self.pending[slot, 0] = 0
            self.host_pos[slot] = 0
        try:
            self.dctx.rma.unregister(self._win(req))
        except RMAError:
            pass
        if req.kv is not None:
            self.alloc.forget_pages(req.kv)
            self.alloc.forget(req.kv)
            req.kv = None
        req.slot = -1
        req.fed = 0
        req.out = []
        req.done = False
        req._snapshot = None
        # deterministic replay: the fresh attempt samples the same stream
        req._rng = np.random.default_rng(
            self.seed * 1_000_003 + req.arrival)
        self.requeued += 1
        self.queue.append(req)

    # -- chunked prefill ----------------------------------------------------
    def _slot_cache(self, slot: int) -> dict:
        sl = {k: v[:, slot:slot + 1]
              for k, v in self.cache.items() if k != "pos"}
        sl["pos"] = jnp.asarray(int(self.host_pos[slot]), jnp.int32)
        return sl

    def _write_slot(self, slot: int, sl: dict) -> None:
        for k, v in sl.items():
            if k != "pos":
                self.cache[k] = self.cache[k].at[:, slot:slot + 1].set(v)

    def _prefill_chunks(self) -> None:
        if self.chunk_step is None:
            return                      # legacy: prompts feed through decode
        cap = self.chunk
        if self.slo_ctl is not None and self.slo_ctl.level >= 2 \
                and self.slo_ctl.policy.degraded_chunk is not None:
            # L2 degraded: feed fewer prompt tokens per device call so
            # decode-ready slots keep their share of the engine loop (the
            # device call shape stays (1, chunk); only `take` shrinks)
            cap = max(1, min(cap, self.slo_ctl.policy.degraded_chunk))
        for slot in sorted(self.active):
            req = self.active[slot]
            plen = len(req.prompt)
            if req.fed >= plen:
                continue
            take = min(cap, plen - req.fed)
            toks = np.zeros((1, self.chunk), np.int32)
            toks[0, :take] = req.prompt[req.fed:req.fed + take]
            with use_default(self.dctx):
                logits, sl = self.chunk_step(
                    self.params, jnp.asarray(toks), self._slot_cache(slot),
                    jnp.asarray(take, jnp.int32))
            self._write_slot(slot, sl)
            req.fed += take
            req.kv.pos += take          # rows actually written, nothing else
            self.host_pos[slot] = req.fed
            req.prefill_steps += 1
            self.device_calls += 1
            if req.fed >= plen:
                # the final chunk's last-position logits commit the first
                # generated token (prefill produces token 1 of max_new)
                row = np.asarray(jax.device_get(logits))[0, 0]
                self._commit(slot, req, row)

    # -- decode -------------------------------------------------------------
    def _decode(self) -> None:
        if self.chunk_step is None:
            ready = sorted(self.active)
        else:
            ready = sorted(s for s, r in self.active.items()
                           if r.fed >= len(r.prompt))
        # capacity BEFORE the device write: one page alloc at most per slot;
        # on OOM, preempt the lowest-priority victim and retry
        for slot in list(ready):
            if slot not in self.active:
                continue
            req = self.active[slot]
            while not self.alloc.extend(req.kv):
                # victim = lowest priority / latest arrival among ALL
                # active slots — if that is the requester itself, it yields
                # (never evict a higher-priority request to keep a lower-
                # priority one decoding)
                victim = self._pick_victim()
                self._preempt(victim if victim is not None else slot)
                if victim is None or victim == slot:
                    break
        ready = [s for s in ready if s in self.active]
        if not ready:
            return
        for slot in ready:
            req = self.active[slot]
            if self.chunk_step is None and req.fed < len(req.prompt):
                self.pending[slot, 0] = req.prompt[req.fed]
            else:
                self.pending[slot, 0] = req.out[-1] if req.out else 0
        # park every other slot on the reserved scratch row S-1: its write
        # cannot touch live rows and the true positions are re-asserted below
        dev_pos = np.full((self.B,), self.S - 1, np.int32)
        for slot in ready:
            dev_pos[slot] = self.host_pos[slot]
        self.cache["pos"] = jnp.asarray(dev_pos)
        with use_default(self.dctx):
            logits, self.cache = self.decode_step(
                self.params, jnp.asarray(self.pending), self.cache)
        self.device_calls += 1
        rows = np.asarray(jax.device_get(logits))
        for slot in ready:
            req = self.active.get(slot)
            if req is None:
                continue
            req.kv.pos += 1
            self.host_pos[slot] += 1
            req.decode_steps += 1
            if self.chunk_step is None and req.fed < len(req.prompt):
                req.fed += 1
                if req.fed < len(req.prompt):
                    continue               # still prefilling: ignore logits
            self._commit(slot, req, rows[slot, 0])
        # authoritative positions back onto the device (parked slots kept)
        self.cache["pos"] = jnp.asarray(self.host_pos.copy())

    # -- commit / sampling / release ----------------------------------------
    def _sample(self, req: GenRequest, row: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(row.argmax())
        z = row.astype(np.float64) / max(self.temperature, 1e-6)
        if self.top_k > 0 and self.top_k < len(z):
            keep = np.argpartition(z, -self.top_k)[-self.top_k:]
        else:
            keep = np.arange(len(z))
        zk = z[keep] - z[keep].max()
        p = np.exp(zk)
        p /= p.sum()
        return int(req._rng.choice(keep, p=p))

    def _commit(self, slot: int, req: GenRequest, row: np.ndarray) -> None:
        req.out.append(self._sample(req, row))
        now = self.clock()
        if req.first_token_t is None:
            req.first_token_t = now
        if req.total_deadline is not None and now > req.total_deadline:
            # a token served past the deadline is wasted work the SLO
            # engine sheds pre-emptively; the baseline accumulates these
            self.tokens_late += 1
        if len(req.out) >= req.max_new:
            self._finish(slot, req)

    def _finish(self, slot: int, req: GenRequest) -> None:
        req.done = True
        req.finish_t = self.clock()
        req.finish_step = self.steps
        self.dctx.rma.unregister(self._win(req))
        self.alloc.release(req.kv)
        del self.active[slot]
        self.free_slots.append(slot)
        # no stale state may leak into the next tenant of this slot: clear
        # the pending token and the device position (the seed engine left
        # both behind, so freed slots kept teacher-forcing garbage)
        self.pending[slot, 0] = 0
        self.host_pos[slot] = 0
        self.cache["pos"] = jnp.asarray(self.host_pos.copy())

    # -- introspection -------------------------------------------------------
    @property
    def kv_stats(self):
        s = dict(self.alloc.stats)
        live = self.alloc.live_pages()
        # the allocator ledger must balance: every page handed out is either
        # live in a page table or back on the free list
        assert s["pages_allocated"] - s["pages_freed"] == live, \
            (s["pages_allocated"], s["pages_freed"], live)
        s["live_pages"] = live
        s["free_list_pages"] = self.alloc.free_list_pages()
        s["ptr_cache_hit_rate"] = self.memory.ptr_cache.hit_rate
        return s

    def latency_stats(self) -> dict:
        done = [r for r in self._all if r.done]
        ttft = [r.first_token_t - r.submit_t for r in done
                if r.first_token_t is not None]
        total = [r.finish_t - r.submit_t for r in done
                 if r.finish_t is not None]
        toks = sum(len(r.out) for r in done)
        # goodput = deadline-met completions (the SLO layer's objective);
        # a finished request that missed a deadline it carried is a
        # violation (structurally zero under an SLO policy — violators are
        # cancelled before they can finish)
        good = [r for r in done if r.deadline_met()]

        def _agg(xs):
            if not xs:
                return None
            return {"mean": sum(xs) / len(xs),
                    **percentiles(xs, (50, 95, 99)),
                    "max": max(xs)}

        return {
            "requests_done": len(done),
            "tokens": toks,
            "engine_steps": self.steps,
            "device_calls": self.device_calls,
            "preemptions": sum(r.preemptions for r in self._all),
            "rank_deaths": len(self.rank_death_log),
            "requeued": self.requeued,
            "live_ranks": len(self._live_ranks()),
            "ttft_s": _agg(ttft),
            "request_s": _agg(total),
            "tokens_per_device_call": (toks / self.device_calls
                                       if self.device_calls else 0.0),
            # SLO surface (docs/SERVING.md "Overload & SLOs")
            "goodput": len(good),
            "goodput_tokens": sum(len(r.out) for r in good),
            "deadline_violations": len(done) - len(good),
            "shed": dict(self.shed),
            "shed_total": sum(self.shed.values()),
            "tokens_wasted": self.tokens_wasted,
            "tokens_late": self.tokens_late,
            "degrade_level": (self.slo_ctl.level
                              if self.slo_ctl is not None else 0),
            "breaker_open": len(self.breaker.open_keys()),
        }

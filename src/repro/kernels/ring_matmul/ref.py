"""Oracles for the blocked matmul kernel and the ring collective matmul."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import ompccl
from repro.core.groups import DiompGroup

__all__ = ["matmul_ref", "ring_allgather_matmul_ref"]


def matmul_ref(x, w):
    """f32-accumulated matmul — oracle for the Pallas blocked kernel."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def ring_allgather_matmul_ref(x_local, w_local, group: DiompGroup):
    """Unoverlapped baseline: all-gather X, then one local matmul.

    Must run inside shard_map.  x_local: (T/n, K) shard; w_local: (K, N/n)
    column shard.  Returns (T, N/n).
    """
    x_full = ompccl.allgather(x_local, group, axis=0)
    return matmul_ref(x_full, w_local)

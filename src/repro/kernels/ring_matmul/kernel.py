"""Pallas TPU blocked matmul — the local compute of the ring collective matmul.

Classic MXU tiling: grid = (M/bm, N/bn, K/bk) with K innermost (sequential on
TPU), f32 accumulator in VMEM scratch.  Tile defaults are MXU-aligned
(multiples of 128 on the minor dims); VMEM working set for (256, 512, 256)
tiles in bf16 is 256·512·2 + 512·256·2 + 256·256·4 ≈ 0.8 MiB — comfortably
double-bufferable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["matmul_pallas"]


def _mm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def matmul_pallas(x, w, *, bm: int = 256, bk: int = 512, bn: int = 256,
                  interpret: bool = False):
    """x: (M, K) @ w: (K, N) -> (M, N), f32 accumulation."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    Mp, Kp, Np = x.shape[0], x.shape[1], w.shape[1]
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    if pm or pn:
        out = out[:M, :N]
    return out

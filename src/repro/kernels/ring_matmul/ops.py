"""Ring collective matmul — Cannon's algorithm adapted to the TP ring.

The paper's matrix-multiplication application (§4.4) pipelines Cannon's ring
exchange so each rank's ``ompx_put`` of the next block stripe overlaps the
current block's GEMM.  On a TPU TP group the same schedule computes the
all-gather matmul ``Y = X_full @ W_col`` without ever materializing X_full:

    for s in 0..n-1:   Y[rows of chunk I hold] = chunk @ W_local
                       chunk <- ompx_put(chunk, +1)      (overlaps next GEMM)

XLA schedules the (async) collective-permute of step s+1 concurrently with
the dot of step s — the paper's "additional block stripe ... to enable
overlap of computation and communication", with the ring unrolled because
the group size is static.

``matmul`` is the jit'd local blocked-GEMM entry point (Pallas on TPU,
XLA dot elsewhere); ``ring_allgather_matmul`` is the shard_map collective.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from repro.core.compat import axis_size
from repro.core.groups import DiompGroup
from repro.core.rma import ompx_put
from .kernel import matmul_pallas
from .ref import matmul_ref, ring_allgather_matmul_ref

__all__ = ["matmul", "ring_allgather_matmul"]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bk", "bn", "interpret"))
def matmul(x, w, *, impl: str = "ref", bm: int = 256, bk: int = 512,
           bn: int = 256, interpret: bool = True):
    if impl == "ref":
        return matmul_ref(x, w)
    if impl == "pallas":
        return matmul_pallas(x, w, bm=bm, bk=bk, bn=bn, interpret=interpret)
    raise ValueError(impl)


def ring_allgather_matmul(
    x_local,
    w_local,
    group: DiompGroup,
    *,
    overlap: bool = True,
    dot: Optional[Callable] = None,
):
    """Inside shard_map: x_local (T/n, K), w_local (K, N/n) -> (T, N/n).

    ``overlap=False`` falls back to all-gather + one big GEMM (the MPI+X
    baseline shape); ``overlap=True`` runs the Cannon-style ring.
    """
    if dot is None:
        dot = matmul_ref
    if not overlap:
        return ring_allgather_matmul_ref(x_local, w_local, group)

    ax = group.axes[0]
    n = axis_size(ax)
    idx = lax.axis_index(ax)
    t_loc = x_local.shape[0]
    from repro.core.vma import zeros_varying

    out = zeros_varying((n * t_loc, w_local.shape[1]), x_local.dtype, x_local)

    chunk = x_local
    for s in range(n):  # unrolled: n is static (the mesh is known)
        src = (idx - s) % n          # whose stripe I hold at step s
        y = dot(chunk, w_local)
        out = lax.dynamic_update_slice(out, y.astype(out.dtype), (src * t_loc, 0))
        if s != n - 1:
            chunk = ompx_put(chunk, group, shift=1)
    return out

"""Ring collective matmul — Cannon's algorithm adapted to the TP ring.

The paper's matrix-multiplication application (§4.4) pipelines Cannon's ring
exchange so each rank's ``ompx_put`` of the next block stripe overlaps the
current block's GEMM.  On a TPU TP group the same schedule computes the
all-gather matmul ``Y = X_full @ W_col`` without ever materializing X_full.

Three implementations, selected by ``overlap`` / ``impl``:

* ``overlap=False``          — all-gather X + one big GEMM (the MPI+X
                               baseline shape);
* ``impl="host"``            — the host-level unrolled ring: one ``dot`` +
                               ``collective-permute`` pair per step, overlap
                               left to the XLA scheduler (kept as the
                               benchmark's middle mode);
* ``impl="fused"`` (default) — ONE fused kernel for the whole ring
                               (:mod:`.fused`): bidirectional double-buffered
                               stripe exchange planned by
                               :class:`~repro.kernels.plan.OverlapPlanner`,
                               ``ceil((n-1)/2)`` exchange steps, compiled
                               with in-kernel remote DMA on TPU and emulated
                               step-for-step over ``ompx_put`` elsewhere.

``matmul`` is the jit'd local blocked-GEMM entry point (Pallas on TPU, XLA
dot elsewhere); its tiles come from the planner when not given, and interpret
mode resolves from the backend at call time so the fast path is never
silently interpreted on real hardware.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from repro.core.compat import axis_size
from repro.core.groups import DiompGroup
from repro.core.rma import ompx_put
from repro.kernels.plan import (RingPlan, default_planner, resolve_interpret,
                                resolve_ring_impl)
from .fused import fused_ring_allgather_matmul
from .kernel import matmul_pallas
from .ref import matmul_ref, ring_allgather_matmul_ref

__all__ = ["matmul", "ring_allgather_matmul"]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bk", "bn", "interpret"))
def matmul(x, w, *, impl: str = "ref", bm: Optional[int] = None,
           bk: Optional[int] = None, bn: Optional[int] = None,
           interpret: Optional[bool] = None):
    if impl == "ref":
        return matmul_ref(x, w)
    if impl == "pallas":
        if bm is None or bk is None or bn is None:
            pm, pk, pn = default_planner().plan_matmul_tiles(
                x.shape[0], x.shape[1], w.shape[1], x.dtype)
            bm = pm if bm is None else bm
            bk = pk if bk is None else bk
            bn = pn if bn is None else bn
        return matmul_pallas(x, w, bm=bm, bk=bk, bn=bn,
                             interpret=resolve_interpret(interpret))
    raise ValueError(impl)


def _host_ring(x_local, w_local, group: DiompGroup, dot: Callable):
    """The host-level unrolled ring (one put + dot per step, n-1 steps)."""
    ax = group.axes[0]
    n = axis_size(ax)
    idx = lax.axis_index(ax)
    t_loc = x_local.shape[0]
    from repro.core.vma import zeros_varying

    out = zeros_varying((n * t_loc, w_local.shape[1]), x_local.dtype, x_local)

    chunk = x_local
    for s in range(n):  # unrolled: n is static (the mesh is known)
        src = (idx - s) % n          # whose stripe I hold at step s
        y = dot(chunk, w_local)
        out = lax.dynamic_update_slice(out, y.astype(out.dtype), (src * t_loc, 0))
        if s != n - 1:
            chunk = ompx_put(chunk, group, shift=1)
    return out


def ring_allgather_matmul(
    x_local,
    w_local,
    group: DiompGroup,
    *,
    overlap: bool = True,
    impl: Optional[str] = None,
    dot: Optional[Callable] = None,
    plan: Optional[RingPlan] = None,
    interpret: Optional[bool] = None,
):
    """Inside shard_map: x_local (T/n, K), w_local (K, N/n) -> (T, N/n).

    ``overlap=False`` falls back to all-gather + one big GEMM; otherwise
    ``impl`` picks ``"fused"`` (default — the in-kernel bidirectional ring)
    or ``"host"`` (the XLA-scheduled unrolled loop).
    """
    if not overlap:
        return ring_allgather_matmul_ref(x_local, w_local, group)
    if resolve_ring_impl(impl) == "fused":
        # dot is forwarded un-defaulted: a caller-supplied dot forces the
        # emulation (the compiled kernel cannot honor custom GEMM semantics)
        return fused_ring_allgather_matmul(
            x_local, w_local, group, plan=plan, dot=dot, interpret=interpret)
    return _host_ring(x_local, w_local, group, dot or matmul_ref)

"""Fused in-kernel ring collective matmul (paper §4.4, done below the runtime).

The host-level ring in :mod:`.ops` leaves the overlap to the XLA scheduler:
every step is a separate ``dot`` + ``collective-permute`` HLO and the compiler
*may* run them concurrently.  This module is the schedule made explicit — the
same move the PGAS distributed-OpenMP line of work makes to hide latency below
the runtime layer: ONE ``pallas_call`` executes the whole ring, each step's
remote copy of the next X stripe is an ``pltpu.make_async_remote_copy`` into a
planned VMEM slot, and the copy is started *before* the step's GEMM so the DMA
engines and the MXU run concurrently by construction.

Two executions of ONE schedule (:meth:`repro.kernels.plan.RingPlan.schedule`):

* ``fused_ring_allgather_matmul_tpu`` — the real kernel: double/multi-buffered
  stripe slots per ring direction (slot count from ``OverlapPlanner`` /
  ``StreamPool.plan_slots``, floored at the reuse-safe minimum — see
  ``_ring_slots``), bidirectional RDMA (clockwise stream serves sources behind
  me, counter-clockwise the sources ahead) so the ring finishes in
  ``ceil((n - 1) / 2)`` exchange steps with both ICI directions busy.
* ``fused_ring_allgather_matmul_interpret`` — the CPU-CI emulation: iterates
  the IDENTICAL step records, with each RDMA realized as the one-sided
  ``ompx_put`` (a ``collective-permute`` remote DMA) started before the step's
  GEMM.  Differentiable, runs under ``shard_map`` on any backend, and is what
  the train/serve layers use.

Layout contract matches :func:`.ops.ring_allgather_matmul`: inside shard_map,
``x_local (T/n, K)``, ``w_local (K, N/n)`` -> ``(T, N/n)``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.groups import DiompGroup
from repro.core.rma import ompx_put
from repro.core.vma import zeros_varying
from repro.kernels.plan import RingPlan, default_planner, resolve_interpret
from .ref import matmul_ref

__all__ = [
    "fused_ring_allgather_matmul",
    "fused_ring_allgather_matmul_interpret",
    "fused_ring_allgather_matmul_tpu",
]


# ---------------------------------------------------------------------------
# the TPU kernel: one pallas_call for the whole ring
# ---------------------------------------------------------------------------


def _ring_slots(plan: RingPlan) -> int:
    """The slot count the TPU kernel actually allocates.

    Slot reuse is made safe by *count*, not by per-step barriers (a shared
    counting barrier semaphore cannot attribute signals to senders, so a
    fast neighbor's step-``s+1`` signal could stand in for the slow
    neighbor's step-``s`` one).  The per-step ``rdma.wait()`` bounds
    neighbor skew on the bidirectional ring to one step — a device cannot
    enter step ``s+1`` before both neighbors' step-``s`` stripes landed —
    so a neighbor reads slot ``(s-1..s) % slots`` while my step-``s`` send
    writes slot ``(s+1) % slots``: three buffers suffice.  Unidirectional
    rings only chain the skew one way around the ring, so they take one
    slot per step (no reuse) — they exist for benchmarking, the fused
    default is bidirectional.
    """
    steps = plan.exchange_steps
    need = min(steps + 1, 3) if plan.direction == "bidi" else steps + 1
    return max(plan.slots, need)


def _fused_ring_kernel(x_ref, w_ref, o_ref, bufs, send_sems, recv_sems,
                       *, axis: str, plan: RingPlan, t_loc: int):
    """Kernel body; the schedule is baked statically, ranks are traced.

    ``bufs``: VMEM (2, slots, t_loc, K) — stripe slots per direction
    (0 = clockwise stream, 1 = counter-clockwise).  Slot ``s % slots``
    holds step ``s``'s stripes; the RDMA for step ``s + 1`` lands in the
    next slot while this step's GEMMs run.
    """
    n, slots = plan.n, _ring_slots(plan)
    my = lax.axis_index(axis)
    right = lax.rem(my + 1, n)
    left = lax.rem(my + n - 1, n)

    # startup barrier: both neighbors entered the kernel before any RDMA
    # touches their buffers (over-signaling from a fast neighbor is benign
    # here — slot 0 is seeded locally, never remotely written)
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=(left,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_signal(barrier, inc=1, device_id=(right,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, 2)

    # seed both streams' slot 0 with the local stripe
    bufs[0, 0] = x_ref[...]
    bufs[1, 0] = x_ref[...]

    def gemm(stream: int, slot: int, src):
        y = lax.dot_general(
            bufs[stream, slot], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        o_ref[pl.ds(src * t_loc, t_loc), :] = y.astype(o_ref.dtype)

    for st in plan.schedule():
        slot = st.index % slots
        nxt = (st.index + 1) % slots
        rdmas = []
        if st.send_cw:        # my cw stripe -> right neighbor's next cw slot
            rdma = pltpu.make_async_remote_copy(
                src_ref=bufs.at[0, slot], dst_ref=bufs.at[0, nxt],
                send_sem=send_sems.at[0, slot], recv_sem=recv_sems.at[0, nxt],
                device_id=(right,),
                device_id_type=pltpu.DeviceIdType.LOGICAL)
            rdma.start()
            rdmas.append(rdma)
        if st.send_ccw:       # my ccw stripe -> left neighbor's next ccw slot
            rdma = pltpu.make_async_remote_copy(
                src_ref=bufs.at[1, slot], dst_ref=bufs.at[1, nxt],
                send_sem=send_sems.at[1, slot], recv_sem=recv_sems.at[1, nxt],
                device_id=(left,),
                device_id_type=pltpu.DeviceIdType.LOGICAL)
            rdma.start()
            rdmas.append(rdma)

        # GEMMs on the CURRENT slot overlap the in-flight stripe transfers
        if st.compute_cw:
            gemm(0, slot, lax.rem(my - st.index + n, n))
        if st.compute_ccw:
            gemm(1, slot, lax.rem(my + st.index, n))

        for rdma in rdmas:    # next step's stripes must have landed
            rdma.wait()


def fused_ring_allgather_matmul_tpu(x_local, w_local, *, axis: str,
                                    plan: RingPlan):
    """The compiled fused kernel (requires a real TPU backend).

    Restriction recorded here rather than hidden: the ring must be a single
    mesh axis (``device_id`` is the logical index along it).
    """
    t_loc, k = x_local.shape
    n_loc = w_local.shape[1]
    slots = _ring_slots(plan)
    return pl.pallas_call(
        functools.partial(_fused_ring_kernel, axis=axis, plan=plan,
                          t_loc=t_loc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        out_shape=jax.ShapeDtypeStruct((plan.n * t_loc, n_loc), x_local.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, slots, t_loc, k), x_local.dtype),
            pltpu.SemaphoreType.DMA((2, slots)),
            pltpu.SemaphoreType.DMA((2, slots)),
        ],
        compiler_params=pltpu.TPUCompilerParams(collective_id=0),
    )(x_local, w_local)


# ---------------------------------------------------------------------------
# the interpret / CPU emulation: identical schedule over ompx_put
# ---------------------------------------------------------------------------


def fused_ring_allgather_matmul_interpret(
    x_local, w_local, group: DiompGroup, *, plan: RingPlan,
    dot: Optional[Callable] = None,
):
    """Execute :meth:`RingPlan.schedule` with ``ompx_put`` as the remote copy.

    Every step starts its forwards BEFORE its GEMMs — the same
    DMA-then-compute order as the kernel, which is exactly what lets XLA's
    async collective-permute overlap the dots.  Differentiable (ppermute,
    dynamic_update_slice and dot all transpose), so this is also the path
    the TP layers train through on CPU.
    """
    if dot is None:
        dot = matmul_ref
    ax = group.axes[0]
    n = plan.n
    idx = lax.axis_index(ax)
    t_loc = x_local.shape[0]
    out = zeros_varying((n * t_loc, w_local.shape[1]), x_local.dtype, x_local)

    cw = ccw = x_local
    for st in plan.schedule():
        # forwards first: step s+1's stripes are in flight during step s's GEMMs
        cw_next = ompx_put(cw, group, shift=1) if st.send_cw else cw
        ccw_next = ompx_put(ccw, group, shift=-1) if st.send_ccw else ccw
        if st.compute_cw:
            src = (idx - st.index) % n
            y = dot(cw, w_local).astype(out.dtype)
            out = lax.dynamic_update_slice(out, y, (src * t_loc, 0))
        if st.compute_ccw:
            src = (idx + st.index) % n
            y = dot(ccw, w_local).astype(out.dtype)
            out = lax.dynamic_update_slice(out, y, (src * t_loc, 0))
        cw, ccw = cw_next, ccw_next
    return out


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def fused_ring_allgather_matmul(
    x_local, w_local, group: DiompGroup, *,
    plan: Optional[RingPlan] = None,
    direction: str = "bidi",
    dot: Optional[Callable] = None,
    interpret: Optional[bool] = None,
):
    """The fused collective matmul entry point (inside shard_map).

    ``plan`` defaults to the process planner's
    :meth:`~repro.kernels.plan.OverlapPlanner.plan_ring_matmul` for the
    traced shapes; ``interpret=None`` resolves from the backend at call
    time (compiled on TPU, emulated elsewhere).  A caller-supplied ``dot``
    carries custom GEMM semantics the in-kernel ``lax.dot_general`` cannot
    honor, so it always routes through the emulation — which XLA still
    compiles (and overlaps) on TPU.
    """
    from repro.core.compat import axis_size

    n = axis_size(group.axes[0])
    if plan is None:
        plan = default_planner().plan_ring_matmul(
            x_local.shape[0], x_local.shape[1], w_local.shape[1],
            x_local.dtype, n, direction=direction)
    if plan.n != n:
        raise ValueError(f"plan for n={plan.n} used on a ring of {n}")
    if resolve_interpret(interpret) or dot is not None:
        return fused_ring_allgather_matmul_interpret(
            x_local, w_local, group, plan=plan, dot=dot)
    return fused_ring_allgather_matmul_tpu(
        x_local, w_local, axis=group.axes[0], plan=plan)

from .fused import fused_ring_allgather_matmul  # noqa: F401
from .ops import matmul, ring_allgather_matmul  # noqa: F401

from .ops import matmul, ring_allgather_matmul  # noqa: F401

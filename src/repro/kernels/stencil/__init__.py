from .fused import Halos, exchange_halos, fused_wave_step  # noqa: F401
from .ops import wave_step  # noqa: F401

from .ops import wave_step  # noqa: F401

"""Pure-jnp oracle for the Minimod acoustic-isotropic 25-point stencil.

8th-order central differences in space (radius 4 per axis -> 25-point star),
2nd order in time:

    u_next = 2 u - u_prev + (c dt)^2 * laplacian(u)

Boundaries are zero-padded (homogeneous Dirichlet), matching Minimod's
damping-free interior kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["COEFFS", "laplacian_ref", "wave_step_ref"]

# 8th-order second-derivative coefficients (center + 4 neighbors per side)
COEFFS = (-205.0 / 72.0, 8.0 / 5.0, -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0)
RADIUS = 4


def laplacian_ref(u, *, dx: float = 1.0):
    """25-point star laplacian with zero boundary halo."""
    up = jnp.pad(u, RADIUS)
    z, y, x = u.shape
    c0, *cs = COEFFS
    lap = 3.0 * c0 * u
    for r, c in zip(range(1, RADIUS + 1), cs):
        for axis in range(3):
            lo = [slice(RADIUS, RADIUS + z), slice(RADIUS, RADIUS + y),
                  slice(RADIUS, RADIUS + x)]
            hi = list(lo)
            lo[axis] = slice(RADIUS - r, RADIUS - r + u.shape[axis])
            hi[axis] = slice(RADIUS + r, RADIUS + r + u.shape[axis])
            lap = lap + c * (up[tuple(lo)] + up[tuple(hi)])
    return lap / (dx * dx)


def wave_step_ref(u, u_prev, c2dt2, *, dx: float = 1.0):
    """One leapfrog step; c2dt2 = (c·dt)² (scalar or (Z,Y,X) velocity model)."""
    return (2.0 * u - u_prev + c2dt2 * laplacian_ref(u, dx=dx)).astype(u.dtype)

"""Fused halo-overlapped Minimod wave step (paper §4.5, Listings 1–2).

The host-loop Minimod (``benchmarks/bench_minimod.py`` seed shape) exchanged
halos OUTSIDE the kernel: every step was exchange → fence → full-grid
stencil, with compute and communication strictly serialized.  This module is
the same move PR 2 made for the ring matmul, applied to the paper's flagship
application: the halo exchange becomes in-kernel one-sided puts, and the
step is split so the interior — which needs no halo at all — computes under
the in-flight exchange.

One schedule (:meth:`repro.kernels.plan.HaloPlan.schedule`), two executions:

* ``fused_wave_step_tpu`` — ONE ``pallas_call`` runs the whole step: the
  boundary slabs are deposited into the neighbors' VMEM landing windows via
  ``pltpu.make_async_remote_copy`` (the ``ompx_put`` of the paper, below the
  runtime), the interior 25-point stencil runs while the DMAs are in flight,
  and a per-step neighbor barrier bounds skew to one step.
* ``fused_wave_step_interpret`` — the CPU-CI emulation: the IDENTICAL phase
  order with each remote copy realized as an ``ompx_put`` (a
  ``collective-permute`` remote DMA) started before the interior compute.
  Differentiable, runs under ``shard_map`` on any backend, and additionally
  supports what the compiled kernel does not: 2-D (Z×Y) decomposition,
  **asymmetric** per-rank Z extents (heterogeneous ranks own proportional
  subdomains — the paper's asymmetric-allocation scenario), and carried
  halos for the multi-step time loop.

Carried-halo time loop (``return_halos=True``): the halos of the *current*
field landed during the previous step, so each step computes the R-thick
boundary output slabs FIRST, puts them one-sided to the neighbors (they are
exactly the neighbors' next-step halos), computes the interior under the
in-flight exchange, and fences.  Every put is recorded against the active
context's :class:`~repro.core.rma.RMATracker` halo windows, so the wire
traffic is auditable against the OMPCCL call log byte for byte.

Asymmetric extents: SPMD tracing requires one static local shape, so every
rank's shard is padded to the maximum extent and ``z_extents`` (a static
per-rank tuple) marks the valid rows; slab extraction/placement happens at
the traced valid edge and invalid rows are kept at zero.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.backends import payload_bytes
from repro.core.groups import DiompGroup
from repro.core.rma import RMAError, halo_window_names, ompx_fence, ompx_put
from repro.core.vma import zeros_varying
from repro.kernels.plan import HaloPlan, default_planner, resolve_interpret
from .ref import COEFFS, RADIUS

__all__ = [
    "Halos",
    "exchange_halos",
    "fused_wave_step",
    "fused_wave_step_interpret",
    "fused_wave_step_tpu",
]


class Halos(NamedTuple):
    """The four halo slabs of one shard (``None`` where the axis is whole).

    ``z_lo``/``z_hi`` are (R, Y, X) slabs from the Z neighbors, ``y_lo``/
    ``y_hi`` (Z, R, X) strips from the Y neighbors.  A pytree, so a Halos
    rides directly in a ``lax.scan`` carry for the multi-step time loop.
    """

    z_lo: Optional[jax.Array] = None
    z_hi: Optional[jax.Array] = None
    y_lo: Optional[jax.Array] = None
    y_hi: Optional[jax.Array] = None


def _tracker():
    from repro.core.context import default_context

    return default_context().rma


def _put_slab(slab, group: DiompGroup, *, shift: int, window: str):
    """One-sided slab put, recorded against the tracker's halo window."""
    tr = _tracker()
    tr.ensure(window)
    tr.on_put(window, payload_bytes(slab))
    return ompx_put(slab, group, shift=shift)


# ---------------------------------------------------------------------------
# the 25-point star on halo-extended slabs (shared by every phase)
# ---------------------------------------------------------------------------


def _leap(uext, prev, c2, *, dx: float, dtype):
    """One leapfrog update of the core of an already halo-extended slab.

    ``uext`` carries R rows/cols of halo (real neighbor data or Dirichlet
    zeros) on every axis; ``prev``/``c2`` are core-shaped.  The arithmetic
    mirrors :func:`repro.kernels.stencil.ref.wave_step_ref` term for term
    so the fused step stays within rounding of the oracle.
    """
    R = RADIUS
    bz = uext.shape[0] - 2 * R
    by = uext.shape[1] - 2 * R
    bx = uext.shape[2] - 2 * R
    zc, yc, xc = slice(R, R + bz), slice(R, R + by), slice(R, R + bx)
    center = uext[zc, yc, xc]
    c0, *cs = COEFFS
    lap = 3.0 * c0 * center
    for r, c in zip(range(1, R + 1), cs):
        lap = lap + c * (uext[slice(R - r, R - r + bz), yc, xc]
                         + uext[slice(R + r, R + r + bz), yc, xc])
        lap = lap + c * (uext[zc, slice(R - r, R - r + by), xc]
                         + uext[zc, slice(R + r, R + r + by), xc])
        lap = lap + c * (uext[zc, yc, slice(R - r, R - r + bx)]
                         + uext[zc, yc, slice(R + r, R + r + bx)])
    lap = lap / (dx * dx)
    return (2.0 * center - prev + c2 * lap).astype(dtype)


def _mask_valid(a, zv, Z: int):
    """Zero every row at or beyond the valid Z extent (the padding rows of
    an asymmetric shard must stay zero — they are other ranks' Dirichlet
    boundary as far as the star is concerned)."""
    if isinstance(zv, int) and zv == Z:
        return a
    ziota = lax.broadcasted_iota(jnp.int32, (Z, 1, 1), 0)
    return jnp.where(ziota < zv, a, jnp.zeros((), a.dtype))


def _assemble(upad, halos: Halos, *, zv, Z: int, Y: int, X: int):
    """Place the landed halos into the zero-padded field at the valid edge."""
    R = RADIUS
    uext = upad
    if halos.z_lo is not None:
        uext = lax.dynamic_update_slice(uext, halos.z_lo, (0, R, R))
        uext = lax.dynamic_update_slice(uext, halos.z_hi, (zv + R, R, R))
    if halos.y_lo is not None:
        uext = lax.dynamic_update_slice(uext, halos.y_lo, (R, 0, R))
        uext = lax.dynamic_update_slice(uext, halos.y_hi, (R, Y + R, R))
    return uext


# ---------------------------------------------------------------------------
# halo exchange over one-sided puts (asymmetric- and 2-D-aware)
# ---------------------------------------------------------------------------


def _slabs_of(u, *, zv, nz: int, ny: int):
    """(z_lo, z_hi, y_lo, y_hi) boundary slabs of a field, at the valid edge."""
    R = RADIUS
    Z, Y, X = u.shape
    z_lo = z_hi = y_lo = y_hi = None
    if nz > 1:
        z_lo = lax.slice_in_dim(u, 0, R, axis=0)
        z_hi = lax.dynamic_slice(u, (zv - R, 0, 0), (R, Y, X))
    if ny > 1:
        y_lo = _mask_valid(lax.slice_in_dim(u, 0, R, axis=1), zv, Z)
        y_hi = _mask_valid(
            lax.slice_in_dim(u, Y - R, Y, axis=1), zv, Z)
    return z_lo, z_hi, y_lo, y_hi


def _halo_puts(slabs, zgroup: DiompGroup, ygroup: Optional[DiompGroup],
               *, nz: int, ny: int) -> Halos:
    """Issue the one-sided puts of a step; returns the (un-fenced) halos.

    Every put is a full-ring permute with the wrap-around edge masked to
    zeros after landing — non-periodic boundaries, same receiver-side
    guard the compiled kernel applies to its landing windows.
    """
    z_lo = z_hi = y_lo = y_hi = None
    if nz > 1:
        lo_w, hi_w = halo_window_names(zgroup, 0)
        iz = lax.axis_index(zgroup.axes[0])
        z_lo = _put_slab(slabs[1], zgroup, shift=1, window=lo_w)
        z_hi = _put_slab(slabs[0], zgroup, shift=-1, window=hi_w)
        z_lo = jnp.where(iz == 0, jnp.zeros_like(z_lo), z_lo)
        z_hi = jnp.where(iz == nz - 1, jnp.zeros_like(z_hi), z_hi)
    if ny > 1:
        lo_w, hi_w = halo_window_names(ygroup, 1)
        iy = lax.axis_index(ygroup.axes[0])
        y_lo = _put_slab(slabs[3], ygroup, shift=1, window=lo_w)
        y_hi = _put_slab(slabs[2], ygroup, shift=-1, window=hi_w)
        y_lo = jnp.where(iy == 0, jnp.zeros_like(y_lo), y_lo)
        y_hi = jnp.where(iy == ny - 1, jnp.zeros_like(y_hi), y_hi)
    return Halos(z_lo, z_hi, y_lo, y_hi)


def _fence_halos(halos: Halos, zgroup: DiompGroup,
                 ygroup: Optional[DiompGroup]) -> Halos:
    """Complete the step's puts; advances the tracker's window epochs so the
    subsequent halo reads satisfy the put→fence→read discipline."""
    live = [h for h in halos if h is not None]
    if not live:
        return halos
    fenced = iter(ompx_fence(*live) if len(live) > 1
                  else (ompx_fence(*live),))
    out = Halos(*(next(fenced) if h is not None else None for h in halos))
    tr = _tracker()
    windows = []
    if halos.z_lo is not None:
        windows += list(halo_window_names(zgroup, 0))
    if halos.y_lo is not None:
        windows += list(halo_window_names(ygroup, 1))
    tr.on_fence(*windows)
    for w in windows:
        tr.on_read(w)
    return out


def exchange_halos(u, zgroup: DiompGroup, ygroup: Optional[DiompGroup] = None,
                   *, z_extents: Optional[Tuple[int, ...]] = None) -> Halos:
    """One complete halo exchange of the current field (puts + one fence).

    The time loop's prologue — and the whole exchange of the non-overlapped
    fallback schedule.  Inside ``shard_map``.
    """
    from repro.core.compat import axis_size

    nz = axis_size(zgroup.axes[0])
    ny = axis_size(ygroup.axes[0]) if ygroup is not None else 1
    Z = u.shape[0]
    zv = Z if z_extents is None else \
        jnp.asarray(z_extents, jnp.int32)[lax.axis_index(zgroup.axes[0])]
    slabs = _slabs_of(u, zv=zv, nz=nz, ny=ny)
    return _fence_halos(_halo_puts(slabs, zgroup, ygroup, nz=nz, ny=ny),
                        zgroup, ygroup)


# ---------------------------------------------------------------------------
# the interpret / CPU emulation: identical schedule over ompx_put
# ---------------------------------------------------------------------------


def _boundary(uext, u_prev, c2, *, zv, nz: int, ny: int, dx: float, dtype):
    """The R-thick boundary output slabs (phase "boundary" of the plan)."""
    R = RADIUS
    Z, Y, X = u_prev.shape
    lo = hi = y_lo = y_hi = None
    if nz > 1:
        lo = _leap(uext[0:3 * R], u_prev[0:R], c2[0:R], dx=dx, dtype=dtype)
        hi = _leap(
            lax.dynamic_slice(uext, (zv - R, 0, 0), (3 * R, Y + 2 * R, X + 2 * R)),
            lax.dynamic_slice(u_prev, (zv - R, 0, 0), (R, Y, X)),
            lax.dynamic_slice(c2, (zv - R, 0, 0), (R, Y, X)),
            dx=dx, dtype=dtype)
    if ny > 1:
        y_lo = _mask_valid(
            _leap(uext[:, 0:3 * R], u_prev[:, 0:R], c2[:, 0:R],
                  dx=dx, dtype=dtype), zv, Z)
        y_hi = _mask_valid(
            _leap(uext[:, Y - R:Y + 2 * R], u_prev[:, Y - R:Y],
                  c2[:, Y - R:Y], dx=dx, dtype=dtype), zv, Z)
    return lo, hi, y_lo, y_hi


def _interior(upad, u_prev, c2, *, nz: int, ny: int, dx: float, dtype):
    """The halo-independent interior (phase "interior"): computed from the
    local field alone, so it runs entirely under the in-flight exchange."""
    R = RADIUS
    Z, Y, X = u_prev.shape
    zsl = slice(R, Z + R) if nz > 1 else slice(0, Z + 2 * R)
    ysl = slice(R, Y + R) if ny > 1 else slice(0, Y + 2 * R)
    pz = slice(R, Z - R) if nz > 1 else slice(0, Z)
    py = slice(R, Y - R) if ny > 1 else slice(0, Y)
    return _leap(upad[zsl, ysl, :], u_prev[pz, py, :], c2[pz, py, :],
                 dx=dx, dtype=dtype)


def _combine(interior, boundary, like, *, zv, nz: int, ny: int):
    """Stitch the passes back into one shard; invalid rows forced to zero."""
    R = RADIUS
    Z, Y, X = like.shape
    out = zeros_varying((Z, Y, X), like.dtype, like)
    if interior is not None:
        out = lax.dynamic_update_slice(
            out, interior, (R if nz > 1 else 0, R if ny > 1 else 0, 0))
    lo, hi, y_lo, y_hi = boundary
    if y_lo is not None:
        out = lax.dynamic_update_slice(out, y_lo, (0, 0, 0))
        out = lax.dynamic_update_slice(out, y_hi, (0, Y - R, 0))
    if lo is not None:
        out = lax.dynamic_update_slice(out, lo, (0, 0, 0))
        out = lax.dynamic_update_slice(out, hi, (zv - R, 0, 0))
    return _mask_valid(out, zv, Z)


def fused_wave_step_interpret(
    u, u_prev, c2dt2, zgroup: DiompGroup,
    ygroup: Optional[DiompGroup] = None, *,
    plan: HaloPlan, dx: float = 1.0, halos: Optional[Halos] = None,
    z_extents: Optional[Tuple[int, ...]] = None, return_halos: bool = False,
):
    """Execute :meth:`HaloPlan.schedule` with ``ompx_put`` as the remote copy.

    Differentiable and asymmetric/2-D-capable; this is what the application
    driver trains and serves through on CPU, and what XLA still compiles
    (and overlaps) on TPU for the configurations the compiled kernel does
    not cover.  With ``return_halos=True`` the step returns
    ``(u_next, halos_of_u_next)`` for the carried time loop.
    """
    R = plan.halo
    Z, Y, X = u.shape
    nz, ny = plan.nz, plan.ny
    dtype = u.dtype
    c2 = jnp.broadcast_to(jnp.asarray(c2dt2, dtype), u.shape)
    zv = Z if z_extents is None else \
        jnp.asarray(z_extents, jnp.int32)[lax.axis_index(zgroup.axes[0])]
    u = _mask_valid(u, zv, Z)
    u_prev = _mask_valid(u_prev, zv, Z)
    upad = jnp.pad(u, R)

    if halos is None and return_halos and plan.overlap:
        # entering the carried loop: prologue exchange of the current field
        halos = exchange_halos(u, zgroup, ygroup, z_extents=z_extents)
    sched = plan.schedule(carried=halos is not None)

    if sched == ("all",):                      # no exchanging axis at all
        out = _mask_valid(_leap(upad, u_prev, c2, dx=dx, dtype=dtype), zv, Z)
        return (out, None) if return_halos else out

    if sched == ("put", "fence", "all"):       # planner fallback: no overlap
        if halos is None:
            halos = exchange_halos(u, zgroup, ygroup, z_extents=z_extents)
        uext = _assemble(upad, halos, zv=zv, Z=Z, Y=Y, X=X)
        out = _mask_valid(_leap(uext, u_prev, c2, dx=dx, dtype=dtype), zv, Z)
        # fallback halos are of the INPUT field — stale after the step, so
        # the time loop re-exchanges next step rather than carrying them
        return (out, None) if return_halos else out

    if sched == ("put", "interior", "fence", "boundary"):
        # single step, no carried halos: exchange the current field's slabs
        # while the interior computes under it
        started = _halo_puts(_slabs_of(u, zv=zv, nz=nz, ny=ny),
                             zgroup, ygroup, nz=nz, ny=ny)
        interior = _interior(upad, u_prev, c2, nz=nz, ny=ny, dx=dx,
                             dtype=dtype)
        landed = _fence_halos(started, zgroup, ygroup)
        uext = _assemble(upad, landed, zv=zv, Z=Z, Y=Y, X=X)
        bnd = _boundary(uext, u_prev, c2, zv=zv, nz=nz, ny=ny, dx=dx,
                        dtype=dtype)
        out = _combine(interior, bnd, u, zv=zv, nz=nz, ny=ny)
        return (out, None) if return_halos else out

    assert sched == ("boundary", "put", "interior", "fence"), sched
    # carried halos: boundary first (it has everything it needs), its fresh
    # values go straight onto the wire, the interior hides the transfer
    uext = _assemble(upad, halos, zv=zv, Z=Z, Y=Y, X=X)
    bnd = _boundary(uext, u_prev, c2, zv=zv, nz=nz, ny=ny, dx=dx, dtype=dtype)
    started = _halo_puts((bnd[0], bnd[1], bnd[2], bnd[3]), zgroup, ygroup,
                         nz=nz, ny=ny)
    interior = _interior(upad, u_prev, c2, nz=nz, ny=ny, dx=dx, dtype=dtype)
    new_halos = _fence_halos(started, zgroup, ygroup)
    out = _combine(interior, bnd, u, zv=zv, nz=nz, ny=ny)
    return (out, new_halos) if return_halos else out


# ---------------------------------------------------------------------------
# the TPU kernel: one pallas_call for the whole step
# ---------------------------------------------------------------------------


def _fused_stencil_kernel(u_ref, uprev_ref, c2_ref, o_ref, halo_bufs,
                          send_sems, recv_sems, *, axis: str, plan: HaloPlan,
                          dx: float):
    """Kernel body; the phase order is baked statically, ranks are traced.

    ``halo_bufs``: VMEM (2, R, Y, X) landing windows — slot 0 receives the
    down-neighbor's hi slab (my lo halo), slot 1 the up-neighbor's lo slab.
    Like the emulation, the puts run the full ring and the wrap-around edge
    is masked to zeros after landing (non-periodic boundaries).
    """
    R = plan.halo
    nz = plan.nz
    Z, Y, X = u_ref.shape
    dtype = o_ref.dtype

    if nz == 1:       # whole axis local: pure Dirichlet, no comm at all
        o_ref[...] = _leap(jnp.pad(u_ref[...], R), uprev_ref[...],
                           c2_ref[...], dx=dx, dtype=dtype)
        return

    me = lax.axis_index(axis)
    up = lax.rem(me + 1, nz)
    down = lax.rem(me + nz - 1, nz)

    # startup barrier: both neighbors entered the kernel before any RDMA
    # touches their landing windows
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=(down,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_signal(barrier, inc=1, device_id=(up,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, 2)

    # phase "put": one-sided deposits of my boundary slabs — my hi slab is
    # the up-neighbor's lo halo, my lo slab the down-neighbor's hi halo
    rdma_hi = pltpu.make_async_remote_copy(
        src_ref=u_ref.at[pl.ds(Z - R, R)], dst_ref=halo_bufs.at[0],
        send_sem=send_sems.at[0], recv_sem=recv_sems.at[0],
        device_id=(up,), device_id_type=pltpu.DeviceIdType.LOGICAL)
    rdma_lo = pltpu.make_async_remote_copy(
        src_ref=u_ref.at[pl.ds(0, R)], dst_ref=halo_bufs.at[1],
        send_sem=send_sems.at[1], recv_sem=recv_sems.at[1],
        device_id=(down,), device_id_type=pltpu.DeviceIdType.LOGICAL)
    rdma_hi.start()
    rdma_lo.start()

    # phase "interior": the halo-independent slab computes under the wire
    u = u_ref[...]
    upad = jnp.pad(u, R)
    if plan.overlap:
        o_ref[pl.ds(R, Z - 2 * R)] = _leap(
            upad[R:Z + R], uprev_ref[pl.ds(R, Z - 2 * R)],
            c2_ref[pl.ds(R, Z - 2 * R)], dx=dx, dtype=dtype)

    # phase "fence": the neighbor slabs must have landed
    rdma_hi.wait()
    rdma_lo.wait()

    # phase "boundary": edge ranks see Dirichlet zeros, not the wrap-around
    lo_halo = jnp.where(me == 0, jnp.zeros_like(halo_bufs[0]), halo_bufs[0])
    hi_halo = jnp.where(me == nz - 1, jnp.zeros_like(halo_bufs[1]),
                        halo_bufs[1])
    uext = upad.at[0:R, R:Y + R, R:X + R].set(lo_halo)
    uext = uext.at[Z + R:Z + 2 * R, R:Y + R, R:X + R].set(hi_halo)
    if plan.overlap:
        o_ref[pl.ds(0, R)] = _leap(uext[0:3 * R], uprev_ref[pl.ds(0, R)],
                                   c2_ref[pl.ds(0, R)], dx=dx, dtype=dtype)
        o_ref[pl.ds(Z - R, R)] = _leap(
            uext[Z - R:Z + 2 * R], uprev_ref[pl.ds(Z - R, R)],
            c2_ref[pl.ds(Z - R, R)], dx=dx, dtype=dtype)
    else:             # degenerate grid: everything is boundary
        o_ref[...] = _leap(uext, uprev_ref[...], c2_ref[...], dx=dx,
                           dtype=dtype)


def fused_wave_step_tpu(u, u_prev, c2dt2, *, axis: str, plan: HaloPlan,
                        dx: float = 1.0):
    """The compiled fused step (requires a real TPU backend).

    Restrictions recorded here rather than hidden: 1-D Z decomposition with
    symmetric extents (2-D, asymmetric and carried-halo configurations
    route through the emulation, which XLA compiles and overlaps on TPU);
    the ring must be a single mesh axis; the whole shard is staged resident
    in VMEM (the dispatcher routes shards that don't fit to the emulation —
    the HaloPlan's bz/by staging pipeline describes the emulation's XLA
    fusion window, not this kernel's residency).
    """
    Z, Y, X = u.shape
    R = plan.halo
    c2 = jnp.broadcast_to(jnp.asarray(c2dt2, u.dtype), u.shape)
    return pl.pallas_call(
        functools.partial(_fused_stencil_kernel, axis=axis, plan=plan, dx=dx),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, R, Y, X), u.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=pltpu.TPUCompilerParams(collective_id=1),
    )(u, u_prev, c2)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def fused_wave_step(
    u, u_prev, c2dt2, zgroup: DiompGroup,
    ygroup: Optional[DiompGroup] = None, *,
    dx: float = 1.0,
    plan: Optional[HaloPlan] = None,
    halos: Optional[Halos] = None,
    z_extents: Optional[Tuple[int, ...]] = None,
    interpret: Optional[bool] = None,
    return_halos: bool = False,
):
    """The fused halo-overlapped wave step entry point (inside shard_map).

    ``u``/``u_prev``: (Z, Y, X) local shards; ``plan`` defaults to the
    process planner's :meth:`~repro.kernels.plan.OverlapPlanner.
    plan_halo_slots` for the traced shapes; ``interpret=None`` resolves
    from the backend at call time.  ``z_extents`` (static per-rank tuple)
    enables asymmetric Z decomposition; ``halos``/``return_halos`` thread
    the carried-halo state of the multi-step time loop.  Configurations the
    compiled kernel does not cover (2-D, asymmetric, carried halos) always
    route through the emulation — which XLA still compiles on TPU.
    """
    from repro.core.compat import axis_size

    nz = axis_size(zgroup.axes[0])
    ny = axis_size(ygroup.axes[0]) if ygroup is not None else 1
    Z, Y, X = u.shape
    if z_extents is not None:
        z_extents = tuple(int(e) for e in z_extents)
        if len(z_extents) != nz:
            raise ValueError(
                f"z_extents has {len(z_extents)} entries for {nz} Z ranks")
        if max(z_extents) > Z:
            raise ValueError(
                f"z_extents {z_extents} exceed the padded shard extent {Z}")
    min_z = Z if z_extents is None else min(z_extents)
    if nz > 1 and min_z < RADIUS:
        raise RMAError(
            f"halo {RADIUS} exceeds the smallest local Z extent {min_z}: "
            "the exchange would wrap non-neighbor data into the slab "
            "(merge ranks or grow the grid)")
    if ny > 1 and Y < RADIUS:
        raise RMAError(
            f"halo {RADIUS} exceeds the local Y extent {Y}")
    if plan is None:
        plan = default_planner().plan_halo_slots(
            Z, Y, X, u.dtype, nz, ny=ny, halo=RADIUS)
    if (plan.nz, plan.ny) != (nz, ny):
        raise ValueError(
            f"plan for (nz={plan.nz}, ny={plan.ny}) used on a "
            f"(nz={nz}, ny={ny}) decomposition")
    if plan.halo != RADIUS:
        raise ValueError(f"plan.halo={plan.halo} != stencil radius {RADIUS}")

    # the compiled kernel keeps u/u_prev/c2/out + the halo landing windows
    # wholly resident in VMEM; larger shards take the emulation, which XLA
    # pipelines through HBM on TPU
    item = jnp.dtype(u.dtype).itemsize
    kernel_bytes = (4 * Z + 2 * RADIUS) * Y * X * item
    needs_emulation = (ny > 1 or z_extents is not None
                       or halos is not None or return_halos
                       or kernel_bytes > default_planner().vmem_budget)
    if resolve_interpret(interpret) or needs_emulation:
        return fused_wave_step_interpret(
            u, u_prev, c2dt2, zgroup, ygroup, plan=plan, dx=dx,
            halos=halos, z_extents=z_extents, return_halos=return_halos)
    return fused_wave_step_tpu(u, u_prev, c2dt2, axis=zgroup.axes[0],
                               plan=plan, dx=dx)

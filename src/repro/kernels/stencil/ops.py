"""jit'd public wrapper for the acoustic wave step.

``bz=None`` sizes the Z slab through the shared OverlapPlanner (the halo
slab must double-buffer inside the VMEM budget — the StreamPool.plan_slots
contract); ``interpret=None`` resolves from the backend at call time.

The resolution happens HERE, before the jit boundary, so the jit cache is
keyed on the *resolved* flag rather than on ``None``: a cached trace can
never pin a stale backend resolution (the silent-interpretation bug class
PR 2 fixed for the matmul path), and calling with ``interpret=None`` vs the
explicitly resolved value hits the same cache entry.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.plan import default_planner, resolve_interpret
from .fused import exchange_halos, fused_wave_step  # noqa: F401 - re-export
from .kernel import wave_step_pallas
from .ref import RADIUS
from .ref import wave_step_ref

__all__ = ["wave_step", "fused_wave_step", "exchange_halos"]


@functools.partial(jax.jit, static_argnames=("dx", "impl", "bz", "interpret"))
def _wave_step_jit(u, u_prev, c2dt2, *, dx: float, impl: str,
                   bz: Optional[int], interpret: Optional[bool]):
    if impl == "ref":
        return wave_step_ref(u, u_prev, c2dt2, dx=dx)
    if impl == "pallas":
        return wave_step_pallas(u, u_prev, c2dt2, dx=dx, bz=bz,
                                interpret=interpret)
    raise ValueError(impl)


def wave_step(u, u_prev, c2dt2, *, dx: float = 1.0, impl: str = "ref",
              bz: Optional[int] = None, interpret: Optional[bool] = None):
    """u, u_prev: (Z, Y, X) f32; c2dt2 scalar or (Z, Y, X).  One leapfrog step."""
    if impl == "pallas":
        interpret = resolve_interpret(interpret)
        if bz is None:
            bz = default_planner().plan_stencil_bz(
                u.shape[0], u.shape[1], u.shape[2], u.dtype, radius=RADIUS)
    else:
        # the ref path ignores both knobs: normalize them out of the jit key
        # so explicit values cannot mint duplicate cache entries
        bz = interpret = None
    return _wave_step_jit(u, u_prev, c2dt2, dx=dx, impl=impl, bz=bz,
                          interpret=interpret)

"""jit'd public wrapper for the acoustic wave step.

``bz=None`` sizes the Z slab through the shared OverlapPlanner (the halo
slab must double-buffer inside the VMEM budget — the StreamPool.plan_slots
contract); ``interpret=None`` resolves from the backend at call time.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.plan import default_planner, resolve_interpret
from .kernel import wave_step_pallas
from .ref import RADIUS
from .ref import wave_step_ref

__all__ = ["wave_step"]


@functools.partial(jax.jit, static_argnames=("dx", "impl", "bz", "interpret"))
def wave_step(u, u_prev, c2dt2, *, dx: float = 1.0, impl: str = "ref",
              bz: Optional[int] = None, interpret: Optional[bool] = None):
    if impl == "ref":
        return wave_step_ref(u, u_prev, c2dt2, dx=dx)
    if impl == "pallas":
        if bz is None:
            bz = default_planner().plan_stencil_bz(
                u.shape[0], u.shape[1], u.shape[2], u.dtype, radius=RADIUS)
        return wave_step_pallas(u, u_prev, c2dt2, dx=dx, bz=bz,
                                interpret=resolve_interpret(interpret))
    raise ValueError(impl)

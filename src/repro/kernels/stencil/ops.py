"""jit'd public wrapper for the acoustic wave step."""

from __future__ import annotations

import functools

import jax

from .kernel import wave_step_pallas
from .ref import wave_step_ref

__all__ = ["wave_step"]


@functools.partial(jax.jit, static_argnames=("dx", "impl", "bz", "interpret"))
def wave_step(u, u_prev, c2dt2, *, dx: float = 1.0, impl: str = "ref",
              bz: int = 8, interpret: bool = True):
    if impl == "ref":
        return wave_step_ref(u, u_prev, c2dt2, dx=dx)
    if impl == "pallas":
        return wave_step_pallas(u, u_prev, c2dt2, dx=dx, bz=bz,
                                interpret=interpret)
    raise ValueError(impl)

"""Pallas TPU kernel for the Minimod 25-point acoustic stencil.

TPU adaptation of Minimod's GPU kernel (DESIGN.md §2): instead of a thread
block per tile with shared-memory halos, we slab the Z axis across the grid
and DMA each (bz + 2R, Y + 2R, X + 2R) halo slab HBM -> VMEM explicitly with
``pltpu.make_async_copy`` — the TPU analogue of the paper's stream-managed
transfers (the DMA slot count is what StreamPool.plan_slots bounds).  The
compute is a vectorized 25-point star over the VMEM slab (VPU work, one
fused multiply-add chain per radius), writing a (bz, Y, X) output block.

VMEM budget: slab (bz+8)(Y+8)(X+8)·4B; for bz=8, Y=X=248 the slab is
~4.2 MiB + out/u_prev blocks ~2 MiB — inside the ~16 MiB budget at the
default tile, and ``ops.wave_step`` shrinks bz for wider grids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import COEFFS, RADIUS

__all__ = ["wave_step_pallas"]


def _stencil_kernel(upad_hbm, uprev_ref, c2dt2_ref, out_ref, slab, sem,
                    *, bz: int, inv_dx2: float):
    iz = pl.program_id(0)

    # explicit HBM -> VMEM DMA of the halo slab for this Z block
    cp = pltpu.make_async_copy(
        upad_hbm.at[pl.ds(iz * bz, bz + 2 * RADIUS)], slab, sem
    )
    cp.start()
    cp.wait()

    u = slab[...]                      # (bz+2R, Y+2R, X+2R) f32
    zc = slice(RADIUS, RADIUS + bz)
    yc = slice(RADIUS, u.shape[1] - RADIUS)
    xc = slice(RADIUS, u.shape[2] - RADIUS)
    center = u[zc, yc, xc]

    c0, *cs = COEFFS
    lap = 3.0 * c0 * center
    for r, c in zip(range(1, RADIUS + 1), cs):
        lap += c * (
            u[slice(RADIUS - r, RADIUS - r + bz), yc, xc]
            + u[slice(RADIUS + r, RADIUS + r + bz), yc, xc]
            + u[zc, slice(RADIUS - r, u.shape[1] - RADIUS - r), xc]
            + u[zc, slice(RADIUS + r, u.shape[1] - RADIUS + r), xc]
            + u[zc, yc, slice(RADIUS - r, u.shape[2] - RADIUS - r)]
            + u[zc, yc, slice(RADIUS + r, u.shape[2] - RADIUS + r)]
        )
    lap = lap * inv_dx2

    out_ref[...] = (
        2.0 * center - uprev_ref[...] + c2dt2_ref[...] * lap
    ).astype(out_ref.dtype)


def wave_step_pallas(u, u_prev, c2dt2, *, dx: float = 1.0, bz: int = 8,
                     interpret: bool = False):
    """u, u_prev: (Z, Y, X) f32; c2dt2 scalar or (Z, Y, X).  One leapfrog step."""
    Z, Y, X = u.shape
    bz = min(bz, Z)
    pz = (-Z) % bz
    c2 = jnp.broadcast_to(jnp.asarray(c2dt2, u.dtype), u.shape)

    upad = jnp.pad(u, RADIUS)                      # halo + Z-slab overrun pad
    if pz:
        upad = jnp.pad(upad, ((0, pz), (0, 0), (0, 0)))
        u_prev = jnp.pad(u_prev, ((0, pz), (0, 0), (0, 0)))
        c2 = jnp.pad(c2, ((0, pz), (0, 0), (0, 0)))
    Zp = Z + pz

    out = pl.pallas_call(
        functools.partial(_stencil_kernel, bz=bz, inv_dx2=1.0 / (dx * dx)),
        grid=(Zp // bz,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),              # padded u in HBM
            pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0)),     # u_prev block
            pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0)),     # velocity block
        ],
        out_specs=pl.BlockSpec((bz, Y, X), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Zp, Y, X), u.dtype),
        scratch_shapes=[
            pltpu.VMEM((bz + 2 * RADIUS, Y + 2 * RADIUS, X + 2 * RADIUS), u.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(upad, u_prev, c2)
    return out[:Z]

"""Fused sequence-parallel ring attention (ROADMAP item 3, paper §4.4).

The all-gather path in :mod:`repro.models.layers` materializes the FULL
K/V on every rank before one local flash pass — O(T) memory per rank and
a bulk collective the scheduler may or may not hide.  This module is the
DiOMP treatment of the same traffic: K/V *stripes* rotate through the
bidirectional ring as one-sided puts while each rank folds
partial-softmax states (:mod:`.kernel`) for the stripes it holds, so
peak memory stays O(T/n) and the exchange of step ``s + 1``'s stripes
rides under step ``s``'s flash block by construction.

Two executions of ONE schedule (:meth:`~repro.kernels.plan.
AttentionRingPlan.schedule` — the matmul ring's step records):

* ``fused_ring_attention_tpu`` — one ``pallas_call`` for the whole ring:
  per-direction VMEM stripe slots, each step's
  ``pltpu.make_async_remote_copy`` started BEFORE the step's flash block,
  a startup neighbor barrier, and ``pl.when`` causal step-skipping —
  ranks holding an only-future stripe spend no FLOPs, which is bitwise
  sound because a fully masked stripe's state is the merge identity.
* ``fused_ring_attention_interpret`` — the CPU-CI emulation: iterates the
  IDENTICAL step records with each RDMA realized as ``ompx_put`` and each
  landing completed by ``ompx_fence`` (differentiable, so the training
  step traces through it).  Every put is recorded against the
  RMATracker's attention windows (:func:`repro.core.rma.
  attention_window_names`) with the same bytes the OMPCCL communicator
  logs — exact put-traffic parity, the Minimod/MoE discipline.

Both fold stripe states in schedule-arrival order, the same chain
:func:`~.ref.ring_attention_ref` replays on one device — so the
equivalence suite asserts bit-equality, not tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.backends import payload_bytes
from repro.core.groups import DiompGroup
from repro.core.rma import attention_window_names, ompx_fence, ompx_put
from repro.kernels.plan import AttentionRingPlan
from .kernel import chain_grads, empty_state, finalize_state, merge_states, \
    scaled_queries, stripe_mask, stripe_state

__all__ = [
    "fused_ring_attention_interpret",
    "fused_ring_attention_tpu",
]


# ---------------------------------------------------------------------------
# the interpret / CPU emulation: identical schedule over ompx_put
# ---------------------------------------------------------------------------


def fused_ring_attention_interpret(
    q, k, v, group: DiompGroup, *, plan: AttentionRingPlan,
    scale=None, q_offset=0, valid_len=None,
):
    """Execute :meth:`AttentionRingPlan.schedule` with ``ompx_put`` as the
    remote copy (inside shard_map; ``q (B, tq_loc, H, D)``, ``k/v
    (B, tk_loc, KH, D/Dv)`` per-rank shards).

    ``plan.overlap=True`` (the fused order): both directions' forwards
    start BEFORE the step's flash block and fence after it — the next
    stripes are in flight during compute, which is what lets XLA's async
    collective-permute hide them.  ``overlap=False`` is the serialized
    "host" listing: put, fence, then compute — same traffic, same merge
    chain, nothing hidden.  Stripes the plan's causal skip would drop are
    folded anyway: fully masked states are the merge identity, so the
    result is bit-identical to the skipping kernel.

    The whole schedule carries a hand-written VJP (:func:`~.kernel.
    chain_grads`): autodiff's ring transpose would accumulate each K/V
    shard's cotangent in a different f32 add order than the oracle's
    slice transpose, breaking the gradient bit contract.  The backward
    replays the arrivals with plain ``lax.ppermute`` (no tracker
    double-count, no chaos reinjection), routes each stripe's cotangent
    back to its owner, and every execution sums contributions in ONE
    canonical order — own stripe, then clockwise deliveries by ascending
    step, then counter-clockwise.
    """
    from repro.core.context import default_context

    ax = group.axes[0]
    n = plan.n
    B, tq, H, D = q.shape
    tk = k.shape[1]
    KH = plan.kh
    if scale is None:
        scale = D ** -0.5
    me = lax.axis_index(ax)
    q0 = jnp.asarray(q_offset) + (me * tq if plan.q_sharded else 0)
    q_pos = q0.reshape(-1, 1) + jnp.arange(tq)[None, :]
    folds = plan.fold_steps()
    fidx = {f: i for i, f in enumerate(folds)}
    # Fold-order visibility masks: exact boolean math, built outside the
    # custom-VJP boundary and passed as an aux input with zero cotangent
    # (they absorb the possibly-traced q_offset/valid_len).
    masks = []
    for dirn, s in folds:
        src = lax.rem(me - s + n, n) if dirn == "cw" else lax.rem(me + s, n)
        vis = stripe_mask(tk, q_pos=q_pos, k_start=src * tk,
                          causal=plan.causal, valid_len=valid_len)
        masks.append(jnp.broadcast_to(vis, (B, tq, tk)))
    masks = jnp.stack(masks).astype(jnp.float32)

    def run(q, k, v, masks):
        qg = scaled_queries(q, KH, scale)
        state = empty_state(qg, v)

        def fold(state, k_str, v_str, i):
            blk = stripe_state(qg, k_str, v_str, vis=masks[i])
            return merge_states(state, blk)

        if n == 1:
            return finalize_state(fold(state, k, v, 0), q.dtype)

        tracker = default_context().rma
        cw_w, ccw_w = attention_window_names(group, n, plan.direction)

        def put(win, k_str, v_str, shift):
            tracker.ensure(win)
            tracker.on_put(win, payload_bytes(k_str))
            tracker.on_put(win, payload_bytes(v_str))
            return ompx_put(k_str, group, shift=shift), \
                ompx_put(v_str, group, shift=shift)

        def land(win, k_str, v_str):
            k_str, v_str = ompx_fence(k_str, v_str)
            tracker.on_fence(win)
            tracker.on_read(win)
            return k_str, v_str

        kcw = kccw = k
        vcw = vccw = v
        for st in plan.schedule():
            s = st.index
            # forwards first: step s+1's stripes fly under this step's block
            kcw_n, vcw_n = put(cw_w[s], kcw, vcw, 1) if st.send_cw \
                else (kcw, vcw)
            kccw_n, vccw_n = put(ccw_w[s], kccw, vccw, -1) if st.send_ccw \
                else (kccw, vccw)
            if not plan.overlap:  # serialized listing: land before computing
                if st.send_cw:
                    kcw_n, vcw_n = land(cw_w[s], kcw_n, vcw_n)
                if st.send_ccw:
                    kccw_n, vccw_n = land(ccw_w[s], kccw_n, vccw_n)
            if st.compute_cw:
                state = fold(state, kcw, vcw, fidx[("cw", s)])
            if st.compute_ccw:
                state = fold(state, kccw, vccw, fidx[("ccw", s)])
            if plan.overlap:      # next step's stripes must have landed
                if st.send_cw:
                    kcw_n, vcw_n = land(cw_w[s], kcw_n, vcw_n)
                if st.send_ccw:
                    kccw_n, vccw_n = land(ccw_w[s], kccw_n, vccw_n)
            kcw, vcw = kcw_n, vcw_n
            kccw, vccw = kccw_n, vccw_n
        return finalize_state(state, q.dtype)

    @jax.custom_vjp
    def ring(q, k, v, masks):
        return run(q, k, v, masks)

    def ring_fwd(q, k, v, masks):
        return run(q, k, v, masks), (q, k, v, masks)

    def ring_bwd(res, ct):
        q, k, v, masks = res
        G = H // KH
        Dv = v.shape[-1]
        ct32 = ct.astype(jnp.float32).reshape(B, tq, KH, G, Dv)
        qg = scaled_queries(q, KH, scale)
        # replay the arrivals (same values the forward folded)
        stripes = [None] * len(folds)
        if n == 1:
            stripes[0] = (k, v, masks[0])
        else:
            perm_cw = [(j, (j + 1) % n) for j in range(n)]
            perm_ccw = [(j, (j - 1) % n) for j in range(n)]
            kcw = kccw = k
            vcw = vccw = v
            for st in plan.schedule():
                s = st.index
                if st.compute_cw:
                    i = fidx[("cw", s)]
                    stripes[i] = (kcw, vcw, masks[i])
                if st.compute_ccw:
                    i = fidx[("ccw", s)]
                    stripes[i] = (kccw, vccw, masks[i])
                if st.send_cw:
                    kcw = lax.ppermute(kcw, ax, perm_cw)
                    vcw = lax.ppermute(vcw, ax, perm_cw)
                if st.send_ccw:
                    kccw = lax.ppermute(kccw, ax, perm_ccw)
                    vccw = lax.ppermute(vccw, ax, perm_ccw)
        gqg, gks, gvs = chain_grads(qg, stripes, ct32)
        gq = (gqg.reshape(B, tq, H, D) * scale).astype(q.dtype)
        # canonical owner-side accumulation (mirrored by the oracle's VJP)
        gk32, gv32 = gks[folds.index(("cw", 0))], gvs[folds.index(("cw", 0))]
        for want in ("cw", "ccw"):
            for i, (dirn, s) in enumerate(folds):
                if dirn != want or s == 0:
                    continue
                sign = -s if dirn == "cw" else s
                perm = [(j, (j + sign) % n) for j in range(n)]
                gk32 = gk32 + lax.ppermute(gks[i], ax, perm)
                gv32 = gv32 + lax.ppermute(gvs[i], ax, perm)
        return (gq, gk32.astype(k.dtype), gv32.astype(v.dtype),
                jnp.zeros_like(masks))

    ring.defvjp(ring_fwd, ring_bwd)
    return ring(q, k, v, masks)


# ---------------------------------------------------------------------------
# the TPU kernel: one pallas_call for the whole ring
# ---------------------------------------------------------------------------


def _ring_slots(plan: AttentionRingPlan) -> int:
    """Slot count the kernel allocates — same skew argument as the matmul
    ring (``ring_matmul.fused._ring_slots``): the per-step ``rdma.wait()``
    bounds bidirectional neighbor skew to one step, so three buffers
    suffice; unidirectional rings take one slot per step."""
    steps = plan.exchange_steps
    need = min(steps + 1, 3) if plan.direction == "bidi" else steps + 1
    return max(plan.slots, need)


def _fused_attention_kernel(q_ref, k_ref, v_ref, o_ref,
                            kbufs, vbufs, macc, lacc, oacc,
                            ksend, krecv, vsend, vrecv,
                            *, axis: str, plan: AttentionRingPlan,
                            scale: float):
    """Kernel body; the schedule is baked statically, ranks are traced.

    ``kbufs/vbufs``: VMEM (2, slots, B, tk_loc, KH, D/Dv) stripe slots per
    direction (0 = clockwise, 1 = counter-clockwise); ``macc/lacc/oacc``
    the f32 (m, l, acc) merge carry.  Step ``s + 1``'s RDMAs start before
    step ``s``'s flash blocks; ``pl.when`` skips the blocks of stripes the
    causal plan proves fully masked (their states are the merge identity,
    so the carry is bit-identical to the non-skipping emulation).
    """
    n, slots = plan.n, _ring_slots(plan)
    B, tq, H, D = q_ref.shape
    tk = k_ref.shape[1]
    me = lax.axis_index(axis)
    right = lax.rem(me + 1, n)
    left = lax.rem(me + n - 1, n)

    if n > 1:
        # startup barrier: both neighbors entered the kernel before any
        # RDMA touches their buffers (slot 0 is seeded locally)
        barrier = pltpu.get_barrier_semaphore()
        pltpu.semaphore_signal(barrier, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_signal(barrier, inc=1, device_id=(right,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(barrier, 2)

        kbufs[0, 0] = k_ref[...]
        kbufs[1, 0] = k_ref[...]
        vbufs[0, 0] = v_ref[...]
        vbufs[1, 0] = v_ref[...]

    qg = scaled_queries(q_ref[...], plan.kh, scale)
    q0 = (jnp.int32(plan.q_offset or 0)
          + (me * tq if plan.q_sharded else 0))
    q_pos = jnp.reshape(q0, (-1, 1)) + jnp.arange(tq)[None, :]
    m0, l0, a0 = empty_state(qg, v_ref[...])
    macc[...] = m0
    lacc[...] = l0
    oacc[...] = a0

    def fold(stream: int, slot: int, src):
        k_str = k_ref[...] if n == 1 else kbufs[stream, slot]
        v_str = v_ref[...] if n == 1 else vbufs[stream, slot]
        blk = stripe_state(qg, k_str, v_str, q_pos=q_pos, k_start=src * tk,
                           causal=plan.causal, valid_len=plan.valid_len,
                           exact=False)
        m, l, a = merge_states((macc[...], lacc[...], oacc[...]), blk,
                               exact=False)
        macc[...] = m
        lacc[...] = l
        oacc[...] = a

    def wanted(src):
        # the traced twin of plan.computes(me, src): skip only stripes the
        # plan proves fully masked for my (static-offset) query range
        ok = jnp.bool_(True)
        if plan.valid_len is not None:
            ok &= src * tk < plan.valid_len
        if plan.causal and plan.q_offset is not None:
            q_hi = q0 + tq - 1
            ok &= src * tk <= q_hi
        return ok

    for st in plan.schedule():
        slot = st.index % slots
        nxt = (st.index + 1) % slots
        rdmas = []
        if st.send_cw:    # my cw stripes -> right neighbor's next cw slots
            for bufs, ss, rs in ((kbufs, ksend, krecv),
                                 (vbufs, vsend, vrecv)):
                rdma = pltpu.make_async_remote_copy(
                    src_ref=bufs.at[0, slot], dst_ref=bufs.at[0, nxt],
                    send_sem=ss.at[0, slot], recv_sem=rs.at[0, nxt],
                    device_id=(right,),
                    device_id_type=pltpu.DeviceIdType.LOGICAL)
                rdma.start()
                rdmas.append(rdma)
        if st.send_ccw:   # my ccw stripes -> left neighbor's next ccw slots
            for bufs, ss, rs in ((kbufs, ksend, krecv),
                                 (vbufs, vsend, vrecv)):
                rdma = pltpu.make_async_remote_copy(
                    src_ref=bufs.at[1, slot], dst_ref=bufs.at[1, nxt],
                    send_sem=ss.at[1, slot], recv_sem=rs.at[1, nxt],
                    device_id=(left,),
                    device_id_type=pltpu.DeviceIdType.LOGICAL)
                rdma.start()
                rdmas.append(rdma)

        # flash blocks on the CURRENT slots overlap the in-flight stripes
        if st.compute_cw:
            src = lax.rem(me - st.index + n, n)
            pl.when(wanted(src))(lambda s=slot, r=src: fold(0, s, r))
        if st.compute_ccw:
            src = lax.rem(me + st.index, n)
            pl.when(wanted(src))(lambda s=slot, r=src: fold(1, s, r))

        for rdma in rdmas:    # next step's stripes must have landed
            rdma.wait()

    o_ref[...] = finalize_state((macc[...], lacc[...], oacc[...]),
                                o_ref.dtype, exact=False)


def fused_ring_attention_tpu(q, k, v, *, axis: str,
                             plan: AttentionRingPlan, scale=None):
    """The compiled fused kernel (requires a real TPU backend).

    Restrictions recorded here rather than hidden: the ring must be a
    single mesh axis (``device_id`` is the logical index along it), and
    the kernel needs STATIC ``q_offset``/``valid_len`` (they are plan
    fields baked into the masks; traced offsets route to the emulation).
    """
    B, tq, H, D = q.shape
    tk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    if scale is None:
        scale = D ** -0.5
    slots = _ring_slots(plan)
    G = H // KH
    return pl.pallas_call(
        functools.partial(_fused_attention_kernel, axis=axis, plan=plan,
                          scale=scale),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        out_shape=jax.ShapeDtypeStruct((B, tq, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, slots, B, tk, KH, D), k.dtype),
            pltpu.VMEM((2, slots, B, tk, KH, Dv), v.dtype),
            pltpu.VMEM((B, tq, KH, G), jnp.float32),
            pltpu.VMEM((B, tq, KH, G), jnp.float32),
            pltpu.VMEM((B, tq, KH, G, Dv), jnp.float32),
            pltpu.SemaphoreType.DMA((2, slots)),
            pltpu.SemaphoreType.DMA((2, slots)),
            pltpu.SemaphoreType.DMA((2, slots)),
            pltpu.SemaphoreType.DMA((2, slots)),
        ],
        compiler_params=pltpu.TPUCompilerParams(collective_id=2),
    )(q, k, v)

from .kernel import empty_state, finalize_state, merge_states, \
    scaled_queries, stripe_state  # noqa: F401
from .ops import resolve_attention_impl, ring_attention  # noqa: F401
from .ref import ring_attention_ref  # noqa: F401

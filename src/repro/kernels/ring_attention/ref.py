"""Single-device oracle executing the EXACT stripe/merge chain of the ring.

``flash_attention_ref`` is the mathematical ground truth, but it folds KV
blocks with the *online* update (rescale-then-accumulate per block), so its
float rounding differs from the ring's state-merge at the last ulp.  This
oracle instead replays, on one device over the full gathered tensors, the
identical computation every ring rank performs: one
:func:`~.kernel.stripe_state` per K/V stripe, folded with
:func:`~.kernel.merge_states` in the ring's schedule-arrival order
(:meth:`AttentionRingPlan.sources`).  The equivalence tests therefore
assert ``ring == ring_attention_ref`` **bitwise** and
``ring_attention_ref ≈ flash_attention_ref`` at float tolerance — the
merge-order difference is all that separates them.

The oracle carries the same hand-written VJP as the emulation
(:func:`~.kernel.chain_grads`), accumulating each stripe's K/V cotangent
contributions in the identical canonical order (owner's own stripe, then
clockwise deliveries by ascending step, then counter-clockwise) — so the
bit contract extends to gradients.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.plan import AttentionRingPlan
from .kernel import chain_grads, empty_state, finalize_state, merge_states, \
    scaled_queries, stripe_mask, stripe_state

__all__ = ["ring_attention_ref"]


def ring_attention_ref(
    q, k, v, *,
    n: int,
    causal: bool = True,
    q_offset=0,
    valid_len=None,
    scale: Optional[float] = None,
    plan: Optional[AttentionRingPlan] = None,
    q_sharded: bool = True,
):
    """q: (B, Tq, H, D) FULL queries; k/v: (B, Tk, KH, D/Dv) FULL keys/values.

    ``Tk`` must divide into ``n`` equal stripes (pad and pass ``valid_len``
    for ragged lengths, exactly like the distributed caller would).  With
    ``q_sharded=True`` rank ``r`` owns query rows ``[r·Tq/n, (r+1)·Tq/n)``
    and the outputs concatenate to (B, Tq, H, Dv); with ``False`` every
    rank holds the same ``Tq`` queries at ``q_offset`` (chunked prefill)
    and the single shared output is returned.  ``q_offset``/``valid_len``
    may be traced — masking handles what static skipping cannot.
    """
    B, Tq, H, D = q.shape
    Tk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    if Tk % n:
        raise ValueError(f"Tk={Tk} not divisible into {n} stripes")
    tk_loc = Tk // n
    if q_sharded and Tq % n:
        raise ValueError(f"Tq={Tq} not divisible over {n} ranks")
    tq_loc = Tq // n if q_sharded else Tq
    if scale is None:
        scale = D ** -0.5
    if plan is None:
        plan = AttentionRingPlan(n=n, tq_loc=tq_loc, tk_loc=tk_loc,
                                 h=H, kh=KH, d=D, dv=Dv, b=B,
                                 causal=causal, q_sharded=q_sharded)
    R = n if q_sharded else 1
    folds = plan.fold_steps()

    def rank_stripes(r, k, v, masks):
        return [(k[:, src * tk_loc:(src + 1) * tk_loc],
                 v[:, src * tk_loc:(src + 1) * tk_loc],
                 masks[r, i])
                for i, src in enumerate(plan.sources(r))]

    masks = []
    for r in range(R):
        q0 = jnp.asarray(q_offset) + (r * tq_loc if q_sharded else 0)
        q_pos = q0.reshape(-1, 1) + jnp.arange(tq_loc)[None, :]
        masks.append(jnp.stack([
            jnp.broadcast_to(
                stripe_mask(tk_loc, q_pos=q_pos, k_start=src * tk_loc,
                            causal=causal, valid_len=valid_len),
                (B, tq_loc, tk_loc))
            for src in plan.sources(r)]))
    masks = jnp.stack(masks).astype(jnp.float32)

    def run(q, k, v, masks):
        outs = []
        for r in range(R):
            qr = q[:, r * tq_loc:(r + 1) * tq_loc] if q_sharded else q
            qg = scaled_queries(qr, KH, scale)
            state = empty_state(qg, v)
            for k_str, v_str, vis in rank_stripes(r, k, v, masks):
                state = merge_states(state,
                                     stripe_state(qg, k_str, v_str, vis=vis))
            outs.append(finalize_state(state, q.dtype))
        return jnp.concatenate(outs, axis=1) if q_sharded else outs[0]

    @jax.custom_vjp
    def ref(q, k, v, masks):
        return run(q, k, v, masks)

    def ref_fwd(q, k, v, masks):
        return run(q, k, v, masks), (q, k, v, masks)

    def ref_bwd(res, ct):
        q, k, v, masks = res
        G = H // KH
        ct32 = ct.astype(jnp.float32)
        gq_parts, gks_by_rank, gvs_by_rank = [], {}, {}
        for r in range(R):
            ctr = ct32[:, r * tq_loc:(r + 1) * tq_loc] if q_sharded else ct32
            qr = q[:, r * tq_loc:(r + 1) * tq_loc] if q_sharded else q
            qg = scaled_queries(qr, KH, scale)
            gqg, gks, gvs = chain_grads(
                qg, rank_stripes(r, k, v, masks),
                ctr.reshape(B, tq_loc, KH, G, Dv))
            gq_parts.append(
                (gqg.reshape(B, tq_loc, H, D) * scale).astype(q.dtype))
            gks_by_rank[r], gvs_by_rank[r] = gks, gvs
        gq = jnp.concatenate(gq_parts, axis=1) if q_sharded else gq_parts[0]
        own = folds.index(("cw", 0))
        gk_stripes, gv_stripes = [], []
        for p in range(n):
            if q_sharded:
                # the emulation's canonical owner-side accumulation, rank
                # by rank: own stripe, then cw deliveries, then ccw
                gk_p, gv_p = gks_by_rank[p][own], gvs_by_rank[p][own]
                for want in ("cw", "ccw"):
                    for i, (dirn, s) in enumerate(folds):
                        if dirn != want or s == 0:
                            continue
                        rr = (p + s) % n if dirn == "cw" else (p - s) % n
                        gk_p = gk_p + gks_by_rank[rr][i]
                        gv_p = gv_p + gvs_by_rank[rr][i]
            else:
                i = plan.sources(0).index(p)
                gk_p, gv_p = gks_by_rank[0][i], gvs_by_rank[0][i]
            gk_stripes.append(gk_p)
            gv_stripes.append(gv_p)
        gk = jnp.concatenate(gk_stripes, axis=1).astype(k.dtype)
        gv = jnp.concatenate(gv_stripes, axis=1).astype(v.dtype)
        return gq, gk, gv, jnp.zeros_like(masks)

    ref.defvjp(ref_fwd, ref_bwd)
    return ref(q, k, v, masks)

"""Public wrapper for sequence-parallel ring attention.

Called inside shard_map with per-rank shards: ``q (B, tq_loc, H, D)``,
``k/v (B, tk_loc, KH, D/Dv)`` -> ``(B, tq_loc, H, Dv)``.  The usual knob
conventions apply: ``plan=None`` asks the shared
:class:`~repro.kernels.plan.OverlapPlanner` for slot/block sizes
(``StreamPool.plan_slots`` contract), ``impl`` resolves ``"auto"``/None to
the ``"fused"`` overlap order (``"host"`` is the serialized listing), and
``interpret=None`` resolves from the backend at call time — compiled on
TPU, the differentiable ``ompx_put`` emulation elsewhere.

Traced ``q_offset``/``valid_len`` (dynamic chunked prefill) are legal:
the plan then disables static causal step-skipping and the masks handle
everything — but only the emulation can run them; the TPU kernel bakes
static offsets and raises otherwise.

Deliberately not jitted here: the callers (model steps) are jitted.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.groups import DiompGroup
from repro.kernels.plan import AttentionRingPlan, default_planner, \
    resolve_interpret
from .fused import fused_ring_attention_interpret, fused_ring_attention_tpu

__all__ = ["ring_attention", "resolve_attention_impl"]


def resolve_attention_impl(impl: Optional[str]) -> str:
    """``"auto"``/None pick the fused overlap order; explicit ``"host"``
    (serialized put-fence-compute listing) and ``"fused"`` pass through —
    the same convention as the ring matmul's knob."""
    if impl in (None, "auto"):
        return "fused"
    if impl in ("host", "fused"):
        return impl
    raise ValueError(f"unknown ring attention impl {impl!r}")


def _static_int(val) -> bool:
    return val is not None and not isinstance(val, jax.core.Tracer)


def ring_attention(
    q, k, v, group: DiompGroup, *,
    causal: bool = True,
    q_offset=0,
    valid_len=None,
    scale: Optional[float] = None,
    q_sharded: bool = True,
    plan: Optional[AttentionRingPlan] = None,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
):
    """The fused ring attention entry point (inside shard_map)."""
    from repro.core.compat import axis_size

    if len(group.axes) != 1:
        raise ValueError(
            f"ring attention needs a single-axis group, got {group.axes}")
    n = axis_size(group.axes[0])
    B, tq, H, D = q.shape
    tk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    if H % KH:
        raise ValueError(f"H={H} not divisible by kv heads {KH}")
    mode = resolve_attention_impl(impl)
    if plan is None:
        plan = default_planner().plan_ring_attention(
            B, tq, tk, H, KH, D, Dv, q.dtype, n,
            causal=causal, q_sharded=q_sharded,
            q_offset=int(q_offset) if _static_int(q_offset) else None,
            valid_len=int(valid_len) if _static_int(valid_len) else None,
            overlap=mode == "fused")
    if plan.n != n:
        raise ValueError(f"plan for n={plan.n} used on a ring of {n}")
    if plan.overlap != (mode == "fused"):
        plan = dataclasses.replace(plan, overlap=mode == "fused")
    if resolve_interpret(interpret):
        return fused_ring_attention_interpret(
            q, k, v, group, plan=plan, scale=scale,
            q_offset=q_offset, valid_len=valid_len)
    if not _static_int(q_offset) or (valid_len is not None
                                     and not _static_int(valid_len)):
        raise ValueError(
            "the TPU ring attention kernel bakes q_offset/valid_len into "
            "its masks at trace time; traced offsets need interpret=True "
            "(the ompx_put emulation)")
    return fused_ring_attention_tpu(q, k, v, axis=group.axes[0], plan=plan,
                                    scale=scale)

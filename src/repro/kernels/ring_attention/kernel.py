"""Online-softmax partial states and the merge monoid of ring attention.

A flash-attention pass over one K/V *stripe* produces a partial-softmax
state ``(m, l, acc)`` — running row max, normalizer, and unnormalized
value accumulator.  Ring attention never sees the stripes in one scan:
each rank folds the states of the stripes the bidirectional ring delivers,
in schedule-arrival order, with :func:`merge_states`.

The algebra the property tests pin down (``tests/test_attention_props.py``):

* **merge is associative** and (up to float tolerance) permutation-
  invariant, so any delivery order yields the same attention;
* **the masked-empty state** ``(m = -inf, l = 0, acc = 0)`` is the EXACT
  (bitwise) identity of the merge — the empty side is detected by its
  ``-inf`` max and the other side passes through verbatim.  That identity
  is what makes the causal step-skip sound: a stripe entirely in a rank's
  future is fully masked, its state is the identity, and skipping its
  FLOPs (the TPU kernel's ``pl.when``) leaves the merge chain
  bit-identical.

Every execution of the ring (TPU kernel, CPU ``ompx_put`` emulation,
single-device :func:`~.ref.ring_attention_ref` oracle) folds stripe states
with these exact ops in the same schedule order, which is why the
equivalence tests can assert ``==`` rather than ``allclose``.

Shapes (f32 throughout; GQA grouped like the flash oracle):
``m, l: (B, Tq, KH, G)``; ``acc: (B, Tq, KH, G, Dv)``.

Why the ``exact`` path computes on the host
-------------------------------------------
The cross-program bit contract (emulation == oracle, forward and
gradients) cannot be met with jnp math on XLA CPU: the backend emits
*different code for the same op per fusion instance* — ``exp`` compiles
to the vectorized polynomial or a libm call depending on what it fuses
with, ``a*b + c`` is FMA-contracted in one program and not another, and
reductions vectorize with different accumulation orders.
``lax.optimization_barrier`` does not help: a barrier on a value that is
not a program output does not stop a consumer fusion from recompiling
the producer.  So the exact path routes each stripe/merge/finalize
through :func:`jax.pure_callback` into plain numpy.  Host numpy is ONE
implementation — the same routine runs for the oracle, the host listing,
and the fused emulation, so equal inputs give equal bits by
construction, in straight f32 and regardless of how XLA fuses the
surrounding program.  Callbacks are opaque to autodiff, so each piece is
a ``jax.custom_vjp`` whose backward is itself a numpy callback.  The
backward exploits that the finalized output is mathematically invariant
to every ``m`` value (the normalizer cancels between ``l`` and ``acc``),
so all ``m``-channel cotangents are *exactly* zero and the remaining
VJPs are the plain softmax/rescale pullbacks.  The TPU kernel opts out
(``exact=False``): Mosaic compiles one program, host callbacks do not
exist inside Pallas, and the CPU/TPU bit contract is meaningless across
hardware anyway.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "scaled_queries",
    "empty_state",
    "stripe_mask",
    "stripe_state",
    "merge_states",
    "finalize_state",
    "stripe_bwd",
    "merge_bwd",
    "finalize_bwd",
    "chain_grads",
]

State = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]

_F32 = np.float32


def scaled_queries(q, kh: int, scale) -> jnp.ndarray:
    """(B, Tq, H, D) queries -> pre-scaled f32 (B, Tq, KH, G, D) GQA groups."""
    B, Tq, H, D = q.shape
    if H % kh:
        raise ValueError(f"H={H} not divisible by kv heads {kh}")
    return (q.astype(jnp.float32) * scale).reshape(B, Tq, kh, H // kh, D)


def empty_state(qg, v) -> State:
    """The merge identity: no keys seen yet (``m = -inf, l = 0, acc = 0``).

    Derives from ``qg``/``v`` so the state's varying-manual-axes match the
    stripe states under shard_map (the flash oracle's carry-tag trick).
    """
    B, Tq, KH, G, _ = qg.shape
    Dv = v.shape[-1]
    tag = (qg.reshape(-1)[0] * 0) + (v.reshape(-1)[0] * 0).astype(jnp.float32)
    m = jnp.full((B, Tq, KH, G), -jnp.inf, jnp.float32) + tag
    l = jnp.zeros((B, Tq, KH, G), jnp.float32) + tag
    acc = jnp.zeros((B, Tq, KH, G, Dv), jnp.float32) + tag
    return m, l, acc


# --------------------------------------------------------------------------
# stripe: one rank's queries against one K/V stripe
# --------------------------------------------------------------------------

def _stripe_f32(qg, k_stripe, v_stripe, vis):
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_stripe.astype(jnp.float32))
    s = jnp.where(vis[:, :, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)                       # -inf on fully masked rows
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vis[:, :, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_stripe.astype(jnp.float32))
    return m, l, acc


def _np_stripe_p(qg, k, mask):
    """Softmax numerator ``p`` and row max ``m`` (both f32 numpy)."""
    vis = mask > 0.5
    s = np.einsum("bqhgd,bkhd->bqhgk", qg, k, dtype=_F32)
    s = np.where(vis[:, :, None, None, :], s, _F32(-np.inf))
    m = s.max(axis=-1)
    m_safe = np.where(np.isneginf(m), _F32(0), m)
    with np.errstate(invalid="ignore", over="ignore", under="ignore"):
        p = np.exp(s - m_safe[..., None], dtype=_F32)   # exactly 0 if masked
    return p, m


def _np_stripe(qg, k, v, mask):
    p, m = _np_stripe_p(qg, k, mask)
    l = p.sum(axis=-1, dtype=_F32)
    acc = np.einsum("bqhgk,bkhd->bqhgd", p, v, dtype=_F32)
    return m, l, acc


def _np_stripe_bwd(qg, k, v, mask, gl, gacc):
    p, _ = _np_stripe_p(qg, k, mask)
    gp = gl[..., None] + np.einsum("bqhgd,bkhd->bqhgk", gacc, v, dtype=_F32)
    ds = p * gp                              # masked rows: p == 0 -> ds == 0
    gqg = np.einsum("bqhgk,bkhd->bqhgd", ds, k, dtype=_F32)
    gk = np.einsum("bqhgk,bqhgd->bkhd", ds, qg, dtype=_F32)
    gv = np.einsum("bqhgk,bqhgd->bkhd", p, gacc, dtype=_F32)
    return gqg, gk, gv


def _state_shapes(qg, v):
    B, Tq, KH, G, _ = qg.shape
    sd = jax.ShapeDtypeStruct
    return (sd((B, Tq, KH, G), jnp.float32),
            sd((B, Tq, KH, G), jnp.float32),
            sd((B, Tq, KH, G, v.shape[-1]), jnp.float32))


@jax.custom_vjp
def _stripe_exact(qg, k32, v32, mask):
    return jax.pure_callback(_np_stripe, _state_shapes(qg, v32),
                             qg, k32, v32, mask)


def _stripe_exact_fwd(qg, k32, v32, mask):
    return _stripe_exact(qg, k32, v32, mask), (qg, k32, v32, mask)


def _stripe_exact_bwd(res, ct):
    qg, k32, v32, mask = res
    _, gl, gacc = ct                         # gm dies here (see module doc)
    sd = jax.ShapeDtypeStruct
    shapes = (sd(qg.shape, jnp.float32), sd(k32.shape, jnp.float32),
              sd(v32.shape, jnp.float32))
    gqg, gk, gv = jax.pure_callback(_np_stripe_bwd, shapes,
                                    qg, k32, v32, mask, gl, gacc)
    return gqg, gk, gv, jnp.zeros_like(mask)


_stripe_exact.defvjp(_stripe_exact_fwd, _stripe_exact_bwd)


def stripe_mask(S: int, *, q_pos, k_start, causal: bool,
                valid_len=None) -> jnp.ndarray:
    """Visibility of one stripe's ``S`` key rows to the ``(B|1, Tq)`` query
    positions — boolean, exact (no float math), so it can be built outside
    the exact path and passed in via ``stripe_state(..., vis=...)``."""
    k_pos = jnp.asarray(k_start) + jnp.arange(S)                  # (S,)
    q_pos = jnp.asarray(q_pos)                                    # (B|1, Tq)
    vis = jnp.ones((1, 1, S), bool)
    if valid_len is not None:
        v_len = jnp.asarray(valid_len)
        vis = vis & (k_pos.reshape(1, 1, -1) < v_len.reshape(-1, 1, 1))
    if causal:
        vis = vis & (k_pos.reshape(1, 1, -1) <= q_pos[:, :, None])
    return vis


def stripe_state(qg, k_stripe, v_stripe, *, q_pos=None, k_start=None,
                 causal: bool = True, valid_len=None, vis=None,
                 exact: bool = True) -> State:
    """Partial-softmax state of ALL my queries against one K/V stripe.

    ``qg (B, Tq, KH, G, D)`` pre-scaled f32 queries; ``k_stripe /
    v_stripe (B, S, KH, D / Dv)`` one rank's K/V rows; ``q_pos (B|1, Tq)``
    global query positions and ``k_start`` the stripe's first global key
    position (both may be traced — dynamic chunked-prefill offsets mask
    instead of skipping); ``valid_len`` masks padded key rows.  A caller
    that already built the visibility (:func:`stripe_mask`) passes ``vis``
    instead.  A fully masked stripe returns exactly :func:`empty_state`'s
    values.
    """
    B, Tq = qg.shape[:2]
    S = k_stripe.shape[1]
    if vis is None:
        vis = stripe_mask(S, q_pos=q_pos, k_start=k_start, causal=causal,
                          valid_len=valid_len)
    vis = jnp.broadcast_to(vis, (B, Tq, S))
    if exact:
        return _stripe_exact(qg, k_stripe.astype(jnp.float32),
                             v_stripe.astype(jnp.float32),
                             vis.astype(jnp.float32))
    return _stripe_f32(qg, k_stripe, v_stripe, vis.astype(bool))


# --------------------------------------------------------------------------
# merge: fold two partial states
# --------------------------------------------------------------------------

def _merge_f32(a: State, b: State) -> State:
    m1, l1, a1 = a
    m2, l2, a2 = b
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    c1 = jnp.where(jnp.isneginf(m1), 0.0, jnp.exp(m1 - m_safe))
    c2 = jnp.where(jnp.isneginf(m2), 0.0, jnp.exp(m2 - m_safe))
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def _np_merge(m1, l1, a1, m2, l2, a2):
    empty1, empty2 = np.isneginf(m1), np.isneginf(m2)
    m = np.maximum(m1, m2)
    m_safe = np.where(np.isneginf(m), _F32(0), m)
    with np.errstate(invalid="ignore", under="ignore"):
        e1 = np.exp(m1 - m_safe, dtype=_F32)             # -inf max -> 0
        e2 = np.exp(m2 - m_safe, dtype=_F32)
    # An empty side passes the other through VERBATIM (bitwise identity,
    # -0.0 included), not as `x * 1.0 + 0.0 * 0.0`.
    l = np.where(empty2, l1, np.where(empty1, l2, l1 * e1 + l2 * e2))
    acc = np.where(empty2[..., None], a1,
                   np.where(empty1[..., None], a2,
                            a1 * e1[..., None] + a2 * e2[..., None]))
    return m, l, acc


def _np_merge_bwd(m1, m2, gl, gacc):
    empty1, empty2 = np.isneginf(m1), np.isneginf(m2)
    m = np.maximum(m1, m2)
    m_safe = np.where(np.isneginf(m), _F32(0), m)
    with np.errstate(invalid="ignore", under="ignore"):
        e1 = np.exp(m1 - m_safe, dtype=_F32)
        e2 = np.exp(m2 - m_safe, dtype=_F32)
    c1 = np.where(empty2, _F32(1), np.where(empty1, _F32(0), e1))
    c2 = np.where(empty2, _F32(0), np.where(empty1, _F32(1), e2))
    return gl * c1, gl * c2, gacc * c1[..., None], gacc * c2[..., None]


@jax.custom_vjp
def _merge_exact(a: State, b: State) -> State:
    m1, l1, a1 = a
    sd = jax.ShapeDtypeStruct
    shapes = (sd(m1.shape, jnp.float32), sd(l1.shape, jnp.float32),
              sd(a1.shape, jnp.float32))
    return jax.pure_callback(_np_merge, shapes, *a, *b)


def _merge_exact_fwd(a, b):
    return _merge_exact(a, b), (a[0], b[0])


def _merge_exact_bwd(res, ct):
    m1, m2 = res
    _, gl, gacc = ct                         # gm dies here (see module doc)
    sd = jax.ShapeDtypeStruct
    shapes = (sd(gl.shape, jnp.float32), sd(gl.shape, jnp.float32),
              sd(gacc.shape, jnp.float32), sd(gacc.shape, jnp.float32))
    gl1, gl2, ga1, ga2 = jax.pure_callback(_np_merge_bwd, shapes,
                                           m1, m2, gl, gacc)
    return (jnp.zeros_like(m1), gl1, ga1), (jnp.zeros_like(m2), gl2, ga2)


_merge_exact.defvjp(_merge_exact_fwd, _merge_exact_bwd)


def merge_states(a: State, b: State, *, exact: bool = True) -> State:
    """Combine two partial-softmax states (associative; identity =
    :func:`empty_state`).

    Each side is rescaled from its own max to the joint max; a ``-inf``
    max (nothing seen) means that side is empty and the other side passes
    through as-is — a bitwise no-op, which is the property the causal
    step-skip relies on.
    """
    if exact:
        return _merge_exact(tuple(a), tuple(b))
    return _merge_f32(a, b)


# --------------------------------------------------------------------------
# finalize: normalize the folded state
# --------------------------------------------------------------------------

def _finalize_f32(state: State):
    m, l, acc = state
    return acc / jnp.maximum(l, 1e-30)[..., None]


def _np_finalize(l, acc):
    return acc / np.maximum(l, _F32(1e-30))[..., None]


def _np_finalize_bwd(l, acc, ct):
    L = np.maximum(l, _F32(1e-30))
    gacc = ct / L[..., None]
    with np.errstate(divide="ignore", invalid="ignore", under="ignore"):
        gl = -(ct * acc).sum(axis=-1, dtype=_F32) / (L * L)
    gl = np.where(l >= _F32(1e-30), gl, _F32(0))         # dead rows
    return gl, gacc


@jax.custom_vjp
def _finalize_exact(state: State):
    m, l, acc = state
    return jax.pure_callback(
        _np_finalize, jax.ShapeDtypeStruct(acc.shape, jnp.float32), l, acc)


def _finalize_exact_fwd(state):
    m, l, acc = state
    return _finalize_exact(state), (l, acc)


def _finalize_exact_bwd(res, ct):
    l, acc = res
    sd = jax.ShapeDtypeStruct
    shapes = (sd(l.shape, jnp.float32), sd(acc.shape, jnp.float32))
    gl, gacc = jax.pure_callback(_np_finalize_bwd, shapes, l, acc, ct)
    return ((jnp.zeros_like(l), gl, gacc),)


_finalize_exact.defvjp(_finalize_exact_fwd, _finalize_exact_bwd)


def finalize_state(state: State, dtype, exact: bool = True) -> jnp.ndarray:
    """Normalize the folded state to the (B, Tq, H, Dv) attention output.

    Fully masked rows (``l == 0``) come out as zeros, matching the flash
    oracle's ``max(l, 1e-30)`` guard.
    """
    B, Tq, KH, G, Dv = state[2].shape
    out = (_finalize_exact if exact else _finalize_f32)(tuple(state))
    return out.reshape(B, Tq, KH * G, Dv).astype(dtype)


# --------------------------------------------------------------------------
# the hand-written VJP of a whole fold chain
# --------------------------------------------------------------------------
#
# Autodiff through the ring cannot meet the gradient bit contract: the
# transpose machinery accumulates each K/V shard's cotangent contributions
# in whatever association order the surrounding jaxpr dictates, and the
# emulation's ring transpose orders those f32 adds differently from the
# oracle's slice transpose.  So both executions install a custom VJP over
# the WHOLE schedule and build the backward from these pieces, summing
# contributions in one canonical order (own stripe, then clockwise
# deliveries by step, then counter-clockwise).  Elementwise f32 adds of
# the same values in the same order are bit-deterministic — XLA does not
# reassociate float adds — so the two programs agree bitwise.


def finalize_bwd(ct, l, acc):
    """Cotangents ``(gl, gacc)`` of :func:`finalize_state`'s exact
    normalize for output cotangent ``ct (B, Tq, KH, G, Dv)`` f32."""
    sd = jax.ShapeDtypeStruct
    shapes = (sd(l.shape, jnp.float32), sd(acc.shape, jnp.float32))
    return jax.pure_callback(_np_finalize_bwd, shapes, l, acc, ct)


def merge_bwd(m1, m2, gl, gacc):
    """Cotangents ``(gl1, gl2, gacc1, gacc2)`` of one exact merge, from
    the two sides' row maxes (the only residual the rescale needs)."""
    sd = jax.ShapeDtypeStruct
    shapes = (sd(gl.shape, jnp.float32), sd(gl.shape, jnp.float32),
              sd(gacc.shape, jnp.float32), sd(gacc.shape, jnp.float32))
    return jax.pure_callback(_np_merge_bwd, shapes, m1, m2, gl, gacc)


def stripe_bwd(qg, k_stripe, v_stripe, vis, gl, gacc):
    """Cotangents ``(gqg, gk, gv)`` (all f32) of one exact stripe pass."""
    k32 = k_stripe.astype(jnp.float32)
    v32 = v_stripe.astype(jnp.float32)
    mask = jnp.broadcast_to(vis, (qg.shape[0], qg.shape[1],
                                  k_stripe.shape[1])).astype(jnp.float32)
    sd = jax.ShapeDtypeStruct
    shapes = (sd(qg.shape, jnp.float32), sd(k32.shape, jnp.float32),
              sd(v32.shape, jnp.float32))
    return jax.pure_callback(_np_stripe_bwd, shapes,
                             qg, k32, v32, mask, gl, gacc)


def chain_grads(qg, stripes, ct):
    """Backward of ``finalize(fold(empty, stripes))`` for one rank.

    ``stripes``: the fold-order sequence of ``(k_stripe, v_stripe, vis)``;
    ``ct``: the f32 ``(B, Tq, KH, G, Dv)`` output cotangent.  Recomputes
    the exact forward chain (cheap at CI scale, and bit-reproducible by
    construction), walks the merges in reverse, and returns
    ``(gqg, [gk_i], [gv_i])`` — the query cotangent summed over stripes in
    fold order and the per-stripe K/V cotangents (f32, fold order), for
    the caller to route to the stripes' owners and accumulate canonically.
    """
    states, blocks = [], []
    state = empty_state(qg, stripes[0][1])
    for k_str, v_str, vis in stripes:
        blk = stripe_state(qg, k_str, v_str, vis=vis)
        states.append(state)
        blocks.append(blk)
        state = merge_states(state, blk)
    gl, gacc = finalize_bwd(ct, state[1], state[2])
    per_stripe = [None] * len(stripes)
    for i in reversed(range(len(stripes))):
        gl1, gl2, ga1, ga2 = merge_bwd(states[i][0], blocks[i][0], gl, gacc)
        per_stripe[i] = (gl2, ga2)
        gl, gacc = gl1, ga1                  # the empty state's dies at i=0
    gqg, gks, gvs = None, [], []
    for (k_str, v_str, vis), (gl_i, ga_i) in zip(stripes, per_stripe):
        gq_i, gk_i, gv_i = stripe_bwd(qg, k_str, v_str, vis, gl_i, ga_i)
        gqg = gq_i if gqg is None else gqg + gq_i
        gks.append(gk_i)
        gvs.append(gv_i)
    return gqg, gks, gvs

"""Chunked Pallas TPU kernel for the unified linear-recurrence scan.

The sequential scan is re-expressed as chunked matrix algebra so the MXU does
the work (the standard chunked linear-attention factorization):

with L_t = Σ_{s≤t} log a_s inside a chunk of length c,

    Y_intra = mask(R' Q'^T) P          R' = r·e^{L_prev},  Q' = q·e^{-L}
    Y_inter = R' S_0^T
    S_end   = S_0·e^{L_c} + P^T (q·e^{L_c - L})

The chunk axis is the innermost grid dimension — TPU grids iterate it
sequentially, so the running state S lives in a VMEM scratch that persists
across chunk steps (same pattern as the flash-attention accumulators).
exp(-L) is clamped at e^30; decays this aggressive have |true contribution|
< e^-30 and underflow to zero either way.

FLOPs per chunk: 2·c²·N + 2·c²·M + 2·c·M·N (three MXU matmuls) vs the
sequential scan's c rank-1 updates — a ~c× arithmetic-intensity win, which is
why this kernel exists (the paper's Minimod/Cannon story: restructure the
computation so compute overlaps and saturates the unit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["linear_scan_pallas"]

_CLAMP = 30.0


def _scan_kernel(p_ref, q_ref, a_ref, r_ref, y_ref, sfin_ref, s_scr,
                 *, nchunks: int, readout_pre: bool):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    p = p_ref[0].astype(jnp.float32)   # (c, M)
    q = q_ref[0].astype(jnp.float32)   # (c, N)
    a = a_ref[0].astype(jnp.float32)   # (c, N)
    r = r_ref[0].astype(jnp.float32)   # (c, N)
    c = p.shape[0]

    log_a = jnp.log(jnp.maximum(a, 1e-38))
    L = jnp.cumsum(log_a, axis=0)                  # (c, N): L_t
    L_prev = L - log_a                             # L_{t-1} (zero at t=0)
    L_read = L_prev if readout_pre else L

    r_w = r * jnp.exp(L_read)                      # R'
    q_w = q * jnp.exp(jnp.minimum(-L, _CLAMP))     # Q' (clamped)

    att = jax.lax.dot_general(                     # (c, c): Σ_n R'[t,n] Q'[s,n]
        r_w, q_w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (s_idx < t_idx) if readout_pre else (s_idx <= t_idx)
    att = jnp.where(mask, att, 0.0)

    s0 = s_scr[...]                                # (M, N)
    y_intra = jax.lax.dot_general(                 # (c, M)
        att, p, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_inter = jax.lax.dot_general(                 # (c, N) @ (M, N)^T -> (c, M)
        r_w, s0, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_tail = jnp.exp(L[-1:] - L)               # (c, N): ∏_{u>s} a_u
    s_new = s0 * jnp.exp(L[-1])[None, :] + jax.lax.dot_general(
        p, q * decay_tail, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s_scr[...] = s_new

    @pl.when(ic == nchunks - 1)
    def _emit_state():
        sfin_ref[0] = s_new


def linear_scan_pallas(p, q, a, r, s0, *, readout_pre: bool = True,
                       chunk: int = 64, interpret: bool = False):
    """p: (BH, T, M); q, a, r: (BH, T, N); s0: (BH, M, N) (must be zeros —
    the TPU kernel owns the state; pass nonzero s0 only to the ref path).

    Returns (y: (BH, T, M), s_final: (BH, M, N) f32).
    """
    BH, T, M = p.shape
    N = q.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, f"T={T} must be a multiple of chunk={c}"
    nchunks = T // c

    kernel = functools.partial(_scan_kernel, nchunks=nchunks,
                               readout_pre=readout_pre)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(BH, nchunks),
        in_specs=[
            pl.BlockSpec((1, c, M), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, M), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, M, N), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, M), p.dtype),
            jax.ShapeDtypeStruct((BH, M, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((M, N), jnp.float32)],
        interpret=interpret,
    )(p, q, a, r)
    # fold a caller-provided initial state through linearity: the recurrence
    # is affine in S_0, handled exactly by the inter-chunk term of chunk 0 —
    # the kernel assumes S_0 = 0, so reject nonzero states loudly.
    del s0
    return y, s_fin

"""jit'd public wrapper for the unified linear-recurrence scan."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import linear_scan_pallas
from .ref import linear_scan_ref

__all__ = ["linear_scan"]


@functools.partial(
    jax.jit, static_argnames=("readout_pre", "impl", "chunk", "interpret")
)
def linear_scan(
    p, q, a, r,
    s0=None,
    *,
    readout_pre: bool = True,
    impl: str = "ref",
    chunk: int = 64,
    interpret: bool = True,
):
    """p: (BH, T, M); q, a, r: (BH, T, N); s0: (BH, M, N) or None (zeros).

    Returns (y: (BH, T, M), s_final: (BH, M, N) f32).  The Pallas path
    requires s0=None (training chunks start from zero state); decode steps
    carry state through the ref path (T=1, scan cost is trivial).
    """
    BH, _, M = p.shape
    N = q.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((BH, M, N), jnp.float32)
    elif impl == "pallas":
        raise ValueError("pallas linear_scan requires s0=None (zero state)")
    if impl == "ref":
        return linear_scan_ref(p, q, a, r, s0, readout_pre=readout_pre)
    if impl == "pallas":
        return linear_scan_pallas(
            p, q, a, r, None, readout_pre=readout_pre, chunk=chunk,
            interpret=interpret,
        )
    raise ValueError(f"unknown impl {impl!r}")

"""Pure-jnp oracle for the unified linear-recurrence scan.

One recurrence covers both RWKV6 time-mix and Mamba2 SSD (DESIGN.md §3):

    S_t = S_{t-1} * a_t[None, :] + p_t ⊗ q_t          S: (M, N)
    y_t = (S_{t-1} if readout_pre else S_t) @ r_t      y: (M,)

* RWKV6:  M = head v-dim, N = head k-dim, a = data-dependent decay w_t,
          p = v_t, q = k_t, r = r_t, readout_pre=True (the diag(u) bonus
          term is added outside — it is pointwise).
* Mamba2: M = head dim, N = ssm state, a = exp(Δt·A) (broadcast over N),
          p = Δt·x_t, q = B_t, r = C_t, readout_pre=False (D·x added
          outside).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["linear_scan_ref"]


def linear_scan_ref(p, q, a, r, s0, *, readout_pre: bool = True):
    """p: (BH, T, M); q, a, r: (BH, T, N); s0: (BH, M, N).

    Returns (y: (BH, T, M) in p.dtype, s_final: (BH, M, N) f32).
    """
    pf, qf, af, rf = (x.astype(jnp.float32) for x in (p, q, a, r))
    # inherit the inputs' varying manual axes (shard_map vma; no-op outside)
    s0 = s0.astype(jnp.float32) + 0.0 * (
        pf.reshape(-1)[0] + qf.reshape(-1)[0] + af.reshape(-1)[0]
        + rf.reshape(-1)[0])

    def step(s, inp):
        pt, qt, at, rt = inp
        s_new = s * at[None, :] + pt[:, None] * qt[None, :]
        y = (s if readout_pre else s_new) @ rt
        return s_new, y

    def scan_one(p1, q1, a1, r1, s1):
        s_fin, ys = jax.lax.scan(step, s1, (p1, q1, a1, r1))
        return ys, s_fin

    ys, s_fin = jax.vmap(scan_one)(pf, qf, af, rf, s0)
    return ys.astype(p.dtype), s_fin

from .ops import moe_dispatch  # noqa: F401
from .ref import measure_expert_load, moe_ref, route_topk  # noqa: F401

"""Oracles + routing helpers for the MoE dispatch kernel family.

The single-device oracle (:func:`moe_ref`) computes the dropless top-k MoE
exactly: every (token, choice) pair reaches its expert, no capacity, no
dispatch.  Both the fused one-sided dispatch and the host collective path
are tested against it — the fused path must match it *bit for bit* under
load-imbalanced routing because dropless dispatch is a pure data movement.

:func:`route_topk` is the router of :func:`repro.models.layers.moe_block`
factored out (same f32 softmax, same top-k renormalization), and
:func:`measure_expert_load` turns concrete routing into the per-expert
load vector :meth:`~repro.kernels.plan.OverlapPlanner.plan_alltoall` sizes
the asymmetric PGAS landing regions from.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["expert_mlp_ref", "route_topk", "measure_expert_load", "moe_ref"]

F32 = jnp.float32


def expert_mlp_ref(x, wg, wu, wd):
    """Grouped silu-gated expert MLP on per-expert row blocks.

    ``x (E, C, d)``, ``wg/wu (E, d, f)``, ``wd (E, f, d)`` -> ``(E, C, d)``.
    The einsum form matches ``moe_block``'s expert GEMMs exactly, so every
    dispatch implementation runs its rows through identical numerics.
    """
    h = jnp.einsum("ecd,edf->ecf", x, wg)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def route_topk(toks, router, k: int):
    """``moe_block``'s router: f32 softmax, top-k, renormalized weights.

    ``toks (t, d)``, ``router (d, E)`` -> ``(top_w, top_e)`` each ``(t, k)``.
    """
    logits = jnp.dot(toks.astype(F32), router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_e


def measure_expert_load(top_e, E: int, *,
                        sources: Optional[int] = None) -> Tuple[int, ...]:
    """Per-expert landing load from concrete routing (host-side numpy).

    ``top_e`` is the routed expert index array — either one source rank's
    ``(t_loc, k)`` choices, or all sources stacked as ``(sources, t_loc,
    k)``.  Returns, per expert, the MAXIMUM rows any single source routes
    to it: what one per-source slice of the expert's PGAS landing region
    must absorb for the dispatch to be dropless.  Feed the result to
    :meth:`~repro.kernels.plan.OverlapPlanner.plan_alltoall` as ``loads``.
    """
    a = np.asarray(top_e)
    if a.ndim == 2:
        a = a[None]
    elif sources is not None and a.shape[0] != sources:
        raise ValueError(f"expected {sources} sources, got {a.shape[0]}")
    counts = np.zeros((a.shape[0], E), dtype=np.int64)
    for s in range(a.shape[0]):
        idx, n = np.unique(a[s].reshape(-1), return_counts=True)
        counts[s, idx] = n
    return tuple(int(v) for v in counts.max(axis=0))


def moe_ref(toks, top_e, top_w, wg, wu, wd):
    """Single-device dropless oracle: every choice reaches its expert.

    ``toks (t, d)``; ``top_e/top_w (t, k)``; ``wg/wu (E, d, f)``;
    ``wd (E, f, d)`` — the FULL expert weights (all E experts).  Returns
    the combined ``(t, d)`` output in ``toks.dtype``.
    """
    t = toks.shape[0]
    E = wg.shape[0]
    x = jnp.broadcast_to(toks[None], (E, t, toks.shape[1]))
    outs = expert_mlp_ref(x, wg, wu, wd).astype(toks.dtype)   # (E, t, d)
    picked = outs[top_e, jnp.arange(t)[:, None]]              # (t, k, d)
    gates = top_w.astype(toks.dtype)[..., None]
    return (picked * gates).sum(axis=1)

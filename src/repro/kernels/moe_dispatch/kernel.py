"""Pallas grouped expert-MLP kernel — the compute core of the fused dispatch.

One grid step per local expert: the expert's landed rows (all sources,
padded to the plan's ``cap_pad``) run through the silu-gated MLP with f32
accumulation on the MXU.  The fused TPU dispatch kernel inlines the same
loop between its remote copies; this standalone entry point exists so the
compute core is testable in the Pallas interpreter against
:func:`repro.kernels.moe_dispatch.ref.expert_mlp_ref` without any
collective machinery.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import resolve_interpret

__all__ = ["expert_mlp_pallas"]


def _expert_mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[0]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)


def expert_mlp_pallas(x, wg, wu, wd, *, interpret: Optional[bool] = None):
    """``x (E, C, d)``, ``wg/wu (E, d, f)``, ``wd (E, f, d)`` -> ``(E, C, d)``.

    Grid over experts; each step holds one expert's rows and weights in
    VMEM.  ``interpret=None`` resolves from the backend at call time.
    """
    E, C, d = x.shape
    f = wg.shape[2]
    return pl.pallas_call(
        _expert_mlp_kernel,
        grid=(E,),
        in_specs=[
            pl.BlockSpec((1, C, d), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, f, d), lambda e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, d), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        interpret=resolve_interpret(interpret),
    )(x, wg, wu, wd)

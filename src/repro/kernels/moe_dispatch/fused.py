"""Fused dropless MoE dispatch — in-kernel all-to-all over asymmetric regions.

The host collective path (``moe_block``'s ``"a2a"`` mode) exposes the full
token exchange on both sides of the expert GEMMs and silently drops
capacity overflow.  This module is the DiOMP treatment of the same traffic:

* token→expert routing scatters rows into per-expert landing layouts whose
  capacities are **asymmetric** — sized per expert from measured load by
  :meth:`~repro.kernels.plan.OverlapPlanner.plan_alltoall` (largest-
  remainder split, the Minimod decomposition), so the dispatch is
  **dropless** by construction (``caps[e] >= load[e]``);
* the exchange is a ring of one-sided ``ompx_put``\\ s: step ``s`` puts the
  block for the rank ``s + 1`` ahead, runs the expert GEMMs on the block
  that landed from the rank ``s`` behind, and puts the *previous* result
  straight back to its source — the return combine rides UNDER the current
  GEMM;
* every put is recorded against both the OMPCCL byte log and the
  RMATracker's MoE dispatch/combine windows
  (:func:`repro.core.rma.dispatch_window_names`), so tests assert exact
  put-traffic parity like the Minimod driver does.

Two executions of ONE schedule (:meth:`~repro.kernels.plan.AllToAllPlan.
schedule`): the compiled TPU kernel (``pltpu.make_async_remote_copy``
started before each step's GEMMs) and the differentiable interpret
emulation every CPU CI run and training step traces through.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.backends import payload_bytes
from repro.core.groups import DiompGroup
from repro.core.rma import dispatch_window_names, ompx_fence, ompx_put
from repro.core.vma import zeros_varying
from repro.kernels.plan import AllToAllPlan
from .ref import expert_mlp_ref

__all__ = [
    "dispatch_buffers",
    "fused_moe_dispatch_interpret",
    "fused_moe_dispatch_tpu",
]


# ---------------------------------------------------------------------------
# shared routing -> buffer layout (both executions, and the oracle tests)
# ---------------------------------------------------------------------------


def dispatch_buffers(toks, top_e, top_w, plan: AllToAllPlan):
    """Scatter routed rows into the padded per-destination wire blocks.

    Slot assignment is ``moe_block``'s running-index cumsum, but checked
    against the plan's per-expert **asymmetric** capacity instead of one
    global ``cap`` — with capacities sized from measured load the ``keep``
    mask is all-true and the dispatch drops nothing.  Returns

    * ``buf (ep, E_loc, cap_pad, d)`` — destination-rank-major wire
      blocks (global expert order; rows beyond ``caps[e]`` stay zero),
    * ``addr (t_loc·k,)`` — flat row address of each (token, choice) in
      the global ``(E·cap_pad, d)`` landing layout (combine unpermute),
    * ``gates (t_loc·k, 1)`` — combine weights, zeroed for dropped rows,
    * ``dropped ()`` — f32 count of capacity-overflow drops (0 when the
      plan is dropless).
    """
    t_loc, d = toks.shape
    k = top_e.shape[-1]
    E, C = plan.E, plan.cap_pad

    e_flat = top_e.reshape(-1)                                # (t_loc*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    slot = slot.sum(-1)
    caps = jnp.asarray(plan.caps, dtype=jnp.int32)[e_flat]
    keep = slot < caps
    addr = e_flat * C + jnp.clip(slot, 0, C - 1)

    buf = zeros_varying((E * C, d), toks.dtype, toks)
    src = jnp.repeat(toks, k, axis=0)
    buf = buf.at[jnp.where(keep, addr, E * C - 1)].add(
        jnp.where(keep[:, None], src, 0.0).astype(toks.dtype), mode="drop")
    gates = (keep[:, None] * top_w.reshape(-1)[:, None]).astype(toks.dtype)
    dropped = jnp.sum(~keep).astype(jnp.float32)
    return buf.reshape(plan.ep, plan.E_loc, C, d), addr, gates, dropped


def _combine(full, addr, gates, t_loc: int, d: int):
    """Unpermute the landed expert outputs back to (token, choice) order
    and gate-combine: ``full (ep, E_loc, C, d)`` -> ``(t_loc, d)``."""
    ret = full.reshape(-1, d)
    picked = ret[addr] * gates
    return picked.reshape(t_loc, -1, d).sum(axis=1)


# ---------------------------------------------------------------------------
# the interpret / CPU emulation: identical schedule over ompx_put
# ---------------------------------------------------------------------------


def fused_moe_dispatch_interpret(
    toks, top_e, top_w, wg, wu, wd, group: DiompGroup, *,
    plan: AllToAllPlan, mlp: Optional[Callable] = None,
):
    """Execute :meth:`AllToAllPlan.schedule` with ``ompx_put`` as the RDMA.

    Every dispatch put starts BEFORE the GEMM it overlaps and every
    combine put rides under the next step's GEMM — the same order the TPU
    kernel hard-codes, which is what lets XLA's async collective-permute
    hide the exchange.  Differentiable end to end (ppermute, scatter-add,
    gather and the fence's identity-JVP all transpose), so this is the
    path the training step traces on CPU.  Returns ``(combined (t_loc,
    d), dropped ())``.
    """
    if mlp is None:
        mlp = expert_mlp_ref
    from repro.core.context import default_context

    ax = group.axes[0]
    ep, E_loc, C = plan.ep, plan.E_loc, plan.cap_pad
    t_loc, d = toks.shape
    me = lax.axis_index(ax)

    buf, addr, gates, dropped = dispatch_buffers(toks, top_e, top_w, plan)

    tracker = default_context().rma
    dwin, cwin = dispatch_window_names(group, ep)

    landed = {0: lax.dynamic_slice(
        buf, (me, 0, 0, 0), (1, E_loc, C, d))[0]}
    outs = {}
    rets = {}
    for phase, s in plan.schedule():
        if phase == "put":
            # my block for the rank s ahead, started before this step's GEMM
            blk = lax.dynamic_slice(
                buf, (lax.rem(me + s, ep), 0, 0, 0), (1, E_loc, C, d))[0]
            tracker.ensure(dwin[s - 1])
            tracker.on_put(dwin[s - 1], payload_bytes(blk))
            landed[s] = ompx_put(blk, group, shift=s)
        elif phase == "fence":
            landed[s] = ompx_fence(landed[s])
            tracker.on_fence(dwin[s - 1])
            tracker.on_read(dwin[s - 1])
        elif phase == "gemm":
            outs[s] = mlp(landed[s], wg, wu, wd).astype(toks.dtype)
        elif phase == "ret":
            # previous result back to its source, under the next GEMM
            tracker.ensure(cwin[s - 1])
            tracker.on_put(cwin[s - 1], payload_bytes(outs[s]))
            rets[s] = ompx_put(outs[s], group, shift=-s)
        elif phase == "fence_ret":
            if rets:
                order = sorted(rets)
                fenced = ompx_fence(*[rets[s] for s in order])
                if len(order) == 1:
                    fenced = (fenced,)
                rets = dict(zip(order, fenced))
                tracker.on_fence(*cwin)
                for w in cwin:
                    tracker.on_read(w)
        else:  # pragma: no cover - schedule() emits only the above
            raise ValueError(phase)

    # assemble the landed returns in home-rank-major (global expert) order
    full = zeros_varying((ep, E_loc, C, d), toks.dtype, toks)
    full = lax.dynamic_update_slice(full, outs[0][None], (me, 0, 0, 0))
    for s, blk in rets.items():
        full = lax.dynamic_update_slice(
            full, blk[None], (lax.rem(me + s, ep), 0, 0, 0))
    return _combine(full, addr, gates, t_loc, d), dropped


# ---------------------------------------------------------------------------
# the TPU kernel: one pallas_call for dispatch + GEMMs + combine
# ---------------------------------------------------------------------------


def _grouped_mlp(x, wg_ref, wu_ref, wd_ref):
    """In-kernel grouped expert MLP on one landed block (E_loc, C, d)."""
    outs = []
    for e in range(wg_ref.shape[0]):
        g = jnp.dot(x[e], wg_ref[e], preferred_element_type=jnp.float32)
        u = jnp.dot(x[e], wu_ref[e], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        outs.append(jnp.dot(h, wd_ref[e],
                            preferred_element_type=jnp.float32))
    return jnp.stack(outs).astype(x.dtype)


def _fused_dispatch_kernel(buf_ref, wg_ref, wu_ref, wd_ref, o_ref,
                           stage, ret_stage, send_sems, recv_sems,
                           ret_send_sems, ret_recv_sems,
                           *, axis: str, plan: AllToAllPlan, slots: int):
    """Kernel body; the schedule is baked statically, ranks are traced.

    ``stage``: VMEM (slots, E_loc, C, d) landing slots for the dispatch
    ring (slot ``s % slots`` holds the block from the rank ``s`` behind);
    ``ret_stage`` the symmetric combine staging.  Every device runs the
    same code, so one ``make_async_remote_copy`` per step realizes both my
    outgoing put (to ``me + s``) and the incoming landing (from
    ``me - s``); combine copies write the remote ``o_ref`` at *my* rank
    index — the home-rank-major return layout the host-side combine reads.
    """
    ep = plan.ep
    me = lax.axis_index(axis)

    # startup barrier: every peer entered the kernel before any RDMA
    # touches its stage buffers
    barrier = pltpu.get_barrier_semaphore()
    for r in range(1, ep):
        pltpu.semaphore_signal(barrier, inc=1,
                               device_id=(lax.rem(me + r, ep),),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, ep - 1)

    in_flight = {}      # ring offset -> dispatch rdma (my landing from me-s)
    ret_flight = {}     # staging slot -> combine rdma (waited before slot
    #                     reuse and at the final fence)
    for phase, s in plan.schedule():
        if phase == "put":
            slot = s % slots
            rdma = pltpu.make_async_remote_copy(
                src_ref=buf_ref.at[lax.rem(me + s, ep)],
                dst_ref=stage.at[slot],
                send_sem=send_sems.at[slot], recv_sem=recv_sems.at[slot],
                device_id=(lax.rem(me + s, ep),),
                device_id_type=pltpu.DeviceIdType.LOGICAL)
            rdma.start()
            in_flight[s] = rdma
        elif phase == "fence":
            # ONLY step s's landing: the put for s+1 stays in flight under
            # this step's GEMM — that is the overlap
            in_flight.pop(s).wait()
        elif phase == "gemm":
            slot = s % slots
            if slot in ret_flight:   # combine still reading this slot
                ret_flight.pop(slot).wait()
            x = buf_ref[me] if s == 0 else stage[slot]
            y = _grouped_mlp(x, wg_ref, wu_ref, wd_ref)
            if s == 0:
                o_ref[me] = y
            else:
                ret_stage[slot] = y
        elif phase == "ret":
            slot = s % slots
            rdma = pltpu.make_async_remote_copy(
                src_ref=ret_stage.at[slot],
                dst_ref=o_ref.at[me],
                send_sem=ret_send_sems.at[slot],
                recv_sem=ret_recv_sems.at[slot],
                device_id=(lax.rem(me - s + ep, ep),),
                device_id_type=pltpu.DeviceIdType.LOGICAL)
            rdma.start()
            ret_flight[slot] = rdma
        elif phase == "fence_ret":
            for rdma in ret_flight.values():
                rdma.wait()
            ret_flight = {}


def fused_moe_dispatch_tpu(toks, top_e, top_w, wg, wu, wd,
                           group: DiompGroup, *, plan: AllToAllPlan):
    """The compiled fused kernel (requires a real TPU backend).

    Restriction recorded here rather than hidden: the EP group must be a
    single mesh axis (``device_id`` is the logical index along it).  The
    routing scatter and the gated combine stay outside the kernel (cheap,
    token-local); the kernel owns the overlapped exchange + GEMMs.
    """
    ep, E_loc, C = plan.ep, plan.E_loc, plan.cap_pad
    t_loc, d = toks.shape
    f = wg.shape[2]
    slots = max(plan.slots, min(ep, 3))

    buf, addr, gates, dropped = dispatch_buffers(toks, top_e, top_w, plan)
    full = pl.pallas_call(
        functools.partial(_fused_dispatch_kernel, axis=group.axes[0],
                          plan=plan, slots=slots),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)] * 4,
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM),
        out_shape=jax.ShapeDtypeStruct((ep, E_loc, C, d), toks.dtype),
        scratch_shapes=[
            pltpu.VMEM((slots, E_loc, C, d), toks.dtype),
            pltpu.VMEM((slots, E_loc, C, d), toks.dtype),
            pltpu.SemaphoreType.DMA((slots,)),
            pltpu.SemaphoreType.DMA((slots,)),
            pltpu.SemaphoreType.DMA((slots,)),
            pltpu.SemaphoreType.DMA((slots,)),
        ],
        compiler_params=pltpu.TPUCompilerParams(collective_id=1),
    )(buf, wg, wu, wd)
    return _combine(full, addr, gates, t_loc, d), dropped

"""MoE dispatch entry point — the op ``moe_block`` routes through.

``moe_dispatch`` is the dropless one-sided counterpart of the host
``ompccl.alltoall`` capacity path: same layout contract (inside shard_map,
per-rank tokens + this rank's expert weights), the exchange realized as
the :class:`~repro.kernels.plan.AllToAllPlan` ring of one-sided puts with
the return combine overlapped under the expert GEMMs.

Implementation selection mirrors :mod:`repro.kernels.ring_matmul.ops`:

* ``impl="fused"`` — the overlapped schedule: compiled in-kernel RDMA on
  TPU, the differentiable step-for-step emulation elsewhere (and whenever
  a custom ``mlp`` is supplied);
* ``impl="host"``  — the same one-sided traffic serialized (all dispatch
  puts, fence, GEMMs, all combine puts, fence): the benchmark's middle
  mode, overlap left to the XLA scheduler.

Routing stats (``moe_dropped`` / ``moe_routed``) are recorded into the
active :class:`~repro.core.context.DispatchStats` frame; on a plan sized
from measured load the dropped count is identically zero — the property
``tests/test_moe_fused.py`` pins.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.compat import axis_size
from repro.core.groups import DiompGroup
from repro.kernels.plan import (AllToAllPlan, default_planner,
                                resolve_dispatch_impl, resolve_interpret)
from .fused import fused_moe_dispatch_interpret, fused_moe_dispatch_tpu

__all__ = ["moe_dispatch"]


def moe_dispatch(toks, top_e, top_w, wg, wu, wd, group: DiompGroup, *,
                 impl: Optional[str] = None,
                 plan: Optional[AllToAllPlan] = None,
                 interpret: Optional[bool] = None,
                 mlp: Optional[Callable] = None):
    """Dropless expert-parallel dispatch + MLP + combine (inside shard_map).

    ``toks (t_loc, d)`` — my token rows; ``top_e/top_w (t_loc, k)`` — my
    routing; ``wg/wu (E_loc, d, f)``, ``wd (E_loc, f, d)`` — MY experts'
    weights.  Returns the gate-combined ``(t_loc, d)`` output.

    ``plan`` defaults to the process planner's worst-case dropless plan
    (``caps[e] = t_loc``: no measurement is available at trace time);
    drivers that measured routing pass a load-sized plan and get the
    asymmetric wire/region sizes.  The EP group must be a single mesh
    axis (the put ring); ``plan.overlap`` is forced to match ``impl``.
    """
    impl = resolve_dispatch_impl(impl)
    if impl == "a2a":
        raise ValueError(
            "impl='a2a' is the host collective path inside moe_block; "
            "moe_dispatch implements the one-sided 'host'/'fused' modes")
    if len(group.axes) != 1:
        raise ValueError(
            f"moe_dispatch needs a single-axis EP group, got {group.axes}")
    ep = axis_size(group.axes[0])
    t_loc, d = toks.shape
    k = top_e.shape[-1]
    E = wg.shape[0] * ep
    if plan is None:
        plan = default_planner().plan_alltoall(
            t_loc, d, k, E, ep, toks.dtype, overlap=(impl == "fused"))
    if plan.ep != ep:
        raise ValueError(f"plan for ep={plan.ep} used on a ring of {ep}")
    if plan.E != E:
        raise ValueError(f"plan for E={plan.E} used with E={E}")
    if plan.overlap != (impl == "fused"):
        plan = dataclasses.replace(plan, overlap=(impl == "fused"))

    if resolve_interpret(interpret) or mlp is not None:
        combined, dropped = fused_moe_dispatch_interpret(
            toks, top_e, top_w, wg, wu, wd, group, plan=plan, mlp=mlp)
    else:
        combined, dropped = fused_moe_dispatch_tpu(
            toks, top_e, top_w, wg, wu, wd, group, plan=plan)

    from repro.core.context import default_context

    default_context().dispatch_stats.record(
        moe_dropped=dropped,
        moe_routed=dropped * 0 + t_loc * k)  # varying like dropped
    return combined

"""Public wrapper for flash attention.

Layout contract with the model zoo: (B, T, H, D) in, (B, T, H, Dv) out.
``impl='ref'`` runs the pure-jnp blockwise oracle (used on CPU, inside the
shard_map'd model steps, and for the dry-run HLO) and accepts *traced*
``q_offset`` / ``valid_len`` (decode).  ``impl='pallas'`` runs the TPU kernel
(``interpret=True`` executes the kernel body in Python on CPU for
validation) and requires static offsets — traced ones raise here, at the
API boundary, instead of failing inside Mosaic.  ``impl='ring'`` is the
sequence-parallel path: per-rank K/V shards rotate through
:func:`~repro.kernels.ring_attention.ring_attention` (requires ``group``;
``q_sharded`` picks the training vs chunked-prefill query layout).

``block=None`` (the default) asks the shared
:class:`~repro.kernels.plan.OverlapPlanner` for the largest block whose
tiles still double-buffer inside the VMEM budget — the
``StreamPool.plan_slots`` contract; ``interpret=None`` resolves from the
backend at call time (compiled on TPU, interpreted elsewhere).

Deliberately not jitted here: the callers (model steps) are jitted.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.plan import default_planner, resolve_interpret
from .kernel import flash_attention_pallas
from .ref import flash_attention_ref

__all__ = ["flash_attention"]


def _traced(val) -> bool:
    return isinstance(val, jax.core.Tracer)


def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    q_offset=0,
    prefix_len: int = 0,
    scale: Optional[float] = None,
    impl: str = "ref",
    block: Optional[int] = None,
    valid_len=None,
    interpret: Optional[bool] = None,
    group=None,
    q_sharded: bool = True,
):
    """q: (B, Tq, H, D); k: (B, Tk, KH, D); v: (B, Tk, KH, Dv)."""
    if impl == "ring":
        from repro.kernels.ring_attention import ring_attention

        if group is None:
            raise ValueError(
                "impl='ring' is the sequence-parallel path: pass the "
                "DiompGroup whose axis the K/V stripes rotate over")
        if prefix_len:
            raise ValueError(
                "impl='ring' does not take prefix_len: bidirectional "
                "prefix attention needs the full K/V, use the all-gather "
                "path (seq_parallel='allgather') for prefix architectures")
        return ring_attention(
            q, k, v, group, causal=causal, q_offset=q_offset,
            valid_len=valid_len, scale=scale, q_sharded=q_sharded,
            interpret=interpret)
    if block is None:
        block = default_planner().plan_attention_block(
            q.shape[1], k.shape[1], q.shape[-1], v.shape[-1], q.dtype)
    if impl == "ref":
        return flash_attention_ref(
            q, k, v, causal=causal, q_offset=q_offset, prefix_len=prefix_len,
            scale=scale, block=block, valid_len=valid_len,
        )
    if impl == "pallas":
        if _traced(q_offset) or _traced(valid_len):
            traced = [name for name, val in
                      (("q_offset", q_offset), ("valid_len", valid_len))
                      if _traced(val)]
            raise ValueError(
                f"impl='pallas' bakes q_offset/valid_len into its block "
                f"masks at trace time, but {' and '.join(traced)} "
                f"{'are' if len(traced) > 1 else 'is'} traced.  Pass "
                f"static Python ints (the static-offsets contract), or "
                f"use impl='ref' / the ring emulation for dynamic "
                f"chunked-prefill offsets.")
        qt = q.transpose(0, 2, 1, 3)  # (B, H, Tq, D)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, q_offset=q_offset, prefix_len=prefix_len,
            scale=scale, block_q=block, block_k=block, valid_len=valid_len,
            interpret=resolve_interpret(interpret),
        )
        return out.transpose(0, 2, 1, 3)
    raise ValueError(f"unknown impl {impl!r}")

"""Pallas TPU flash attention (GQA, causal/prefix-LM, decode offsets).

Tiling: grid = (B, H, Tq/bq, Tk/bk); the Tk dimension is innermost and TPU
grids execute it sequentially, so the online-softmax state (running max,
denominator, accumulator) lives in VMEM scratch and persists across Tk steps.
GQA needs no KV copy: the k/v BlockSpec index_map folds the q-head -> kv-head
mapping (h // group) so each q-head grid row DMAs its group's KV block only.

VMEM working set per step: q tile (bq, D) + k/v tiles (bk, D) + scores
(bq, bk) + accumulators (bq, D) — for bq = bk = 256, D = 128 in f32 that is
~0.7 MiB, far under the ~16 MiB/core budget, leaving room for the pipeline's
double buffering (the StreamPool.plan_slots contract).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, nk: int, causal: bool, q_offset: int,
    prefix_len: int, valid_len: int,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D) — scale pre-folded
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(                      # (bq, bk) on the MXU
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    vis = k_pos < valid_len
    if causal:
        vis &= (k_pos <= q_pos) | ((k_pos < prefix_len) & (q_pos < prefix_len))
    s = jnp.where(vis, s, NEG_INF)

    m_prev = m_scr[...]                           # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(vis, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v,
    *,
    causal: bool = True,
    q_offset: int = 0,
    prefix_len: int = 0,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 256,
    valid_len: Optional[int] = None,
    interpret: bool = False,
):
    """q: (B, H, Tq, D); k: (B, KH, Tk, D); v: (B, KH, Tk, Dv) -> (B, H, Tq, Dv).

    Static q_offset/valid_len only (the kernel bakes the masks); decode loops
    with traced offsets use the ref path.
    """
    B, H, Tq, D = q.shape
    KH, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    assert H % KH == 0
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    if valid_len is None:
        valid_len = Tk

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    # pad to tile multiples (padded keys masked by valid_len / positions)
    pq, pk = (-Tq) % bq, (-Tk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = q.shape[2] // bq, k.shape[2] // bk

    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)

    kernel = functools.partial(
        _attn_kernel,
        bq=bq, bk=bk, nk=nk, causal=causal, q_offset=q_offset,
        prefix_len=prefix_len, valid_len=min(valid_len, Tk),
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, q.shape[2], Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qs, k, v)
    if pq:
        out = out[:, :, :Tq]
    return out

"""Pure-jnp oracle for blockwise (flash) attention with GQA.

This is both the numerical ground truth for the Pallas kernel and the
memory-safe attention used on non-TPU backends: it never materializes the
full (Tq, Tk) score matrix — KV is consumed in blocks with an online
softmax, so peak memory is O(Tq · block) per head.

Supports:
* GQA (q heads grouped over kv heads),
* causal masking with a query position offset (decode: Tq=1, offset=cache
  length), and a bidirectional prefix window (PaliGemma prefix-LM),
* variable valid KV length (padded caches),
* f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def _block_update(carry, kv, q, *, causal, q_offset, prefix_len, block, valid_len):
    """Online-softmax update for one KV block.

    ``q_offset`` / ``valid_len`` may be scalars or (B,) vectors (per-slot
    decode positions under continuous batching).
    """
    m_prev, l_prev, acc_prev, j = carry
    k_blk, v_blk = kv  # (B, block, KH, D)

    B, Tq, KH, G, D = q.shape
    # scores: (B, Tq, KH, G, block), f32
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k_blk.astype(jnp.float32))

    k_pos = j * block + jnp.arange(block)          # (block,)
    q_off = jnp.asarray(q_offset)
    v_len = jnp.asarray(valid_len)
    # broadcast to (B, Tq, block)
    q_pos = (q_off.reshape(-1, 1, 1) + jnp.arange(Tq).reshape(1, -1, 1))
    vis = k_pos.reshape(1, 1, -1) < v_len.reshape(-1, 1, 1)
    if causal:
        # bidirectional inside the prefix window, causal after it
        vis = vis & ((k_pos.reshape(1, 1, -1) <= q_pos) | (
            (k_pos.reshape(1, 1, -1) < prefix_len) & (q_pos < prefix_len)))
    vis = jnp.broadcast_to(vis, (B, Tq, block))
    s = jnp.where(vis[:, :, None, None, :], s, -jnp.inf)

    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # guard: fully-masked rows keep m = -inf; use a safe subtrahend there
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vis[:, :, None, None, :], p, 0.0)
    scale = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_prev * scale + p.sum(axis=-1)
    acc_new = acc_prev * scale[..., None] + jnp.einsum(
        "bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32)
    )
    return (m_new, l_new, acc_new, j + 1), None


def flash_attention_ref(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_offset=0,
    prefix_len: int = 0,
    scale: Optional[float] = None,
    block: int = 512,
    valid_len=None,
):
    """q: (B, Tq, H, D); k: (B, Tk, KH, D); v: (B, Tk, KH, Dv) -> (B, Tq, H, Dv).

    ``q_offset`` and ``valid_len`` may be traced scalars (decode steps pass
    the running cache position).  Dv may differ from D (MLA).
    """
    B, Tq, H, D = q.shape
    Tk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    assert H % KH == 0, (H, KH)
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    if valid_len is None:
        valid_len = Tk
    block = min(block, Tk)

    qg = (q.astype(jnp.float32) * scale).reshape(B, Tq, KH, G, D)

    pad = (-Tk) % block
    if pad:
        k = jnp.concatenate([k, jnp.zeros((B, pad, KH, D), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, KH, Dv), v.dtype)], axis=1)
    nblk = k.shape[1] // block
    kb = k.reshape(B, nblk, block, KH, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, KH, Dv).transpose(1, 0, 2, 3, 4)

    # carries derive from q/v so their varying-manual-axes match the scan
    # body outputs under shard_map (see repro.core.vma)
    tag = (qg.reshape(-1)[0] * 0) + (v.reshape(-1)[0] * 0).astype(jnp.float32)
    m0 = jnp.full((B, Tq, KH, G), -jnp.inf, jnp.float32) + tag
    l0 = jnp.zeros((B, Tq, KH, G), jnp.float32) + tag
    a0 = jnp.zeros((B, Tq, KH, G, Dv), jnp.float32) + tag

    step = functools.partial(
        _block_update,
        q=qg,
        causal=causal,
        q_offset=q_offset,
        prefix_len=prefix_len,
        block=block,
        valid_len=valid_len,
    )
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kb, vb))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).reshape(B, Tq, H, Dv)
    return out.astype(q.dtype)

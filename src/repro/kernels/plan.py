"""OverlapPlanner — the §3.2 bounded-concurrency contract made concrete.

``StreamPool.plan_slots`` answers ONE question ("how many DMA buffers may a
kernel keep in flight for a given working set?"); this module turns that
answer into the *concrete* slot/tile plans the Pallas kernels execute, so the
documented contract ("plan_slots is queried by the kernels' ops.py wrappers")
is real rather than aspirational:

* :class:`RingPlan` — the full schedule of the fused collective matmul: how
  many VMEM stripe slots per ring direction, which stripe each step computes,
  which buffers each step forwards.  The bidirectional ring covers the
  ``n - 1`` remote stripes in ``ceil((n - 1) / 2)`` exchange steps: the
  clockwise stream serves the "left half" of the ring (sources behind me),
  the counter-clockwise stream the "right half" (sources ahead), and both
  ICI link directions carry one stripe per step.
* matmul tile / flash-attention block / stencil slab planning — each kernel's
  working set is sized against the VMEM budget with ``plan_slots`` buffers
  reserved for the pipeline, replacing the former hardcoded defaults.

The planner is deliberately cheap and deterministic: everything is derived
from static shapes, so plans are computed at trace time and baked into the
unrolled schedules/kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.streams import MAX_ACTIVE_STREAMS_DEFAULT, StreamPool

__all__ = [
    "AllToAllPlan",
    "AttentionRingPlan",
    "RingStep",
    "RingPlan",
    "HaloPlan",
    "OverlapPlanner",
    "default_planner",
    "resolve_interpret",
    "resolve_ring_impl",
    "resolve_dispatch_impl",
    "resolve_seq_parallel",
    "split_extents",
]

# Per-core VMEM a kernel may plan against.  Real v5e cores have ~16 MiB more,
# but the compiler needs headroom for spills and the pipeline's own buffers.
VMEM_BUDGET_DEFAULT = 16 * 2**20


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Interpret mode resolved from the backend AT CALL TIME.

    ``None`` (the default everywhere) means "compile on TPU, interpret
    elsewhere" — the fast path is never silently interpreted on real
    hardware, and CPU CI exercises the identical kernel bodies in the
    Pallas interpreter.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def resolve_ring_impl(impl: Optional[str]) -> str:
    """Resolve a ring-matmul implementation knob to a concrete mode.

    ``"auto"``/None pick the fused bidirectional schedule; explicit
    ``"host"`` (unidirectional XLA-overlap loop) and ``"fused"`` pass
    through.  The train/serve step builders call this once so the whole
    jitted step traces against one concrete schedule.
    """
    if impl in (None, "auto"):
        return "fused"
    if impl in ("host", "fused"):
        return impl
    raise ValueError(f"unknown ring matmul impl {impl!r}")


def resolve_dispatch_impl(impl: Optional[str]) -> str:
    """Resolve a MoE dispatch implementation knob to a concrete mode.

    ``"auto"``/None keep the host collective ``"a2a"`` path (the status
    quo: GShard capacity dispatch through ``ompccl.alltoall``); the
    dropless one-sided paths — ``"host"`` (puts serialized around the
    expert GEMMs) and ``"fused"`` (combine overlapped under the GEMMs per
    :class:`AllToAllPlan`) — are explicit opt-ins because dropless
    routing changes the numbers whenever the capacity path would have
    dropped tokens.  The train/serve step builders call this once so the
    whole jitted step traces against one concrete dispatch schedule.
    """
    if impl in (None, "auto"):
        return "a2a"
    if impl in ("a2a", "host", "fused"):
        return impl
    raise ValueError(f"unknown moe dispatch impl {impl!r}")


def resolve_seq_parallel(impl: Optional[str]) -> str:
    """Resolve the sequence-parallel attention knob to a concrete mode.

    ``"auto"``/None keep the host collective ``"allgather"`` path (the
    status quo: K/V all-gathered over the model group, then local flash
    attention); ``"ring"`` — K/V stripes rotated through the bidirectional
    one-sided ring while partial softmax accumulates per
    :class:`AttentionRingPlan` — is an explicit opt-in because the
    stripe-merge reduction order changes the numerics at float tolerance
    against the all-gather scan.  The train/serve step builders call this
    once so the whole jitted step traces against one concrete schedule.
    """
    if impl in (None, "auto"):
        return "allgather"
    if impl in ("allgather", "ring"):
        return impl
    raise ValueError(f"unknown seq_parallel mode {impl!r}")


def split_extents(total: int, parts: int,
                  weights: Optional[Sequence[float]] = None,
                  *, minimum: int = 1) -> Tuple[int, ...]:
    """Proportional largest-remainder split of ``total`` into ``parts``.

    The asymmetric-decomposition primitive shared by the Minimod driver
    (per-rank Z extents proportional to device weights) and the MoE
    dispatch planner (per-expert landing capacities proportional to
    measured load).  Every extent is at least ``minimum``; with integral
    weights summing to ``total`` the split reproduces the weights exactly
    (largest-remainder assigns each raw quota its own floor).
    ``weights=None`` degrades to the near-even split, which also covers
    non-divisible grids — a non-divisible symmetric request is just the
    asymmetric path with unit weights.
    """
    if parts < 1:
        raise ValueError("parts must be >= 1")
    weights = tuple(weights) if weights is not None else (1,) * parts
    if len(weights) != parts:
        raise ValueError(f"{len(weights)} weights for {parts} parts")
    if min(weights) <= 0:
        raise ValueError("weights must be positive")
    if minimum * parts > total:
        raise ValueError(
            f"cannot give {parts} ranks at least {minimum} of {total} rows")
    wsum = float(sum(weights))
    raw = [total * w / wsum for w in weights]
    ext = [max(int(r), minimum) for r in raw]
    order = sorted(range(parts), key=lambda i: raw[i] - int(raw[i]),
                   reverse=True)
    i = 0
    while sum(ext) < total:
        ext[order[i % parts]] += 1
        i += 1
    donors = sorted(range(parts), key=lambda i: ext[i] - raw[i], reverse=True)
    i = 0
    while sum(ext) > total:
        j = donors[i % parts]
        if ext[j] > minimum:
            ext[j] -= 1
        i += 1
    return tuple(ext)


# ---------------------------------------------------------------------------
# ring schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RingStep:
    """One compute step of the ring collective matmul.

    ``index`` is the step number ``s``; the clockwise stream holds the
    stripe of rank ``(me - s) % n`` at step ``s``, the counter-clockwise
    stream the stripe of rank ``(me + s) % n``.  ``send_*`` are the
    forwards launched at this step (they deliver step ``s + 1``'s
    stripes and overlap this step's GEMMs); ``slot`` is the VMEM buffer
    slot both streams use for step ``s``.
    """

    index: int
    compute_cw: bool
    compute_ccw: bool
    send_cw: bool
    send_ccw: bool
    slot: int


@dataclasses.dataclass(frozen=True)
class RingPlan:
    """Concrete slot/step plan for one ring collective matmul.

    ``direction``:

    * ``"bidi"`` — the fused default: both link directions carry one stripe
      per step, ``ceil((n - 1) / 2)`` exchange steps;
    * ``"cw"`` / ``"ccw"`` — unidirectional rings (``n - 1`` steps), kept
      for the host-loop benchmark mode and for exercising both directions.
    """

    n: int
    direction: str = "bidi"
    slots: int = 2
    tile: Tuple[int, int, int] = (256, 512, 256)
    stripe_bytes: int = 0
    vmem_bytes: int = 0

    def __post_init__(self):
        if self.direction not in ("bidi", "cw", "ccw"):
            raise ValueError(f"unknown ring direction {self.direction!r}")
        if self.n < 1:
            raise ValueError("group size must be >= 1")

    @property
    def exchange_steps(self) -> int:
        """Ring steps that move data: ceil((n-1)/2) bidi, n-1 one-way."""
        if self.n <= 1:
            return 0
        if self.direction == "bidi":
            return (self.n - 1 + 1) // 2
        return self.n - 1

    def schedule(self) -> Tuple[RingStep, ...]:
        """The per-step schedule both the TPU kernel and the interpret
        emulation execute (compute steps = exchange_steps + 1)."""
        n = self.n
        if n == 1:
            return (RingStep(0, True, False, False, False, 0),)
        steps = []
        if self.direction == "bidi":
            s_cw = (n - 1 + 1) // 2          # cw serves the ring's left half
            s_ccw = (n - 1) // 2             # ccw the right half (no overlap)
            for s in range(s_cw + 1):
                steps.append(RingStep(
                    index=s,
                    compute_cw=s <= s_cw,            # s == 0 is the local stripe
                    compute_ccw=1 <= s <= s_ccw,
                    send_cw=s < s_cw,
                    send_ccw=s < s_ccw,
                    slot=s % self.slots,
                ))
        else:
            cw = self.direction == "cw"
            for s in range(n):
                steps.append(RingStep(
                    index=s,
                    compute_cw=cw or s == 0,
                    compute_ccw=(not cw) and s >= 1,
                    send_cw=cw and s < n - 1,
                    send_ccw=(not cw) and s < n - 1,
                    slot=s % self.slots,
                ))
        return tuple(steps)

    def sources(self, rank: int = 0) -> Tuple[int, ...]:
        """Stripe owners computed by ``rank``, in schedule order (oracle for
        coverage tests: must be a permutation of range(n))."""
        out = []
        for st in self.schedule():
            if st.compute_cw:
                out.append((rank - st.index) % self.n)
            if st.compute_ccw:
                out.append((rank + st.index) % self.n)
        return tuple(out)

    def fold_steps(self) -> Tuple[Tuple[str, int], ...]:
        """Rank-agnostic ``(direction, step)`` of each fold, in schedule
        order — the i-th entry describes where :meth:`sources`' i-th
        stripe came from (``("cw", s)`` = owner ``rank - s``, ``("ccw",
        s)`` = owner ``rank + s``).  The ring-attention backward keys its
        canonical cotangent routing off this list."""
        out = []
        for st in self.schedule():
            if st.compute_cw:
                out.append(("cw", st.index))
            if st.compute_ccw:
                out.append(("ccw", st.index))
        return tuple(out)


# ---------------------------------------------------------------------------
# ring attention schedule (sequence parallelism)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionRingPlan:
    """Concrete schedule for one sequence-parallel ring attention pass.

    The K/V stripes rotate through the same bidirectional ring as the
    collective matmul (the step records ARE :meth:`RingPlan.schedule`),
    but the compute is a flash-attention block per stripe whose partial
    softmax states fold with the :mod:`~repro.kernels.ring_attention.
    kernel` merge operator — so this plan adds the attention-specific
    facts on top of the ring:

    * **causal step skipping** — :meth:`computes` is the static predicate
      for "does ``rank`` spend FLOPs on stripe ``src``".  A stripe whose
      keys all lie in the rank's future (or beyond ``valid_len``) is
      fully masked, its state is the merge identity, and the TPU kernel
      skips it under ``pl.when`` — *bit-identically*, by the identity
      property.  Sends are NEVER skipped (downstream ranks need the
      forwarded stripe), so skipping changes FLOPs, not wire bytes.
      ``q_offset=None`` means the query positions are traced (dynamic
      chunked prefill): nothing can be skipped statically and every
      stripe masks instead.
    * **wire-byte accounting** — K and V are separate one-sided puts, so
      a full pass issues ``2·(n-1)`` puts of ``stripe_bytes`` total wire
      ``(n-1)·stripe_bytes`` per rank, the exact figure the RMATracker
      windows and the OMPCCL byte log must both report.
    * ``q_sharded=True`` is the training layout (rank ``r`` holds queries
      ``q_offset + r·tq_loc ..``); ``False`` the chunked-prefill layout
      (every rank holds the same ``tq_loc`` queries at ``q_offset``).
    """

    n: int
    tq_loc: int
    tk_loc: int
    h: int                      # query heads
    kh: int                     # kv heads (stripe width on the wire)
    d: int
    dv: int
    b: int = 1
    itemsize: int = 4
    causal: bool = True
    q_sharded: bool = True
    q_offset: Optional[int] = 0     # None: traced offsets, no static skip
    valid_len: Optional[int] = None  # None: all n*tk_loc key rows are real
    direction: str = "bidi"
    slots: int = 2
    block: int = 512
    overlap: bool = True            # False: serialized "host" listing
    vmem_bytes: int = 0

    def __post_init__(self):
        if self.n < 1:
            raise ValueError("group size must be >= 1")
        if self.tq_loc < 1 or self.tk_loc < 1:
            raise ValueError("per-rank extents must be >= 1")
        if self.h % self.kh:
            raise ValueError(f"H={self.h} not divisible by KH={self.kh}")
        if self.direction not in ("bidi", "cw", "ccw"):
            raise ValueError(f"unknown ring direction {self.direction!r}")

    @property
    def ring(self) -> RingPlan:
        """The underlying exchange schedule (shared with the matmul ring)."""
        return RingPlan(n=self.n, direction=self.direction, slots=self.slots,
                        stripe_bytes=self.stripe_bytes)

    @property
    def exchange_steps(self) -> int:
        return self.ring.exchange_steps

    def schedule(self) -> Tuple[RingStep, ...]:
        return self.ring.schedule()

    def sources(self, rank: int = 0) -> Tuple[int, ...]:
        """Stripe owners delivered to ``rank``, in schedule (= merge) order."""
        return self.ring.sources(rank)

    def fold_steps(self) -> Tuple[Tuple[str, int], ...]:
        """Per-fold ``(direction, step)`` records (see
        :meth:`RingPlan.fold_steps`)."""
        return self.ring.fold_steps()

    def q_lo(self, rank: int) -> int:
        """First global query position of ``rank`` (static plans only)."""
        if self.q_offset is None:
            raise ValueError("dynamic q_offset has no static query range")
        return self.q_offset + (rank * self.tq_loc if self.q_sharded else 0)

    def computes(self, rank: int, src: int) -> bool:
        """Does ``rank`` spend FLOPs on stripe ``src``?  False only when
        every (query, key) pair of the stripe is masked — beyond
        ``valid_len`` or entirely in the causal future — so skipping is
        sound by the merge-identity property."""
        k_lo = src * self.tk_loc
        if self.valid_len is not None and k_lo >= self.valid_len:
            return False
        if not self.causal or self.q_offset is None:
            return True
        return k_lo <= self.q_lo(rank) + self.tq_loc - 1

    def computed_sources(self, rank: int = 0) -> Tuple[int, ...]:
        return tuple(s for s in self.sources(rank) if self.computes(rank, s))

    @property
    def stripe_bytes(self) -> int:
        """Wire bytes of one K/V stripe (K put + V put)."""
        return self.b * self.tk_loc * self.kh * (self.d + self.dv) \
            * self.itemsize

    @property
    def puts_per_rank(self) -> int:
        """One-sided puts per rank per pass (K and V put separately)."""
        return 2 * (self.n - 1)

    @property
    def wire_bytes(self) -> int:
        """Per-rank put bytes for the whole pass: every remote stripe
        crosses each link once regardless of causal skipping."""
        return (self.n - 1) * self.stripe_bytes

    @property
    def stripe_flops(self) -> int:
        """FLOPs of one stripe's block: QK^T + PV einsums over all local
        queries and ``h`` query heads."""
        return 2 * self.b * self.tq_loc * self.tk_loc * self.h \
            * (self.d + self.dv)

    def flops(self, rank: int) -> int:
        """FLOPs ``rank`` actually spends after causal step skipping."""
        return len(self.computed_sources(rank)) * self.stripe_flops


# ---------------------------------------------------------------------------
# halo schedule (Minimod)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Concrete slab/slot plan for one fused halo-overlapped stencil step.

    The schedule the fused Minimod step executes (TPU kernel and interpret
    emulation alike — see :mod:`repro.kernels.stencil.fused`):

    * **carried halos** (the multi-step time loop): the R-thick *boundary*
      output slabs are computed FIRST (they only need the halos that landed
      last step), their values are immediately put one-sided to the
      neighbors (they are the neighbors' next-step halos), and the
      *interior* — which needs no halo at all — computes under the
      in-flight exchange.  One neighbor barrier/fence per step.
    * **single step** (no carried halos): the current field's boundary
      slabs are put first, the interior computes under the exchange, and
      the boundary region computes after the fence.

    ``overlap=False`` is the planner's *fallback* plan (degenerate grids
    with no interior, or a VMEM budget too small to double-buffer the
    pipeline): exchange-then-compute, still numerically identical.

    Extents are LOCAL (the per-rank maximum when extents are asymmetric).
    ``slab_bytes``/``strip_bytes`` are the wire sizes of one Z-slab /
    Y-strip halo put; ``bz`` is the interior Z-slab height of the DMA
    pipeline and ``slots`` the number of staging buffers granted by
    ``StreamPool.plan_slots`` against the VMEM budget.
    """

    nz: int
    ny: int = 1
    halo: int = 4
    z_loc: int = 0
    y_loc: int = 0
    x: int = 0
    slots: int = 2
    bz: int = 8
    by: int = 0               # Y staging chunk (== y_loc when untiled)
    slab_bytes: int = 0
    strip_bytes: int = 0
    vmem_bytes: int = 0
    overlap: bool = True

    def __post_init__(self):
        if self.nz < 1 or self.ny < 1:
            raise ValueError("halo decomposition needs nz, ny >= 1")
        if self.halo < 1:
            raise ValueError("halo must be >= 1")

    @property
    def exchange_axes(self) -> Tuple[str, ...]:
        """Sharded axes that actually exchange (edge groups of 1 don't)."""
        axes = []
        if self.nz > 1:
            axes.append("z")
        if self.ny > 1:
            axes.append("y")
        return tuple(axes)

    @property
    def interior_z(self) -> int:
        return max(self.z_loc - 2 * self.halo, 0) if self.nz > 1 else self.z_loc

    @property
    def interior_y(self) -> int:
        return max(self.y_loc - 2 * self.halo, 0) if self.ny > 1 else self.y_loc

    @property
    def puts_per_step(self) -> int:
        """One-sided puts each step issues (2 per exchanging axis)."""
        return 2 * len(self.exchange_axes)

    @property
    def halo_bytes_per_step(self) -> int:
        return (2 * self.slab_bytes if self.nz > 1 else 0) + \
            (2 * self.strip_bytes if self.ny > 1 else 0)

    def schedule(self, *, carried: bool = True) -> Tuple[str, ...]:
        """Ordered phase names both executions follow.

        ``carried=True`` is the time-loop order (halos of the current field
        already landed; the step exchanges the freshly computed boundary),
        ``carried=False`` the single-step order (exchange the current
        field's slabs, compute the interior under it).
        """
        if not self.exchange_axes:
            return ("all",)
        if not self.overlap:
            return ("put", "fence", "all")
        if carried:
            return ("boundary", "put", "interior", "fence")
        return ("put", "interior", "fence", "boundary")


# ---------------------------------------------------------------------------
# MoE dispatch schedule (expert-parallel all-to-all)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AllToAllPlan:
    """Concrete schedule for one dropless expert-parallel MoE dispatch.

    The ragged token→expert traffic is realized as a ring of one-sided
    puts: at step ``s`` every rank puts the block destined for the rank
    ``s + 1`` ahead (the exchange that feeds step ``s + 1``), runs the
    expert GEMMs on the block that landed from the rank ``s`` behind
    (step 0 computes the local block), and puts the *previous* GEMM's
    result straight back to its source — the return combine rides under
    the current compute.  One fence per landed block, one final fence for
    the combine windows.  Both the TPU kernel and the differentiable
    interpret emulation execute exactly :meth:`schedule`.

    Capacities are per-expert and **asymmetric** (``caps[e]`` rows per
    source rank, sized from measured load by
    :meth:`OverlapPlanner.plan_alltoall` through :func:`split_extents`);
    the home rank of expert ``e`` registers a PGAS landing region of
    ``ep * caps[e]`` rows while the other ranks register zero bytes —
    the paper's asymmetric-allocation story.  SPMD execution pads every
    wire block to ``cap_pad = max(caps)`` rows per expert (the same
    max-extent-shard trick Minimod uses); :meth:`block_rows` reports the
    *true* per-destination row counts the cost model bills for.
    """

    ep: int                    # EP group size (ring length)
    E: int                     # global expert count
    t_loc: int                 # tokens per rank entering dispatch
    k: int                     # experts per token
    d: int                     # model dim of one token row
    itemsize: int = 4
    caps: Tuple[int, ...] = ()  # per-expert landing rows per source rank
    slots: int = 2             # staging buffers granted by StreamPool
    overlap: bool = True       # False: puts, fence, GEMMs, puts, fence

    def __post_init__(self):
        if self.ep < 1:
            raise ValueError("EP group size must be >= 1")
        if self.E % self.ep != 0:
            raise ValueError(f"E={self.E} not divisible by ep={self.ep}")
        if len(self.caps) != self.E:
            raise ValueError(f"{len(self.caps)} caps for {self.E} experts")
        if self.caps and min(self.caps) < 1:
            raise ValueError("per-expert capacities must be >= 1")

    @property
    def E_loc(self) -> int:
        return self.E // self.ep

    @property
    def cap_pad(self) -> int:
        """Padded per-expert rows of one SPMD wire block (max over experts)."""
        return max(self.caps)

    @property
    def block_bytes(self) -> int:
        """Wire bytes of one padded dispatch/combine put."""
        return self.E_loc * self.cap_pad * self.d * self.itemsize

    def block_rows(self, rank: int) -> int:
        """TRUE rows one source sends to ``rank`` (the asymmetric sizes the
        PGAS regions and the cost model use; the wire block pads to
        ``E_loc * cap_pad``)."""
        lo = rank * self.E_loc
        return sum(self.caps[lo:lo + self.E_loc])

    @property
    def region_rows(self) -> Tuple[int, ...]:
        """Per-expert PGAS landing-region rows on the expert's home rank
        (``ep`` sources × ``caps[e]`` rows each)."""
        return tuple(self.ep * c for c in self.caps)

    @property
    def wire_bytes(self) -> int:
        """Modeled wire bytes per rank per dispatch+combine (true rows,
        remote destinations only)."""
        me = 0  # symmetric in the model: every rank sends all remote blocks
        remote = sum(self.block_rows(r) for r in range(self.ep) if r != me)
        return 2 * remote * self.d * self.itemsize

    @property
    def staging_bytes(self) -> int:
        """VMEM the pipeline pins: ``slots`` in-flight padded blocks."""
        return self.slots * self.block_bytes

    def schedule(self) -> Tuple[Tuple[str, int], ...]:
        """Ordered ``(phase, ring_offset)`` records both executions follow.

        * ``("put", s)``   — one-sided put of my block for the rank ``s``
          ahead (dispatch direction);
        * ``("fence", s)`` — complete the landing of the block from the
          rank ``s`` behind before its GEMM reads it;
        * ``("gemm", s)``  — expert GEMMs on that landed block (``s == 0``
          is the local block);
        * ``("ret", s)``   — one-sided put of that result back to its
          source, overlapped under step ``s + 1``'s GEMM;
        * ``("fence_ret", 0)`` — final fence of the combine windows.

        ``overlap=False`` is the serialized ``"host"`` mode: all dispatch
        puts, one fence, all GEMMs, all combine puts, one fence — the
        same traffic with nothing hidden.
        """
        if self.ep == 1:
            return (("gemm", 0),)
        out = []
        if self.overlap:
            for s in range(self.ep):
                if s + 1 < self.ep:
                    out.append(("put", s + 1))
                if s > 0:
                    out.append(("fence", s))
                out.append(("gemm", s))
                if s > 0:
                    out.append(("ret", s))
            out.append(("fence_ret", 0))
        else:
            for s in range(1, self.ep):
                out.append(("put", s))
            for s in range(1, self.ep):
                out.append(("fence", s))
            for s in range(self.ep):
                out.append(("gemm", s))
            for s in range(1, self.ep):
                out.append(("ret", s))
            out.append(("fence_ret", 0))
        return tuple(out)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OverlapPlanner:
    """Converts (StreamPool.plan_slots, VMEM budget, tile shape, group size)
    into the concrete plans the kernels consume.

    ``pool`` supplies the §3.2 bounded-concurrency policy — the number of
    in-flight DMA buffers a kernel may pin is exactly what
    ``StreamPool.plan_slots`` grants for the kernel's working set.
    """

    pool: StreamPool = dataclasses.field(
        default_factory=lambda: StreamPool(MAX_ACTIVE_STREAMS_DEFAULT))
    vmem_budget: int = VMEM_BUDGET_DEFAULT

    def _fits(self, working_set_bytes: int) -> bool:
        """Would the slots plan_slots grants actually fit the budget?

        plan_slots never grants fewer than 2 (double buffering is the point
        of the pipeline), so "fits" means the granted slot count times the
        working set stays inside the budget.
        """
        slots = self.pool.plan_slots(working_set_bytes, self.vmem_budget)
        return slots * working_set_bytes <= self.vmem_budget

    # -- ring collective matmul ---------------------------------------------
    def plan_ring_matmul(self, t_loc: int, k: int, n_loc: int, dtype,
                         n: int, *, direction: str = "bidi") -> RingPlan:
        """Slot/step plan for the fused all-gather matmul.

        Working set: per-slot stripe buffers for BOTH directions, the
        resident W column block, and the f32 output stripe tile.
        """
        item = _itemsize(dtype)
        stripe = max(t_loc * k * item, 1)
        resident = k * n_loc * item + t_loc * n_loc * 4   # W block + f32 out tile
        budget = max(self.vmem_budget - resident, stripe * 2)
        ndir = 2 if direction == "bidi" else 1
        slots = self.pool.plan_slots(ndir * stripe, budget)
        # the grant is a concurrency bound; the pinned bytes must also fit
        slots = min(slots, max(budget // (ndir * stripe), 2))
        plan = RingPlan(n=n, direction=direction,
                        slots=1 if n == 1 else max(2, min(slots, n)),
                        tile=self.plan_matmul_tiles(t_loc, k, n_loc, dtype),
                        stripe_bytes=stripe)
        return dataclasses.replace(
            plan, vmem_bytes=ndir * plan.slots * stripe + resident)

    # -- blocked matmul tiles -----------------------------------------------
    def plan_matmul_tiles(self, m: int, k: int, n: int, dtype,
                          *, bm: int = 256, bk: int = 512, bn: int = 256
                          ) -> Tuple[int, int, int]:
        """MXU-aligned tiles shrunk until plan_slots grants double buffering.

        Working set per pipeline stage: x (bm, bk) + w (bk, bn) in ``dtype``
        + f32 accumulator (bm, bn).  bk halves first (the accumulator is
        bk-independent), then bm/bn together, never below 128.
        """
        item = _itemsize(dtype)
        bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
        while True:
            ws = (bm * bk + bk * bn) * item + bm * bn * 4
            if self._fits(ws) or (bm <= 128 and bk <= 128 and bn <= 128):
                return bm, bk, bn
            if bk > 128:
                bk //= 2
            else:
                bm = max(128, bm // 2)
                bn = max(128, bn // 2)

    # -- flash attention block ----------------------------------------------
    def plan_attention_block(self, tq: int, tk: int, d: int, dv: int, dtype,
                             *, block: int = 512) -> int:
        """Largest block ≤ ``block`` whose tiles double-buffer in budget.

        ``block`` chunks the KV axis (and, in the Pallas kernel, the q axis
        too — both kernels clamp to their actual extents).  Per-step working
        set: q (bq, d) + k/v (bk, d/dv) in ``dtype`` + scores (bq, bk) and
        accumulator (bq, dv) in f32.
        """
        item = _itemsize(dtype)
        b = max(min(block, max(tq, tk)), 1)
        while b > 128:
            bq, bk = min(b, tq), min(b, tk)
            ws = (bq * d + bk * (d + dv)) * item + (bq * bk + bq * dv) * 4
            if self._fits(ws):
                break
            b //= 2
        return b

    # -- ring attention -------------------------------------------------------
    def plan_ring_attention(self, b: int, tq_loc: int, tk_loc: int,
                            h: int, kh: int, d: int, dv: int, dtype, n: int,
                            *, causal: bool = True, q_sharded: bool = True,
                            q_offset: Optional[int] = 0,
                            valid_len: Optional[int] = None,
                            direction: str = "bidi",
                            overlap: bool = True) -> AttentionRingPlan:
        """Slot/block plan for the fused sequence-parallel attention ring.

        Working set: per-slot K+V stripe buffers for BOTH ring directions
        (what ``StreamPool.plan_slots`` bounds), against a budget net of
        the residents — the grouped f32 queries and the (m, l, acc) merge
        carry.  The flash block size reuses :meth:`plan_attention_block`
        on the per-rank extents.  ``q_offset=None`` marks traced query
        offsets (dynamic chunked prefill): the plan then skips nothing
        and every stripe masks.
        """
        item = _itemsize(dtype)
        block = self.plan_attention_block(tq_loc, tk_loc, d, dv, dtype)
        stripe = max(b * tk_loc * kh * (d + dv) * item, 1)
        resident = b * tq_loc * h * (d + 2 + dv) * 4   # qg + m/l + acc, f32
        budget = max(self.vmem_budget - resident, stripe * 2)
        ndir = 2 if direction == "bidi" else 1
        slots = self.pool.plan_slots(ndir * stripe, budget)
        # the grant is a concurrency bound; the pinned bytes must also fit
        slots = min(slots, max(budget // (ndir * stripe), 2))
        plan = AttentionRingPlan(
            n=n, tq_loc=tq_loc, tk_loc=tk_loc, h=h, kh=kh, d=d, dv=dv, b=b,
            itemsize=item, causal=causal, q_sharded=q_sharded,
            q_offset=q_offset, valid_len=valid_len, direction=direction,
            slots=1 if n == 1 else max(2, min(slots, n)), block=block,
            overlap=overlap)
        return dataclasses.replace(
            plan, vmem_bytes=ndir * plan.slots * stripe + resident)

    # -- MoE dispatch all-to-all ----------------------------------------------
    def plan_alltoall(self, t_loc: int, d: int, k: int, E: int, ep: int,
                      dtype, *, loads: Optional[Sequence[int]] = None,
                      slack: float = 1.0, overlap: bool = True
                      ) -> AllToAllPlan:
        """Schedule + asymmetric capacities for one dropless MoE dispatch.

        ``loads`` are measured per-expert row counts — the *maximum over
        source ranks* of rows routed to each expert (what one landing
        region must absorb per source).  The staging budget
        ``ceil(sum(loads) * slack)`` is decomposed over experts by the
        largest-remainder split (:func:`split_extents`, the Minimod
        decomposition); with ``slack == 1.0`` the split reproduces the
        loads exactly, and any split is re-clamped to ``>= loads[e]`` so
        the plan is dropless by construction.  ``loads=None`` is the
        trace-time fallback (no measurement available inside a jitted
        step): every expert gets the worst-case ``t_loc`` rows.

        Slot count is ``StreamPool.plan_slots``' grant for one padded
        wire block against the VMEM budget (the §3.2 bounded-concurrency
        contract), and the plan degrades to ``overlap=False`` when the
        budget cannot double-buffer the staging pipeline.
        """
        if E % ep != 0:
            raise ValueError(f"E={E} not divisible by ep={ep}")
        item = _itemsize(dtype)
        if loads is None:
            caps = (t_loc,) * E
        else:
            loads = tuple(int(l) for l in loads)
            if len(loads) != E:
                raise ValueError(f"{len(loads)} loads for {E} experts")
            total = max(int(-(-sum(loads) * slack // 1)),
                        sum(max(l, 1) for l in loads))
            weights = tuple(max(l, 1e-6) for l in loads)
            caps = split_extents(total, E, weights, minimum=1)
            caps = tuple(max(c, l) for c, l in zip(caps, loads))
        plan = AllToAllPlan(ep=ep, E=E, t_loc=t_loc, k=k, d=d,
                            itemsize=item, caps=caps, overlap=overlap)
        if ep == 1:
            return dataclasses.replace(plan, slots=1)
        block = plan.block_bytes
        slots = self.pool.plan_slots(block, self.vmem_budget)
        slots = max(2, min(slots, max(self.vmem_budget // max(block, 1), 2)))
        slots = min(slots, ep)
        if overlap and 2 * block > self.vmem_budget:
            return dataclasses.replace(plan, overlap=False, slots=1)
        return dataclasses.replace(plan, slots=slots)

    # -- gradient buckets -----------------------------------------------------
    def plan_grad_buckets(self, cfg, mesh, ctx):
        """The DP gradient-reduction schedule (see
        :mod:`repro.distributed.buckets`) — exposed here so every planned
        schedule (ring steps, kernel tiles, reduction buckets) resolves
        through the one planner surface.  Like every other plan it is pure
        static-shape data, cached per (config, mesh, ctx)."""
        from repro.distributed.buckets import plan_for_config

        return plan_for_config(cfg, mesh, ctx)

    # -- stencil slab ---------------------------------------------------------
    def plan_stencil_bz(self, z: int, y: int, x: int, dtype,
                        *, radius: int = 4, bz: int = 8) -> int:
        """Z-slab height whose halo slab still double-buffers in budget.

        Degenerate inputs fall back instead of producing an invalid plan:
        ``bz`` exceeding the Z extent clamps to it, a grid shorter than the
        stencil support still yields a positive slab, and a budget too
        small for any slab bottoms out at ``bz == 1`` (the kernel then
        streams one plane at a time — slow, never wrong).
        """
        item = _itemsize(dtype)
        bz = max(min(bz, z), 1)
        while bz > 1:
            slab = (bz + 2 * radius) * (y + 2 * radius) * (x + 2 * radius)
            ws = slab * item + 3 * bz * y * x * item   # slab + prev/c2/out blocks
            if self._fits(ws):
                break
            bz = max(1, bz // 2)
        return bz

    # -- halo exchange (Minimod) ----------------------------------------------
    def plan_halo_slots(self, z_loc: int, y_loc: int, x: int, dtype,
                        nz: int, *, ny: int = 1, halo: int = 4) -> HaloPlan:
        """Slab/slot plan for the fused halo-overlapped stencil step.

        The halo landing windows live in HBM (one-sided puts target the
        PGAS segment); what VMEM must hold is the *staging* pipeline — the
        (bz + 2·halo)-high halo-extended slabs the boundary and interior
        passes stream through, ``slots`` of them in flight at once.  The
        slot count is ``StreamPool.plan_slots``' grant for that working
        set (the §3.2 bounded-concurrency contract), re-clamped so the
        pinned bytes actually fit the budget.

        Falls back to an ``overlap=False`` plan (exchange-then-compute)
        rather than emitting an invalid slab plan when the local grid has
        no interior (extent ≤ 2·halo on an exchanging axis) or the budget
        cannot double-buffer even the minimum slab.
        """
        item = _itemsize(dtype)
        slab = halo * y_loc * x * item if nz > 1 else 0
        strip = z_loc * halo * x * item if ny > 1 else 0
        bz = self.plan_stencil_bz(z_loc, y_loc, x, dtype, radius=halo)

        def stage_bytes(by):
            return (bz + 2 * halo) * (by + 2 * halo) * (x + 2 * halo) * item

        # the staging unit tiles Y once bz has bottomed out (wide grids:
        # one full Y×X plane can exceed the whole budget by itself)
        by = y_loc
        while 2 * stage_bytes(by) > self.vmem_budget and by > 2 * halo:
            by = max(by // 2, 2 * halo)
        stage = stage_bytes(by)
        slots = self.pool.plan_slots(stage, self.vmem_budget)
        slots = max(2, min(slots, max(self.vmem_budget // max(stage, 1), 2)))

        plan = HaloPlan(
            nz=nz, ny=ny, halo=halo, z_loc=z_loc, y_loc=y_loc, x=x,
            slots=slots, bz=bz, by=by, slab_bytes=slab, strip_bytes=strip,
            vmem_bytes=slots * stage, overlap=True)
        # fallback: no interior to hide the exchange under (the plan's own
        # interior_* properties are THE definition the kernels split by),
        # or a budget that cannot double-buffer the staging pipeline
        has_interior = plan.interior_z > 0 and plan.interior_y > 0
        overlap = bool(plan.exchange_axes) and has_interior and \
            2 * stage <= self.vmem_budget
        if not overlap:
            # fallback plans pipeline nothing: one staging buffer, and the
            # reported pinned bytes are that single chunk — never a
            # multi-slot plan the budget cannot hold
            plan = dataclasses.replace(plan, overlap=False, slots=1,
                                       vmem_bytes=stage)
        return plan


_DEFAULT_PLANNER: Optional[OverlapPlanner] = None


def default_planner() -> OverlapPlanner:
    """The process-default planner, backed by the default DiompContext's
    StreamPool so the §3.2 policy knob (``max_active_streams``) governs
    kernel DMA slots and host async lanes alike."""
    global _DEFAULT_PLANNER
    from repro.core.context import default_context

    pool = default_context().streams
    if _DEFAULT_PLANNER is None or _DEFAULT_PLANNER.pool is not pool:
        _DEFAULT_PLANNER = OverlapPlanner(pool=pool)
    return _DEFAULT_PLANNER

"""Logical-axis sharding rules (MaxText-style) for the DiOMP-JAX runtime.

Model code annotates every tensor with *logical* axis names ("embed", "mlp",
"heads", "vocab", "expert", "batch", "seq", ...).  The runtime translates
those to *mesh* axes via a rule table — this is the TPU counterpart of
DiOMP's PGAS placement decisions: the centralized mapping table stores the
logical spec, and placement onto the pod topology is one rule lookup.

Rules are ordered: the first mesh axis in a rule's list that exists in the
mesh AND is not already taken by another tensor dim wins.  ``None`` = +
replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "named_sharding",
    "param_bytes_per_device",
]


# mesh axes, in the order the production meshes define them
POD, DATA, MODEL = "pod", "data", "model"


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> candidate mesh axes (first available wins)."""

    rules: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...]

    def lookup(self, logical: Optional[str], mesh: Mesh, taken: set) -> Optional[object]:
        if logical is None:
            return None
        for name, candidates in self.rules:
            if name != logical:
                continue
            picked: List[str] = []
            for cand in candidates:
                if cand is None:
                    continue
                if cand in mesh.shape and cand not in taken:
                    picked.append(cand)
            if not picked:
                return None
            taken.update(picked)
            return picked[0] if len(picked) == 1 else tuple(picked)
        return None

    def replace(self, logical: str, candidates: Tuple[Optional[str], ...]) -> "ShardingRules":
        """Return a copy with one rule overridden (hillclimb knob)."""
        new = []
        replaced = False
        for name, cands in self.rules:
            if name == logical:
                new.append((name, candidates))
                replaced = True
            else:
                new.append((name, cands))
        if not replaced:
            new.append((logical, candidates))
        return ShardingRules(tuple(new))


# The default placement, mirroring MaxText conventions on a
# ("pod", "data", "model") mesh:
#   * batch over pod+data (hierarchical DP),
#   * d_model ("embed") replicated for activations, FSDP-sharded for weights,
#   * heads / mlp / vocab / expert over "model" (TP / EP),
#   * seq over "model" only for sequence-parallel paths (explicit opt-in).
DEFAULT_RULES = ShardingRules(
    rules=(
        ("batch", (POD, DATA)),
        ("seq", (None,)),
        ("seq_shard", (MODEL,)),        # sequence parallelism (opt-in)
        ("embed", (None,)),             # activations keep d_model whole
        ("embed_fsdp", (DATA,)),        # ZeRO-3 weight shard over data axis
        ("heads", (MODEL,)),
        ("kv_heads", (MODEL,)),
        ("mlp", (MODEL,)),
        ("vocab", (MODEL,)),
        ("expert", (MODEL,)),
        ("expert_mlp", (None,)),
        ("conv_state", (None,)),
        ("ssm_state", (None,)),
        ("stage", (None,)),             # pipeline stages (unused on 2-pod mesh)
    )
)


# Beyond-paper layout variants (the §Perf hillclimb surface):
#
# * EXPERT2D — MoE expert weights sharded over BOTH "model" and "data" on the
#   expert dim (256-way for DeepSeek's 256 experts): each chip owns whole
#   experts with full d/ff, so the per-microbatch ZeRO-3 d-gathers vanish;
#   dispatch runs one all-to-all over the combined (model×data) EP group.
# * DP_ONLY — no tensor parallelism: batch over every mesh axis.  For small
#   dense models whose TP activation all-reduces dominate the roofline.
EXPERT2D_RULES = DEFAULT_RULES.replace("expert", (MODEL, DATA))

DP_ONLY_RULES = ShardingRules(rules=tuple(
    (name, (POD, DATA, MODEL)) if name == "batch" else
    (name, (None,)) if cands and set(cands) <= {MODEL} else
    (name, cands)
    for name, cands in DEFAULT_RULES.rules
))


def rules_for_ctx(ctx) -> ShardingRules:
    """Pick the placement-rule table for a ParallelCtx's layout knobs."""
    if getattr(ctx, "layout", "tp") == "dp_only":
        return DP_ONLY_RULES
    rules = DEFAULT_RULES
    if getattr(ctx, "expert2d", False):
        rules = rules.replace("expert", (MODEL, DATA))
    if not getattr(ctx, "fsdp_params", True):
        # inference weight-stationary: dense weights TP-sharded only
        rules = rules.replace("embed_fsdp", (None,))
    return rules


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> PartitionSpec:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    taken: set = set()
    parts = [rules.lookup(ax, mesh, taken) for ax in logical_axes]
    # trim trailing Nones (canonical PartitionSpec form)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules))


def param_bytes_per_device(
    shape: Sequence[int],
    dtype_bytes: int,
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> int:
    """Local shard size in bytes — what GlobalMemory charges the arena."""
    spec = logical_to_spec(logical_axes, mesh, rules)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    n = 1
    for dim, part in zip(shape, parts):
        div = 1
        if part is not None:
            axes = part if isinstance(part, tuple) else (part,)
            for ax in axes:
                div *= mesh.shape[ax]
        n *= -(-dim // div)  # ceil-div: padded shard
    return n * dtype_bytes

"""Gradient bucketing — the planned flat-bucket DP reduction subsystem.

Per-parameter gradient reduction is latency-bound: every small tensor pays
a full collective launch (and, on a ring, ``2(n-1)`` per-hop latencies),
and every call re-resolves its group and pads/reshapes its own payload.
:class:`BucketPlanner` turns the parameter schema into a *plan* — the same
"schedule as data" discipline as :class:`repro.kernels.plan.RingPlan`:

* the gradient pytree is partitioned by ``(group-of-unreduced-DP-axes,
  wire dtype, duplication factor)`` — every member of a partition needs the
  exact same collective and the same 1/dup weighting in the global norm;
* each partition is packed, in deterministic name order, into flat buckets
  of at most ``bucket_bytes`` (params split across bucket boundaries, so a
  partition with ``T`` payload bytes issues exactly
  ``ceil(T / bucket_bytes)`` collectives — the bound the call-log test
  asserts);
* every bucket is padded **once, in the layout** to a multiple of its
  group size (times the int8 quantization block when a codec is active),
  so neither :func:`repro.distributed.hierarchical.hierarchical_allreduce`
  nor :func:`repro.distributed.compression.compressed_allreduce` ever pads
  or reshapes per call.

Plans are derived from static shapes only, computed once at trace time
(or ahead of it, from the schema) and identical across traces.  Pack /
unpack are pure reshape/concat index maps baked from the plan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.context import default_context
from repro.core.groups import DiompGroup, group_for_axes

__all__ = [
    "DEFAULT_BUCKET_BYTES",
    "GRAD_QUANT_BLOCK",
    "BucketSlice",
    "Bucket",
    "BucketPlan",
    "BucketPlanner",
    "unreduced_dp_axes",
    "local_shape",
    "duplication_factor",
    "plan_for_config",
    "pack_buckets",
    "unpack_buckets",
    "backend_for_axes",
    "backend_for_bucket",
    "reduce_bucketed",
]

F32 = jnp.float32
WIRE_ITEMSIZE = 4                  # buckets reduce in f32 (the step's discipline)
DEFAULT_BUCKET_BYTES = 4 * 2**20
GRAD_QUANT_BLOCK = 1024            # int8 per-block scale granularity


def unreduced_dp_axes(pspec, dp_axes) -> Tuple[str, ...]:
    """The DP axes a parameter's sharding does NOT consume — exactly the
    axes its gradient still needs a cross-device reduction over."""
    spec_axes = set()
    for part in pspec:
        if part is None:
            continue
        spec_axes |= set(part if isinstance(part, tuple) else (part,))
    return tuple(a for a in dp_axes if a not in spec_axes)


def local_shape(shape: Sequence[int], pspec,
                mesh_sizes: Mapping[str, int]) -> Tuple[int, ...]:
    """Per-device shard shape of a global tensor under ``pspec``."""
    parts = list(pspec) + [None] * (len(shape) - len(pspec))
    out = []
    for dim, part in zip(shape, parts):
        div = 1
        if part is not None:
            for ax in (part if isinstance(part, tuple) else (part,)):
                div *= mesh_sizes[ax]
        out.append(dim // div)
    return tuple(out)


def duplication_factor(pspec, mesh_sizes: Mapping[str, int]) -> int:
    """Device copies per element: world size / sharded ways — the 1/dup
    weight in the global norm.  The ONE shared implementation (the bucket
    partition key and the per-param norm fallback must agree)."""
    world = 1
    for s in mesh_sizes.values():
        world *= s
    sharded = 1
    for part in pspec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            sharded *= mesh_sizes[ax]
    return world // sharded


# ---------------------------------------------------------------------------
# the plan objects
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketSlice:
    """One contiguous run of a parameter's flattened local gradient.

    ``offset`` locates the run inside the bucket, ``start`` inside the
    parameter; a parameter larger than the bucket budget is split across
    consecutive buckets (sum is elementwise, so a split reduces exactly
    like an unsplit tensor).
    """

    name: str
    offset: int
    start: int
    size: int


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One flat wire payload: reduced by ONE collective on ``group``."""

    key: str
    axes: Tuple[str, ...]
    dtype: str
    dup: int
    index: int
    size: int                       # live elements
    padded_size: int                # size rounded up to the layout multiple
    slices: Tuple[BucketSlice, ...]

    @property
    def group(self) -> DiompGroup:
        return group_for_axes(self.axes)

    def group_size(self, mesh_sizes: Mapping[str, int]) -> int:
        g = 1
        for ax in self.axes:
            g *= mesh_sizes[ax]
        return g

    def shard_size(self, mesh_sizes: Mapping[str, int]) -> int:
        """Per-device elements of the reduce-scattered bucket (the overlap
        carry) — exact because ``padded_size`` is a group-size multiple."""
        return self.padded_size // self.group_size(mesh_sizes)

    @property
    def nbytes(self) -> int:
        return self.size * WIRE_ITEMSIZE

    @property
    def padded_nbytes(self) -> int:
        return self.padded_size * WIRE_ITEMSIZE


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """The full reduction schedule for one (config, mesh, ctx)."""

    buckets: Tuple[Bucket, ...]
    local: Tuple[str, ...]          # params needing no cross-device reduce
    shapes: Mapping[str, Tuple[int, ...]]   # local grad shapes, all params
    dups: Mapping[str, int]         # duplication factor, all params
    bucket_bytes: int

    def bucket_count(self) -> Dict[Tuple[str, ...], int]:
        out: Dict[Tuple[str, ...], int] = {}
        for b in self.buckets:
            out[b.axes] = out.get(b.axes, 0) + 1
        return out

    def total_bytes(self) -> int:
        return sum(b.nbytes for b in self.buckets)


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketPlanner:
    """Partition + pack the gradient pytree into planned flat buckets.

    ``quant_block`` > 0 aligns every bucket to ``group_size * quant_block``
    so the blockwise int8 codec's chunking never pads per call (set when
    ``grad_codec="int8"``); otherwise buckets align to the group size,
    which every hierarchical fast-axis reduce-scatter divides.
    """

    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    quant_block: int = 0

    def plan(self, shapes: Mapping[str, Sequence[int]],
             pspecs: Mapping[str, object], dp_axes: Sequence[str],
             mesh_sizes: Mapping[str, int]) -> BucketPlan:
        """Build the plan from static *local* shapes.

        Deterministic: partitions are visited in sorted key order, members
        in sorted name order, so the same inputs always produce the same
        buckets (asserted across traces by the tests).
        """
        dp_axes = tuple(dp_axes)
        parts: Dict[Tuple, list] = {}
        local = []
        loc_shapes = {}
        dups = {}
        for name in sorted(shapes):
            shp = tuple(int(d) for d in shapes[name])
            loc_shapes[name] = shp
            dups[name] = duplication_factor(pspecs[name], mesh_sizes)
            need = unreduced_dp_axes(pspecs[name], dp_axes)
            if not need:
                local.append(name)
                continue
            parts.setdefault((need, "float32", dups[name]), []).append(name)

        # capacity rounds UP to whole elements: flooring would let a
        # bucket_bytes that is not a multiple of the wire itemsize exceed
        # the documented ceil(partition_bytes / bucket_bytes) call bound
        bucket_elems = max(-(-self.bucket_bytes // WIRE_ITEMSIZE), 1)
        buckets = []
        for (axes, dtype, dup) in sorted(parts):
            names = parts[(axes, dtype, dup)]
            gsize = 1
            for ax in axes:
                gsize *= mesh_sizes[ax]
            align = gsize * (self.quant_block or 1)
            index = 0
            pos = 0
            slices: list = []

            def close():
                nonlocal index, pos, slices
                if not slices:
                    return
                padded = -(-pos // align) * align
                key = f"{'+'.join(axes)}|{dtype}|dup{dup}|{index}"
                buckets.append(Bucket(
                    key=key, axes=axes, dtype=dtype, dup=dup, index=index,
                    size=pos, padded_size=padded, slices=tuple(slices)))
                index += 1
                pos = 0
                slices = []

            for name in names:
                left = 1
                for d in loc_shapes[name]:
                    left *= d
                start = 0
                while left > 0:
                    take = min(bucket_elems - pos, left)
                    slices.append(BucketSlice(name, pos, start, take))
                    pos += take
                    start += take
                    left -= take
                    if pos == bucket_elems:
                        close()
            close()
        return BucketPlan(buckets=tuple(buckets), local=tuple(local),
                          shapes=loc_shapes, dups=dups,
                          bucket_bytes=self.bucket_bytes)

    def plan_from_arrays(self, grads: Mapping[str, object],
                         pspecs: Mapping[str, object],
                         dp_axes: Sequence[str],
                         mesh_sizes: Mapping[str, int]) -> BucketPlan:
        """Plan from live (local) gradient arrays at trace time — shapes
        are static under shard_map, so this is identical to :meth:`plan`
        fed the derived local shapes."""
        return self.plan({n: g.shape for n, g in grads.items()},
                         pspecs, dp_axes, mesh_sizes)


@functools.lru_cache(maxsize=64)
def plan_for_config(cfg, mesh, ctx, *,
                    bucket_bytes: Optional[int] = None) -> BucketPlan:
    """The plan for one (ModelConfig, Mesh, ParallelCtx) — cached, so every
    trace of a step (and every bench / test inspecting the schedule) shares
    one plan object."""
    from repro.distributed.sharding import rules_for_ctx
    from repro.models import schema as sch

    pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    sizes = dict(mesh.shape)
    shapes = {name: local_shape(spec.shape, pspecs[name], sizes)
              for name, spec in sch.build_schema(cfg).items()}
    planner = BucketPlanner(
        bucket_bytes=(ctx.bucket_bytes if bucket_bytes is None
                      else bucket_bytes),
        quant_block=GRAD_QUANT_BLOCK if ctx.grad_codec == "int8" else 0)
    return planner.plan(shapes, pspecs, ctx.dp_group.axes, sizes)


# ---------------------------------------------------------------------------
# pack / unpack (pure index maps baked from the plan)
# ---------------------------------------------------------------------------


def pack_buckets(grads: Mapping[str, jax.Array], plan: BucketPlan,
                 *, vary: Tuple[str, ...] = ()) -> Dict[str, jax.Array]:
    """Flatten + concatenate each bucket's member slices (f32, zero-padded).

    ``vary`` promotes every slice to be varying over those mesh axes before
    the concat — members of one bucket can carry different vma sets (their
    own sharded axes differ), and a concat operand set must agree.
    """
    from repro.core.backends import ensure_varying

    out = {}
    for b in plan.buckets:
        pieces = []
        for s in b.slices:
            flat = grads[s.name].astype(F32).reshape(-1)
            if not (s.start == 0 and s.size == flat.size):
                flat = flat[s.start:s.start + s.size]
            if vary:
                flat = ensure_varying(flat, vary)
            pieces.append(flat)
        if b.padded_size > b.size:
            padz = jnp.zeros((b.padded_size - b.size,), F32)
            pieces.append(ensure_varying(padz, vary) if vary else padz)
        out[b.key] = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    return out


def unpack_buckets(bufs: Mapping[str, jax.Array],
                   plan: BucketPlan) -> Dict[str, jax.Array]:
    """Inverse of :func:`pack_buckets`: reassemble per-param f32 grads."""
    pieces: Dict[str, list] = {}
    for b in plan.buckets:
        buf = bufs[b.key]
        for s in b.slices:
            pieces.setdefault(s.name, []).append(
                buf[s.offset:s.offset + s.size])
    out = {}
    for name, ps in pieces.items():
        flat = ps[0] if len(ps) == 1 else jnp.concatenate(ps)
        out[name] = flat.reshape(plan.shapes[name])
    return out


# ---------------------------------------------------------------------------
# the whole-bucket reduction
# ---------------------------------------------------------------------------


def backend_for_axes(axes: Sequence[str], ctx) -> str:
    """The dp_backend dispatch policy — the ONE copy both the bucketed and
    the per-param reduction paths resolve backends through."""
    if (ctx.dp_backend == "hierarchical" and "pod" in axes
            and len(axes) > 1):
        return "hierarchical"
    return "xla"


def backend_for_bucket(bucket: Bucket, ctx) -> str:
    """The OMPCCL backend one bucket's collective dispatches through."""
    return backend_for_axes(bucket.axes, ctx)


def reduce_bucketed(grads: Mapping[str, jax.Array], plan: BucketPlan, ctx,
                    *, errors: Optional[dict] = None, context=None,
                    vary: Tuple[str, ...] = ()):
    """DP mean-reduction of whole buckets, one communicator handle each.

    Mirrors the per-param contract of ``train.step.reduce_gradients``
    (grads divided by ``ctx.dp``, summed over each bucket's group; int8
    buckets reduce through the blockwise compressed codec with ONE
    error-feedback state per bucket), but issues
    ``ceil(partition_bytes / bucket_bytes)`` collectives per partition
    instead of one per parameter.

    Returns ``(reduced_grads, reduced_bufs, new_errors)`` — the reduced
    flat buckets ride along so the caller can compute the global grad norm
    bucket-wise without re-packing.
    """
    from repro.distributed.compression import compressed_allreduce

    dctx = context or default_context()
    dp_axes = tuple(ctx.dp_group.axes)
    if errors and plan.buckets and not any(b.key in errors
                                           for b in plan.buckets):
        # name-keyed residual from a per-param caller: silently reducing
        # with error=None would drop the accumulated int8 feedback — fail
        # loudly instead of degrading convergence
        raise ValueError(
            "error-feedback state keys match no bucket in the plan "
            f"(got {sorted(errors)[:3]}...); carried per-param errors? "
            "pass bucket_bytes=0 / plan=None to stay on the per-param path")
    out = {n: grads[n].astype(F32) / ctx.dp for n in plan.local}
    bufs = pack_buckets(grads, plan, vary=vary)
    new_errors = {}
    red = {}
    for b in plan.buckets:
        if ctx.grad_codec == "int8" and set(b.axes) == set(dp_axes):
            # the codec returns the group MEAN, and the bucket's group IS
            # the dp group here, so the raw sum goes in — no /dp round trip
            err = errors.get(b.key) if errors else None
            buf, e = compressed_allreduce(bufs[b.key], b.group, error=err,
                                          block=GRAD_QUANT_BLOCK)
            new_errors[b.key] = e
        else:
            comm = dctx.communicator(b.group, backend_for_bucket(b, ctx))
            buf = comm.allreduce(bufs[b.key] / ctx.dp)
        red[b.key] = buf
    out.update(unpack_buckets(red, plan))
    return out, red, new_errors

"""Gradient compression with error feedback (distributed-optimization trick).

Not in the DiOMP paper — a beyond-paper extension for 1000+-node scale where
the inter-pod all-reduce becomes bandwidth-bound.  Two codecs:

* **int8** uniform quantization (4x wire reduction vs f32, 2x vs bf16) with
  per-tensor scale and error feedback (the residual is carried to the next
  step, which keeps SGD convergence — Karimireddy et al. 2019);
* **top-k** magnitude sparsification (wire = 2k entries) with error feedback.

On this CPU container the *wire* saving cannot be observed; the codecs are
numerically real (quantize -> reduce -> dequantize) and the byte saving is
accounted by :func:`wire_bytes` for the roofline/§Perf math.  The decode->
psum->encode structure matches what a real deployment would run as a
reduce-scatter in the compressed domain.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import all_gather_invariant, axis_size
from repro.core.groups import DiompGroup

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_allreduce",
    "topk_compress",
    "topk_allreduce",
    "wire_bytes",
]


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: q = round(x/scale), scale = amax/127."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_allreduce(
    x,
    group: DiompGroup,
    *,
    error: Optional[jnp.ndarray] = None,
):
    """int8 all-reduce with error feedback (ZeRO++ qgZ-style two phase).

    Phase 1: all-to-all the int8 chunks + all-gather the per-rank scales,
    dequantize each received chunk with its *source* scale and reduce
    locally (an exact compressed-domain reduce-scatter).  Phase 2:
    re-quantize the reduced shard and all-gather it.  Wire traffic is int8
    payload + one f32 scale per rank per phase; the only lossy steps are the
    two quantizations, whose residual feeds back via ``error``.

    Returns ``(mean_grad, new_error)``.
    """
    if error is not None:
        x = x + error
    n = 1
    for ax in group.axes:
        n *= axis_size(ax)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])

    q, scale = quantize_int8(flat)
    # phase 1 wire: chunk i of my int8 payload -> rank i; scales broadcast
    chunks = q.reshape(n, -1)
    recv = lax.all_to_all(chunks, group.lax_axes, split_axis=0, concat_axis=0, tiled=True)
    scales = scale.reshape(1)
    for ax in reversed(group.axes):
        scales = lax.all_gather(scales, ax, axis=0, tiled=True)
    shard = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0) / n

    # phase 2 wire: re-quantized reduced shard all-gathered back (invariant:
    # every rank reconstructs the same reduced tensor)
    q2, s2 = quantize_int8(shard)
    gathered = q2
    for ax in reversed(group.axes):
        gathered = all_gather_invariant(gathered, ax, axis=0, tiled=True)
    s2_all = s2.reshape(1)
    for ax in reversed(group.axes):
        s2_all = all_gather_invariant(s2_all, ax, axis=0, tiled=True)
    out = (gathered.reshape(n, -1).astype(jnp.float32) * s2_all[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
        flat = flat[:-pad]
        q = q[:-pad]
    new_error = flat - dequantize_int8(q, scale)
    return out.reshape(orig_shape).astype(orig_dtype), new_error.reshape(orig_shape).astype(orig_dtype)


def topk_compress(x, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the k largest-|x| entries of the flattened tensor."""
    flat = x.reshape(-1)
    _, idx = lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx


def topk_allreduce(
    x,
    group: DiompGroup,
    *,
    k: int,
    error: Optional[jnp.ndarray] = None,
):
    """Top-k sparsified mean with error feedback.  Returns (grad, error)."""
    if error is not None:
        x = x + error
    flat = x.reshape(-1)
    vals, idx = topk_compress(flat, k)
    sparse = jnp.zeros_like(flat).at[idx].set(vals)
    n = 1
    for ax in group.axes:
        n *= axis_size(ax)
    reduced = lax.psum(sparse, group.lax_axes) / n
    new_error = flat - sparse
    return reduced.reshape(x.shape), new_error.reshape(x.shape)


def wire_bytes(numel: int, *, codec: str, k: int = 0) -> int:
    """Bytes on the wire per rank for one reduce — roofline accounting."""
    if codec == "f32":
        return 4 * numel
    if codec == "bf16":
        return 2 * numel
    if codec == "int8":
        return numel + 4  # payload + scale
    if codec == "topk":
        return 8 * k      # (f32 value + i32 index) per kept entry
    raise ValueError(codec)

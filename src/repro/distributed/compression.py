"""Gradient compression with error feedback (distributed-optimization trick).

Not in the DiOMP paper — a beyond-paper extension for 1000+-node scale where
the inter-pod all-reduce becomes bandwidth-bound.  Two codecs:

* **int8** uniform quantization (4x wire reduction vs f32, 2x vs bf16) with
  per-tensor scale and error feedback (the residual is carried to the next
  step, which keeps SGD convergence — Karimireddy et al. 2019);
* **top-k** magnitude sparsification (wire = 2k entries) with error feedback.

On this CPU container the *wire* saving cannot be observed; the codecs are
numerically real (quantize -> reduce -> dequantize) and the byte saving is
accounted by :func:`wire_bytes` for the roofline/§Perf math.  The decode->
psum->encode structure matches what a real deployment would run as a
reduce-scatter in the compressed domain.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import all_gather_invariant, axis_size
from repro.core.groups import DiompGroup

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_allreduce",
    "topk_compress",
    "topk_allreduce",
    "wire_bytes",
]


def quantize_int8(x, *, block: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8: q = round(x/scale), scale = amax/127.

    ``block=None`` keeps the historical per-tensor scale.  With ``block``
    set, ``x`` must be flat with ``size % block == 0`` and one scale is
    emitted per ``block`` contiguous elements — the granularity a flat
    gradient *bucket* needs, where a single per-bucket amax would let one
    large-magnitude tensor wipe out the resolution of every small-gradient
    tensor packed beside it.
    """
    if block is None:
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
        return q, scale
    blocks = x.astype(jnp.float32).reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                 ).astype(jnp.int8).reshape(x.shape)
    return q, scale


def dequantize_int8(q, scale, *, block: Optional[int] = None):
    if block is None:
        return q.astype(jnp.float32) * scale
    return (q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
            ).reshape(q.shape)


def compressed_allreduce(
    x,
    group: DiompGroup,
    *,
    error: Optional[jnp.ndarray] = None,
    block: Optional[int] = None,
):
    """int8 all-reduce with error feedback (ZeRO++ qgZ-style two phase).

    Phase 1: all-to-all the int8 chunks + all-gather the scales, dequantize
    each received chunk with its *source* scale and reduce locally (an exact
    compressed-domain reduce-scatter).  Phase 2: re-quantize the reduced
    shard and all-gather it.  Wire traffic is int8 payload + f32 scales per
    phase; the only lossy steps are the two quantizations, whose residual
    feeds back via ``error``.

    ``block`` selects per-block scales (see :func:`quantize_int8`) — the
    granularity the bucketed gradient path uses, with ONE error-feedback
    state per bucket.  A flat payload already padded to ``n * block``
    (the bucket layout guarantees this) takes the no-pad fast path: no
    reshape/pad round-trip per call.

    Returns ``(mean_grad, new_error)``.
    """
    if error is not None:
        x = x + error
    n = 1
    for ax in group.axes:
        n *= axis_size(ax)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % (n * block if block else n)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])

    q, scale = quantize_int8(flat, block=block)
    # phase 1 wire: chunk i of my int8 payload -> rank i; scales broadcast
    chunks = q.reshape(n, -1)
    recv = lax.all_to_all(chunks, group.lax_axes, split_axis=0, concat_axis=0, tiled=True)
    scales = scale if block else scale.reshape(1)
    for ax in reversed(group.axes):
        scales = lax.all_gather(scales, ax, axis=0, tiled=True)
    if block:
        # (n, B) source-major scale table; my chunk spans blocks
        # [rank*Bc, (rank+1)*Bc) of every source's payload
        from repro.core.backends import group_rank

        bc = chunks.shape[1] // block
        scales = scales.reshape(n, -1)
        mine = lax.dynamic_slice_in_dim(scales, group_rank(group) * bc, bc,
                                        axis=1)
        shard = jnp.sum(
            recv.reshape(n, bc, block).astype(jnp.float32) * mine[:, :, None],
            axis=0).reshape(-1) / n
    else:
        shard = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0) / n

    # phase 2 wire: re-quantized reduced shard all-gathered back (invariant:
    # every rank reconstructs the same reduced tensor)
    q2, s2 = quantize_int8(shard, block=block)
    gathered = q2
    for ax in reversed(group.axes):
        gathered = all_gather_invariant(gathered, ax, axis=0, tiled=True)
    s2_all = s2 if block else s2.reshape(1)
    for ax in reversed(group.axes):
        s2_all = all_gather_invariant(s2_all, ax, axis=0, tiled=True)
    if block:
        out = (gathered.reshape(-1, block).astype(jnp.float32)
               * s2_all[:, None]).reshape(-1)
    else:
        out = (gathered.reshape(n, -1).astype(jnp.float32) * s2_all[:, None]).reshape(-1)
    deq = dequantize_int8(q, scale, block=block)
    if pad:
        out = out[:-pad]
        flat = flat[:-pad]
        deq = deq[:-pad]
    new_error = flat - deq
    return out.reshape(orig_shape).astype(orig_dtype), new_error.reshape(orig_shape).astype(orig_dtype)


def topk_compress(x, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the k largest-|x| entries of the flattened tensor."""
    flat = x.reshape(-1)
    _, idx = lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx


def topk_allreduce(
    x,
    group: DiompGroup,
    *,
    k: int,
    error: Optional[jnp.ndarray] = None,
):
    """Top-k sparsified mean with error feedback.  Returns (grad, error)."""
    if error is not None:
        x = x + error
    flat = x.reshape(-1)
    vals, idx = topk_compress(flat, k)
    sparse = jnp.zeros_like(flat).at[idx].set(vals)
    n = 1
    for ax in group.axes:
        n *= axis_size(ax)
    reduced = lax.psum(sparse, group.lax_axes) / n
    new_error = flat - sparse
    return reduced.reshape(x.shape), new_error.reshape(x.shape)


def wire_bytes(numel: int, *, codec: str, k: int = 0,
               block: Optional[int] = None) -> int:
    """Bytes on the wire per rank for one reduce — roofline accounting."""
    if codec == "f32":
        return 4 * numel
    if codec == "bf16":
        return 2 * numel
    if codec == "int8":
        if block:
            return numel + 4 * (-(-numel // block))  # payload + per-block scales
        return numel + 4  # payload + scale
    if codec == "topk":
        return 8 * k      # (f32 value + i32 index) per kept entry
    raise ValueError(codec)

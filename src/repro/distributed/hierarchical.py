"""Pod-aware hierarchical collectives (OMPCCL's topology-aware backend).

The paper's OMPCCL defers topology awareness to NCCL/RCCL; on TPU the
topology is the mesh itself, so the runtime *is* the topology-aware layer.
For a ("pod", "data", ...) group where "pod" rides the slow inter-pod links
and the remaining axes ride intra-pod ICI, a flat all-reduce would push the
full payload over the slow axis.  The hierarchical algorithm is the classic
three-phase decomposition:

    reduce-scatter (fast axes)  ->  all-reduce (slow axis, 1/F of the data)
                                ->  all-gather (fast axes)

which moves ``2·B·(F-1)/F`` bytes per chip on fast links and ``2·B/F·(S-1)/S``
on slow links, vs. ``2·B·(P-1)/P`` on *every* link for the flat ring
(F = fast-domain size, S = slow-domain size, P = F·S).  The inter-pod traffic
drops by a factor of F — the same reason NCCL builds intra-node rings first.

All functions run inside ``shard_map``.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
# Varying -> Invariant all-gather: same wire traffic as all_gather, but the
# type system knows every rank ends with identical bytes (transposes to
# dynamic_slice).  Exactly the semantics of an allreduce's final gather.
from repro.core.compat import all_gather_invariant, axis_size
from repro.core.groups import DiompGroup

__all__ = [
    "hierarchical_allreduce",
    "hierarchical_allgather",
    "flat_allreduce",
    "inter_pod_traffic_bytes",
]


def _sizes(axes) -> int:
    n = 1
    for ax in axes:
        n *= axis_size(ax)
    return n


def flat_allreduce(x, group: DiompGroup, *, op: str = "sum"):
    if op == "sum":
        return lax.psum(x, group.lax_axes)
    if op == "max":
        return lax.pmax(x, group.lax_axes)
    if op == "min":
        return lax.pmin(x, group.lax_axes)
    raise ValueError(op)


def hierarchical_allreduce(x, group: DiompGroup, *, op: str = "sum"):
    """RS(fast) -> AR(slow) -> AG(fast).  First group axis is the slow one.

    Exact for ``op="sum"``; other ops fall back to the flat algorithm (they
    do not decompose through a scatter).
    """
    if len(group.axes) < 2 or op != "sum":
        return flat_allreduce(x, group, op=op)

    slow, fast = group.axes[0], group.axes[1:]
    fast_size = _sizes(fast)

    shape = x.shape
    # a fast-size-divisible payload (the bucket layout guarantees this for
    # every gradient bucket) pays no pad concat and no slice on the way
    # out — the per-call cost is governed entirely by `pad` below
    flat = x.reshape(-1)
    pad = (-flat.size) % fast_size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])

    # phase 1: reduce-scatter across fast axes (innermost first so shard
    # order matches the row-major group rank order)
    shard = flat
    for ax in fast:
        shard = lax.psum_scatter(shard, ax, scatter_dimension=0, tiled=True)
    # phase 2: all-reduce across the slow axis on 1/fast_size of the bytes
    shard = lax.psum(shard, slow)
    # phase 3: all-gather across fast axes (invariant: every rank ends with
    # the same reduced tensor, and the type system knows it)
    out = shard
    for ax in reversed(fast):
        out = all_gather_invariant(out, ax, axis=0, tiled=True)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def hierarchical_allgather(x, group: DiompGroup, *, axis: int = 0):
    """Gather along fast axes first (cheap), slow axis last."""
    if len(group.axes) < 2:
        return lax.all_gather(x, group.axes[0], axis=axis, tiled=True)
    slow, fast = group.axes[0], group.axes[1:]
    out = x
    for ax in reversed(fast):
        out = lax.all_gather(out, ax, axis=axis, tiled=True)
    return lax.all_gather(out, slow, axis=axis, tiled=True)


def inter_pod_traffic_bytes(payload_bytes: int, fast_size: int, slow_size: int,
                            *, hierarchical: bool = True) -> float:
    """Analytic inter-pod bytes/chip — the §Perf napkin-math helper."""
    if slow_size <= 1:
        return 0.0
    if hierarchical:
        b = payload_bytes / fast_size
        return 2 * b * (slow_size - 1) / slow_size
    p = fast_size * slow_size
    return 2 * payload_bytes * (p - 1) / p

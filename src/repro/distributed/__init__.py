"""Distribution layer: sharding rules, hierarchical collectives, compression."""

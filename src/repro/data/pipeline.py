"""Deterministic synthetic data pipeline with async prefetch.

The stream is a counter-seeded PRNG per (step, host_shard) so every run —
and every *restart* — sees identical batches (resumable from any step), and
different DP shards see disjoint streams.  The Prefetcher runs on the DiOMP
StreamPool; its depth is the knob the straggler monitor boosts.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.streams import StreamPool
from repro.models.config import ModelConfig

__all__ = ["SyntheticLM", "Prefetcher"]


class SyntheticLM:
    """Batch factory for every model family (token / audio / vlm batches)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 977 + self.shard) % 2**31)
        cfg, B, S = self.cfg, self.batch, self.seq
        if cfg.family == "audio":
            return {
                "embeds": rng.randn(B, S, cfg.d_model).astype(np.float32) * 0.1,
                "targets": rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32),
                "mask": (rng.rand(B, S) < 0.3).astype(np.float32),
            }
        if cfg.family == "vlm":
            Ptoks = cfg.prefix_tokens
            return {
                "tokens": rng.randint(0, cfg.vocab_size,
                                      (B, S - Ptoks)).astype(np.int32),
                "prefix_embeds": rng.randn(B, Ptoks, cfg.d_model)
                    .astype(np.float32) * 0.1,
            }
        return {"tokens": rng.randint(0, cfg.vocab_size, (B, S))
                .astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Depth-bounded async prefetch on the StreamPool (boostable)."""

    def __init__(self, source: SyntheticLM, *, depth: int = 2,
                 pool: Optional[StreamPool] = None, start_step: int = 0):
        self.source = source
        self.depth = depth
        self.pool = pool or StreamPool(max_active=2)
        self._q: "queue.Queue" = queue.Queue()
        self._next_submit = start_step
        self._lock = threading.Lock()
        for _ in range(depth):
            self._submit_one()

    def _submit_one(self):
        with self._lock:
            step = self._next_submit
            self._next_submit += 1
        fut = self.pool.submit(self.source.batch_at, step)
        self._q.put((step, fut))

    def boost(self, extra: int = 1):
        """Straggler-monitor hook: deepen the pipeline."""
        self.depth += extra
        for _ in range(extra):
            self._submit_one()

    def get(self):
        step, fut = self._q.get()
        batch = fut.result()
        self._submit_one()
        return step, batch

from .pipeline import SyntheticLM, Prefetcher  # noqa: F401

"""DiompContext — the explicit entry point of the DiOMP runtime.

The paper's runtime owns ONE table: every group maps to one registered
communicator, and every collective/RMA call dispatches through it (§3.3,
Fig. 1b).  :class:`DiompContext` realizes that claim as an object you create
once per deployment::

    import repro as diomp

    ctx = diomp.init(mesh=mesh)                  # install process default
    comm = ctx.communicator(group)               # the OMPCCL handle
    y = comm.allreduce(x)                        # recorded + dispatched
    h = ctx.communicator(dp, backend="hierarchical")
    g = h.allreduce(grads)                       # pod-aware wire algorithm

The context owns

* the **group registry** (named :class:`~repro.core.groups.DiompGroup`
  handles, descriptor-validated at registration — the UniqueID handshake),
* the **GlobalMemory** PGAS arena plan,
* the **StreamPool** + **HybridPoller** (bounded async host work, §3.2),
* the **RMATracker** (host-side put/fence discipline),
* the **communicator table**: one shared per-group call log, with one
  :class:`Communicator` handle per (group, backend) pair so backend choice
  propagates to *every* op issued through that handle.

A process-default context backs the paper-verbatim free functions in
:mod:`repro.core.ompccl` / :mod:`repro.core.rma` / :mod:`repro.core.ompx`,
so listing-style code keeps working while new code holds explicit handles.
"""

from __future__ import annotations

import contextvars
import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from . import backends as _backends
from .backends import CclBackend, get_backend
from .coordination import (LocalCoordinator, ProcessCoordinator,
                           coordinator_for, init_distributed,
                           process_local_ranks)
from .faults import ChaosBackend, FaultPlan
from .groups import DiompGroup, GroupError, standard_groups
from .pgas import GlobalMemory
from .resilience import RetryPolicy, call_with_retries
from .rma import RMATracker
from .streams import HybridPoller, StreamPool

__all__ = [
    "Communicator",
    "CommTable",
    "DispatchStats",
    "DiompContext",
    "init",
    "default_context",
    "default_communicator",
    "install_default",
    "use_default",
    "reset_default_context",
]

BackendLike = Union[str, CclBackend, None]


class Communicator:
    """The OMPCCL communicator handle for one (group, backend) pair.

    Every op is (1) recorded against the group's shared call log — the
    faithful per-communicator call stream OMPCCL keeps, consumed by the
    benchmark layer — and (2) dispatched through the backend instance, so
    the backend choice made at handle creation governs *all* collectives
    and RMA verbs issued through it.  All methods are usable inside
    ``shard_map``.

    Alongside the per-op call counts, each op's *payload bytes* accumulate
    in a parallel per-group byte log (``DiompContext.byte_stats()``): the
    bucketed gradient path is sized in whole flat buckets, and the byte log
    is how benchmarks/tests verify the planned wire volume without parsing
    HLO.  Counts and bytes are trace-time numbers (one entry per call site
    per trace), same as the seed's call-count semantics — except that
    delegating ops (``reduce`` via ``allreduce``, ``get`` via ``put``)
    log their bytes only at the leaf op, so summing a group's ops never
    double-counts wire volume.

    Faults and retries: when the handle carries a :class:`RetryPolicy`
    (the context default), every verb dispatch runs under
    :func:`~repro.core.resilience.call_with_retries` — a backend raising
    ``TransientFault`` (a chaos injection, or a real transport error) is
    re-dispatched with backoff.  Re-issued *wire* traffic accumulates in
    separate retry logs (``retries`` / ``retry_nbytes``), never in the
    logical call/byte logs above, so the OMPCCL-byte-log == RMATracker
    audits keep holding exactly under chaos.
    """

    __slots__ = ("group", "backend", "calls", "nbytes",
                 "retries", "retry_nbytes", "policy")

    def __init__(self, group: DiompGroup, backend: CclBackend,
                 calls: Dict[str, int], nbytes: Dict[str, int],
                 retries: Optional[Dict[str, int]] = None,
                 retry_nbytes: Optional[Dict[str, int]] = None,
                 policy: Optional[RetryPolicy] = None):
        self.group = group
        self.backend = backend
        self.calls = calls    # shared across handles of the same group
        self.nbytes = nbytes  # op -> cumulative payload bytes, same sharing
        self.retries = {} if retries is None else retries
        self.retry_nbytes = {} if retry_nbytes is None else retry_nbytes
        self.policy = policy

    def record(self, op: str, payload=None) -> None:
        self.calls[op] = self.calls.get(op, 0) + 1
        if payload is not None:
            self.nbytes[op] = self.nbytes.get(op, 0) \
                + _backends.payload_bytes(payload)

    def record_retry(self, op: str, payload=None) -> None:
        """Account one re-issued wire attempt — kept OUT of the logical
        call/byte logs so planned-volume audits stay exact."""
        self.retries[op] = self.retries.get(op, 0) + 1
        if payload is not None:
            self.retry_nbytes[op] = self.retry_nbytes.get(op, 0) \
                + _backends.payload_bytes(payload)

    def _dispatch(self, op: str, payload, thunk):
        """Record the logical call once, then dispatch under the retry
        policy (straight through when no policy is attached)."""
        self.record(op, payload)
        if self.policy is None:
            return thunk()
        return call_with_retries(
            thunk, op, self.policy,
            on_retry=lambda attempt, tf: self.record_retry(op, payload))

    # -- collectives --------------------------------------------------------
    def allreduce(self, x, *, op: str = "sum"):
        """ompx_allreduce: reduction across the group, result everywhere."""
        return self._dispatch(
            "allreduce", x,
            lambda: self.backend.allreduce(x, self.group, op=op))

    def reduce(self, x, *, root: int = 0, op: str = "sum"):
        """ompx_reduce: like allreduce but only ``root`` keeps the result
        (others receive zeros), matching MPI_Reduce semantics in SPMD form.
        Runs through this handle's backend, so hierarchical/compressed
        wire paths apply here too.  Counts only: the inner allreduce logs
        the payload bytes, so the wire-volume log stays exact for
        delegating ops."""
        self.record("reduce")
        full = self.allreduce(x, op=op)
        rank = _backends.group_rank(self.group)
        return jnp.where(rank == root, full, jnp.zeros_like(full))

    def bcast(self, x, *, root: int = 0):
        """ompx_bcast: root's value delivered to every group member."""
        return self._dispatch(
            "bcast", x, lambda: self.backend.bcast(x, self.group, root=root))

    def allgather(self, x, *, axis: int = 0, tiled: bool = True,
                  invariant: bool = False):
        """ompx_allgather along a tensor axis (tiled: concatenates shards).

        ``invariant=True`` uses the Varying->Invariant gather: same wire
        bytes, but the type system records that every member ends with
        identical data.  Inference paths use it."""
        return self._dispatch(
            "allgather", x,
            lambda: self.backend.allgather(x, self.group, axis=axis,
                                           tiled=tiled, invariant=invariant))

    def reducescatter(self, x, *, axis: int = 0):
        """ompx_reducescatter: sum across group, scatter along ``axis``."""
        return self._dispatch(
            "reducescatter", x,
            lambda: self.backend.reducescatter(x, self.group, axis=axis))

    def alltoall(self, x, *, split_axis: int = 0, concat_axis: int = 0):
        """ompx_alltoall — the MoE dispatch primitive."""
        return self._dispatch(
            "alltoall", x,
            lambda: self.backend.alltoall(x, self.group,
                                          split_axis=split_axis,
                                          concat_axis=concat_axis))

    def permute(self, x, *, shift: int = 1):
        """Ring permute within the group — the transport under ompx_put."""
        return self._dispatch(
            "permute", x,
            lambda: self.backend.permute(x, self.group, shift=shift))

    def barrier(self):
        """A collective-ordering token (the compiled ompx_barrier)."""
        return self._dispatch(
            "barrier", None, lambda: self.backend.barrier(self.group))

    # -- one-sided RMA ------------------------------------------------------
    def put(self, x, *, shift: int = 1):
        """One-sided put to the rank ``shift`` ahead on the group's ring."""
        return self._dispatch(
            "put", x, lambda: self.backend.put(x, self.group, shift=shift))

    def put_perm(self, x, perm: Sequence[Tuple[int, int]]):
        """General one-sided put along an arbitrary (src, dst) permutation."""
        return self._dispatch(
            "put", x, lambda: self.backend.put_perm(x, self.group, perm))

    def get(self, x, *, shift: int = 1):
        """One-sided get of the shard owned by the rank ``shift`` ahead
        (a read = a put with inverted permutation).  Counts only: the
        inner put logs the payload bytes once."""
        self.record("get")
        return self.put(x, shift=-shift)

    def fence(self, *arrays):
        """Complete all outstanding RMA before anything downstream runs."""
        return _backends.fence(*arrays)

    def halo_exchange(self, x, *, halo: int, axis: int = 0):
        """Minimod's halo pattern (paper Listing 1) as one fused exchange."""
        return self._dispatch(
            "halo_exchange", x,
            lambda: self.backend.halo_exchange(x, self.group, halo=halo,
                                               axis=axis))

    # -- introspection ------------------------------------------------------
    @property
    def backend_name(self) -> str:
        return self.backend.name

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Communicator(group={self.group.name}, "
                f"backend={self.backend.name})")


class CommTable:
    """The context's communicator table (OMPCCL's per-group comm registry).

    One call log per group descriptor — shared by every backend's handle
    for that group, mirroring how OMPCCL keys NCCL communicators by group —
    plus one cached backend instance per backend name (so stateful backends
    like the analytic cost model accumulate across handles).

    When the table carries a :class:`~repro.core.faults.FaultPlan`, every
    backend instance it creates is wrapped in a
    :class:`~repro.core.faults.ChaosBackend` (caller-owned instances are
    the caller's responsibility), and every handle carries the table's
    :class:`RetryPolicy` so injected faults are retried and logged.
    """

    def __init__(self, *, fault_plan: Optional[FaultPlan] = None,
                 retry_policy: Optional[RetryPolicy] = None):
        self._comms: Dict[Tuple[str, str], Communicator] = {}
        self._calls: Dict[str, Dict[str, int]] = {}
        self._nbytes: Dict[str, Dict[str, int]] = {}
        self._retries: Dict[str, Dict[str, int]] = {}
        self._retry_nbytes: Dict[str, Dict[str, int]] = {}
        self._backends: Dict[str, CclBackend] = {}
        self.fault_plan = fault_plan
        self.retry_policy = retry_policy

    def backend_instance(self, backend: BackendLike,
                         default: str = "xla") -> CclBackend:
        if isinstance(backend, CclBackend):
            return backend
        name = backend or default
        if name not in self._backends:
            inst = get_backend(name)()
            if self.fault_plan is not None \
                    and not isinstance(inst, ChaosBackend):
                inst = ChaosBackend(inst, self.fault_plan)
            self._backends[name] = inst
        return self._backends[name]

    def communicator(self, group: DiompGroup,
                     backend: BackendLike = None) -> Communicator:
        if isinstance(backend, CclBackend):
            # caller-owned instance: keyed by identity so two differently
            # configured instances of one backend class never alias
            inst, bkey = backend, f"instance:{id(backend)}"
        else:
            inst = self.backend_instance(backend)
            bkey = inst.name
        key = (group.descriptor(), bkey)
        if key not in self._comms:
            calls = self._calls.setdefault(key[0], {})
            nbytes = self._nbytes.setdefault(key[0], {})
            retries = self._retries.setdefault(key[0], {})
            retry_nbytes = self._retry_nbytes.setdefault(key[0], {})
            self._comms[key] = Communicator(
                group, inst, calls, nbytes, retries, retry_nbytes,
                self.retry_policy)
        return self._comms[key]

    def reset(self) -> None:
        """Zero every call count IN PLACE.

        Live Communicator handles keep writing into the same dicts, so a
        reset never orphans a handle's recording (handles outlive resets in
        the new API, unlike the per-call lookups of the free functions).
        Backend instances — and e.g. the analytic backend's cost log — are
        deliberately untouched.
        """
        for calls in self._calls.values():
            calls.clear()
        for nbytes in self._nbytes.values():
            nbytes.clear()
        for retries in self._retries.values():
            retries.clear()
        for retry_nbytes in self._retry_nbytes.values():
            retry_nbytes.clear()

    def stats(self) -> Dict[str, Dict[str, int]]:
        """descriptor -> per-op call counts, aggregated over backends."""
        return {k: dict(v) for k, v in self._calls.items() if v}

    def byte_stats(self) -> Dict[str, Dict[str, int]]:
        """descriptor -> per-op cumulative payload bytes (see Communicator).

        A separate log (not folded into :meth:`stats`) so call-count
        consumers keep their exact historical shape.
        """
        return {k: dict(v) for k, v in self._nbytes.items() if v}

    def retry_stats(self) -> Dict[str, Dict[str, int]]:
        """descriptor -> per-op re-issued wire attempts (the retry log)."""
        return {k: dict(v) for k, v in self._retries.items() if v}

    def retry_byte_stats(self) -> Dict[str, Dict[str, int]]:
        """descriptor -> per-op re-issued wire bytes — the chaos overhead,
        kept apart from the logical byte log by construction."""
        return {k: dict(v) for k, v in self._retry_nbytes.items() if v}


class DispatchStats:
    """Trace-scoped auxiliary-stat collector for the MoE dispatch paths.

    The context's call/byte logs (:meth:`DiompContext.stats` /
    :meth:`DiompContext.byte_stats`) are *host-side* trace-time counters;
    token drops are *data-dependent* (the ``slot < cap`` overflow mask),
    so they must flow out of the jitted step as traced scalars.  A caller
    that wants them opens a collection frame INSIDE its traced function::

        with ctx.dispatch_stats.collect() as ds:
            loss = loss_fn(params, batch, cfg, pctx)
        dropped, routed = ds.get("moe_dropped"), ds.get("moe_routed")

    and returns the frame's values as ordinary outputs.  ``moe_block``
    records ``moe_dropped`` (capacity-overflow drops of the host
    ``a2a``/``gather`` paths; identically zero on the dropless fused
    path) and ``moe_routed`` (total (token, choice) pairs) into the
    innermost active frame; records outside any frame are discarded, so
    steps that don't ask pay nothing.  Values recorded under the same key
    accumulate by addition (layers and microbatches sum naturally).
    """

    def __init__(self):
        self._frames = []

    @property
    def active(self) -> bool:
        return bool(self._frames)

    def record(self, **values) -> None:
        if not self._frames:
            return
        frame = self._frames[-1]
        for key, val in values.items():
            frame[key] = frame[key] + val if key in frame else val

    @contextmanager
    def collect(self):
        frame: Dict[str, object] = {}
        self._frames.append(frame)
        try:
            yield frame
        finally:
            self._frames.pop()


class DiompContext:
    """One deployment's unified runtime state (paper Fig. 1b, host side).

    ``mesh`` may be None for a bootstrap context (collective recording and
    dispatch need no mesh — groups resolve axis sizes at trace time); a
    mesh-bearing context additionally validates its standard groups'
    descriptors (the UniqueID handshake) and sizes its PGAS arena per
    device.

    Chaos/resilience: pass ``fault_plan`` (a
    :class:`~repro.core.faults.FaultPlan`) to run every backend this
    context creates under deterministic fault injection; absent that, the
    ``DIOMP_CHAOS_SEED`` env var enables ambient chaos so existing suites
    can run unmodified under a fixed seed.  ``retry_policy`` governs the
    communicator-level retry/backoff (a default policy is always
    attached; see :meth:`retry_stats`).

    Multi-controller SPMD: when the job spans several processes (the mesh
    holds devices of more than one ``jax`` process), the context detects
    it, owns only its process-local PGAS arenas (remote ranks have none —
    true per-process device visibility), runs every collective allocation
    through the coordinated exchange protocol, and performs the UniqueID
    handshake *across processes*: each process's group-descriptor table is
    allgathered at construction and any divergence raises
    :class:`~repro.core.groups.GroupError` on every process.  The
    per-process call/byte logs stay host-local; :meth:`gather_stats`
    collects all of them for rank-against-rank diffing.  Context
    construction is therefore a **collective** in a multi-process job —
    every process must construct the same contexts in the same order.
    """

    def __init__(
        self,
        mesh=None,
        *,
        segment_bytes: int = 16 * 2**30,
        allocator: str = "linear",
        max_active_streams: int = 8,
        default_backend: str = "xla",
        comm_backend: str = "gasnet-ex",  # config fidelity; no-op on TPU
        fault_plan: Optional[FaultPlan] = None,
        retry_policy: Optional[RetryPolicy] = None,
        coordinator: Optional[ProcessCoordinator] = None,
    ):
        self.mesh = mesh
        self.comm_backend = comm_backend
        self.default_backend = default_backend
        self.ndev = int(mesh.devices.size) if mesh is not None else 1
        if coordinator is None:
            # a meshless bootstrap context must never touch jax (the
            # dry-run sets XLA_FLAGS first): assume single-process there
            coordinator = coordinator_for(mesh) if mesh is not None \
                else LocalCoordinator()
        self.coordinator = coordinator
        self.process_id = coordinator.process_id
        self.num_processes = coordinator.num_processes
        local_ranks = None
        if mesh is not None and self.num_processes > 1:
            local_ranks = process_local_ranks(mesh)
            if not local_ranks:
                raise GroupError(
                    f"process {self.process_id} owns no device of the mesh "
                    f"{dict(mesh.shape)} — every participating process "
                    "must contribute devices")
        self.memory = GlobalMemory(self.ndev, segment_bytes,
                                   allocator=allocator,
                                   local_ranks=local_ranks,
                                   coordinator=coordinator)
        self.groups: Dict[str, DiompGroup] = (
            standard_groups(mesh) if mesh is not None else {})
        self.streams = StreamPool(max_active=max_active_streams)
        self.poller = HybridPoller()
        self.rma = RMATracker()
        self.fault_plan = fault_plan if fault_plan is not None \
            else FaultPlan.from_env()
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy()
        self.comms = CommTable(fault_plan=self.fault_plan,
                               retry_policy=self.retry_policy)
        self.dispatch_stats = DispatchStats()
        # bootstrap: validate every group's descriptor (UniqueID handshake)
        self._descriptors = {
            name: g.validate(mesh).descriptor()
            for name, g in self.groups.items()
        } if mesh is not None else {}
        if mesh is not None and self.num_processes > 1:
            self._descriptor_handshake()

    def _descriptor_handshake(self) -> None:
        """The cross-process UniqueID handshake: every process broadcasts
        its (group name -> descriptor) table + mesh signature; any
        divergence means the processes did not construct consistent
        communicators, and every process raises before a collective can
        silently mismatch."""
        mine = {
            "descriptors": sorted(self._descriptors.items()),
            "mesh": [list(self.mesh.shape.items()), self.ndev],
        }
        rows = self.coordinator.allgather(mine)
        # compare post-JSON rows against my own round-tripped row, so the
        # check sees value differences, not serialization artifacts
        me = rows[self.process_id]
        for pid, row in enumerate(rows):
            if row != me:
                raise GroupError(
                    f"group-descriptor handshake failed: process {pid} "
                    f"registered {row}, process {self.process_id} "
                    f"registered {me} — inconsistent SPMD bootstrap")

    @property
    def multiprocess(self) -> bool:
        return self.num_processes > 1

    def gather_stats(self) -> list:
        """Per-process log snapshot, allgathered for rank-vs-rank diffing.

        Returns one dict per process (indexed by process id) holding that
        process's logical OMPCCL call/byte logs, retry logs, and RMA
        tracker counters.  In a single-process job this is a one-element
        list around the local logs — same shape, no wire traffic — so
        harnesses diff the same structure at any scale.  Collective: in a
        multi-process job every process must call it at the same point.
        """
        snapshot = {
            "process_id": self.process_id,
            "stats": self.stats(),
            "byte_stats": self.byte_stats(),
            "retry_stats": self.retry_stats(),
            "retry_byte_stats": self.retry_byte_stats(),
            "rma": {
                "puts": self.rma.puts,
                "fences": self.rma.fences,
                "put_bytes": self.rma.put_bytes,
                "window_bytes": dict(self.rma.window_bytes),
                "retry_puts": self.rma.retry_puts,
                "retry_bytes": self.rma.retry_bytes,
            },
            "pgas": {
                "alloc_counts": dict(self.memory.alloc_counts),
                "regions": [
                    [r["name"], bool(r["symmetric"]), list(r["bytes"]),
                     list(r["offsets"])]
                    for r in self.memory.mapping_table()
                ],
            },
        }
        return self.coordinator.allgather(snapshot)

    # -- group management ---------------------------------------------------
    def group(self, name: str) -> DiompGroup:
        return self.groups[name]

    def add_group(self, name: str, group: DiompGroup) -> DiompGroup:
        if self.mesh is not None:
            group.validate(self.mesh)
        self.groups[name] = group
        self._descriptors[name] = group.descriptor()
        return group

    # -- the communicator-handle API ----------------------------------------
    def communicator(self, group: Union[DiompGroup, str],
                     backend: BackendLike = None) -> Communicator:
        """The OMPCCL handle for ``group`` (by handle or registered name).

        ``backend`` is a registry name (``"xla"``, ``"hierarchical"``,
        ``"compressed"``, ``"analytic"``, or any plugin registered via
        :func:`repro.core.backends.register_backend`) or a ready
        :class:`CclBackend` instance; None uses the context default.
        """
        if isinstance(group, str):
            group = self.groups[group]
        return self.comms.communicator(
            group, backend if backend is not None else self.default_backend)

    def stats(self) -> Dict[str, Dict[str, int]]:
        """Per-group, per-op collective call counts (the OMPCCL call log)."""
        return self.comms.stats()

    def byte_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-group, per-op cumulative payload bytes (the wire-volume log
        the bucketed gradient path is audited against).  Data-dependent
        MoE routing stats (capacity-overflow drop counts) are traced
        scalars, not host counters — they live on :attr:`dispatch_stats`.
        """
        return self.comms.byte_stats()

    def retry_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-group, per-op re-issued wire attempts (chaos/fault retries)."""
        return self.comms.retry_stats()

    def retry_byte_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-group, per-op re-issued wire bytes — accounted apart from
        :meth:`byte_stats` so planned-volume audits hold under chaos."""
        return self.comms.retry_byte_stats()

    def reset_stats(self) -> None:
        self.comms.reset()

    # -- synchronization -----------------------------------------------------
    def fence(self, timeout_s: float = 120.0) -> None:
        """Host-side ompx_fence: drain streams + every registered poll
        source, then advance the RMA epoch."""
        self.streams.synchronize_all()
        self.poller.fence(timeout_s=timeout_s)
        self.rma.on_fence()

    def close(self) -> None:
        self.streams.close()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        shape = dict(self.mesh.shape) if self.mesh is not None else None
        proc = (f", process={self.process_id}/{self.num_processes}"
                if self.num_processes > 1 else "")
        return (f"DiompContext(ndev={self.ndev}, mesh={shape}, "
                f"groups={sorted(self.groups)}, "
                f"default_backend={self.default_backend!r}{proc})")


# ---------------------------------------------------------------------------
# default context (backs the paper-verbatim ompx_* free functions)
#
# Two layers: a process-wide default (init / install_default — visible from
# every thread, the deployment's one table) and a ContextVar overlay for
# scoped use (use_default — token-paired and per-thread/per-task, so nested
# or concurrent scopes can never permanently clobber the process default).
# ---------------------------------------------------------------------------

_default: Optional[DiompContext] = None
_default_lock = threading.Lock()
_scoped: "contextvars.ContextVar[Optional[DiompContext]]" = \
    contextvars.ContextVar("diomp_scoped_context", default=None)


def install_default(ctx: DiompContext) -> DiompContext:
    """Install ``ctx`` as the process default (returns it)."""
    global _default
    with _default_lock:
        _default = ctx
    return ctx


def init(mesh=None, *, coordinator=None, num_processes: Optional[int] = None,
         process_id: Optional[int] = None,
         local_device_count: Optional[int] = None, **kwargs) -> DiompContext:
    """Create a :class:`DiompContext` and install it as the process default.

    ``diomp.init(mesh=...)`` is the one entry point the paper's listings
    assume: after it, both explicit handles (``ctx.communicator(...)``) and
    the compat free functions (``ompx_allreduce`` etc.) hit the same table.

    Multi-controller SPMD entry (built on ``jax.distributed.initialize``)::

        diomp.init(coordinator="host:1234", num_processes=4, process_id=i,
                   local_device_count=2)      # join the job, no mesh yet
        mesh = make_process_mesh(ndev_per_proc=2)
        ctx = diomp.init(mesh=mesh)           # the process-aware context

    ``coordinator`` is process 0's ``host:port`` (every process passes the
    same address), or a ready
    :class:`~repro.core.coordination.ProcessCoordinator` for tests that
    stub the exchange.  The two-step shape exists because a mesh can only
    be built *after* the job is joined (device visibility is per-process);
    passing ``mesh`` together with ``coordinator`` does both at once.
    """
    if isinstance(coordinator, str):
        init_distributed(coordinator, num_processes, process_id,
                         local_device_count=local_device_count)
        coordinator = None
    elif coordinator is None and (num_processes is not None
                                  or process_id is not None):
        raise ValueError(
            "num_processes/process_id need coordinator='host:port' "
            "(the jax.distributed coordination service address)")
    if coordinator is not None:
        kwargs["coordinator"] = coordinator
    return install_default(DiompContext(mesh=mesh, **kwargs))


def default_context() -> DiompContext:
    """The active context: the innermost ``use_default`` scope if one is
    open on this thread, else the process default (bootstrapping a
    meshless one on first use — collective recording needs no mesh)."""
    scoped = _scoped.get()
    if scoped is not None:
        return scoped
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = DiompContext(segment_bytes=1 << 20)
    return _default


@contextmanager
def use_default(ctx: DiompContext):
    """Make ``ctx`` the active context within the ``with`` block — for
    query-style tooling (dry-run cells, serve engines, report generators)
    that must not hijack the application's process default.  ContextVar-
    scoped: concurrent scopes on other threads are unaffected, and exit
    restores exactly what this scope shadowed."""
    token = _scoped.set(ctx)
    try:
        yield ctx
    finally:
        _scoped.reset(token)


def default_communicator(group: DiompGroup,
                         backend: BackendLike = None) -> Communicator:
    """The active context's communicator handle for ``group`` — the single
    resolution point behind every paper-verbatim free function
    (:mod:`repro.core.ompccl`, :mod:`repro.core.rma`)."""
    return default_context().communicator(group, backend)


def reset_default_context() -> None:
    """Drop the process default (tests); the next use bootstraps afresh."""
    global _default
    with _default_lock:
        _default = None

"""DiompRuntime — the unified runtime of paper Fig. 1(b).

A registration layer over one :class:`~repro.core.context.DiompContext`,
which owns what MPI+libomptarget keep in separate, duplicated tables:

* the **mesh** (the topology the PGAS space spans),
* the **GlobalMemory** arena plan (symmetric/asymmetric regions),
* the **groups** (communicators) and their OMPCCL communicator table,
* the **StreamPool** (bounded async host work: checkpoint I/O, prefetch),
* the **sharding rules** that translate logical placement to mesh axes.

Every tensor the framework materializes is *registered* here first: the same
table entry records its arena offsets, its sharding spec and its group — so
the compute layer (jit/shard_map), the P2P layer (rma.py) and the collective
layer (ompccl.py) read one source of truth.  That is the paper's "deep
integration" claim, realized as: registration returns the NamedSharding the
jax layer must use, and the byte plan the checkpoint layer must follow.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed import sharding as shrd
from .context import DiompContext, install_default as _install_default
from .groups import DiompGroup
from .pgas import GlobalMemory, Region, SecondLevelPtr

__all__ = ["DiompRuntime", "RegisteredTensor"]

_DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2, "int8": 1, "uint8": 1,
    "int32": 4, "int64": 8, "bool": 1, "float64": 8, "uint32": 4,
}


def dtype_bytes(dtype) -> int:
    return _DTYPE_BYTES[str(np.dtype(dtype) if not hasattr(dtype, "name") else dtype.name)]


@dataclasses.dataclass(frozen=True)
class RegisteredTensor:
    """One row of the unified mapping table."""

    name: str
    shape: Tuple[int, ...]
    dtype: str
    logical_axes: Tuple[Optional[str], ...]
    spec: PartitionSpec
    region: Any  # Region | SecondLevelPtr
    group: DiompGroup

    @property
    def symmetric(self) -> bool:
        r = self.region.region if isinstance(self.region, SecondLevelPtr) else self.region
        return r.symmetric


class DiompRuntime:
    """The single-process, multi-device deployment model the paper argues for.

    JAX's single-controller multi-device execution *is* DiOMP's preferred
    "one process drives N accelerators" mode: host threads stay unified (the
    StreamPool drives async I/O) while collectives run on-device through
    OMPCCL groups.
    """

    def __init__(
        self,
        mesh: Mesh,
        *,
        segment_bytes: int = 16 * 2**30,
        allocator: str = "linear",
        rules: shrd.ShardingRules = shrd.DEFAULT_RULES,
        max_active_streams: int = 8,
        comm_backend: str = "gasnet-ex",  # kept for config fidelity; no-op on TPU
        context: Optional[DiompContext] = None,
        install_default: bool = True,
    ):
        # the runtime is a registration layer over ONE DiompContext; creating
        # a runtime installs its context as the process default so the
        # paper-verbatim free functions and the registered tensors share the
        # same table (the Fig. 1b "deep integration").
        if context is None:
            context = DiompContext(
                mesh=mesh,
                segment_bytes=segment_bytes,
                allocator=allocator,
                max_active_streams=max_active_streams,
                comm_backend=comm_backend,
            )
        if install_default:
            _install_default(context)
        self.ctx = context
        self.mesh = context.mesh if context.mesh is not None else mesh
        self.rules = rules
        self.comm_backend = context.comm_backend
        self.ndev = context.ndev
        self.memory = context.memory
        self.groups: Dict[str, DiompGroup] = context.groups
        self.streams = context.streams
        self.poller = context.poller
        self.rma = context.rma
        self.ccl = context.comms
        self._table: Dict[str, RegisteredTensor] = {}

    # -- group management ------------------------------------------------------
    def group(self, name: str) -> DiompGroup:
        return self.groups[name]

    def add_group(self, name: str, group: DiompGroup) -> DiompGroup:
        return self.ctx.add_group(name, group)

    def communicator(self, group, backend=None):
        """The OMPCCL communicator handle (delegates to the context)."""
        return self.ctx.communicator(group, backend)

    # -- registration (the Fig. 1(b) mapping table) ------------------------------
    def register(
        self,
        name: str,
        shape: Sequence[int],
        dtype: str,
        logical_axes: Sequence[Optional[str]],
        *,
        group: Optional[DiompGroup] = None,
        symmetric: bool = True,
        sizes: Optional[Sequence[int]] = None,
    ) -> RegisteredTensor:
        """Plan a tensor into the PGAS space; returns its table row.

        Symmetric (default): every device holds an identically-sized shard —
        parameters, optimizer state, activations.  Asymmetric: per-device
        sizes differ (``sizes`` required) — KV pages, ragged serving state.
        """
        if name in self._table:
            raise ValueError(f"tensor {name!r} already registered")
        group = group or self.groups["world"]
        spec = shrd.logical_to_spec(logical_axes, self.mesh, self.rules)
        if symmetric:
            nbytes = shrd.param_bytes_per_device(
                shape, dtype_bytes(dtype), logical_axes, self.mesh, self.rules
            )
            region: Any = self.memory.alloc_symmetric(
                name, nbytes, group, tuple(logical_axes), dtype
            )
        else:
            if sizes is None:
                raise ValueError("asymmetric registration requires per-device sizes")
            region = self.memory.alloc_asymmetric(
                name, list(sizes), group, tuple(logical_axes), dtype
            )
        row = RegisteredTensor(
            name=name,
            shape=tuple(shape),
            dtype=dtype,
            logical_axes=tuple(logical_axes),
            spec=spec,
            region=region,
            group=group,
        )
        self._table[name] = row
        self.rma.register(name)
        return row

    def register_pytree(
        self,
        prefix: str,
        shapes: Dict[str, Tuple[Tuple[int, ...], str, Tuple[Optional[str], ...]]],
        *,
        group: Optional[DiompGroup] = None,
    ) -> Dict[str, RegisteredTensor]:
        return {
            k: self.register(f"{prefix}/{k}", shp, dt, axes, group=group)
            for k, (shp, dt, axes) in shapes.items()
        }

    def release(self, name: str) -> None:
        row = self._table.pop(name)
        self.memory.free(row.region)

    # -- placement --------------------------------------------------------------
    def sharding_for(self, name_or_axes) -> NamedSharding:
        if isinstance(name_or_axes, str):
            spec = self._table[name_or_axes].spec
        else:
            spec = shrd.logical_to_spec(name_or_axes, self.mesh, self.rules)
        return NamedSharding(self.mesh, spec)

    def place(self, name: str, value):
        """Device-put a host value according to its registered spec."""
        return jax.device_put(value, self.sharding_for(name))

    # -- synchronization ---------------------------------------------------------
    def fence(self, timeout_s: float = 120.0) -> None:
        """Host-side ompx_fence: drain streams + every registered poll source."""
        self.ctx.fence(timeout_s=timeout_s)

    # -- introspection ------------------------------------------------------------
    def table(self) -> List[RegisteredTensor]:
        return list(self._table.values())

    def lookup(self, name: str) -> RegisteredTensor:
        return self._table[name]

    def bytes_in_use(self, device: int = 0) -> int:
        return self.memory.bytes_in_use(device)

    def report(self) -> str:
        lines = [
            f"DiompRuntime: {self.ndev} devices, mesh {dict(self.mesh.shape)}, "
            f"backend={self.comm_backend}",
            f"heap: {self.bytes_in_use()/2**20:.1f} MiB/device in "
            f"{len(self._table)} regions",
        ]
        for row in self._table.values():
            lines.append(
                f"  {row.name:<40s} {str(row.shape):<24s} {row.dtype:<9s} "
                f"spec={row.spec} group={row.group.name} "
                f"{'sym' if row.symmetric else 'asym'}"
            )
        return "\n".join(lines)

    def close(self) -> None:
        self.ctx.close()

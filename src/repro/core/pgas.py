"""PGAS global memory for the DiOMP-JAX runtime.

Reproduces the paper's §3.1–3.2 memory architecture on TPU:

* a **global segment** per device (the GASNet-EX segment), carved up by a
  **linear** or **buddy** allocator;
* **symmetric allocation**: every rank allocates identical bytes, so a region
  is addressed remotely as ``(remote_base + local_offset)`` — here: identical
  per-device shard sizes, addressed as ``(device_index, offset)``;
* **asymmetric allocation**: per-rank sizes differ; a uniformly-replicated
  **second-level pointer** (32-byte wrapper) holds each rank's actual address,
  and a **remote-pointer cache** avoids re-fetching it (paper Fig. 2 (as-1));
* a **centralized mapping table** shared by compute, P2P and collective layers
  (paper Fig. 1(b)) — here the table also records the sharding spec and the
  owning group, so the same metadata steers ``jax`` placement, OMPCCL calls
  and checkpoint layout.

On TPU the actual bytes live inside XLA-managed buffers; what the runtime
owns is the *address space plan*: which arena offsets a logical region uses on
which devices.  That plan is exactly what the serving KV-cache allocator needs
(pages = asymmetric regions; page table = the second-level pointer table), and
what the checkpoint manager uses to lay out shards.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .coordination import LocalCoordinator, ProcessCoordinator
from .groups import DiompGroup

__all__ = [
    "AllocError",
    "LinearAllocator",
    "BuddyAllocator",
    "Region",
    "SecondLevelPtr",
    "RemotePtrCache",
    "GlobalMemory",
]

_ALIGN = 256  # bytes; TPU-friendly alignment (≥ lane*dtype granularity)
_SLP_BYTES = 32  # the paper's 32-byte second-level pointer wrapper


def _align_up(n: int, a: int = _ALIGN) -> int:
    return (n + a - 1) // a * a


class AllocError(RuntimeError):
    """Out of segment space / invalid free."""


# ---------------------------------------------------------------------------
# allocators (paper: "strategies such as a linear heap allocator or a buddy
# allocator to build a unified PGAS global space")
# ---------------------------------------------------------------------------


class LinearAllocator:
    """Bump allocator with free-list coalescing — the paper's 'linear heap'."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        # sorted list of (offset, size) free extents
        self._free: List[Tuple[int, int]] = [(0, capacity)]
        self._live: Dict[int, int] = {}  # offset -> size

    def alloc(self, size: int) -> int:
        size = _align_up(max(size, 1))
        for i, (off, ext) in enumerate(self._free):
            if ext >= size:
                if ext == size:
                    self._free.pop(i)
                else:
                    self._free[i] = (off + size, ext - size)
                self._live[off] = size
                return off
        raise AllocError(f"linear allocator: no extent for {size} bytes")

    def free(self, offset: int) -> None:
        size = self._live.pop(offset, None)
        if size is None:
            raise AllocError(f"invalid free at offset {offset}")
        self._free.append((offset, size))
        self._free.sort()
        # coalesce
        merged: List[Tuple[int, int]] = []
        for off, ext in self._free:
            if merged and merged[-1][0] + merged[-1][1] == off:
                merged[-1] = (merged[-1][0], merged[-1][1] + ext)
            else:
                merged.append((off, ext))
        self._free = merged

    def free_extents(self) -> List[Tuple[int, int]]:
        """Sorted (offset, size) free extents — coordinated-alloc input."""
        return list(self._free)

    def alloc_at(self, offset: int, size: int) -> int:
        """Place ``size`` bytes at exactly ``offset`` (coordinated symmetric
        allocation: every rank commits the same offset)."""
        size = _align_up(max(size, 1))
        for i, (off, ext) in enumerate(self._free):
            if off <= offset and offset + size <= off + ext:
                pieces: List[Tuple[int, int]] = []
                if offset > off:
                    pieces.append((off, offset - off))
                if off + ext > offset + size:
                    pieces.append((offset + size, off + ext - offset - size))
                self._free[i:i + 1] = pieces
                self._live[offset] = size
                return offset
        raise AllocError(f"linear allocator: offset {offset} not free for "
                         f"{size} bytes")

    def alignment_for(self, size: int) -> int:
        del size
        return _ALIGN

    @property
    def bytes_in_use(self) -> int:
        return sum(self._live.values())

    @property
    def bytes_free(self) -> int:
        return sum(ext for _, ext in self._free)

    def check_invariants(self) -> None:
        """Free + live extents exactly tile [0, capacity) without overlap."""
        extents = sorted(
            [(o, s, "free") for o, s in self._free]
            + [(o, s, "live") for o, s in self._live.items()]
        )
        cursor = 0
        for off, size, _kind in extents:
            if off != cursor:
                raise AssertionError(f"gap/overlap at {cursor}..{off}")
            cursor = off + size
        if cursor != self.capacity:
            raise AssertionError(f"heap ends at {cursor}, capacity {self.capacity}")


class BuddyAllocator:
    """Power-of-two buddy allocator — the paper's alternative strategy.

    O(log n) alloc/free with bounded fragmentation; preferred for the
    serving KV-page arena where pages churn at high rate.
    """

    MIN_BLOCK = _ALIGN

    def __init__(self, capacity: int):
        cap = self.MIN_BLOCK
        while cap < capacity:
            cap <<= 1
        self.capacity = cap
        self._max_order = (cap // self.MIN_BLOCK).bit_length() - 1
        self._free: List[List[int]] = [[] for _ in range(self._max_order + 1)]
        self._free[self._max_order].append(0)
        self._live: Dict[int, int] = {}  # offset -> order

    def _order_for(self, size: int) -> int:
        size = max(size, self.MIN_BLOCK)
        order = 0
        block = self.MIN_BLOCK
        while block < size:
            block <<= 1
            order += 1
        return order

    def alloc(self, size: int) -> int:
        order = self._order_for(size)
        if order > self._max_order:
            raise AllocError(f"buddy: request {size} exceeds capacity")
        o = order
        while o <= self._max_order and not self._free[o]:
            o += 1
        if o > self._max_order:
            raise AllocError(f"buddy: no block of order {order}")
        off = self._free[o].pop()
        while o > order:  # split down
            o -= 1
            buddy = off + (self.MIN_BLOCK << o)
            self._free[o].append(buddy)
        self._live[off] = order
        return off

    def free(self, offset: int) -> None:
        order = self._live.pop(offset, None)
        if order is None:
            raise AllocError(f"buddy: invalid free at {offset}")
        while order < self._max_order:
            size = self.MIN_BLOCK << order
            buddy = offset ^ size
            if buddy in self._free[order]:
                self._free[order].remove(buddy)
                offset = min(offset, buddy)
                order += 1
            else:
                break
        self._free[order].append(offset)

    def free_extents(self) -> List[Tuple[int, int]]:
        """Sorted (offset, size) of free blocks (uncoalesced: adjacent buddy
        blocks of different parents cannot serve one allocation)."""
        return sorted(
            (off, self.MIN_BLOCK << o)
            for o, blocks in enumerate(self._free)
            for off in blocks
        )

    def alloc_at(self, offset: int, size: int) -> int:
        """Claim the block at exactly ``offset`` (must be block-aligned for
        the request's order), splitting a containing free block down."""
        order = self._order_for(size)
        bsize = self.MIN_BLOCK << order
        if offset % bsize:
            raise AllocError(f"buddy: offset {offset} misaligned for {size}")
        for o in range(order, self._max_order + 1):
            sz = self.MIN_BLOCK << o
            cand = (offset // sz) * sz
            if cand in self._free[o]:
                self._free[o].remove(cand)
                while o > order:  # split toward the requested offset
                    o -= 1
                    half = self.MIN_BLOCK << o
                    if offset < cand + half:
                        self._free[o].append(cand + half)
                    else:
                        self._free[o].append(cand)
                        cand = cand + half
                self._live[offset] = order
                return offset
        raise AllocError(f"buddy: offset {offset} not free for {size} bytes")

    def alignment_for(self, size: int) -> int:
        return self.MIN_BLOCK << self._order_for(size)

    @property
    def bytes_in_use(self) -> int:
        return sum(self.MIN_BLOCK << o for o in self._live.values())

    @property
    def bytes_free(self) -> int:
        return sum(len(blocks) * (self.MIN_BLOCK << o) for o, blocks in enumerate(self._free))

    def check_invariants(self) -> None:
        if self.bytes_in_use + self.bytes_free != self.capacity:
            raise AssertionError("buddy accounting mismatch")
        seen = set()
        for o, blocks in enumerate(self._free):
            for off in blocks:
                if off % (self.MIN_BLOCK << o) != 0:
                    raise AssertionError(f"misaligned free block {off} order {o}")
                rng = (off, off + (self.MIN_BLOCK << o))
                for s in seen:
                    if rng[0] < s[1] and s[0] < rng[1]:
                        raise AssertionError("overlapping free blocks")
                seen.add(rng)


# ---------------------------------------------------------------------------
# regions + second-level pointers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Region:
    """One PGAS allocation in the centralized mapping table."""

    rid: int
    name: str
    symmetric: bool
    # per-rank byte sizes; for symmetric regions all entries are equal
    sizes: Tuple[int, ...]
    # per-rank arena offsets
    offsets: Tuple[int, ...]
    group: DiompGroup
    # sharding metadata consumed by the jax layer (logical axis names)
    logical_axes: Tuple[Optional[str], ...] = ()
    dtype: str = "bfloat16"

    def remote_address(self, rank: int) -> Tuple[int, int]:
        """(rank, offset) of this region on ``rank`` — the put/get target.

        For symmetric regions offset is identical on every rank (offset-based
        translation); for asymmetric regions callers must go through the
        second-level pointer instead (enforced here).
        """
        if not self.symmetric:
            raise AllocError(
                f"region {self.name!r} is asymmetric: dereference via "
                "SecondLevelPtr, not direct offset translation"
            )
        return (rank, self.offsets[rank])


@dataclasses.dataclass(frozen=True)
class SecondLevelPtr:
    """The paper's 32-byte uniformly-allocated pointer wrapper.

    Symmetrically allocated on all ranks (same slot offset everywhere), its
    *value* on rank r is the address of rank r's asymmetric payload.
    """

    slot_offset: int  # symmetric — identical on all ranks
    region: Region

    def dereference(self, rank: int) -> Tuple[int, int]:
        if self.region.sizes[rank] == 0:
            raise AllocError(
                f"rank {rank} holds no payload of region "
                f"{self.region.name!r} (zero-size asymmetric rank)")
        return (rank, self.region.offsets[rank])


class RemotePtrCache:
    """Cache of fetched second-level pointer values (paper §3.2).

    Each miss models a round-trip fetch of the remote pointer value; hits skip
    it.  The runtime invalidates entries when a region is freed — validity is
    guaranteed "throughout the lifetime of its corresponding allocation".
    """

    def __init__(self):
        self._cache: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self.hits = 0
        self.misses = 0

    def lookup(self, ptr: SecondLevelPtr, rank: int) -> Tuple[int, int]:
        key = (ptr.region.rid, rank)
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.misses += 1  # first access pays the two-step communication
        addr = ptr.dereference(rank)
        self._cache[key] = addr
        return addr

    def invalidate_region(self, rid: int) -> None:
        for key in [k for k in self._cache if k[0] == rid]:
            del self._cache[key]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# ---------------------------------------------------------------------------
# the global memory manager
# ---------------------------------------------------------------------------


class GlobalMemory:
    """DiOMP's unified memory view: one arena per rank + one mapping table.

    ``nranks`` is the number of participants of the world group (devices).
    ``segment_bytes`` models each device's registered global segment (on v5e:
    the HBM slice the runtime plans into, default 16 GB).

    Multi-controller mode: in a multi-process job each process *owns* only
    the arenas of its ``local_ranks`` (device visibility is per-process);
    remote ranks have no arena object here at all.  Every collective
    allocation then runs the paper's "all participating nodes coordinate"
    protocol over ``coordinator``: symmetric allocs agree on one common
    offset from the *intersection of every process's free extents* (not a
    single process's view), asymmetric allocs assemble the global
    size/offset vectors from per-process contributions, and any process's
    local failure is voted into a collective failure so all processes
    raise (or commit) together.  The default — all ranks local, a
    :class:`~repro.core.coordination.LocalCoordinator` — is bit-for-bit
    the old single-controller behavior.
    """

    def __init__(
        self,
        nranks: int,
        segment_bytes: int = 16 * 2**30,
        allocator: str = "linear",
        *,
        local_ranks: Optional[Sequence[int]] = None,
        coordinator: Optional[ProcessCoordinator] = None,
    ):
        if nranks <= 0:
            raise ValueError("nranks must be positive")
        self.nranks = nranks
        self.segment_bytes = segment_bytes
        self.coordinator = coordinator if coordinator is not None \
            else LocalCoordinator()
        if local_ranks is None:
            local_ranks = range(nranks)
        self.local_ranks: Tuple[int, ...] = tuple(int(r) for r in local_ranks)
        if not self.local_ranks:
            raise ValueError("a process must own at least one rank")
        for r in self.local_ranks:
            if not 0 <= r < nranks:
                raise ValueError(f"local rank {r} outside [0, {nranks})")
        alloc_cls = {"linear": LinearAllocator, "buddy": BuddyAllocator}[allocator]
        local = set(self.local_ranks)
        self._arenas: List[Optional[object]] = [
            alloc_cls(segment_bytes) if r in local else None
            for r in range(nranks)
        ]
        self._slp_arena = LinearAllocator(2**20)  # symmetric 1 MiB SLP table
        self._regions: Dict[int, Region] = {}
        self._slps: Dict[int, SecondLevelPtr] = {}
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self.ptr_cache = RemotePtrCache()
        # arena-traffic counters: how many collective alloc/free calls hit
        # the arenas.  The serving KV allocator's free-list is audited
        # against these (page churn must NOT translate into arena churn —
        # see docs/SERVING.md).
        self.alloc_counts = {"symmetric": 0, "asymmetric": 0, "free": 0}

    @property
    def multiprocess(self) -> bool:
        return self.coordinator.num_processes > 1

    def _local_arenas(self):
        """(rank, arena) pairs this process owns, in rank order."""
        return [(r, self._arenas[r]) for r in self.local_ranks]

    def _arena(self, rank: int):
        if not 0 <= rank < self.nranks:
            raise AllocError(f"rank {rank} outside [0, {self.nranks})")
        arena = self._arenas[rank]
        if arena is None:
            raise AllocError(
                f"rank {rank} is not process-local (this process owns "
                f"{self.local_ranks}); remote arenas are reachable only "
                "through the coordinated collective calls")
        return arena

    # -- collective allocation (paper: "all participating nodes coordinate") --
    def alloc_symmetric(
        self,
        name: str,
        size: int,
        group: DiompGroup,
        logical_axes: Tuple[Optional[str], ...] = (),
        dtype: str = "bfloat16",
    ) -> Region:
        """Identical ``size`` bytes at the SAME offset on every rank —
        the offset-translation property remote puts/gets rely on.

        Fast path: arenas still in lockstep (collective alloc/free only)
        hand out identical offsets independently.  Once asymmetric
        allocations have diverged the arenas, the collective falls back to
        a *coordinated* allocation: intersect every rank's free extents —
        across all processes in a multi-controller job — and commit the
        first common offset on all ranks (the paper's "all participating
        nodes coordinate").
        """
        with self._lock:
            self.alloc_counts["symmetric"] += 1
            offsets = []
            done = []
            try:
                for _, arena in self._local_arenas():
                    offsets.append(arena.alloc(size))
                    done.append(arena)
            except AllocError:
                for arena, off in zip(done, offsets):
                    arena.free(off)
                offsets, done = [], []
            candidate = offsets[0] if offsets and len(set(offsets)) == 1 \
                else -1
            if self.multiprocess:
                # one common offset needs *global* agreement, not just the
                # local arenas': vote the candidate across processes
                votes = self.coordinator.allgather(candidate)
                if candidate >= 0 and any(v != candidate for v in votes):
                    candidate = -1
            if candidate < 0 and offsets:
                # diverged (asymmetric churn, or a remote process saw a
                # different offset): roll back and retry coordinated
                for arena, off in zip(done, offsets):
                    arena.free(off)
                offsets = []
            if not offsets:
                common = self._alloc_common_offset(size)
                offsets = [common] * len(self.local_ranks)
            offsets = self._assemble_symmetric(offsets)
            region = Region(
                rid=next(self._rid),
                name=name,
                symmetric=True,
                sizes=tuple([size] * self.nranks),
                offsets=tuple(offsets),
                group=group,
                logical_axes=logical_axes,
                dtype=dtype,
            )
            self._regions[region.rid] = region
            return region

    def _assemble_symmetric(self, local_offsets: List[int]) -> List[int]:
        """Expand the agreed common offset to the global per-rank vector
        (symmetric by construction: one offset everywhere)."""
        return [local_offsets[0]] * self.nranks

    def _alloc_common_offset(self, size: int) -> int:
        """Coordinated symmetric allocation across diverged arenas.

        Intersects all ranks' free extents — every process contributes its
        *local* arenas' extents, and the global intersection is computed
        identically everywhere from the exchanged lists — then commits the
        first aligned offset every arena of every process can honor.  A
        candidate any process cannot place is rolled back on all of them
        (a per-candidate commit vote), so the chosen offset is common by
        protocol, not by assumption.
        """

        def intersect(a: List[Tuple[int, int]], b: List[Tuple[int, int]]):
            out: List[Tuple[int, int]] = []
            i = j = 0
            while i < len(a) and j < len(b):
                lo = max(a[i][0], b[j][0])
                hi = min(a[i][0] + a[i][1], b[j][0] + b[j][1])
                if lo < hi:
                    out.append((lo, hi - lo))
                if a[i][0] + a[i][1] < b[j][0] + b[j][1]:
                    i += 1
                else:
                    j += 1
            return out

        local = self._local_arenas()
        exts = sorted(local[0][1].free_extents())
        for _, arena in local[1:]:
            exts = intersect(exts, sorted(arena.free_extents()))
        align = max(arena.alignment_for(size) for _, arena in local)
        if self.multiprocess:
            # per-process contributions -> one global view on every process
            contributions = self.coordinator.allgather(
                {"extents": [list(e) for e in exts], "align": align})
            exts = [tuple(e) for e in contributions[0]["extents"]]
            for contrib in contributions[1:]:
                exts = intersect(
                    exts, [tuple(e) for e in contrib["extents"]])
            align = max(int(c["align"]) for c in contributions)
        needed = _align_up(max(size, 1), align)
        for off, ext in exts:
            cand = _align_up(off, align)
            if cand + needed > off + ext:
                continue
            placed = []
            ok = True
            try:
                for _, arena in local:
                    arena.alloc_at(cand, size)
                    placed.append(arena)
            except AllocError:
                ok = False
            if self.multiprocess:
                ok = all(self.coordinator.allgather(ok))
            if ok:
                return cand
            for arena in placed:
                arena.free(cand)
        raise AllocError(
            f"no common symmetric offset for {size} bytes across "
            f"{self.nranks} diverged arenas"
            + (f" on {self.coordinator.num_processes} processes"
               if self.multiprocess else ""))

    def alloc_asymmetric(
        self,
        name: str,
        sizes: Optional[Sequence[int]] = None,
        group: DiompGroup = None,
        logical_axes: Tuple[Optional[str], ...] = (),
        dtype: str = "bfloat16",
        *,
        local_sizes: Optional[Sequence[int]] = None,
    ) -> SecondLevelPtr:
        """Per-rank sizes differ; returns the second-level pointer handle.

        Implementation detail from the paper: the wrapper slots are
        symmetric (identical offset on all ranks), while payloads land
        "at the end of the global segment" wherever each arena has room.
        A size of 0 means the rank holds NO payload at all (fully ragged
        allocation — e.g. a KV page homed on one rank): only the symmetric
        32-byte wrapper exists there, recorded as offset -1.

        Multi-controller extent exchange: callers pass either the full
        global ``sizes`` vector (every process must pass the same one —
        verified collectively, a torn bootstrap raises everywhere) or
        ``local_sizes`` covering only this process's :attr:`local_ranks`;
        the global vector is then *assembled from per-process
        contributions*.  Either way each process places payloads only in
        its own arenas, and the per-rank offsets of the mapping-table
        entry are exchanged so every process records the identical,
        globally-consistent :class:`Region`.
        """
        if (sizes is None) == (local_sizes is None):
            raise ValueError("pass exactly one of sizes / local_sizes")
        if local_sizes is not None:
            if len(local_sizes) != len(self.local_ranks):
                raise ValueError(
                    f"need {len(self.local_ranks)} local sizes for ranks "
                    f"{self.local_ranks}, got {len(local_sizes)}")
            sizes = self._exchange_sizes(local_sizes)
        if len(sizes) != self.nranks:
            raise ValueError(f"need {self.nranks} sizes, got {len(sizes)}")
        with self._lock:
            self.alloc_counts["asymmetric"] += 1
            slot = self._slp_arena.alloc(_SLP_BYTES)
            offsets = {}
            ok = True
            try:
                for rank, arena in self._local_arenas():
                    size = sizes[rank]
                    offsets[rank] = -1 if size <= 0 else arena.alloc(size)
            except AllocError:
                ok = False
            err = None
            if self.multiprocess:
                offsets, ok, err = self._exchange_asymmetric(
                    sizes, offsets, slot, ok)
            if not ok:
                for rank, off in offsets.items():
                    if off >= 0 and self._arenas[rank] is not None:
                        self._arenas[rank].free(off)
                self._slp_arena.free(slot)
                raise AllocError(
                    err or f"asymmetric allocation {name!r} failed "
                    "collectively (no room on at least one rank)")
            offsets = [offsets.get(r, -1) for r in range(self.nranks)]
            region = Region(
                rid=next(self._rid),
                name=name,
                symmetric=False,
                sizes=tuple(int(s) for s in sizes),
                offsets=tuple(offsets),
                group=group,
                logical_axes=logical_axes,
                dtype=dtype,
            )
            self._regions[region.rid] = region
            slp = SecondLevelPtr(slot_offset=slot, region=region)
            self._slps[region.rid] = slp
            return slp

    def _exchange_sizes(self, local_sizes: Sequence[int]) -> List[int]:
        """Assemble the global size vector from per-process contributions
        (each process speaks only for its own ranks)."""
        payload = [[int(r), int(s)]
                   for r, s in zip(self.local_ranks, local_sizes)]
        rows = self.coordinator.allgather(payload)
        full: Dict[int, int] = {}
        for row in rows:
            for r, s in row:
                if int(r) in full:
                    raise AllocError(
                        f"extent exchange: rank {r} contributed twice "
                        "(overlapping local_ranks across processes)")
                full[int(r)] = int(s)
        if sorted(full) != list(range(self.nranks)):
            raise AllocError(
                f"extent exchange covered ranks {sorted(full)}, "
                f"expected 0..{self.nranks - 1}")
        return [full[r] for r in range(self.nranks)]

    def _exchange_asymmetric(self, sizes, offsets, slot, ok):
        """One collective round that (a) verifies every process ran the
        same allocation (sizes + SLP slot agree — a torn bootstrap fails
        everywhere), (b) votes local placement success into a collective
        verdict, and (c) assembles the global per-rank offset vector from
        each owner's contribution."""
        payload = {
            "ok": bool(ok),
            "slot": int(slot),
            "sizes": [int(s) for s in sizes],
            "offsets": [[int(r), int(o)] for r, o in sorted(offsets.items())],
        }
        rows = self.coordinator.allgather(payload)
        err = None
        if any(row["sizes"] != payload["sizes"] for row in rows):
            err = ("asymmetric extent exchange: processes disagree on the "
                   "per-rank size vector (torn SPMD bootstrap)")
        elif any(row["slot"] != payload["slot"] for row in rows):
            err = ("asymmetric allocation: second-level-pointer slots "
                   "diverged across processes (SLP arenas out of lockstep)")
        if err is not None:
            return offsets, False, err
        if not all(row["ok"] for row in rows):
            return offsets, False, None
        merged: Dict[int, int] = {}
        for row in rows:
            for r, o in row["offsets"]:
                merged[int(r)] = int(o)
        return merged, True, None

    def free(self, handle) -> None:
        """Collective free; invalidates any cached remote pointers."""
        region = handle.region if isinstance(handle, SecondLevelPtr) else handle
        with self._lock:
            self.alloc_counts["free"] += 1
            if region.rid not in self._regions:
                raise AllocError(f"double free of region {region.name!r}")
            for arena, off in zip(self._arenas, region.offsets):
                if off < 0 or arena is None:
                    # zero-size rank, or a rank another process owns:
                    # nothing was placed in *this* process's arenas
                    continue
                arena.free(off)
            slp = self._slps.pop(region.rid, None)
            if slp is not None:
                self._slp_arena.free(slp.slot_offset)
            del self._regions[region.rid]
            self.ptr_cache.invalidate_region(region.rid)

    # -- address translation ---------------------------------------------------
    def translate(self, handle, rank: int) -> Tuple[int, int]:
        """Resolve a handle to a (rank, offset) remote address.

        Symmetric regions use offset translation directly; asymmetric ones go
        through the cached second-level pointer — transparently, which is the
        "consistent and efficient access model" the runtime promises.
        """
        if isinstance(handle, SecondLevelPtr):
            return self.ptr_cache.lookup(handle, rank)
        return handle.remote_address(rank)

    # -- introspection ----------------------------------------------------------
    def bytes_in_use(self, rank: int = 0) -> int:
        return self._arena(rank).bytes_in_use

    def bytes_free(self, rank: int = 0) -> int:
        return self._arena(rank).bytes_free

    def capacity(self, rank: int = 0) -> int:
        """Actual arena capacity (the buddy allocator rounds the segment up
        to a power of two)."""
        return self._arena(rank).capacity

    def regions(self) -> List[Region]:
        return list(self._regions.values())

    def mapping_table(self) -> List[dict]:
        """The centralized mapping table of paper Fig. 1(b), for inspection."""
        return [
            {
                "rid": r.rid,
                "name": r.name,
                "symmetric": r.symmetric,
                "bytes": r.sizes,
                "offsets": r.offsets,
                "group": r.group.name,
                "logical_axes": r.logical_axes,
                "dtype": r.dtype,
            }
            for r in self._regions.values()
        ]

    def check_invariants(self) -> None:
        for _, arena in self._local_arenas():
            arena.check_invariants()

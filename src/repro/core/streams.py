"""Stream/event management — the paper's §3.2 policy, adapted to TPU.

The paper manages CUDA/HIP streams with four techniques: lazy allocation,
stream reuse, bounded concurrency (``MAX_ACTIVE_STREAMS`` + *partial
synchronization*: when the bound is hit, sync-and-release only half of the
completed streams so the pipeline keeps moving), and hybrid polling of network
and device events inside ``ompx_fence``.

On TPU there are no user-visible streams; the analogue is the number of
*in-flight asynchronous operations* the runtime allows:

* in Pallas kernels — the number of DMA double/multi-buffer slots:
  ``StreamPool.plan_slots`` is consumed by
  :class:`repro.kernels.plan.OverlapPlanner`, which turns the grant into
  the concrete slot/tile plans the kernels' ops.py wrappers execute (the
  fused ring matmul's stripe slots, attention blocks, stencil slabs);
* on the host — genuinely asynchronous work (checkpoint writes, data
  prefetch) driven by the same pool with real threads.

The pool is also used as a *discrete-event simulator* by the benchmark layer
to reproduce the paper's throughput/responsiveness trade-off curves.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Deque, Dict, List, Optional

__all__ = ["Stream", "StreamPool", "HybridPoller"]

MAX_ACTIVE_STREAMS_DEFAULT = 8


class Stream:
    """One asynchronous lane: a worker thread consuming a task queue."""

    _ids = 0
    _ids_lock = threading.Lock()   # pools on different threads share the counter

    def __init__(self):
        with Stream._ids_lock:
            Stream._ids += 1
            self.sid = Stream._ids
        self._queue: Deque = deque()
        self._cv = threading.Condition()
        self._pending = 0
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue:
                    return
                fn, args, fut = self._queue.popleft()
            try:
                fut.set_result(fn(*args))
            except BaseException as e:  # noqa: BLE001 - propagate via future
                fut.set_exception(e)
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    def submit(self, fn: Callable, *args) -> Future:
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("stream closed")
            self._queue.append((fn, args, fut))
            self._pending += 1
            self._cv.notify_all()
        return fut

    @property
    def idle(self) -> bool:
        with self._cv:
            return self._pending == 0

    def synchronize(self) -> None:
        with self._cv:
            while self._pending:
                self._cv.wait()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=5)


class StreamPool:
    """Lazy-allocating, reusing, bounded pool of streams (paper §3.2).

    * **Lazy allocation** — no stream exists until the first submit.
    * **Reuse** — an idle pooled stream is handed out before creating new ones.
    * **Bounded concurrency** — at most ``max_active`` streams are live; on
      overflow the pool performs *partial synchronization*: it waits for
      completions and releases only ``len(completed)//2`` of the completed
      streams, keeping the rest warm, so throughput is sustained while memory
      and scheduler pressure stay bounded.
    """

    def __init__(self, max_active: int = MAX_ACTIVE_STREAMS_DEFAULT):
        if max_active < 1:
            raise ValueError("max_active must be >= 1")
        self.max_active = max_active
        self._idle: List[Stream] = []
        self._active: List[Stream] = []
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "created": 0,
            "reused": 0,
            "partial_syncs": 0,
            "released": 0,
        }

    # -- acquisition -----------------------------------------------------------
    def acquire(self) -> Stream:
        with self._lock:
            if self._idle:  # stream reuse
                s = self._idle.pop()
                self.stats["reused"] += 1
                self._active.append(s)
                return s
            if len(self._active) >= self.max_active:
                self._partial_sync_locked()
                if self._idle:   # the sync released streams: reuse, don't grow
                    s = self._idle.pop()
                    self.stats["reused"] += 1
                    self._active.append(s)
                    return s
            s = Stream()  # lazy allocation
            self.stats["created"] += 1
            self._active.append(s)
            return s

    def release(self, stream: Stream) -> None:
        with self._lock:
            if stream in self._active:
                self._active.remove(stream)
            if stream not in self._idle:   # tolerate racing double-release
                self._idle.append(stream)

    def _partial_sync_locked(self) -> None:
        """Paper's partial synchronization: release half the *completed*.

        Called with the pool lock held.  When nothing has finished yet we
        must block on the oldest stream, which requires DROPPING the lock
        (the stream's completion path re-enters ``release``); while the
        lock is down, concurrent ``release``/``acquire`` calls may mutate
        ``_active`` and even recycle the stream we waited on — so after
        reacquiring, everything is re-derived from the pool's current
        membership and nothing is removed without a membership check.
        """
        self.stats["partial_syncs"] += 1
        completed = [s for s in self._active if s.idle]
        while not completed and self._active:
            # nothing finished yet: block on the oldest stream only
            oldest = self._active[0]
            self._lock.release()
            try:
                oldest.synchronize()
            finally:
                self._lock.acquire()
            if oldest not in self._active:
                # a concurrent release() recycled it while we were blocked;
                # the pool shrank, so the bound no longer forces a sync
                if len(self._active) < self.max_active:
                    return
            completed = [s for s in self._active if s.idle]
        n_release = max(1, len(completed) // 2) if completed else 0
        for s in completed[:n_release]:
            if s in self._active:          # guard against racing release()
                self._active.remove(s)
                if s not in self._idle:
                    self._idle.append(s)
                self.stats["released"] += 1

    # -- convenience -----------------------------------------------------------
    def submit(self, fn: Callable, *args) -> Future:
        s = self.acquire()
        fut = s.submit(fn, *args)
        fut.add_done_callback(lambda _f: self.release(s))
        return fut

    def synchronize_all(self) -> None:
        with self._lock:
            streams = list(self._active) + list(self._idle)
        for s in streams:
            s.synchronize()

    def close(self) -> None:
        self.synchronize_all()
        with self._lock:
            for s in self._active + self._idle:
                s.close()
            self._active.clear()
            self._idle.clear()

    # -- planning hook for Pallas kernels ---------------------------------------
    def plan_slots(self, working_set_bytes: int, vmem_budget: int = 64 * 2**20) -> int:
        """How many DMA buffers a kernel may keep in flight.

        The kernel analogue of MAX_ACTIVE_STREAMS: enough slots to overlap
        (≥2 = double buffering), bounded by the VMEM the slots would pin.
        """
        if working_set_bytes <= 0:
            return 2
        by_budget = max(1, vmem_budget // max(working_set_bytes, 1))
        return max(2, min(self.max_active, by_budget))


class HybridPoller:
    """Unified polling over heterogeneous completion sources (paper §3.2).

    DiOMP's ``ompx_fence`` polls GASNet-EX events and CUDA/HIP stream events in
    one loop so neither side stalls the other.  Our fence polls every
    registered completion source (host futures, data-pipeline queues, stream
    pools) round-robin until all are quiescent.
    """

    def __init__(self, interval_s: float = 1e-4):
        self._sources: List[Callable[[], bool]] = []  # each returns "is done"
        self.interval_s = interval_s
        self.polls = 0

    def register(self, is_done: Callable[[], bool]) -> None:
        self._sources.append(is_done)

    def fence(self, timeout_s: float = 120.0) -> None:
        deadline = time.monotonic() + timeout_s
        pending = list(self._sources)
        while pending:
            self.polls += 1
            pending = [src for src in pending if not src()]
            if not pending:
                return
            if time.monotonic() > deadline:
                raise TimeoutError(f"fence timed out with {len(pending)} pending sources")
            time.sleep(self.interval_s)

"""DiOMP Groups — communicator-like handles over TPU mesh axes.

The paper's ``ompx_group_t`` partitions the global communication domain into
logically distinct subgroups that can be created, split and merged at runtime
(§3.3).  On GPU clusters a group is an arbitrary rank subset; on a TPU pod the
efficient subsets are *subtori*, i.e. cartesian products of mesh axes.  We
therefore represent a group as an ordered tuple of mesh axis names.  This is
the topology-aware restriction the paper itself advocates ("OMPCCL leverages
the topology-aware initialization mechanisms ... to select optimized transport
paths"): every group is an ICI-contiguous torus slice by construction.

``jax.lax`` collectives accept tuples of axis names, so a group handle plugs
directly into psum/all_gather/ppermute inside ``shard_map``.

Split/merge semantics:

* ``WORLD.split("model")``     -> (group over "model", residual group)
* ``merge(g1, g2)``            -> group over the union of axes (paper's
                                  "group recomposition")
* ``group.axis_size(mesh)``    -> number of participants
* ``group.descriptor()``       -> stable identifier broadcast at init time,
                                  modeling OMPCCL's UniqueID handshake.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

__all__ = [
    "DiompGroup",
    "GroupError",
    "group_for_axes",
    "world_group",
    "merge",
]


class GroupError(ValueError):
    """Raised on invalid group construction (unknown axis, overlap, ...)."""


@dataclasses.dataclass(frozen=True)
class DiompGroup:
    """A communicator handle: an ordered subset of mesh axis names.

    Frozen + hashable so a group can key the runtime's mapping table, exactly
    like ``ompx_group_t`` keys NCCL communicators in the paper.
    """

    axes: Tuple[str, ...]
    name: str = ""

    def __post_init__(self):
        if len(set(self.axes)) != len(self.axes):
            raise GroupError(f"duplicate axes in group: {self.axes}")
        if not self.name:
            object.__setattr__(self, "name", "+".join(self.axes) or "self")

    # -- collective plumbing -------------------------------------------------
    @property
    def lax_axes(self) -> Tuple[str, ...]:
        """Axis-name tuple accepted by jax.lax collectives."""
        return self.axes

    def axis_size(self, mesh: Mesh) -> int:
        size = 1
        for ax in self.axes:
            if ax not in mesh.shape:
                raise GroupError(f"group axis {ax!r} not in mesh {tuple(mesh.shape)}")
            size *= mesh.shape[ax]
        return size

    def validate(self, mesh: Mesh) -> "DiompGroup":
        self.axis_size(mesh)  # raises on unknown axis
        return self

    # -- group algebra (paper §3.3: create / split / merge) ------------------
    def split(self, *axes: str) -> Tuple["DiompGroup", "DiompGroup"]:
        """Split this group into (group over ``axes``, residual group).

        Mirrors communicator splitting: the returned pair partitions the
        participant set of ``self`` (as a cartesian factorization — the
        topology-aligned analogue of MPI_Comm_split colors).
        """
        for ax in axes:
            if ax not in self.axes:
                raise GroupError(f"cannot split on {ax!r}: not in group {self.axes}")
        picked = tuple(ax for ax in self.axes if ax in axes)
        rest = tuple(ax for ax in self.axes if ax not in axes)
        return DiompGroup(picked), DiompGroup(rest)

    def contains(self, other: "DiompGroup") -> bool:
        return set(other.axes) <= set(self.axes)

    def overlaps(self, other: "DiompGroup") -> bool:
        return bool(set(self.axes) & set(other.axes))

    # -- identity / bootstrap -------------------------------------------------
    def descriptor(self) -> str:
        """Stable unique id for this group (models OMPCCL's UniqueID).

        On real multi-host deployments every host derives the same descriptor
        from the same mesh + axes, which is how we validate that all hosts
        constructed consistent communicators before any collective runs.

        The digest is memoized on the instance: descriptors key every
        communicator-table lookup, so hot paths (one lookup per collective
        per trace) must not re-hash.
        """
        memo = self.__dict__.get("_descriptor")
        if memo is None:
            h = hashlib.sha256(("|".join(self.axes)).encode()).hexdigest()[:16]
            memo = f"diomp-group-{self.name}-{h}"
            object.__setattr__(self, "_descriptor", memo)
        return memo

    def is_self_group(self) -> bool:
        return not self.axes

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"DiompGroup({self.name}: axes={self.axes})"


@functools.lru_cache(maxsize=None)
def group_for_axes(axes: Tuple[str, ...]) -> DiompGroup:
    """Interned group handle for an axis tuple.

    Gradient reduction used to construct ``DiompGroup(need)`` afresh for
    every parameter on every trace (validation + descriptor hashing each
    time); axis tuples are tiny and few, so the handles are interned here
    and shared by every call site that keys groups by axes alone.
    """
    return DiompGroup(tuple(axes))


def world_group(mesh: Mesh) -> DiompGroup:
    """The WORLD communicator: all mesh axes in mesh order."""
    return DiompGroup(tuple(mesh.axis_names), name="world")


def merge(*groups: DiompGroup, name: Optional[str] = None) -> DiompGroup:
    """Recompose several disjoint groups into one (paper: group merge).

    Axis order follows the order of the given groups, which determines
    collective rank ordering — callers that care pass groups in mesh order.
    """
    axes: list = []
    for g in groups:
        for ax in g.axes:
            if ax in axes:
                raise GroupError(f"merge overlap on axis {ax!r}")
            axes.append(ax)
    return DiompGroup(tuple(axes), name=name or "+".join(g.name for g in groups))


def standard_groups(mesh: Mesh) -> dict:
    """The standard communicators the LM framework uses (see DESIGN §4)."""
    names = set(mesh.axis_names)
    groups = {"world": world_group(mesh)}
    if "model" in names:
        groups["tp"] = DiompGroup(("model",), name="tp")
        groups["ep"] = DiompGroup(("model",), name="ep")
    dp_axes = tuple(ax for ax in ("pod", "data") if ax in names)
    if dp_axes:
        groups["dp"] = DiompGroup(dp_axes, name="dp")
    if "data" in names:
        groups["dp_inner"] = DiompGroup(("data",), name="dp_inner")
    if "pod" in names:
        groups["pod"] = DiompGroup(("pod",), name="pod")
    return groups

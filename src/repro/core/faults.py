"""Deterministic fault injection for the communication stack.

Chaos engineering for the PGAS runtime: a seeded :class:`FaultPlan`
decides — reproducibly — which verb dispatches fail and which ranks die
at which step, and :class:`ChaosBackend` wraps any registered
:class:`~repro.core.backends.CclBackend` to inject those faults at the
verb level.  Because injection happens at *dispatch* (trace) time,
before the inner backend lowers anything, a retried verb re-traces the
exact same XLA collective — so every equivalence suite in the repo runs
bit-identically under chaos with a fixed seed, while the retry logs
prove the faults were actually hit and recovered.

Fault model (what each kind means on real hardware):

* ``drop``    — a one-sided put or collective whose completion event
  never arrives (GASNet-EX would surface a failed AM reply).  Raised as
  :class:`~repro.core.resilience.TransientFault`; the communicator's
  retry loop re-issues the verb.
* ``fail``    — the transport returned an error code for the whole
  collective (a GPI-2 queue error).  Same recovery path as ``drop``.
* ``timeout`` — the completion budget elapsed.  Raised as
  :class:`~repro.core.resilience.FaultTimeout` (still transient).
* ``delay``   — a slow link: the dispatch sleeps briefly, then
  proceeds.  No retry; latency only.
* ``corrupt`` — payload damaged in flight.  On traced collectives the
  transport CRC catches this and reports a failed transfer (so it
  degenerates to ``drop``); on host-buffer RMA paths (the paged-KV
  ``migrate``) the corruption lands a wrong *window checksum* which the
  reader's ``RMATracker.validate`` detects and repairs by re-putting.
  Either way: detected, never silently absorbed.
* rank death — scheduled with :meth:`FaultPlan.kill_rank`; consumed by
  the serving engine (drain/requeue) and the training driver (elastic
  restore), not by the backend wrapper.

Determinism: every decision derives from
``sha256(seed, verb, call_index)`` (see
:func:`~repro.core.resilience.derive_rng`), never from Python's
randomized ``hash()`` — the run that found a bug and the run
reproducing it must inject identically.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .backends import CclBackend
from .resilience import FaultTimeout, TransientFault, derive_rng

__all__ = [
    "INJECTABLE_VERBS",
    "TRANSIENT_KINDS",
    "FaultSpec",
    "InjectedFault",
    "RankDeath",
    "FaultPlan",
    "ChaosBackend",
]

#: verbs the plan can target (``migrate`` is the host-side paged-KV path).
INJECTABLE_VERBS = (
    "allreduce", "bcast", "allgather", "reducescatter", "alltoall",
    "permute", "barrier", "put", "put_perm", "halo_exchange", "migrate",
)

TRANSIENT_KINDS = ("drop", "fail", "timeout", "corrupt", "delay")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """An explicit scheduled fault: the ``at_call``-th dispatch (0-based,
    counted per verb) of ``verb`` suffers ``kind``."""

    verb: str
    at_call: int
    kind: str = "drop"

    def __post_init__(self):
        if self.kind not in TRANSIENT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass
class InjectedFault:
    """Log record of one injected fault; ``recovered`` is flipped by the
    retry machinery when the faulted call eventually succeeds."""

    verb: str
    call_index: int
    kind: str
    recovered: bool = False


@dataclasses.dataclass
class RankDeath:
    """A scheduled rank death, consumed once via :meth:`FaultPlan.deaths_at`.

    ``graceful`` deaths announce themselves (the engine drains the rank's
    paged KV over RMA before removing it); abrupt deaths lose the pages.
    """

    step: int
    rank: int
    graceful: bool = False
    fired: bool = False


class FaultPlan:
    """Seeded, deterministic schedule of wire faults and rank deaths.

    Two sources of faults compose:

    * explicit ``specs`` — exact (verb, call_index, kind) triples;
    * probabilistic — each dispatch of a verb in ``verbs`` faults with
      probability ``p``, kind drawn uniformly from ``kinds``, both from
      the per-call sha256 stream.

    The plan is shared across backends/threads; per-verb call counters
    are lock-protected.  Everything injected lands in ``self.injected``
    so tests can assert faults were hit *and* recovered.
    """

    def __init__(self, seed: int, *, p: float = 0.0,
                 kinds: Sequence[str] = ("drop",),
                 verbs: Sequence[str] = INJECTABLE_VERBS,
                 specs: Sequence[FaultSpec] = (),
                 max_faults: Optional[int] = None,
                 max_delay_s: float = 1e-3):
        for k in kinds:
            if k not in TRANSIENT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self.seed = int(seed)
        self.p = float(p)
        self.kinds = tuple(kinds)
        self.verbs = tuple(verbs)
        self.specs = tuple(specs)
        self.max_faults = max_faults
        self.max_delay_s = float(max_delay_s)
        self.injected: List[InjectedFault] = []
        self.deaths: List[RankDeath] = []
        self._counters: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- schedule authoring -------------------------------------------------
    def kill_rank(self, step: int, rank: int, *,
                  graceful: bool = False) -> "FaultPlan":
        self.deaths.append(RankDeath(step=step, rank=rank, graceful=graceful))
        return self

    # -- runtime queries ----------------------------------------------------
    def deaths_at(self, step: int) -> List[RankDeath]:
        """Deaths due at-or-before ``step`` that have not fired yet (each
        fires exactly once)."""
        due = []
        for d in self.deaths:
            if not d.fired and d.step <= step:
                d.fired = True
                due.append(d)
        return due

    def next_fault(self, verb: str) -> Optional[InjectedFault]:
        """Advance the per-verb call counter; return a fault record if this
        dispatch is scheduled to fail, else None."""
        with self._lock:
            idx = self._counters.get(verb, 0)
            self._counters[verb] = idx + 1
            kind = None
            for spec in self.specs:
                if spec.verb == verb and spec.at_call == idx:
                    kind = spec.kind
                    break
            if kind is None and self.p > 0.0 and verb in self.verbs:
                if (self.max_faults is None
                        or len(self.injected) < self.max_faults):
                    rng = derive_rng(self.seed, verb, idx)
                    if rng.random() < self.p:
                        kind = self.kinds[rng.randrange(len(self.kinds))]
            if kind is None:
                return None
            record = InjectedFault(verb=verb, call_index=idx, kind=kind)
            self.injected.append(record)
            return record

    # -- introspection ------------------------------------------------------
    def injected_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.injected:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def unrecovered(self) -> List[InjectedFault]:
        return [f for f in self.injected if not f.recovered]

    def reset_counters(self) -> None:
        """Restart the per-verb call streams (new trace, same schedule)."""
        with self._lock:
            self._counters.clear()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"FaultPlan(seed={self.seed}, p={self.p}, "
                f"kinds={self.kinds}, specs={len(self.specs)}, "
                f"deaths={len(self.deaths)}, injected={len(self.injected)})")

    # -- ambient chaos ------------------------------------------------------
    @classmethod
    def from_env(cls, env=None) -> Optional["FaultPlan"]:
        """Build a plan from ``DIOMP_CHAOS_*`` env vars, or None.

        ``DIOMP_CHAOS_SEED`` (required to enable), ``DIOMP_CHAOS_P``
        (default 0.02), ``DIOMP_CHAOS_KINDS`` and ``DIOMP_CHAOS_VERBS``
        (comma lists).  Lets CI run the existing tier-1 suites under
        chaos without touching each test.
        """
        env = os.environ if env is None else env
        seed = env.get("DIOMP_CHAOS_SEED")
        if seed is None or seed == "":
            return None
        p = float(env.get("DIOMP_CHAOS_P", "0.02"))
        kinds = tuple(k for k in env.get(
            "DIOMP_CHAOS_KINDS", "drop,fail,timeout").split(",") if k)
        verbs = tuple(v for v in env.get(
            "DIOMP_CHAOS_VERBS", ",".join(INJECTABLE_VERBS)).split(",") if v)
        return cls(int(seed), p=p, kinds=kinds, verbs=verbs)


class ChaosBackend(CclBackend):
    """Wrap any backend and inject the plan's faults at verb dispatch.

    Every verb delegates *directly* to ``inner.<verb>`` — never through
    the base-class defaults — otherwise a wrapped ``bcast`` would route
    through ``self.allreduce`` and roll the dice twice.  Transient kinds
    raise before the inner backend traces anything, so the retry at the
    communicator layer replays an identical lowering (bit-identical
    results); ``delay`` sleeps at trace time only (compiled steady-state
    is unaffected); ``corrupt`` on traced verbs is the transport-CRC
    story — see the module docstring.
    """

    def __init__(self, inner: CclBackend, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.name = f"chaos:{inner.name}"

    def _roll(self, verb: str) -> None:
        fault = self.plan.next_fault(verb)
        if fault is None:
            return
        if fault.kind == "delay":
            time.sleep(min(self.plan.max_delay_s,
                           derive_rng(self.plan.seed, "delay",
                                      fault.call_index).random()
                           * self.plan.max_delay_s))
            fault.recovered = True
            return
        if fault.kind == "timeout":
            raise FaultTimeout(
                f"injected timeout on {verb} (call {fault.call_index})",
                fault=fault)
        raise TransientFault(
            f"injected {fault.kind} on {verb} (call {fault.call_index})",
            fault=fault)

    # -- collectives --------------------------------------------------------
    def allreduce(self, x, group, *, op="sum"):
        self._roll("allreduce")
        return self.inner.allreduce(x, group, op=op)

    def bcast(self, x, group, *, root=0):
        self._roll("bcast")
        return self.inner.bcast(x, group, root=root)

    def allgather(self, x, group, *, axis=0, tiled=True, invariant=False):
        self._roll("allgather")
        return self.inner.allgather(x, group, axis=axis, tiled=tiled,
                                    invariant=invariant)

    def reducescatter(self, x, group, *, axis=0):
        self._roll("reducescatter")
        return self.inner.reducescatter(x, group, axis=axis)

    def alltoall(self, x, group, *, split_axis=0, concat_axis=0):
        self._roll("alltoall")
        return self.inner.alltoall(x, group, split_axis=split_axis,
                                   concat_axis=concat_axis)

    def permute(self, x, group, *, shift=1):
        self._roll("permute")
        return self.inner.permute(x, group, shift=shift)

    def barrier(self, group):
        self._roll("barrier")
        return self.inner.barrier(group)

    # -- one-sided RMA ------------------------------------------------------
    def put(self, x, group, *, shift=1):
        self._roll("put")
        return self.inner.put(x, group, shift=shift)

    def put_perm(self, x, group, perm):
        self._roll("put_perm")
        return self.inner.put_perm(x, group, perm)

    def halo_exchange(self, x, group, *, halo, axis=0):
        self._roll("halo_exchange")
        return self.inner.halo_exchange(x, group, halo=halo, axis=axis)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ChaosBackend({self.inner!r}, {self.plan!r})"

"""The paper's user-facing API surface, verbatim names (§3.2–3.3).

DiOMP exposes ``ompx_``-prefixed runtime calls (and matching pragmas, which
a directive-based host language would lower to exactly these calls):

    ompx_put / ompx_get / ompx_fence / ompx_barrier
    ompx_bcast / ompx_reduce / ompx_allreduce
    ompx_group_t (create / split / merge)

This module re-exports the runtime under those names so code written
against the paper's listings ports one-to-one (see examples/minimod.py for
Listing 1 in this API).  Every name is bound to the process-default
:class:`~repro.core.context.DiompContext` — identical results and per-op
call counts to calling the communicator handles directly.
"""

from __future__ import annotations

from .groups import DiompGroup as ompx_group_t  # noqa: N813
from .groups import merge as ompx_group_merge
from .groups import world_group as ompx_group_world
from .ompccl import allgather as ompx_allgather
from .ompccl import allreduce as ompx_allreduce
from .ompccl import alltoall as ompx_alltoall
from .ompccl import barrier_value as ompx_barrier
from .ompccl import bcast as ompx_bcast
from .ompccl import reduce as ompx_reduce
from .ompccl import reducescatter as ompx_reducescatter
from .rma import halo_exchange as ompx_halo_exchange
from .rma import ompx_fence, ompx_get, ompx_put  # noqa: F401

__all__ = [
    "ompx_group_t", "ompx_group_merge", "ompx_group_world",
    "ompx_put", "ompx_get", "ompx_fence", "ompx_barrier",
    "ompx_bcast", "ompx_reduce", "ompx_allreduce", "ompx_allgather",
    "ompx_reducescatter", "ompx_alltoall", "ompx_halo_exchange",
]

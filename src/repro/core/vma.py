"""Varying-manual-axes helpers for code shared between shard_map and plain jit.

Under ``shard_map`` with vma checking (the default, and the thing that makes
AD through our explicit collectives sound), freshly created constants are
*unvarying* while values derived from inputs are *varying*; loop carries must
match.  ``zeros_like_varying`` creates a zero array that inherits the varying
axes of a reference value, working identically (and at ~zero cost) outside
shard_map.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["zeros_varying", "full_varying"]


def zeros_varying(shape, dtype, like):
    """Zeros of ``shape``/``dtype`` carrying ``like``'s varying axes."""
    tag = (like.reshape(-1)[0] * 0).astype(dtype)
    return jnp.zeros(shape, dtype) + tag


def full_varying(shape, dtype, value, like):
    tag = (like.reshape(-1)[0] * 0).astype(dtype)
    return jnp.full(shape, value, dtype) + tag

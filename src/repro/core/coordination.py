"""Multi-controller process coordination — the SPMD bootstrap layer.

Everything before PR 10 ran one Python process driving N virtual XLA
devices, so "coordination" was a loop over arenas that all lived in the
same address space.  DiOMP's runtime is *multi-controller*: every process
runs the same program, sees only its own devices, and global state (the
PGAS mapping table, group descriptors, call/byte logs) is only consistent
because the processes *exchange* their contributions (GASNet-EX's
segment-exchange bootstrap, OMPCCL's UniqueID handshake).  This module is
that exchange, in three pieces:

* :func:`init_distributed` — ``jax.distributed.initialize`` wrapped with
  the CPU (gloo) collectives knob and an idempotence guard; the transport
  under ``diomp.init(coordinator=...)``.
* :class:`ProcessCoordinator` — host-metadata allgather/broadcast/barrier
  over the initialized jax runtime.  :class:`LocalCoordinator` is the
  single-process no-op (today's behavior, bit for bit);
  :class:`JaxCoordinator` moves JSON payloads over device collectives via
  ``jax.experimental.multihost_utils``.  Both are deterministic: every
  process receives the identical, process-indexed list.
* :func:`fetch_global` — materialize a (possibly non-addressable) global
  ``jax.Array`` as a full numpy array on every process, the harness's way
  of comparing outputs bit-for-bit across runs with different process
  counts.

Design rule: everything here is **collective** — either every process of
the job calls it in the same order, or none does.  The PGAS allocator and
the context handshake are built on that discipline, mirroring the paper's
"all participating nodes coordinate" allocation contract.
"""

from __future__ import annotations

import json
import os
from typing import Any, List, Optional, Sequence

__all__ = [
    "CoordinationError",
    "ProcessCoordinator",
    "LocalCoordinator",
    "JaxCoordinator",
    "coordinator_for",
    "init_distributed",
    "is_distributed",
    "fetch_global",
    "process_local_ranks",
]


class CoordinationError(RuntimeError):
    """Raised when the multi-controller bootstrap or an exchange fails."""


# ---------------------------------------------------------------------------
# jax.distributed bootstrap
# ---------------------------------------------------------------------------

_initialized = False


def is_distributed() -> bool:
    """True once :func:`init_distributed` has run in this process."""
    return _initialized


def init_distributed(
    coordinator: str,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
    *,
    local_device_count: Optional[int] = None,
) -> tuple:
    """Join the multi-controller job; returns ``(process_id, num_processes)``.

    ``coordinator`` is the ``host:port`` of process 0's coordination
    service (the GASNet-EX conduit bootstrap analogue);  ``num_processes``
    / ``process_id`` may be None when the cluster environment provides
    them (SLURM & co. auto-detection in ``jax.distributed``).

    ``local_device_count`` pins the number of virtual CPU devices this
    process exposes and must be set BEFORE anything initializes jax —
    we set ``XLA_FLAGS`` here and raise if jax already has a backend with
    a different count (device visibility is per-process and immutable).

    Idempotent: a second call with the same topology is a no-op; a second
    call with a different one raises :class:`CoordinationError`.
    """
    global _initialized
    import jax

    if local_device_count is not None and not _initialized:
        flag = f"--xla_force_host_platform_device_count={local_device_count}"
        cur = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in cur:
            os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()

    if _initialized:
        if process_id is not None and jax.process_index() != process_id:
            raise CoordinationError(
                f"init_distributed called twice with different process_id "
                f"({jax.process_index()} then {process_id})")
        return (jax.process_index(), jax.process_count())

    # CPU collectives need the gloo transport to cross process boundaries;
    # on TPU/GPU the platform transport is already cross-process.
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - very old/new jax: flag renamed
        pass
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
        )
    except Exception as e:
        raise CoordinationError(
            f"jax.distributed.initialize({coordinator!r}, "
            f"num_processes={num_processes}, process_id={process_id}) "
            f"failed: {e}") from e
    _initialized = True
    return (jax.process_index(), jax.process_count())


# ---------------------------------------------------------------------------
# host-metadata exchange
# ---------------------------------------------------------------------------


class ProcessCoordinator:
    """Deterministic host-metadata exchange among the job's processes.

    The unit of exchange is a JSON-serializable object; every collective
    returns the same process-indexed list on every process.  Subclasses
    provide :meth:`allgather_bytes`; the object layer is shared.
    """

    process_id: int = 0
    num_processes: int = 1

    def allgather_bytes(self, payload: bytes) -> List[bytes]:
        raise NotImplementedError

    def allgather(self, obj: Any) -> List[Any]:
        """Every process contributes ``obj``; all receive ``[obj_0..obj_n]``.

        JSON round-trips the payload, so tuples come back as lists —
        callers normalize shapes themselves (the PGAS layer does).
        """
        rows = self.allgather_bytes(
            json.dumps(obj, sort_keys=True).encode("utf-8"))
        return [json.loads(r.decode("utf-8")) for r in rows]

    def broadcast(self, obj: Any, *, root: int = 0) -> Any:
        return self.allgather(obj)[root]

    def agree(self, obj: Any) -> bool:
        """True iff every process contributed an identical value."""
        rows = self.allgather(obj)
        return all(r == rows[0] for r in rows[1:]) if rows else True

    def barrier(self, tag: str = "barrier") -> None:
        self.allgather_bytes(tag.encode("utf-8"))


class LocalCoordinator(ProcessCoordinator):
    """The single-process job: every exchange is the identity."""

    def allgather_bytes(self, payload: bytes) -> List[bytes]:
        return [payload]

    def barrier(self, tag: str = "barrier") -> None:
        pass


class JaxCoordinator(ProcessCoordinator):
    """Exchange over the initialized ``jax.distributed`` runtime.

    Payloads ride device collectives (``multihost_utils``): lengths are
    exchanged first, then the max-length-padded byte rows — two tiny
    allgathers per exchange, which is bootstrap/audit traffic, never a hot
    path.
    """

    def __init__(self):
        import jax

        if jax.process_count() <= 1:
            # legal (a 1-process distributed job) — behaves like Local
            pass
        self.process_id = jax.process_index()
        self.num_processes = jax.process_count()

    def allgather_bytes(self, payload: bytes) -> List[bytes]:
        import numpy as np
        from jax.experimental import multihost_utils

        if self.num_processes == 1:
            return [payload]
        lens = multihost_utils.process_allgather(np.int64(len(payload)))
        lens = np.asarray(lens).reshape(self.num_processes)
        width = max(int(lens.max()), 1)
        row = np.zeros(width, np.uint8)
        row[: len(payload)] = np.frombuffer(payload, np.uint8)
        rows = np.asarray(multihost_utils.process_allgather(row))
        rows = rows.reshape(self.num_processes, width)
        return [bytes(rows[i, : int(lens[i])])
                for i in range(self.num_processes)]


def coordinator_for(mesh=None) -> ProcessCoordinator:
    """The coordinator matching the active jax runtime.

    Single-process jobs (including every pre-PR-10 test) get the
    :class:`LocalCoordinator` — no jax traffic, identical semantics.
    ``mesh`` is accepted for call-site symmetry; topology comes from the
    process, not the mesh (a mesh never spans more processes than the
    job).
    """
    del mesh
    import jax

    if not _initialized and jax.process_count() == 1:
        return LocalCoordinator()
    return JaxCoordinator()


# ---------------------------------------------------------------------------
# global-array materialization + device/rank topology
# ---------------------------------------------------------------------------


def fetch_global(x):
    """Full logical value of ``x`` as numpy, on every process.

    Single-process (or fully-addressable) arrays take the plain
    ``np.asarray`` path — unchanged behavior and no wire traffic.  A
    multi-process sharded array is assembled with one cross-process
    allgather; the result is bit-identical on every process, which is what
    the equivalence harness diffs across runs.
    """
    import numpy as np

    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


def process_local_ranks(mesh) -> List[int]:
    """Global ranks (flat positions in ``mesh.devices``) owned by me.

    Rank order is mesh order — the same order the PGAS arenas, group
    rings and collective permutes use — so ``local_ranks`` indexes
    straight into per-rank tables.
    """
    import jax

    me = jax.process_index()
    return [i for i, d in enumerate(mesh.devices.flat)
            if d.process_index == me]


def device_process_map(mesh) -> List[int]:
    """Per-global-rank owning process ids, in mesh order."""
    return [int(d.process_index) for d in mesh.devices.flat]

"""One-sided RMA — ``ompx_put`` / ``ompx_get`` / ``ompx_fence`` on TPU.

The paper's RMA layer issues one-sided ``put``/``get`` over GASNet-EX (or
GPI-2) into the PGAS segment, with ``ompx_fence`` completing all outstanding
operations by polling network + device events in one loop (§3.2).

TPU adaptation (recorded in DESIGN.md §2): ICI transfers are *compiled*, not
runtime-initiated.  A one-sided put into a remote window is exactly what
``lax.ppermute`` (XLA ``collective-permute``) lowers to — a remote DMA write
with no receiver-side participation.  The wire lowerings live on the
:class:`~repro.core.backends.CclBackend` classes; this module is the
paper-verbatim free-function surface, dispatching through the
process-default :class:`~repro.core.context.DiompContext` communicator
handle exactly like :mod:`repro.core.ompccl` — handle-style code calls
``ctx.communicator(group).put(...)`` directly.

* ``ompx_put(x, group, shift)``   — deposit my shard into the window of the
  rank ``shift`` positions ahead on the group's ring; returns what landed in
  *my* window (SPMD view of the same one-sided write).
* ``ompx_get(x, group, shift)``   — fetch the shard of the rank ``shift``
  positions ahead (a read = a put with inverted permutation).
* ``halo_exchange(x, group)``     — the Minimod pattern (paper Listing 1):
  both boundary slabs put to both neighbors, one fence.
* ``ompx_fence(*arrays)``         — completion/ordering barrier: an
  ``optimization_barrier`` that pins every outstanding transfer before any
  consumer, the compiled analogue of the hybrid event-polling fence.

The host-side :class:`RMATracker` enforces the *programming model* (reads of
a window require a fence after the last put), so misuse fails loudly in tests
even though the compiled program would order correctly by dataflow.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from .backends import fence as _fence
from .groups import DiompGroup

__all__ = [
    "ompx_put",
    "ompx_get",
    "ompx_put_perm",
    "ompx_fence",
    "halo_exchange",
    "halo_window_names",
    "dispatch_window_names",
    "attention_window_names",
    "validate_halo",
    "RMATracker",
    "RMAError",
]


class RMAError(RuntimeError):
    """Programming-model violation (read before fence, unknown window)."""


def _comm(group: DiompGroup, backend: str = None):
    # deferred: context imports RMATracker from this module at load time
    from .context import default_communicator

    return default_communicator(group, backend)


def ompx_put(x, group: DiompGroup, *, shift: int = 1, backend: str = None):
    """One-sided put of my shard to the rank ``shift`` ahead on the ring.

    SPMD semantics: every rank's window receives the shard of the rank
    ``shift`` *behind* it.  ``shift`` may be negative.  Lowers to a single
    ``collective-permute`` (a remote DMA on ICI).
    """
    return _comm(group, backend).put(x, shift=shift)


def ompx_get(x, group: DiompGroup, *, shift: int = 1, backend: str = None):
    """One-sided get of the shard owned by the rank ``shift`` ahead."""
    return _comm(group, backend).get(x, shift=shift)


def ompx_put_perm(x, group: DiompGroup, perm: Sequence[Tuple[int, int]],
                  *, backend: str = None):
    """General one-sided put along an arbitrary (src, dst) permutation."""
    return _comm(group, backend).put_perm(x, perm)


def ompx_fence(*arrays):
    """Complete all outstanding RMA before anything downstream runs.

    ``lax.optimization_barrier`` prevents XLA from reordering/fusing across
    the fence — the compiled counterpart of DiOMP's hybrid polling loop that
    waits on both network and device events.  Returns the fenced arrays.
    """
    return _fence(*arrays)


def halo_window_names(group: DiompGroup, axis: int) -> Tuple[str, str]:
    """The (lo, hi) RMATracker window names of one halo-exchange pair."""
    return (f"halo:{group.name}:{axis}:lo", f"halo:{group.name}:{axis}:hi")


def dispatch_window_names(group: DiompGroup, ep: int
                          ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """The (dispatch, combine) RMATracker window names of one MoE dispatch.

    One window per ring offset ``s`` in each direction: ``dispatch:s`` is
    the landing window the put of step ``s`` fills (tokens from the rank
    ``s`` behind), ``combine:s`` the window the return put of step ``s``
    fills (my rows' expert outputs from the rank ``s`` ahead).  The fused
    MoE dispatch records every one-sided put against these windows with
    the same bytes the OMPCCL communicator logs, so tests can assert exact
    put-traffic parity (the PR-5 Minimod discipline).
    """
    return (tuple(f"moe:{group.name}:dispatch:{s}" for s in range(1, ep)),
            tuple(f"moe:{group.name}:combine:{s}" for s in range(1, ep)))


def attention_window_names(group: DiompGroup, n: int,
                           direction: str = "bidi"
                           ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """The (cw, ccw) RMATracker window names of one ring-attention pass.

    Window ``dir:s`` is the landing window *feeding* step ``s``: the K/V
    stripe put launched at step ``s - 1`` lands there while step
    ``s - 1``'s flash block computes.  The clockwise stream serves the
    ring's left half (``n // 2`` windows on the bidirectional ring), the
    counter-clockwise stream the right half (``(n - 1) // 2``) — exactly
    :meth:`repro.kernels.plan.RingPlan.schedule`'s send steps.  The fused
    ring attention records every put (K and V separately) against these
    windows with the same bytes the OMPCCL communicator logs, so tests
    assert exact put-traffic parity (the Minimod/MoE discipline).
    """
    if direction == "bidi":
        s_cw, s_ccw = n // 2, (n - 1) // 2
    elif direction == "cw":
        s_cw, s_ccw = n - 1, 0
    elif direction == "ccw":
        s_cw, s_ccw = 0, n - 1
    else:
        raise ValueError(f"unknown ring direction {direction!r}")
    return (tuple(f"attn:{group.name}:cw:{s}" for s in range(1, s_cw + 1)),
            tuple(f"attn:{group.name}:ccw:{s}" for s in range(1, s_ccw + 1)))


def validate_halo(halo: int, extent: int, axis: int) -> None:
    """Reject a halo the local shard cannot serve (shared by the free
    function, the backend lowering and the fused step): a slab wider than
    the shard would silently wrap neighbor-of-neighbor data on the
    compiled ring."""
    if halo < 1 or halo > extent:
        raise RMAError(
            f"halo_exchange(halo={halo}) invalid for local shard extent "
            f"{extent} along axis {axis}: the put would "
            + ("be empty" if halo < 1 else
               "wrap non-neighbor data into the slab")
            + " (shrink the halo or the rank count)")


def halo_exchange(x, group: DiompGroup, *, halo: int, axis: int = 0,
                  backend: str = None):
    """Minimod's halo pattern (paper Listing 1) as one fused exchange.

    Every rank puts its *left* boundary slab to the left neighbor's right
    halo and its *right* boundary slab to the right neighbor's left halo,
    then fences.  Returns ``(left_halo, right_halo)`` — the slabs that landed
    in my window.  Edge ranks receive zeros (the paper's ``rank != 0`` /
    ``rank != nranks-1`` guards), matching non-periodic stencil boundaries.

    A ``halo`` thicker than the local shard would silently wrap neighbor-
    of-neighbor data into the slab on the compiled ring; that is rejected
    here (and in the backend lowering) with :class:`RMAError`.  Each call
    is also recorded against the active context's :class:`RMATracker`:
    two slab puts into the group's halo windows, one fence, then the reads
    — so the put→fence→read epoch discipline of the programming model is
    checkable host-side.
    """
    extent = x.shape[axis]
    validate_halo(halo, extent, axis)
    from .backends import payload_bytes
    from .compat import axis_size
    from .context import default_context

    # a 1-rank ring exchanges nothing (both halos are the edge zeros):
    # record no puts, same as the fused path — the audit trail reports
    # only bytes that actually go on the wire
    if len(group.axes) == 1 and axis_size(group.axes[0]) == 1:
        return _comm(group, backend).halo_exchange(x, halo=halo, axis=axis)
    tracker = default_context().rma
    lo_w, hi_w = halo_window_names(group, axis)
    slab_bytes = payload_bytes(x) // extent * halo
    for w in (lo_w, hi_w):
        tracker.ensure(w)
        tracker.on_put(w, slab_bytes)
    out = _comm(group, backend).halo_exchange(x, halo=halo, axis=axis)
    tracker.on_fence(lo_w, hi_w)
    tracker.on_read(lo_w)
    tracker.on_read(hi_w)
    return out


# ---------------------------------------------------------------------------
# host-side programming-model tracker
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _WindowState:
    epoch: int = 0          # bumped by fence
    dirty_since: int = -1   # epoch of the last un-fenced put, -1 = clean
    checksum: str = None    # digest the last put claims to have landed


class RMATracker:
    """Host-side epoch tracker for put/fence discipline (tests + examples).

    The compiled program is always correct by dataflow; this tracker exists to
    make the *programming model* of the paper checkable: reading a window that
    received a put since the last fence raises :class:`RMAError`, exactly the
    bug class ``ompx_fence`` exists to prevent on real hardware.
    """

    def __init__(self):
        self._windows: Dict[str, _WindowState] = {}
        self.puts = 0
        self.fences = 0
        self.put_bytes = 0
        self.window_bytes: Dict[str, int] = {}
        # re-issued wire traffic (fault retries) — accounted apart from the
        # logical counters above so byte-parity audits hold under chaos
        self.retry_puts = 0
        self.retry_bytes = 0
        self.window_retry_bytes: Dict[str, int] = {}

    def register(self, name: str) -> None:
        if name in self._windows:
            raise RMAError(f"window {name!r} already registered")
        self._windows[name] = _WindowState()

    def ensure(self, name: str) -> None:
        """Register ``name`` if it isn't yet (idempotent).

        Long-lived windows that persist across traces — the halo windows a
        stencil time loop puts into every step — are ensured at each call
        site instead of registered once at a setup point the trace may not
        own."""
        if name not in self._windows:
            self._windows[name] = _WindowState()

    def unregister(self, name: str) -> None:
        """Drop a window at the end of its allocation's lifetime (e.g. a
        serving request's KV window at release).  Its cumulative byte count
        survives in :attr:`window_bytes` for post-hoc accounting."""
        if self._windows.pop(name, None) is None:
            raise RMAError(f"unknown window {name!r}")

    def _state(self, name: str) -> _WindowState:
        try:
            return self._windows[name]
        except KeyError:
            raise RMAError(f"unknown window {name!r}") from None

    def on_put(self, name: str, nbytes: int = 0, *,
               checksum: str = None, retry: bool = False) -> None:
        """Record a put into ``name``.

        ``checksum`` is the digest the transfer claims to have landed
        (what :meth:`validate` checks after the fence); ``retry=True``
        marks a re-issued wire attempt, accounted in the retry counters
        instead of the logical put/byte log.
        """
        st = self._state(name)
        st.dirty_since = st.epoch
        st.checksum = checksum
        if retry:
            self.retry_puts += 1
            self.retry_bytes += nbytes
            if nbytes:
                self.window_retry_bytes[name] = \
                    self.window_retry_bytes.get(name, 0) + nbytes
            return
        self.puts += 1
        self.put_bytes += nbytes
        if nbytes:
            self.window_bytes[name] = self.window_bytes.get(name, 0) + nbytes

    def on_fence(self, *names: str) -> None:
        targets = names or tuple(self._windows)
        for name in targets:
            st = self._state(name)
            st.epoch += 1
            st.dirty_since = -1
        self.fences += 1

    def on_read(self, name: str) -> None:
        st = self._state(name)
        if st.dirty_since >= 0:
            raise RMAError(
                f"window {name!r} read with un-fenced puts outstanding "
                "(call ompx_fence first)"
            )

    def validate(self, name: str, checksum: str) -> None:
        """Check that the last fenced put landed ``checksum`` — the get-side
        integrity check that turns injected corruption into a detected,
        retryable error instead of silent bad data.  Reading an un-fenced
        window is the usual discipline violation; a digest mismatch after
        the fence raises :class:`RMAError` so the caller re-puts (accounted
        as retry traffic)."""
        st = self._state(name)
        if st.dirty_since >= 0:
            raise RMAError(
                f"window {name!r} validated with un-fenced puts outstanding "
                "(call ompx_fence first)"
            )
        if st.checksum != checksum:
            landed = (st.checksum or "<none>")[:12]
            raise RMAError(
                f"window {name!r} checksum mismatch: expected "
                f"{checksum[:12]}..., wire landed {landed}... "
                "(corrupted or dropped put)"
            )

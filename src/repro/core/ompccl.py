"""OMPCCL — the portable collective communication layer (paper §3.3).

The paper's OMPCCL exposes device-side collectives (broadcast, reduce,
all-reduce, ...) through one portable API and dispatches to the vendor library
(NCCL / RCCL).  On TPU the "vendor library" is XLA's collective runtime; the
portable API here is a set of functions that run **inside shard_map**, scoped
by a :class:`~repro.core.groups.DiompGroup`, with a backend switch:

* ``xla``          — direct ``jax.lax`` collectives (flat algorithms);
* ``hierarchical`` — pod-aware two-level algorithms from
  :mod:`repro.distributed.hierarchical` (reduce-scatter intra-pod →
  all-reduce inter-pod → all-gather intra-pod), the TPU analogue of
  NCCL's topology-aware trees/rings;
* ``compressed``   — int8 quantization + error feedback around the wire
  collective (:mod:`repro.distributed.compression`).

Every call is recorded against its communicator, mirroring how OMPCCL
registers NCCL communicators per DiOMP group, and giving the benchmark layer a
faithful call log.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .groups import DiompGroup

__all__ = [
    "Communicator",
    "CclRegistry",
    "registry",
    "allreduce",
    "reduce",
    "bcast",
    "allgather",
    "reducescatter",
    "alltoall",
    "permute",
    "barrier_value",
    "group_rank",
    "group_size",
]


# ---------------------------------------------------------------------------
# communicator registry (models OMPCCL's UniqueID bootstrap + per-group comms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Communicator:
    group: DiompGroup
    backend: str = "xla"
    calls: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record(self, op: str) -> None:
        self.calls[op] = self.calls.get(op, 0) + 1


class CclRegistry:
    """Host-side table: group descriptor -> communicator (paper: UniqueID
    generation + broadcast happens once per group at init)."""

    def __init__(self):
        self._comms: Dict[str, Communicator] = {}

    def communicator(self, group: DiompGroup, backend: str = "xla") -> Communicator:
        key = group.descriptor()
        if key not in self._comms:
            self._comms[key] = Communicator(group=group, backend=backend)
        return self._comms[key]

    def reset(self) -> None:
        self._comms.clear()

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {k: dict(c.calls) for k, c in self._comms.items()}


registry = CclRegistry()


def _axes(group: DiompGroup) -> Tuple[str, ...]:
    if group.is_self_group():
        raise ValueError("collective on empty (self) group")
    return group.lax_axes


def ensure_varying(x, axes: Tuple[str, ...]):
    """Promote x to be varying over ``axes`` (vma bookkeeping).

    A collective over a group must see its operand varying on every group
    axis; values that are invariant on some axis (e.g. a loss already
    psum'd over "model") are pvary'd first — a pure type-level operation.
    """
    def promote(v):
        vma = getattr(jax.typeof(v), "vma", frozenset())
        missing = tuple(a for a in axes if a not in vma)
        return lax.pcast(v, missing, to="varying") if missing else v

    return jax.tree.map(promote, x)


def group_rank(group: DiompGroup):
    """Linearized rank of the caller within the group (row-major over axes)."""
    rank = jnp.int32(0)
    for ax in group.axes:
        rank = rank * lax.axis_size(ax) + lax.axis_index(ax)
    return rank


def group_size(group: DiompGroup) -> int:
    size = 1
    for ax in group.axes:
        size *= lax.axis_size(ax)
    return size


# ---------------------------------------------------------------------------
# collectives — all usable inside shard_map
# ---------------------------------------------------------------------------


def allreduce(x, group: DiompGroup, *, op: str = "sum", backend: str = "xla"):
    """ompx_allreduce: reduction across the group, result on every member."""
    registry.communicator(group, backend).record("allreduce")
    x = ensure_varying(x, _axes(group))
    if backend == "hierarchical":
        from repro.distributed.hierarchical import hierarchical_allreduce

        return hierarchical_allreduce(x, group, op=op)
    if backend == "compressed":
        from repro.distributed.compression import compressed_allreduce

        return compressed_allreduce(x, group)
    axes = _axes(group)
    if op == "sum":
        return lax.psum(x, axes)
    if op == "max":
        return lax.pmax(x, axes)
    if op == "min":
        return lax.pmin(x, axes)
    if op == "mean":
        return lax.pmean(x, axes)
    raise ValueError(f"unsupported op {op!r}")


def reduce(x, group: DiompGroup, *, root: int = 0, op: str = "sum"):
    """ompx_reduce: like allreduce but only ``root`` keeps the result
    (others receive zeros), matching MPI_Reduce semantics in SPMD form."""
    registry.communicator(group).record("reduce")
    full = allreduce(x, group, op=op)
    rank = group_rank(group)
    return jnp.where(rank == root, full, jnp.zeros_like(full))


def bcast(x, group: DiompGroup, *, root: int = 0):
    """ompx_bcast: root's value delivered to every group member.

    SPMD formulation: zero out non-root contributions and sum — on TPU this
    lowers to a single all-reduce whose cost equals a broadcast tree (XLA
    picks the algorithm; the semantics are exact because non-root terms are
    literal zeros).
    """
    registry.communicator(group).record("bcast")
    x = ensure_varying(x, _axes(group))
    rank = group_rank(group)
    contribution = jnp.where(rank == root, x, jnp.zeros_like(x))
    return lax.psum(contribution, _axes(group))


def allgather(x, group: DiompGroup, *, axis: int = 0, tiled: bool = True,
              invariant: bool = False):
    """ompx_allgather along a tensor axis (tiled: concatenates shards).

    ``invariant=True`` uses the Varying->Invariant gather: same wire bytes,
    but the type system records that every member ends with identical data
    (its transpose is a free dynamic-slice instead of a reduce-scatter).
    Inference paths use it — no AD, exact replication typing.
    """
    registry.communicator(group).record("allgather")
    out = ensure_varying(x, _axes(group))
    # gather across each mesh axis of the group, innermost last so that the
    # concatenation order equals the group's row-major rank order
    if invariant:
        from jax._src.lax.parallel import all_gather_invariant

        for ax in reversed(group.axes):
            out = all_gather_invariant(out, ax, axis=axis, tiled=tiled)
        return out
    for ax in reversed(group.axes):
        out = lax.all_gather(out, ax, axis=axis, tiled=tiled)
    return out


def reducescatter(x, group: DiompGroup, *, axis: int = 0):
    """ompx_reducescatter: sum across group, scatter shards along ``axis``."""
    registry.communicator(group).record("reducescatter")
    out = ensure_varying(x, _axes(group))
    for ax in group.axes:
        out = lax.psum_scatter(out, ax, scatter_dimension=axis, tiled=True)
    return out


def alltoall(x, group: DiompGroup, *, split_axis: int = 0, concat_axis: int = 0):
    """ompx_alltoall — the MoE dispatch primitive.

    Multi-axis groups act as one combined axis (row-major rank order), so the
    split dim must be divisible by the full group size.
    """
    registry.communicator(group).record("alltoall")
    x = ensure_varying(x, _axes(group))
    return lax.all_to_all(
        x, group.lax_axes, split_axis=split_axis, concat_axis=concat_axis,
        tiled=True,
    )


def permute(x, group: DiompGroup, *, shift: int = 1):
    """Ring permute within the group — the transport under ompx_put."""
    registry.communicator(group).record("permute")
    if len(group.axes) != 1:
        raise ValueError("permute requires a single-axis group")
    x = ensure_varying(x, _axes(group))
    ax = group.axes[0]
    n = lax.axis_size(ax)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, ax, perm)


def barrier_value(group: DiompGroup):
    """A collective-ordering token: psum of a zero scalar across the group.

    Data-depending later ops on this token enforces collective completion —
    the compiled-SPMD analogue of ompx_barrier(group).
    """
    registry.communicator(group).record("barrier")
    return lax.psum(jnp.zeros((), jnp.float32), _axes(group))


# ---------------------------------------------------------------------------
# analytic cost model (used by benchmarks + the hillclimb napkin math)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """v5e ICI link model; one link per mesh-torus direction."""

    bandwidth_Bps: float = 50e9  # ~50 GB/s per link direction
    latency_s: float = 1e-6  # per-hop launch latency


def ring_allreduce_time(bytes_: int, ndev: int, link: LinkModel = LinkModel()) -> float:
    """2(n-1)/n · B / bw + 2(n-1) · lat — the classic ring bound."""
    if ndev <= 1:
        return 0.0
    steps = 2 * (ndev - 1)
    return steps * link.latency_s + (steps / ndev) * bytes_ / link.bandwidth_Bps


def ring_allgather_time(bytes_out: int, ndev: int, link: LinkModel = LinkModel()) -> float:
    if ndev <= 1:
        return 0.0
    steps = ndev - 1
    return steps * link.latency_s + (steps / ndev) * bytes_out / link.bandwidth_Bps


def hierarchical_allreduce_time(
    bytes_: int,
    intra: int,
    inter: int,
    intra_link: LinkModel = LinkModel(),
    inter_link: LinkModel = LinkModel(bandwidth_Bps=25e9, latency_s=5e-6),
) -> float:
    """RS(intra) + AR(inter, on 1/intra of the data) + AG(intra)."""
    t_rs = ring_allgather_time(bytes_, intra, intra_link)  # RS cost == AG cost
    t_ar = ring_allreduce_time(bytes_ // max(intra, 1), inter, inter_link)
    t_ag = ring_allgather_time(bytes_, intra, intra_link)
    return t_rs + t_ar + t_ag

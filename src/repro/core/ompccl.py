"""OMPCCL — the portable collective communication layer (paper §3.3).

The wire algorithms live in :mod:`repro.core.backends` (pluggable
``CclBackend`` classes: flat XLA, pod-hierarchical, int8-compressed,
analytic); the communicator handles and the per-group call log live in
:mod:`repro.core.context`.  This module is the paper-verbatim *free
function* surface: every call resolves the process-default
:class:`~repro.core.context.DiompContext`, obtains the communicator handle
for ``(group, backend)``, and dispatches through it — so listing-style code
(`ompccl.allreduce(x, g)`) and handle-style code
(`ctx.communicator(g).allreduce(x)`) hit the same table, record the same
call stream, and honor the same backend choice.

Unlike the pre-context API, ``backend=`` now propagates to **every**
collective (including ``reduce`` and ``bcast``, which previously dropped
it), because dispatch happens on the handle, not in per-op branches.
"""

from __future__ import annotations

from typing import Dict

from .backends import (  # noqa: F401  (re-exports: benchmark/compat surface)
    LinkModel,
    ensure_varying,
    group_rank,
    group_size,
    hierarchical_allreduce_time,
    ring_allgather_time,
    ring_allreduce_time,
)
from .context import (CommTable, Communicator, default_communicator as
                      _comm, default_context)
from .groups import DiompGroup

__all__ = [
    "Communicator",
    "CclRegistry",
    "registry",
    "allreduce",
    "reduce",
    "bcast",
    "allgather",
    "reducescatter",
    "alltoall",
    "permute",
    "barrier_value",
    "group_rank",
    "group_size",
    "ensure_varying",
    "LinkModel",
    "ring_allreduce_time",
    "ring_allgather_time",
    "hierarchical_allreduce_time",
]

# the handle-owning table class, under its historical name
CclRegistry = CommTable


class _DefaultRegistryProxy:
    """``ompccl.registry`` now proxies the default context's table.

    Kept for callers that inspect ``registry.stats()`` / call
    ``registry.reset()``; no library code reads it — every op goes through
    a context communicator handle.
    """

    def communicator(self, group: DiompGroup, backend: str = None
                     ) -> Communicator:
        return _comm(group, backend)

    def reset(self) -> None:
        default_context().reset_stats()

    def stats(self) -> Dict[str, Dict[str, int]]:
        return default_context().stats()


registry = _DefaultRegistryProxy()


# ---------------------------------------------------------------------------
# collectives — all usable inside shard_map
# ---------------------------------------------------------------------------


def allreduce(x, group: DiompGroup, *, op: str = "sum", backend: str = None):
    """ompx_allreduce: reduction across the group, result on every member."""
    return _comm(group, backend).allreduce(x, op=op)


def reduce(x, group: DiompGroup, *, root: int = 0, op: str = "sum",
           backend: str = None):
    """ompx_reduce: like allreduce but only ``root`` keeps the result
    (others receive zeros), matching MPI_Reduce semantics in SPMD form."""
    return _comm(group, backend).reduce(x, root=root, op=op)


def bcast(x, group: DiompGroup, *, root: int = 0, backend: str = None):
    """ompx_bcast: root's value delivered to every group member."""
    return _comm(group, backend).bcast(x, root=root)


def allgather(x, group: DiompGroup, *, axis: int = 0, tiled: bool = True,
              invariant: bool = False, backend: str = None):
    """ompx_allgather along a tensor axis (tiled: concatenates shards)."""
    return _comm(group, backend).allgather(x, axis=axis, tiled=tiled,
                                           invariant=invariant)


def reducescatter(x, group: DiompGroup, *, axis: int = 0,
                  backend: str = None):
    """ompx_reducescatter: sum across group, scatter shards along ``axis``."""
    return _comm(group, backend).reducescatter(x, axis=axis)


def alltoall(x, group: DiompGroup, *, split_axis: int = 0,
             concat_axis: int = 0, backend: str = None):
    """ompx_alltoall — the MoE dispatch primitive."""
    return _comm(group, backend).alltoall(x, split_axis=split_axis,
                                          concat_axis=concat_axis)


def permute(x, group: DiompGroup, *, shift: int = 1, backend: str = None):
    """Ring permute within the group — the transport under ompx_put."""
    return _comm(group, backend).permute(x, shift=shift)


def barrier_value(group: DiompGroup, *, backend: str = None):
    """A collective-ordering token: psum of a zero scalar across the group."""
    return _comm(group, backend).barrier()

"""JAX version compatibility shims.

The runtime targets the modern explicit-vma API surface (``jax.shard_map``,
``jax.typeof``, ``lax.pcast``, the invariant all-gather); the jax pinned in
this container (0.4.37) still keeps ``shard_map`` under ``jax.experimental``
and predates vma tracking entirely.  Every site in src/tests/examples/
benchmarks imports these names from here so the rest of the codebase is
version-agnostic:

* :func:`shard_map` — ``jax.shard_map`` when present, else the experimental
  one with ``check_rep=False`` (vma/replication discipline is enforced by
  our own ``ensure_varying`` calls, which the old checker cannot see);
* :func:`typeof` — ``jax.typeof`` or the abstract-value fallback.  Callers
  read ``.vma`` via ``getattr(..., "vma", frozenset())`` so the fallback's
  lack of vma degrades to "promote everything", which :func:`pcast` then
  turns into a no-op;
* :func:`pcast` — ``lax.pcast`` or identity (pre-vma jax has no
  varying/invariant distinction, so the promotion is vacuous);
* :func:`all_gather_invariant` — falls back to ``lax.all_gather`` (same
  wire bytes; only the type-level replication annotation is lost);
* :func:`make_mesh` — swallows ``axis_types`` on jax builds whose
  ``jax.make_mesh`` does not accept it yet.
"""

from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax
from jax import lax

__all__ = [
    "shard_map",
    "typeof",
    "pcast",
    "axis_size",
    "all_gather_invariant",
    "make_mesh",
    "HAS_VMA",
]


# -- vma (varying-manual-axes) typing ----------------------------------------

HAS_VMA = hasattr(jax, "typeof") and hasattr(lax, "pcast")


# -- shard_map ---------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_PARAMS = frozenset(
        inspect.signature(_shard_map).parameters)
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_PARAMS = frozenset(
        inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """Version-stable ``shard_map``.

    On pre-vma jax the old ``check_rep`` checker cannot see our explicit
    ``ensure_varying`` promotions and would reject programs the vma type
    system accepts, so it is disabled there.  On vma-capable jax the
    default checking stays ON — the implicit pvary-transpose psums that
    train/step.py's HAS_VMA branch relies on require it.
    """
    if not HAS_VMA and "check_rep" in _SHARD_MAP_PARAMS:
        kwargs.setdefault("check_rep", False)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


if hasattr(jax, "typeof"):
    typeof = jax.typeof
else:
    def typeof(x) -> Any:
        """Abstract value of ``x``; has no ``.vma`` attribute on old jax."""
        return jax.core.get_aval(x)


if hasattr(lax, "pcast"):
    pcast = lax.pcast
else:
    def pcast(x, axes, *, to: str = "varying"):
        """Identity: pre-vma jax has no varying/invariant distinction."""
        del axes, to
        return x


# -- named-axis size ---------------------------------------------------------

if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:
    def axis_size(axis_name) -> int:
        """Static size of a named mesh axis under trace.

        ``psum`` of the literal 1 constant-folds to the axis size as a
        Python int on every jax version — the documented pre-``axis_size``
        idiom.
        """
        return lax.psum(1, axis_name)


# -- invariant all-gather ----------------------------------------------------

try:  # pragma: no cover - depends on the installed jax
    from jax._src.lax.parallel import all_gather_invariant as \
        _all_gather_invariant
except ImportError:
    _all_gather_invariant = None


def all_gather_invariant(x, axis_name, *, axis: int = 0, tiled: bool = False):
    """Varying->Invariant all-gather, or the plain one where unsupported.

    Numerically identical either way; the invariant form only adds the
    type-level fact that every rank holds the same bytes afterwards.
    """
    if _all_gather_invariant is not None:
        return _all_gather_invariant(x, axis_name, axis=axis, tiled=tiled)
    return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


# -- mesh construction -------------------------------------------------------

_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Optional[Sequence] = None, devices=None):
    """``jax.make_mesh`` that tolerates the ``axis_types`` kwarg everywhere.

    ``axis_types`` may be a tuple of ``jax.sharding.AxisType`` (new jax), the
    string ``"auto"`` (resolved here), or None.  Old jax has neither the
    kwarg nor the enum; all axes are implicitly Auto there, so dropping the
    argument preserves behavior.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if "axis_types" in _MAKE_MESH_PARAMS and \
            hasattr(jax.sharding, "AxisType"):
        if axis_types is None or axis_types == "auto":
            axis_types = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)

"""OMPCCL backends — first-class pluggable collective implementations.

The paper's OMPCCL registers one communicator per DiOMP group and dispatches
every collective to the vendor library behind a stable API (NCCL on CUDA,
RCCL on ROCm; §3.3).  Here the "vendor libraries" are backend *classes*
implementing the :class:`CclBackend` protocol:

* :class:`XlaBackend`          — direct ``jax.lax`` collectives (flat
  single-phase algorithms; XLA's collective runtime is the TPU vendor lib);
* :class:`HierarchicalBackend` — pod-aware two-level algorithms from
  :mod:`repro.distributed.hierarchical` (reduce-scatter intra-pod →
  all-reduce inter-pod → all-gather intra-pod), the TPU analogue of NCCL's
  topology-aware trees/rings;
* :class:`CompressedBackend`   — int8 quantization + error feedback around
  the wire collective (:mod:`repro.distributed.compression`);
* :class:`AnalyticBackend`     — the XLA wire path plus a per-call analytic
  cost estimate (the dry-run / roofline napkin math), logged host-side at
  trace time.

Backends register by name in a module registry so new ones plug in without
touching any call site: ``@register_backend`` + ``ctx.communicator(group,
backend="mine")``.  A backend instance never records call counts — that is
the communicator handle's job (:mod:`repro.core.context`); backends own only
the wire lowering, so every method here is safe to call from inside
``shard_map`` tracing.

The analytic link-cost models (ring/hierarchical time bounds) also live
here; :mod:`repro.core.ompccl` re-exports them for the benchmark layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
from jax import lax

from .compat import all_gather_invariant, axis_size, pcast, typeof
from .groups import DiompGroup

__all__ = [
    "CclBackend",
    "XlaBackend",
    "HierarchicalBackend",
    "CompressedBackend",
    "AnalyticBackend",
    "register_backend",
    "get_backend",
    "available_backends",
    "BackendError",
    "ensure_varying",
    "group_rank",
    "group_size",
    "fence",
    "LinkModel",
    "ring_allreduce_time",
    "ring_allgather_time",
    "hierarchical_allreduce_time",
    "per_param_reduce_time",
    "bucketed_reduce_time",
    "overlapped_reduce_time",
]


class BackendError(ValueError):
    """Unknown backend name / invalid backend registration."""


# ---------------------------------------------------------------------------
# trace-level helpers shared by every backend
# ---------------------------------------------------------------------------


def _axes(group: DiompGroup) -> Tuple[str, ...]:
    if group.is_self_group():
        raise ValueError("collective on empty (self) group")
    return group.lax_axes


def ensure_varying(x, axes: Tuple[str, ...]):
    """Promote x to be varying over ``axes`` (vma bookkeeping).

    A collective over a group must see its operand varying on every group
    axis; values that are invariant on some axis (e.g. a loss already
    psum'd over "model") are pvary'd first — a pure type-level operation.
    On pre-vma jax this is the identity.
    """
    def promote(v):
        vma = getattr(typeof(v), "vma", frozenset())
        missing = tuple(a for a in axes if a not in vma)
        return pcast(v, missing, to="varying") if missing else v

    return jax.tree.map(promote, x)


def group_rank(group: DiompGroup):
    """Linearized rank of the caller within the group (row-major over axes)."""
    rank = jnp.int32(0)
    for ax in group.axes:
        rank = rank * axis_size(ax) + lax.axis_index(ax)
    return rank


def payload_bytes(x) -> int:
    """Static payload size of a (possibly traced) operand pytree — the ONE
    byte counter behind both the communicator wire-volume log and the
    analytic backend's cost estimates."""
    total = 0
    for leaf in jax.tree.leaves(x):
        n = 1
        for d in getattr(leaf, "shape", ()):
            n *= int(d)
        total += n * jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
    return total


def group_size(group: DiompGroup) -> int:
    size = 1
    for ax in group.axes:
        size *= axis_size(ax)
    return size


def _ring_axis(group: DiompGroup) -> str:
    if len(group.axes) != 1:
        raise ValueError(
            f"RMA rings need a single-axis group (one ICI ring), got {group.axes}"
        )
    return group.axes[0]


@jax.custom_jvp
def _fence_tuple(arrays):
    return lax.optimization_barrier(arrays)


@_fence_tuple.defjvp
def _fence_tuple_jvp(primals, tangents):
    # the barrier is an ordering property of the PRIMAL program; tangents
    # ride through as the identity (which also makes the reverse-mode
    # transpose trivial), so fenced pipelines stay differentiable — the
    # fused halo-overlapped stencil trains through its per-step fence
    (arrays,), (dots,) = primals, tangents
    return _fence_tuple(arrays), dots


def fence(*arrays):
    """Complete all outstanding RMA before anything downstream runs.

    ``lax.optimization_barrier`` prevents XLA from reordering/fusing across
    the fence — the compiled counterpart of DiOMP's hybrid polling loop that
    waits on both network and device events.  Returns the fenced arrays.
    Backend-independent: the fence is an ordering property of the compiled
    program, not of any one transport — and differentiable (see the custom
    JVP above), so overlapped schedules can sit inside training steps.
    """
    if not arrays:
        return ()
    fenced = _fence_tuple(tuple(arrays))
    return fenced[0] if len(arrays) == 1 else fenced


# ---------------------------------------------------------------------------
# the backend protocol + flat XLA implementation
# ---------------------------------------------------------------------------


class CclBackend:
    """Protocol + default flat-XLA lowering for every OMPCCL verb.

    Subclasses override individual collectives; anything not overridden
    falls through to the flat single-phase algorithm, so a backend only has
    to implement what it actually changes (exactly how OMPCCL falls back to
    the generic path for ops a vendor library lacks).
    """

    #: registry name; subclasses must override.
    name = "xla"

    # -- collectives (usable inside shard_map) ------------------------------
    def allreduce(self, x, group: DiompGroup, *, op: str = "sum"):
        x = ensure_varying(x, _axes(group))
        axes = _axes(group)
        if op == "sum":
            return lax.psum(x, axes)
        if op == "max":
            return lax.pmax(x, axes)
        if op == "min":
            return lax.pmin(x, axes)
        if op == "mean":
            return lax.pmean(x, axes)
        raise ValueError(f"unsupported op {op!r}")

    def bcast(self, x, group: DiompGroup, *, root: int = 0):
        """Root's value delivered to every member.

        SPMD formulation: zero out non-root contributions and sum through
        ``self.allreduce`` — so a backend that only overrides allreduce
        automatically broadcasts over its own wire algorithm (exact because
        non-root terms are literal zeros; on the flat path XLA lowers it to
        one all-reduce whose cost equals a broadcast tree).
        """
        x = ensure_varying(x, _axes(group))
        rank = group_rank(group)
        contribution = jnp.where(rank == root, x, jnp.zeros_like(x))
        return self.allreduce(contribution, group)

    def allgather(self, x, group: DiompGroup, *, axis: int = 0,
                  tiled: bool = True, invariant: bool = False):
        out = ensure_varying(x, _axes(group))
        # gather across each mesh axis of the group, innermost last so that
        # the concatenation order equals the group's row-major rank order
        if invariant:
            for ax in reversed(group.axes):
                out = all_gather_invariant(out, ax, axis=axis, tiled=tiled)
            return out
        for ax in reversed(group.axes):
            out = lax.all_gather(out, ax, axis=axis, tiled=tiled)
        return out

    def reducescatter(self, x, group: DiompGroup, *, axis: int = 0):
        out = ensure_varying(x, _axes(group))
        for ax in group.axes:
            out = lax.psum_scatter(out, ax, scatter_dimension=axis, tiled=True)
        return out

    def alltoall(self, x, group: DiompGroup, *, split_axis: int = 0,
                 concat_axis: int = 0):
        x = ensure_varying(x, _axes(group))
        return lax.all_to_all(
            x, group.lax_axes, split_axis=split_axis, concat_axis=concat_axis,
            tiled=True,
        )

    def permute(self, x, group: DiompGroup, *, shift: int = 1):
        if len(group.axes) != 1:
            raise ValueError("permute requires a single-axis group")
        x = ensure_varying(x, _axes(group))
        ax = group.axes[0]
        n = axis_size(ax)
        perm = [(i, (i + shift) % n) for i in range(n)]
        return lax.ppermute(x, ax, perm)

    def barrier(self, group: DiompGroup):
        """A collective-ordering token: psum of a zero scalar across the
        group.  Data-depending later ops on this token enforces collective
        completion — the compiled-SPMD analogue of ompx_barrier(group)."""
        return lax.psum(jnp.zeros((), jnp.float32), _axes(group))

    # -- one-sided RMA ------------------------------------------------------
    def put(self, x, group: DiompGroup, *, shift: int = 1):
        """One-sided put of my shard to the rank ``shift`` ahead on the ring.

        SPMD semantics: every rank's window receives the shard of the rank
        ``shift`` *behind* it.  ``shift`` may be negative.  Lowers to a
        single ``collective-permute`` (a remote DMA on ICI).
        """
        ax = _ring_axis(group)
        n = axis_size(ax)
        perm = [(i, (i + shift) % n) for i in range(n)]
        return lax.ppermute(x, ax, perm)

    def put_perm(self, x, group: DiompGroup, perm: Sequence[Tuple[int, int]]):
        """General one-sided put along an arbitrary (src, dst) permutation."""
        ax = _ring_axis(group)
        return lax.ppermute(x, ax, list(perm))

    def halo_exchange(self, x, group: DiompGroup, *, halo: int,
                      axis: int = 0):
        """Minimod's halo pattern (paper Listing 1) as one fused exchange.

        Every rank puts its *left* boundary slab to the left neighbor's
        right halo and its *right* boundary slab to the right neighbor's
        left halo, then fences.  Returns ``(left_halo, right_halo)``; edge
        ranks receive zeros (non-periodic stencil boundaries).
        """
        # deferred import: rma imports this module at load time
        from .rma import validate_halo

        validate_halo(halo, x.shape[axis], axis)
        ax = _ring_axis(group)
        n = axis_size(ax)
        idx = lax.axis_index(ax)

        left_slab = lax.slice_in_dim(x, 0, halo, axis=axis)
        right_slab = lax.slice_in_dim(
            x, x.shape[axis] - halo, x.shape[axis], axis=axis)

        # put right_slab -> rank+1's left halo; left_slab -> rank-1's right
        # halo.  Non-periodic: drop the wrap-around edge.
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
        from_left = lax.ppermute(right_slab, ax, fwd)
        from_right = lax.ppermute(left_slab, ax, bwd)

        from_left = jnp.where(idx == 0, jnp.zeros_like(from_left), from_left)
        from_right = jnp.where(idx == n - 1, jnp.zeros_like(from_right),
                               from_right)
        return fence(from_left, from_right)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}({self.name!r})"


class XlaBackend(CclBackend):
    """The flat vendor path: every verb is the base-class XLA lowering."""

    name = "xla"


class HierarchicalBackend(CclBackend):
    """Pod-aware two-level algorithms (NCCL's topology-trees analogue)."""

    name = "hierarchical"

    def allreduce(self, x, group: DiompGroup, *, op: str = "sum"):
        from repro.distributed.hierarchical import hierarchical_allreduce

        x = ensure_varying(x, _axes(group))
        return hierarchical_allreduce(x, group, op=op)

    def reducescatter(self, x, group: DiompGroup, *, axis: int = 0):
        """Fast-axes-first reduce-scatter: the payload is cut to 1/F
        intra-pod before anything crosses the slow inter-pod link.

        Shard order is therefore fast-major — the exact inverse of this
        backend's ``allgather(invariant=True)``, so an RS -> invariant-AG
        pair through one hierarchical handle reconstructs the flat result
        (the bucketed overlap path's contract).  It is NOT the row-major
        shard order of the flat backend, and the handle's *non-invariant*
        allgather keeps the row-major concat order (the standalone
        gather-a-sharded-tensor contract) — pairing RS with
        ``invariant=False`` returns element-permuted data.
        """
        if len(group.axes) < 2:
            return super().reducescatter(x, group, axis=axis)
        slow, fast = group.axes[0], group.axes[1:]
        out = ensure_varying(x, _axes(group))
        for ax in (*fast, slow):
            out = lax.psum_scatter(out, ax, scatter_dimension=axis,
                                   tiled=True)
        return out

    def allgather(self, x, group: DiompGroup, *, axis: int = 0,
                  tiled: bool = True, invariant: bool = False):
        if len(group.axes) < 2 or not tiled:
            return super().allgather(x, group, axis=axis, tiled=tiled,
                                     invariant=invariant)
        x = ensure_varying(x, _axes(group))
        if invariant:
            # slow link first, while the payload is smallest (1/(F·S) ->
            # 1/F crosses inter-pod; the fast axes finish intra-pod) —
            # inverts this backend's reducescatter step for step
            slow, fast = group.axes[0], group.axes[1:]
            out = x
            for ax in (slow, *reversed(fast)):
                out = all_gather_invariant(out, ax, axis=axis, tiled=tiled)
            return out
        from repro.distributed.hierarchical import hierarchical_allgather

        return hierarchical_allgather(x, group, axis=axis)


class CompressedBackend(CclBackend):
    """int8 + error-feedback wire compression around the reduce.

    ``allreduce`` honors the CclBackend contract (returns the reduced
    array); the quantization residual is discarded.  Error-feedback
    training loops need the residual as a traced carry, so they call
    :func:`repro.distributed.compression.compressed_allreduce` directly —
    backend-instance state cannot thread a per-step carry.
    """

    name = "compressed"

    def allreduce(self, x, group: DiompGroup, *, op: str = "sum",
                  error=None):
        from repro.distributed.compression import compressed_allreduce

        if op != "sum":
            raise ValueError(
                f"compressed backend reduces op='sum' only, got {op!r} "
                "(min/max do not decompose through quantized chunks)")
        x = ensure_varying(x, _axes(group))
        # compressed_allreduce returns the group MEAN; scale back to the
        # sum the CclBackend contract promises
        out, _residual = compressed_allreduce(x, group, error=error)
        return jax.tree.map(lambda o: o * group_size(group), out)


class AnalyticBackend(CclBackend):
    """XLA wire path + a host-side analytic cost log per call.

    Each collective traced through this backend appends an estimate row to
    :attr:`estimates` (op, payload bytes, group size, modeled seconds on
    the v5e link model) — the dry-run's napkin math, attached to the same
    call stream the communicator records.  Estimation failures (e.g. a
    pytree operand outside shard_map) degrade to ``est_s=None`` rather than
    perturbing the traced program.
    """

    name = "analytic"

    def __init__(self, link: Optional["LinkModel"] = None):
        self.link = link or LinkModel()
        self.estimates: List[dict] = []

    def _note(self, op: str, x, group: DiompGroup, time_fn) -> None:
        try:
            nbytes = payload_bytes(x)
            ndev = group_size(group)
            est = time_fn(nbytes, ndev)
        except Exception:  # noqa: BLE001 - cost model must never break trace
            nbytes, ndev, est = None, None, None
        self.estimates.append(
            {"op": op, "bytes": nbytes, "ndev": ndev, "est_s": est})

    def allreduce(self, x, group: DiompGroup, *, op: str = "sum"):
        self._note("allreduce", x, group,
                   lambda b, n: ring_allreduce_time(b, n, self.link))
        return super().allreduce(x, group, op=op)

    # bcast needs no override: the base class routes it through
    # self.allreduce, which logs the underlying all-reduce estimate

    def allgather(self, x, group: DiompGroup, *, axis: int = 0,
                  tiled: bool = True, invariant: bool = False):
        self._note("allgather", x, group,
                   lambda b, n: ring_allgather_time(b * n, n, self.link))
        return super().allgather(x, group, axis=axis, tiled=tiled,
                                 invariant=invariant)

    def reducescatter(self, x, group: DiompGroup, *, axis: int = 0):
        self._note("reducescatter", x, group,
                   lambda b, n: ring_allgather_time(b, n, self.link))
        return super().reducescatter(x, group, axis=axis)

    def alltoall(self, x, group: DiompGroup, *, split_axis: int = 0,
                 concat_axis: int = 0):
        self._note("alltoall", x, group,
                   lambda b, n: ring_allgather_time(b, n, self.link))
        return super().alltoall(x, group, split_axis=split_axis,
                                concat_axis=concat_axis)

    def put(self, x, group: DiompGroup, *, shift: int = 1):
        self._note("put", x, group,
                   lambda b, n: b / self.link.bandwidth_Bps
                   + self.link.latency_s)
        return super().put(x, group, shift=shift)


# ---------------------------------------------------------------------------
# backend registry (models OMPCCL's vendor-library dispatch table)
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Type[CclBackend]] = {}


def register_backend(cls: Type[CclBackend], *,
                     name: Optional[str] = None,
                     aliases: Sequence[str] = ()) -> Type[CclBackend]:
    """Register a backend class under ``cls.name`` (usable as a decorator).

    New backends plug in without touching a single call site: every
    communicator handle resolves its backend through this table.
    """
    if not (isinstance(cls, type) and issubclass(cls, CclBackend)):
        raise BackendError(f"{cls!r} is not a CclBackend subclass")
    key = name or cls.name
    if not key:
        raise BackendError(f"{cls.__name__} has no backend name")
    _BACKENDS[key] = cls
    for alias in aliases:
        _BACKENDS[alias] = cls
    return cls


def get_backend(name: str) -> Type[CclBackend]:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise BackendError(
            f"unknown OMPCCL backend {name!r}; available: "
            f"{sorted(set(_BACKENDS))}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(set(_BACKENDS)))


register_backend(XlaBackend, aliases=("flat",))
register_backend(HierarchicalBackend)
register_backend(CompressedBackend)
register_backend(AnalyticBackend)


# ---------------------------------------------------------------------------
# analytic cost model (used by benchmarks + the hillclimb napkin math)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """v5e ICI link model; one link per mesh-torus direction."""

    bandwidth_Bps: float = 50e9  # ~50 GB/s per link direction
    latency_s: float = 1e-6  # per-hop launch latency
    dispatch_s: float = 5e-6  # host/XLA launch overhead per collective

    def collective_time(self, nbytes: int, ndev: int) -> float:
        """One ring all-reduce including the per-call dispatch overhead —
        the unit cost both gradient-reduction schedules are built from."""
        return self.dispatch_s + ring_allreduce_time(nbytes, ndev, self)


def ring_allreduce_time(bytes_: int, ndev: int, link: LinkModel = LinkModel()) -> float:
    """2(n-1)/n · B / bw + 2(n-1) · lat — the classic ring bound."""
    if ndev <= 1:
        return 0.0
    steps = 2 * (ndev - 1)
    return steps * link.latency_s + (steps / ndev) * bytes_ / link.bandwidth_Bps


def ring_allgather_time(bytes_out: int, ndev: int, link: LinkModel = LinkModel()) -> float:
    if ndev <= 1:
        return 0.0
    steps = ndev - 1
    return steps * link.latency_s + (steps / ndev) * bytes_out / link.bandwidth_Bps


def hierarchical_allreduce_time(
    bytes_: int,
    intra: int,
    inter: int,
    intra_link: LinkModel = LinkModel(),
    inter_link: LinkModel = LinkModel(bandwidth_Bps=25e9, latency_s=5e-6),
) -> float:
    """RS(intra) + AR(inter, on 1/intra of the data) + AG(intra)."""
    t_rs = ring_allgather_time(bytes_, intra, intra_link)  # RS cost == AG cost
    t_ar = ring_allreduce_time(bytes_ // max(intra, 1), inter, inter_link)
    t_ag = ring_allgather_time(bytes_, intra, intra_link)
    return t_rs + t_ar + t_ag


def per_param_reduce_time(sizes_bytes: Sequence[int], ndev: int,
                          link: LinkModel = LinkModel(),
                          *, compute_s: float = 0.0) -> float:
    """The per-param issue schedule: the whole backward finishes, then one
    collective per parameter runs back-to-back — nothing overlaps."""
    return compute_s + sum(link.collective_time(b, ndev) for b in sizes_bytes)


def bucketed_reduce_time(bucket_bytes: Sequence[int], ndev: int,
                         link: LinkModel = LinkModel(),
                         *, compute_s: float = 0.0) -> float:
    """The NON-overlap bucketed schedule (``overlap_grad_reduce=False`` or
    ``microbatch == 1``): the whole backward finishes, then every bucket's
    all-reduce runs back-to-back — exactly what ``reduce_bucketed`` issues
    after the scan.  On a layout whose raw parameter count is already
    small (stacked-layer schemas) this *loses* to per-param issue by the
    extra dispatches; the shipped win comes from the overlap pipeline
    (:func:`overlapped_reduce_time`) plus the per-call padding/group-
    resolution overhead the LinkModel does not charge.

    The serial cost model is identical to per-param issue — one collective
    per payload after the compute — so this delegates to
    :func:`per_param_reduce_time`; only the payload list differs.
    """
    return per_param_reduce_time(bucket_bytes, ndev, link,
                                 compute_s=compute_s)


def overlapped_reduce_time(bucket_bytes: Sequence[int], ndev: int,
                           link: LinkModel = LinkModel(),
                           *, compute_s: float = 0.0,
                           microbatches: int = 1) -> float:
    """The backward-overlap schedule build_train_step actually ships with
    ``overlap_grad_reduce`` and ``microbatch = k``: every microbatch's
    buckets reduce-scatter under the NEXT microbatch's backward, and one
    all-gather per bucket trails the scan.

    Wire volume is ``(k + 1)·B·(n-1)/n`` per bucket (k one-phase RS + one
    one-phase AG) vs the single allreduce's ``2B(n-1)/n`` — the price of
    pipelining — so this model, not :func:`bucketed_reduce_time`, is what
    the CI gate must also check: in a wire-bound regime the extra
    reduce-scatters can lose to per-param issue even when the one-shot
    bucketed schedule wins.
    """
    buckets = list(bucket_bytes)
    k = max(microbatches, 1)
    if not buckets:
        return compute_s

    def phase(b):  # one RS or AG pass: half an allreduce + its dispatch
        if ndev <= 1:
            return link.dispatch_s
        return link.dispatch_s + (ndev - 1) * (
            link.latency_s + b / (ndev * link.bandwidth_Bps))

    per_slot_compute = compute_s / (k * len(buckets))
    done = 0.0
    slot = 0
    for _ in range(k):
        for b in buckets:
            slot += 1
            done = max(done, slot * per_slot_compute) + phase(b)
    for b in buckets:            # trailing all-gathers: nothing hides them
        done += phase(b)
    return done

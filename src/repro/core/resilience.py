"""Retry/timeout/backoff for the communication verbs (self-healing layer).

The paper's GASNet-EX/GPI-2 substrate retries transient wire faults
below the OpenMP runtime; our XLA lowering has no such substrate, so
this module supplies the equivalent policy layer.  It is deliberately
dependency-free (no jax, no repro imports) so it sits *below*
``core/faults.py`` and ``core/context.py`` in the layering:

* ``TransientFault`` / ``FaultTimeout`` — what a failed wire attempt
  raises.  ``ChaosBackend`` (see `faults.py`) raises these at verb
  dispatch time; a real GPI-2 transport would surface its error returns
  through the same types.
* ``RetryPolicy`` — per-verb retry budgets with capped exponential
  backoff and deterministic jitter.  Deterministic matters: a chaos run
  with a fixed seed must replay bit-identically, so jitter is derived
  from ``sha256(seed, verb, attempt)`` rather than wall-clock entropy.
* ``call_with_retries`` — the loop itself, used by the communicator
  handles in ``core/context.py``.  Retried *wire* traffic is accounted
  by the caller via ``on_retry`` so the logical call/byte logs (and the
  OMPCCL-byte-log == RMATracker audit) stay exact.
* ``CircuitBreaker`` — the escalation layer above the retry loop: when a
  *destination* keeps spending whole retry budgets (not just single
  attempts), retrying forever is the wrong policy.  The breaker counts
  budget-level failures per key (the serving engine keys it per
  ``(verb, rank)``), OPENs the key after ``failure_threshold`` of them so
  callers route around it, and probes it again (HALF_OPEN) after a
  cooldown — one clean success CLOSEs it.  The clock is injectable so
  tests and the deterministic serving benchmarks drive the cooldown
  explicitly.

Digest helpers (``content_digest``/``corrupt_digest``) back the optional
RMA-window checksum validation: corruption injection must be *detected*
by the reader, never silently absorbed.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
import time
from typing import Callable, Mapping, Optional

__all__ = [
    "TransientFault",
    "FaultTimeout",
    "RetryError",
    "RetryPolicy",
    "CircuitBreaker",
    "call_with_retries",
    "derive_rng",
    "content_digest",
    "corrupt_digest",
]


class TransientFault(RuntimeError):
    """A retryable wire fault: a dropped put, a failed collective, a
    corrupted payload caught by the transport CRC.  Carries the injected
    fault record (when raised by ``ChaosBackend``) as ``.fault`` so the
    retry loop can mark it recovered."""

    def __init__(self, msg: str, fault=None):
        super().__init__(msg)
        self.fault = fault


class FaultTimeout(TransientFault):
    """An attempt exceeded its completion budget (modeled, not slept)."""


class RetryError(RuntimeError):
    """The per-verb retry budget is exhausted; ``.last`` holds the final
    ``TransientFault``.  This is the point where the runtime escalates —
    the serving engine requeues, the trainer evicts and restores."""

    def __init__(self, msg: str, last: Optional[TransientFault] = None):
        super().__init__(msg)
        self.last = last


def derive_rng(*key) -> random.Random:
    """A process-stable RNG for a structured key.

    Python's ``hash()`` of strings is randomized per process, which
    would make a "deterministic" fault plan differ between the run that
    found a bug and the run trying to reproduce it — so all seeded
    decisions in this layer and in `faults.py` go through sha256.
    """
    blob = ":".join(str(k) for k in key).encode()
    return random.Random(int.from_bytes(
        hashlib.sha256(blob).digest()[:8], "little"))


def content_digest(buf) -> str:
    """sha256 hex digest of a host buffer (what a put *should* land)."""
    return hashlib.sha256(bytes(memoryview(buf).cast("B"))).hexdigest()


def corrupt_digest(digest: str, salt) -> str:
    """A deterministic wrong digest: what a corrupted/dropped put lands.

    Guaranteed to differ from ``digest`` so window validation always
    notices.
    """
    bad = hashlib.sha256(f"corrupt:{salt}:{digest}".encode()).hexdigest()
    if bad == digest:  # pragma: no cover - sha256 collision
        bad = "0" * 64 if digest != "0" * 64 else "f" * 64
    return bad


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Timeout + capped exponential backoff + jitter, per verb.

    ``max_retries`` is the default budget; ``per_verb`` overrides it for
    verbs with different urgency (a barrier can afford more retries than
    a latency-critical decode put).  Backoff for attempt *k* is
    ``min(base * 2^(k-1), max) * jitter`` with jitter drawn
    deterministically from ``(seed, verb, attempt)``.
    """

    max_retries: int = 8
    per_verb: Mapping[str, int] = dataclasses.field(default_factory=dict)
    base_backoff_s: float = 1e-4
    max_backoff_s: float = 5e-3
    jitter: float = 0.5            # backoff scaled by [1 - j/2, 1 + j/2)
    timeout_s: float = 0.25        # per-attempt completion budget (modeled)
    seed: int = 0
    sleep: bool = True             # False: account backoff, do not sleep

    def budget(self, verb: str) -> int:
        return int(self.per_verb.get(verb, self.max_retries))

    def backoff_s(self, verb: str, attempt: int) -> float:
        base = min(self.base_backoff_s * (2.0 ** max(attempt - 1, 0)),
                   self.max_backoff_s)
        u = derive_rng(self.seed, verb, attempt).random()
        return base * (1.0 - self.jitter / 2.0 + self.jitter * u)


class CircuitBreaker:
    """Closed / open / half-open breaker over arbitrary hashable keys.

    One failure here means "a whole retry budget was spent" (a
    :class:`RetryError` / ``RMAError`` surfaced), so the breaker sits
    strictly *above* :class:`RetryPolicy` in the escalation ladder:
    transient faults are retried, repeat budget exhaustion quarantines
    the destination.  States per key:

    * ``closed`` — healthy; ``allow`` always grants.  ``failure_threshold``
      consecutive failures trip it to ``open``.
    * ``open`` — quarantined; ``allow`` denies until ``cooldown_s`` has
      elapsed on the injected ``clock``, then flips to ``half_open``.
    * ``half_open`` — probing; ``allow`` grants at most
      ``half_open_probes`` attempts.  A recorded success closes the key,
      a failure re-opens it (and restarts the cooldown).

    ``record_success(key, retries=...)`` accepts the retry-ledger delta of
    the successful call so per-key wear is visible in :meth:`snapshot`
    even while the key stays closed.  All transitions land in
    ``self.transitions`` — the deterministic audit log the overload tests
    and ``bench_overload`` decision logs replay.
    """

    def __init__(self, *, failure_threshold: int = 3,
                 cooldown_s: float = 0.25, half_open_probes: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.half_open_probes = int(half_open_probes)
        self.clock = clock
        self._cells: dict = {}
        self.transitions: list = []   # (key, old_state, new_state)
        self.stats = {"opened": 0, "reopened": 0, "closed": 0, "probes": 0,
                      "denied": 0}

    def _cell(self, key) -> dict:
        return self._cells.setdefault(
            key, {"state": "closed", "failures": 0, "opened_at": 0.0,
                  "probes": 0, "retries": 0, "successes": 0})

    def _trans(self, key, cell: dict, new: str) -> None:
        self.transitions.append((key, cell["state"], new))
        cell["state"] = new

    # -- the gate -----------------------------------------------------------
    def allow(self, key) -> bool:
        """May a call to ``key`` be attempted now?  Open keys flip to
        half-open once the cooldown elapses; half-open keys grant at most
        ``half_open_probes`` probe slots (``allow`` consumes one — call it
        only when about to attempt)."""
        cell = self._cell(key)
        if cell["state"] == "open":
            if self.clock() - cell["opened_at"] < self.cooldown_s:
                self.stats["denied"] += 1
                return False
            self._trans(key, cell, "half_open")
            cell["probes"] = 0
        if cell["state"] == "half_open":
            if cell["probes"] >= self.half_open_probes:
                self.stats["denied"] += 1
                return False
            cell["probes"] += 1
            self.stats["probes"] += 1
        return True

    # -- outcome feed (the retry ledger reports here) -----------------------
    def record_failure(self, key) -> str:
        """A call to ``key`` spent its whole retry budget.  Returns the
        key's state after accounting."""
        cell = self._cell(key)
        if cell["state"] == "half_open":
            self._trans(key, cell, "open")
            cell["opened_at"] = self.clock()
            self.stats["reopened"] += 1
            return cell["state"]
        cell["failures"] += 1
        if cell["state"] == "closed" \
                and cell["failures"] >= self.failure_threshold:
            self._trans(key, cell, "open")
            cell["opened_at"] = self.clock()
            self.stats["opened"] += 1
        return cell["state"]

    def record_success(self, key, *, retries: int = 0) -> str:
        """A call to ``key`` completed (``retries`` = re-issued attempts it
        needed, from the caller's retry ledger)."""
        cell = self._cell(key)
        cell["retries"] += int(retries)
        cell["successes"] += 1
        if cell["state"] == "half_open":
            self._trans(key, cell, "closed")
            cell["failures"] = 0
            self.stats["closed"] += 1
        elif cell["state"] == "closed":
            cell["failures"] = 0
        return cell["state"]

    # -- introspection ------------------------------------------------------
    def state(self, key) -> str:
        """Current recorded state (non-mutating: an elapsed cooldown shows
        as ``open`` until :meth:`allow` probes it)."""
        return self._cells.get(key, {"state": "closed"})["state"]

    def open_keys(self) -> list:
        return [k for k, c in self._cells.items() if c["state"] != "closed"]

    def snapshot(self) -> dict:
        return {k: dict(c) for k, c in self._cells.items()}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"CircuitBreaker(keys={len(self._cells)}, "
                f"open={len(self.open_keys())}, stats={self.stats})")


def call_with_retries(thunk: Callable[[], object], verb: str,
                      policy: RetryPolicy, *,
                      on_retry: Optional[Callable] = None,
                      on_recover: Optional[Callable] = None):
    """Run ``thunk`` under ``policy``, retrying on ``TransientFault``.

    ``on_retry(attempt, fault)`` fires before each re-issue — the
    communicator uses it to log the retried wire bytes separately from
    the logical byte log.  ``on_recover(n_faults)`` fires once when a
    faulted call finally succeeds.  Injected-fault records attached to
    the raised exceptions are marked ``recovered`` on success.
    """
    faults = []
    backoff_total = 0.0
    while True:
        try:
            out = thunk()
        except TransientFault as tf:
            faults.append(tf)
            attempt = len(faults)
            if attempt > policy.budget(verb):
                raise RetryError(
                    f"{verb}: retry budget ({policy.budget(verb)}) "
                    f"exhausted after {attempt} attempts: {tf}",
                    last=tf) from tf
            if on_retry is not None:
                on_retry(attempt, tf)
            delay = policy.backoff_s(verb, attempt)
            backoff_total += delay
            if policy.sleep and delay > 0.0:
                time.sleep(delay)
            continue
        for tf in faults:
            if tf.fault is not None:
                tf.fault.recovered = True
        if faults and on_recover is not None:
            on_recover(len(faults))
        return out

# The DiOMP runtime core: context.py (DiompContext + communicator handles),
# backends.py (pluggable CclBackend wire algorithms), groups.py, pgas.py,
# streams.py, rma.py, runtime.py, and the paper-verbatim compat surfaces
# ompccl.py / ompx.py.  compat.py shims jax version differences.

"""Training substrate: optimizers, step builder, checkpointing, monitoring."""

"""Train-step builder: grad accumulation + explicit OMPCCL gradient reduction
+ optimizer, all inside one shard_map (the DiOMP unified-runtime discipline).

Gradient-reduction strategy (DESIGN.md §4):

* ``ctx.explicit_dp=True`` (DiOMP mode): parameters are ``pvary``'d over the
  DP axes before differentiation, so AD yields *per-device* gradients with
  no implicit cross-batch collectives; the reduction then runs explicitly
  through OMPCCL with a selectable backend — flat psum / pod-hierarchical /
  int8-compressed with error feedback.  ZeRO-3 params still reduce-scatter
  over "data" inside AD (the all_gather transpose — structurally the
  intra-pod half of the hierarchical algorithm), leaving only the tiny
  inter-pod psum to OMPCCL.
* ``ctx.explicit_dp=False`` (the MPI+X-shaped baseline): AD's automatic
  pvary-transpose psums do the reduction implicitly inside XLA.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import HAS_VMA, shard_map

from repro.core import ompccl
from repro.core.context import default_context
from repro.distributed.compression import compressed_allreduce
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx
from .optim import Optimizer

__all__ = ["build_train_step", "opt_state_specs", "reduce_gradients",
           "sharded_global_norm"]

F32 = jnp.float32


def _unreduced_dp_axes(pspec: P, dp_axes) -> tuple:
    """The DP axes a parameter's sharding does NOT consume — exactly the
    axes its gradient still needs a cross-device reduction over."""
    spec_axes = set()
    for part in pspec:
        if part is None:
            continue
        spec_axes |= set(part if isinstance(part, tuple) else (part,))
    return tuple(a for a in dp_axes if a not in spec_axes)


def _spec_drop_dim(spec: P, rank: int, drop: int) -> P:
    parts = list(spec) + [None] * (rank - len(spec))
    del parts[drop]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, optimizer_name: str,
                    rules=None):
    """PartitionSpecs for the optimizer state (mirrors param sharding)."""
    pspecs = sch.partition_specs(cfg, mesh, rules)
    schema = sch.build_schema(cfg)
    if optimizer_name == "adamw":
        return {"m": dict(pspecs), "v": dict(pspecs)}
    out = {}
    for name, spec in pspecs.items():
        rank = len(schema[name].shape)
        shape = schema[name].shape
        if rank >= 2 and shape[-1] > 1 and shape[-2] > 1:
            out[name] = {"vr": _spec_drop_dim(spec, rank, rank - 1),
                         "vc": _spec_drop_dim(spec, rank, rank - 2)}
        else:
            out[name] = {"v": spec}
    return out


def _dup_factor(name: str, cfg: ModelConfig, mesh: Mesh) -> int:
    """How many devices hold a copy of each element of param ``name``."""
    from repro.distributed.sharding import DEFAULT_RULES, logical_to_spec

    spec = logical_to_spec(sch.build_schema(cfg)[name].axes, mesh)
    sharded = 1
    for part in spec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            sharded *= mesh.shape[ax]
    return mesh.devices.size // sharded


def sharded_global_norm(grads, cfg: ModelConfig, ctx: ParallelCtx, mesh: Mesh,
                        pspecs: Optional[dict] = None):
    """Global L2 norm of a sharded gradient pytree.

    Each param's local sum-of-squares is weighted by 1/duplication (so
    replicated copies count once), then psum'd across the world group.
    """
    if pspecs is None:
        from repro.distributed.sharding import rules_for_ctx
        pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    sizes = dict(mesh.shape)
    total = jnp.zeros((), F32)
    for name, g in grads.items():
        sharded = 1
        for part in pspecs[name]:
            if part is None:
                continue
            for ax in (part if isinstance(part, tuple) else (part,)):
                sharded *= sizes[ax]
        dup = mesh.devices.size // sharded
        total = total + jnp.sum(g.astype(F32) ** 2) / dup
    total = default_context().communicator(ctx.world).allreduce(total)
    return jnp.sqrt(total)


def reduce_gradients(grads: Dict[str, jax.Array], cfg: ModelConfig,
                     ctx: ParallelCtx, errors: Optional[dict] = None,
                     pspecs: Optional[dict] = None, mesh: Optional[Mesh] = None):
    """Explicit DP mean-reduction per parameter through OMPCCL.

    Input grads are per-device (params were pvary'd over DP).  A parameter
    needs reduction only over the DP axes its own sharding does NOT use:
    ZeRO-3 / expert2d shards already had their cross-shard sums folded in by
    AD (the all_gather transpose / the all_to_all round trip).  Returns
    (reduced_grads, new_errors).
    """
    from repro.core.groups import DiompGroup
    from repro.distributed.sharding import rules_for_ctx

    if pspecs is None:
        pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    dctx = default_context()
    new_errors = {}
    out = {}
    dp_axes = ctx.dp_group.axes
    for name, g in grads.items():
        need = _unreduced_dp_axes(pspecs[name], dp_axes)
        g = g.astype(F32) / ctx.dp
        if not need:
            out[name] = g
            continue
        group = DiompGroup(need)
        if ctx.grad_codec == "int8" and set(need) == set(dp_axes):
            err = errors.get(name) if errors else None
            g, e = compressed_allreduce(g * ctx.dp, group, error=err)
            new_errors[name] = e
        else:
            backend = ("hierarchical"
                       if ctx.dp_backend == "hierarchical"
                       and "pod" in need and len(need) > 1 else "xla")
            g = dctx.communicator(group, backend).allreduce(g)
        out[name] = g
    return out, new_errors


def _flat_dp_reduce(grads: Dict[str, jax.Array], pspecs: dict,
                    dp_axes: Tuple[str, ...], dp: int):
    """DP mean-reduction per parameter over the axes its sharding does not
    already consume — the reduction a vma-aware AD emits implicitly."""
    out = {}
    for name, g in grads.items():
        need = _unreduced_dp_axes(pspecs[name], dp_axes)
        g = g.astype(F32) / dp
        out[name] = lax.psum(g, need) if need else g
    return out


def build_train_step(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx,
                     optimizer: Optimizer, *, optimizer_name: str = "adamw",
                     clip_norm: float = 1.0, donate: bool = True,
                     global_batch: int = 0):
    """Returns the jitted step:

    step(params, opt_state, batch, step_idx) ->
        (params', opt_state', metrics{loss, grad_norm})

    ``global_batch`` determines the batch sharding (divisibility over the DP
    axes); pass the real batch size — 0 falls back to dp-divisible.
    """
    import dataclasses

    from repro.distributed.sharding import rules_for_ctx
    from repro.kernels.plan import resolve_ring_impl

    # resolve the ring-matmul schedule ONCE so the whole step traces against
    # one concrete plan (fused bidirectional unless the ctx pins "host")
    ctx = dataclasses.replace(ctx, ring_impl=resolve_ring_impl(ctx.ring_impl))
    rules = rules_for_ctx(ctx)
    loss_fn = model_api.loss_fn(cfg)
    pspecs = sch.partition_specs(cfg, mesh, rules)
    ospecs = opt_state_specs(cfg, mesh, optimizer_name, rules)
    dp_axes = ctx.dp_group.axes
    if not global_batch:  # default: assume a dp-divisible batch
        global_batch = ctx.dp
    _, bspecs = model_api.batch_structs(cfg, mesh, global_batch, 1,
                                        dp_axes=dp_axes)

    def step(params, opt_state, batch, step_idx):
        # DiOMP mode: per-device grads, reduction owned by OMPCCL
        p_diff = (ompccl.ensure_varying(params, dp_axes)
                  if ctx.explicit_dp and dp_axes else params)

        def local_loss(p, mb):
            return loss_fn(p, mb, cfg, ctx)

        b_local = jax.tree.leaves(batch)[0].shape[0]
        k = max(min(ctx.microbatch, b_local), 1)
        while b_local % k:          # clamp to a divisor of the local batch
            k -= 1
        if k > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            # per-leaf carry vma: grads vary over the DP axes (iff params
            # were pvary'd there) plus the param's own sharded axes
            grad_dp = tuple(dp_axes) if ctx.explicit_dp else ()

            def leaf_axes(name):
                spec_axes = []
                for part in pspecs[name]:
                    if part is None:
                        continue
                    spec_axes += list(part if isinstance(part, tuple)
                                      else (part,))
                return tuple(dict.fromkeys(grad_dp + tuple(spec_axes)))

            def norm_g(g):
                return {n: ompccl.ensure_varying(v, leaf_axes(n))
                        for n, v in g.items()}

            all_axes = tuple(mesh.axis_names)

            def micro(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(local_loss)(p_diff, mb)
                g_acc = {n: g_acc[n] + g[n].astype(F32) for n in g_acc}
                # scalar loss: canonicalize to all mesh axes (an unsharded-
                # vocab CE stays model-varying; a sharded one does not)
                return (ompccl.ensure_varying(loss_acc + l, all_axes),
                        norm_g(g_acc)), None

            zero_g = norm_g({n: jnp.zeros(p.shape, F32)
                             for n, p in params.items()})
            loss0 = ompccl.ensure_varying(jnp.zeros((), F32), all_axes)
            (loss, grads), _ = lax.scan(micro, (loss0, zero_g), mbs)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        else:
            loss, grads = jax.value_and_grad(local_loss)(p_diff, batch)

        if ctx.explicit_dp and dp_axes:
            grads, _ = reduce_gradients(grads, cfg, ctx, pspecs=pspecs)
        elif dp_axes and not HAS_VMA:
            # pre-vma jax inserts no automatic pvary-transpose psums under
            # shard_map, so the "implicit" baseline must still reduce on the
            # wire: same flat psum the vma transpose would have emitted
            grads = _flat_dp_reduce(grads, pspecs, dp_axes, ctx.dp)
        else:
            grads = jax.tree.map(lambda g: g.astype(F32) / ctx.dp, grads)

        gnorm = sharded_global_norm(grads, cfg, ctx, mesh, pspecs=pspecs)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              step_idx)
        params = jax.tree.map(lambda p, u: (p.astype(F32) + u.astype(F32)
                                            ).astype(p.dtype), params, updates)
        # resolved at trace time like every other collective site, so the
        # whole step records into whichever context is default when traced
        world_comm = default_context().communicator(ctx.world)
        metrics = {
            "loss": world_comm.allreduce(loss, op="mean"),
            "grad_norm": gnorm,
        }
        return params, opt_state, metrics

    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ospecs, bspecs, P()),
        out_specs=(pspecs, ospecs, {"loss": P(), "grad_norm": P()}),
    )
    jit_kwargs = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(mapped, **jit_kwargs)

"""Train-step builder: grad accumulation + explicit OMPCCL gradient reduction
+ optimizer, all inside one shard_map (the DiOMP unified-runtime discipline).

Gradient-reduction strategy (DESIGN.md §4):

* ``ctx.explicit_dp=True`` (DiOMP mode): parameters are ``pvary``'d over the
  DP axes before differentiation, so AD yields *per-device* gradients with
  no implicit cross-batch collectives; the reduction then runs explicitly
  through OMPCCL with a selectable backend — flat psum / pod-hierarchical /
  int8-compressed with error feedback.  ZeRO-3 params still reduce-scatter
  over "data" inside AD (the all_gather transpose — structurally the
  intra-pod half of the hierarchical algorithm), leaving only the tiny
  inter-pod psum to OMPCCL.
* ``ctx.explicit_dp=False`` (the MPI+X-shaped baseline): AD's automatic
  pvary-transpose psums do the reduction implicitly inside XLA.

Bucketing + backward overlap (the §Perf reduction path):

* With ``ctx.bucket_bytes > 0`` (the default) the per-param reduction is
  replaced by the planned flat-bucket schedule of
  :mod:`repro.distributed.buckets`: the gradient pytree is packed into
  fixed-byte f32 buckets per (group, dtype, dup) partition and each bucket
  reduces through ONE communicator handle — ``ceil(bytes / bucket_bytes)``
  collectives per partition instead of one per parameter.
* With ``ctx.overlap_grad_reduce`` (and ``microbatch > 1``,
  ``grad_codec="none"``) the microbatch ``lax.scan`` carries *reduce-
  scattered* bucket partial sums: each microbatch's bucket gradients
  reduce-scatter inside the accumulation loop (ZeRO-style — the shard is
  1/|group| of the bucket, and the wire work rides under the next
  microbatch's backward), and one invariant all-gather per bucket after
  the scan completes the mean.  Numerically this is the same psum, split
  RS+AG and pipelined.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import HAS_VMA, shard_map

from repro.core import ompccl
from repro.core.context import default_context
from repro.core.groups import group_for_axes
from repro.distributed import buckets as bk
from repro.distributed.buckets import unreduced_dp_axes as _unreduced_dp_axes
from repro.distributed.compression import compressed_allreduce
from repro.models import api as model_api
from repro.models import schema as sch
from repro.models.config import ModelConfig, ParallelCtx
from .optim import Optimizer, bucketed_sq_norm

__all__ = ["build_train_step", "opt_state_specs", "reduce_gradients",
           "sharded_global_norm"]

F32 = jnp.float32


def _spec_drop_dim(spec: P, rank: int, drop: int) -> P:
    parts = list(spec) + [None] * (rank - len(spec))
    del parts[drop]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, optimizer_name: str,
                    rules=None):
    """PartitionSpecs for the optimizer state (mirrors param sharding)."""
    pspecs = sch.partition_specs(cfg, mesh, rules)
    schema = sch.build_schema(cfg)
    if optimizer_name == "adamw":
        return {"m": dict(pspecs), "v": dict(pspecs)}
    out = {}
    for name, spec in pspecs.items():
        rank = len(schema[name].shape)
        shape = schema[name].shape
        if rank >= 2 and shape[-1] > 1 and shape[-2] > 1:
            out[name] = {"vr": _spec_drop_dim(spec, rank, rank - 1),
                         "vc": _spec_drop_dim(spec, rank, rank - 2)}
        else:
            out[name] = {"v": spec}
    return out


def sharded_global_norm(grads, cfg: ModelConfig, ctx: ParallelCtx, mesh: Mesh,
                        pspecs: Optional[dict] = None, *, plan=None,
                        bufs=None):
    """Global L2 norm of a sharded gradient pytree.

    Each param's local sum-of-squares is weighted by 1/duplication (so
    replicated copies count once), then psum'd across the world group.

    When the reduced flat buckets are still at hand (``plan`` + ``bufs``
    from the bucketed reduction), the bucketed local sums are used
    directly — one fused sum per bucket instead of one per parameter; only
    the plan's unbucketed params walk the per-param loop.
    """
    total = jnp.zeros((), F32)
    if plan is not None and bufs is not None:
        total = total + bucketed_sq_norm(bufs, plan)
        for name in plan.local:
            total = total + jnp.sum(grads[name].astype(F32) ** 2) \
                / plan.dups[name]
    else:
        if pspecs is None:
            from repro.distributed.sharding import rules_for_ctx
            pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
        sizes = dict(mesh.shape)
        for name, g in grads.items():
            dup = bk.duplication_factor(pspecs[name], sizes)
            total = total + jnp.sum(g.astype(F32) ** 2) / dup
    total = default_context().communicator(ctx.world).allreduce(total)
    return jnp.sqrt(total)


def reduce_gradients(grads: Dict[str, jax.Array], cfg: ModelConfig,
                     ctx: ParallelCtx, errors: Optional[dict] = None,
                     pspecs: Optional[dict] = None, mesh: Optional[Mesh] = None,
                     plan=None):
    """Explicit DP mean-reduction through OMPCCL.

    Input grads are per-device (params were pvary'd over DP).  A parameter
    needs reduction only over the DP axes its own sharding does NOT use:
    ZeRO-3 / expert2d shards already had their cross-shard sums folded in by
    AD (the all_gather transpose / the all_to_all round trip).  Returns
    (reduced_grads, new_errors).

    Dispatch: with a :class:`~repro.distributed.buckets.BucketPlan` — passed
    in, or derivable (``mesh`` given and ``ctx.bucket_bytes > 0``) — whole
    flat buckets reduce through one communicator handle each (errors keyed
    by bucket).  Otherwise the per-param baseline path runs: one collective
    per parameter, errors keyed by name.
    """
    from repro.distributed.sharding import rules_for_ctx

    if plan is None and mesh is not None and ctx.bucket_bytes:
        plan = bk.plan_for_config(cfg, mesh, ctx)
    if plan is not None:
        # vary over every world axis: bucket members carry different vma
        # sets (their own sharded axes differ) and a concat must agree
        out, _bufs, new_errors = bk.reduce_bucketed(
            grads, plan, ctx, errors=errors, vary=tuple(ctx.world.axes))
        return out, new_errors

    if pspecs is None:
        pspecs = sch.partition_specs(cfg, mesh, rules_for_ctx(ctx))
    dctx = default_context()
    new_errors = {}
    out = {}
    dp_axes = ctx.dp_group.axes
    for name, g in grads.items():
        need = _unreduced_dp_axes(pspecs[name], dp_axes)
        g = g.astype(F32) / ctx.dp
        if not need:
            out[name] = g
            continue
        group = group_for_axes(need)
        if ctx.grad_codec == "int8" and set(need) == set(dp_axes):
            err = errors.get(name) if errors else None
            g, e = compressed_allreduce(g * ctx.dp, group, error=err)
            new_errors[name] = e
        else:
            backend = bk.backend_for_axes(need, ctx)
            g = dctx.communicator(group, backend).allreduce(g)
        out[name] = g
    return out, new_errors


def _flat_dp_reduce(grads: Dict[str, jax.Array], pspecs: dict,
                    dp_axes: Tuple[str, ...], dp: int):
    """DP mean-reduction per parameter over the axes its sharding does not
    already consume — the reduction a vma-aware AD emits implicitly."""
    out = {}
    for name, g in grads.items():
        need = _unreduced_dp_axes(pspecs[name], dp_axes)
        g = g.astype(F32) / dp
        out[name] = lax.psum(g, need) if need else g
    return out


def build_train_step(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx,
                     optimizer: Optimizer, *, optimizer_name: str = "adamw",
                     clip_norm: float = 1.0, donate: bool = True,
                     global_batch: int = 0):
    """Returns the jitted step:

    step(params, opt_state, batch, step_idx) ->
        (params', opt_state', metrics{loss, grad_norm[, moe_dropped,
        moe_drop_rate on MoE configs]})

    ``global_batch`` determines the batch sharding (divisibility over the DP
    axes); pass the real batch size — 0 falls back to dp-divisible.
    """
    import dataclasses

    from repro.distributed.sharding import rules_for_ctx
    from repro.kernels.plan import (default_planner, resolve_dispatch_impl,
                                    resolve_ring_impl, resolve_seq_parallel)

    # resolve the ring-matmul schedule ONCE so the whole step traces against
    # one concrete plan (fused bidirectional unless the ctx pins "host");
    # the MoE dispatch mode and the sequence-parallel attention strategy
    # resolve the same way
    ctx = dataclasses.replace(
        ctx, ring_impl=resolve_ring_impl(ctx.ring_impl),
        dispatch_impl=resolve_dispatch_impl(ctx.dispatch_impl),
        seq_parallel=resolve_seq_parallel(ctx.seq_parallel))
    rules = rules_for_ctx(ctx)
    loss_fn = model_api.loss_fn(cfg)
    pspecs = sch.partition_specs(cfg, mesh, rules)
    ospecs = opt_state_specs(cfg, mesh, optimizer_name, rules)
    dp_axes = ctx.dp_group.axes
    all_axes = tuple(mesh.axis_names)
    mesh_sizes = dict(mesh.shape)
    if not global_batch:  # default: assume a dp-divisible batch
        global_batch = ctx.dp
    _, bspecs = model_api.batch_structs(cfg, mesh, global_batch, 1,
                                        dp_axes=dp_axes)

    # the reduction schedule, like the ring schedule, is resolved once at
    # build time: static shapes in, flat-bucket index maps out
    plan = (default_planner().plan_grad_buckets(cfg, mesh, ctx)
            if ctx.explicit_dp and dp_axes and ctx.bucket_bytes else None)

    def step(params, opt_state, batch, step_idx):
        # DiOMP mode: per-device grads, reduction owned by OMPCCL
        p_diff = (ompccl.ensure_varying(params, dp_axes)
                  if ctx.explicit_dp and dp_axes else params)

        def local_loss(p, mb):
            # drop stats are data-dependent (the capacity overflow mask), so
            # they leave the trace as has_aux outputs; the frame must open
            # INSIDE the traced function (DispatchStats is trace-scoped)
            with default_context().dispatch_stats.collect() as ds:
                loss = loss_fn(p, mb, cfg, ctx)
            zero = jnp.zeros((), F32)
            return loss, (ds.get("moe_dropped", zero),
                          ds.get("moe_routed", zero))

        b_local = jax.tree.leaves(batch)[0].shape[0]
        k = max(min(ctx.microbatch, b_local), 1)
        while b_local % k:          # clamp to a divisor of the local batch
            k -= 1
        # buckets RS inside the scan, AG after it (backward overlap)?
        overlap = (plan is not None and plan.buckets and k > 1
                   and ctx.overlap_grad_reduce and ctx.grad_codec == "none")
        bufs = None
        reduced = False
        if k > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            # per-leaf carry vma: grads vary over the DP axes (iff params
            # were pvary'd there) plus the param's own sharded axes
            grad_dp = tuple(dp_axes) if ctx.explicit_dp else ()

            def leaf_axes(name):
                spec_axes = []
                for part in pspecs[name]:
                    if part is None:
                        continue
                    spec_axes += list(part if isinstance(part, tuple)
                                      else (part,))
                return tuple(dict.fromkeys(grad_dp + tuple(spec_axes)))

            def norm_g(g):
                return {n: ompccl.ensure_varying(v, leaf_axes(n))
                        for n, v in g.items()}

            if overlap:
                # resolved at trace time like every other collective site
                dctx = default_context()
                comms = {b.key: dctx.communicator(
                    b.group, bk.backend_for_bucket(b, ctx))
                    for b in plan.buckets}

                def micro(carry, mb):
                    loss_acc, aux_acc, g_acc, sh_acc = carry
                    (l, aux), g = jax.value_and_grad(
                        local_loss, has_aux=True)(p_diff, mb)
                    # unbucketed params accumulate whole, as before
                    g_acc = {n: g_acc[n] + g[n].astype(F32) for n in g_acc}
                    # bucketed params: pack THIS microbatch's grads and
                    # reduce-scatter each bucket — the collective overlaps
                    # the next microbatch's backward; the carry holds only
                    # the 1/|group| partial-sum shard
                    mb_bufs = bk.pack_buckets(g, plan, vary=all_axes)
                    sh = {}
                    for b in plan.buckets:
                        piece = comms[b.key].reducescatter(mb_bufs[b.key],
                                                           axis=0)
                        sh[b.key] = ompccl.ensure_varying(
                            sh_acc[b.key] + piece, all_axes)
                    aux_acc = tuple(
                        ompccl.ensure_varying(a + x, all_axes)
                        for a, x in zip(aux_acc, aux))
                    return (ompccl.ensure_varying(loss_acc + l, all_axes),
                            aux_acc, norm_g(g_acc), sh), None

                zero_g = norm_g({n: jnp.zeros(params[n].shape, F32)
                                 for n in plan.local})
                zero_sh = {
                    b.key: ompccl.ensure_varying(
                        jnp.zeros((b.shard_size(mesh_sizes),), F32), all_axes)
                    for b in plan.buckets}
                loss0 = ompccl.ensure_varying(jnp.zeros((), F32), all_axes)
                aux0 = tuple(ompccl.ensure_varying(jnp.zeros((), F32),
                                                   all_axes)
                             for _ in range(2))
                (loss, aux, g_local, shards), _ = lax.scan(
                    micro, (loss0, aux0, zero_g, zero_sh), mbs)
                loss = loss / k
                # the trailing exchange: ONE invariant all-gather per bucket
                # (the only wire work not hidden behind backward compute)
                bufs = {
                    b.key: comms[b.key].allgather(
                        shards[b.key] / (k * ctx.dp), axis=0, tiled=True,
                        invariant=True)
                    for b in plan.buckets}
                grads = {n: g_local[n] / (k * ctx.dp) for n in plan.local}
                grads.update(bk.unpack_buckets(bufs, plan))
                reduced = True
            else:
                def micro(carry, mb):
                    loss_acc, aux_acc, g_acc = carry
                    (l, aux), g = jax.value_and_grad(
                        local_loss, has_aux=True)(p_diff, mb)
                    g_acc = {n: g_acc[n] + g[n].astype(F32) for n in g_acc}
                    aux_acc = tuple(
                        ompccl.ensure_varying(a + x, all_axes)
                        for a, x in zip(aux_acc, aux))
                    # scalar loss: canonicalize to all mesh axes (an
                    # unsharded-vocab CE stays model-varying; a sharded one
                    # does not)
                    return (ompccl.ensure_varying(loss_acc + l, all_axes),
                            aux_acc, norm_g(g_acc)), None

                zero_g = norm_g({n: jnp.zeros(p.shape, F32)
                                 for n, p in params.items()})
                loss0 = ompccl.ensure_varying(jnp.zeros((), F32), all_axes)
                aux0 = tuple(ompccl.ensure_varying(jnp.zeros((), F32),
                                                   all_axes)
                             for _ in range(2))
                (loss, aux, grads), _ = lax.scan(micro, (loss0, aux0, zero_g),
                                                 mbs)
                loss = loss / k
                grads = jax.tree.map(lambda g: g / k, grads)
        else:
            (loss, aux), grads = jax.value_and_grad(
                local_loss, has_aux=True)(p_diff, batch)

        if ctx.explicit_dp and dp_axes:
            if not reduced:
                if plan is not None:
                    grads, bufs, _ = bk.reduce_bucketed(
                        grads, plan, ctx, vary=all_axes)
                else:
                    grads, _ = reduce_gradients(grads, cfg, ctx,
                                                pspecs=pspecs)
        elif dp_axes and not HAS_VMA:
            # pre-vma jax inserts no automatic pvary-transpose psums under
            # shard_map, so the "implicit" baseline must still reduce on the
            # wire: same flat psum the vma transpose would have emitted
            grads = _flat_dp_reduce(grads, pspecs, dp_axes, ctx.dp)
        else:
            grads = jax.tree.map(lambda g: g.astype(F32) / ctx.dp, grads)

        gnorm = sharded_global_norm(grads, cfg, ctx, mesh, pspecs=pspecs,
                                    plan=plan if bufs is not None else None,
                                    bufs=bufs)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              step_idx)
        params = jax.tree.map(lambda p, u: (p.astype(F32) + u.astype(F32)
                                            ).astype(p.dtype), params, updates)
        # resolved at trace time like every other collective site, so the
        # whole step records into whichever context is default when traced
        world_comm = default_context().communicator(ctx.world)
        metrics = {
            "loss": world_comm.allreduce(loss, op="mean"),
            "grad_norm": gnorm,
        }
        if cfg.moe:
            # drop counters are per-rank sums over layers x microbatches;
            # the world sum gives the step's global capacity-overflow drops
            # (identically zero under the dropless fused/host dispatch)
            dropped = world_comm.allreduce(aux[0])
            routed = world_comm.allreduce(aux[1])
            metrics["moe_dropped"] = dropped
            metrics["moe_drop_rate"] = dropped / jnp.maximum(routed, 1.0)
        return params, opt_state, metrics

    mspecs = {"loss": P(), "grad_norm": P()}
    if cfg.moe:
        mspecs.update({"moe_dropped": P(), "moe_drop_rate": P()})
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, ospecs, bspecs, P()),
        out_specs=(pspecs, ospecs, mspecs),
    )
    jit_kwargs = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(mapped, **jit_kwargs)

"""Fault-tolerant checkpointing: async, atomic, resumable, elastic.

Design (DESIGN.md §6):

* **Async** — array serialization runs on the DiOMP StreamPool (the paper's
  bounded-concurrency host lanes), so training continues while bytes drain.
* **Atomic** — writes go to ``step_XXXX.tmp`` and are renamed only after
  every shard file + a checksum manifest are durable; a crash mid-write can
  never leave a readable-but-corrupt checkpoint.
* **Resumable** — ``latest()`` finds the newest complete step; restore
  verifies checksums before any byte reaches a device.
* **Elastic re-shard** — arrays are saved in *global* layout; restore
  ``device_put``s against whatever mesh the new job brings up, so a restart
  on a different pod count (or after losing a slice) re-shards transparently
  (ZeRO/TP placement is recomputed from the schema, not from the file).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.streams import StreamPool

__all__ = ["CheckpointManager"]


def _tree_to_flat(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_tree_to_flat(v, f"{prefix}{k}|"))
    else:
        out[prefix.rstrip("|")] = np.asarray(tree)
    return out


def _flat_to_tree(flat: Dict[str, np.ndarray]):
    tree: Dict = {}
    for key, val in flat.items():
        parts = key.split("|")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 pool: Optional[StreamPool] = None):
        self.dir = directory
        self.keep = keep
        self.pool = pool or StreamPool(max_active=4)
        os.makedirs(directory, exist_ok=True)
        self._pending = []

    # -- save -------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[dict] = None,
             *, blocking: bool = False):
        """Snapshot host-side, then drain asynchronously."""
        flat = _tree_to_flat({"params": params, "opt": opt_state})
        meta = {"step": step, "time": time.time(), "extra": extra or {},
                "files": {}}
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)

        def write_one(name: str, arr: np.ndarray) -> Tuple[str, str, str]:
            fn = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
            path = os.path.join(tmp, fn)
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":       # numpy can't round-trip bf16
                arr = arr.view(np.uint16)
            np.save(path, arr)
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            return fn, digest, dtype_name

        futures = {name: self.pool.submit(write_one, name, arr)
                   for name, arr in flat.items()}

        def finalize():
            for name, fut in futures.items():
                fn, digest, dtype_name = fut.result()
                meta["files"][name] = {"file": fn, "sha256": digest,
                                       "dtype": dtype_name}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            os.replace(tmp, final)           # atomic commit
            self._gc()

        fut = self.pool.submit(finalize)
        self._pending.append(fut)
        if blocking:
            fut.result()
        return fut

    def wait(self):
        for f in self._pending:
            f.result()
        self._pending.clear()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                shard_fn: Optional[Callable[[str, np.ndarray], jax.Array]] = None):
        """Returns (step, params, opt_state, extra).

        ``shard_fn(name, array)`` places each global array onto the *current*
        mesh (elastic re-shard); identity if None.
        """
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        flat = {}
        for name, info in meta["files"].items():
            path = os.path.join(d, info["file"])
            with open(path, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != info["sha256"]:
                    raise IOError(f"checksum mismatch for {name} in step {step}")
            arr = np.load(path)
            if info.get("dtype") == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            flat[name] = shard_fn(name, arr) if shard_fn else arr
        tree = _flat_to_tree(flat)
        return step, tree["params"], tree["opt"], meta.get("extra", {})

"""Fault-tolerant checkpointing: async, atomic, resumable, elastic.

Design (DESIGN.md §6):

* **Async** — array serialization runs on the DiOMP StreamPool (the paper's
  bounded-concurrency host lanes), so training continues while bytes drain.
* **Atomic** — writes go to ``step_XXXX.tmp`` and are renamed only after
  every shard file + a checksum manifest are durable; a crash mid-write can
  never leave a readable-but-corrupt checkpoint.  *Durable* means fsynced:
  each shard file, the manifest, the tmp directory, and the parent
  directory around the rename — rename-without-fsync is not crash-safe
  (the rename can land while the data blocks are still in the page
  cache).  Orphaned ``.tmp`` directories from a crashed writer are
  garbage-collected on manager startup.
* **Resumable** — ``latest()`` finds the newest complete *and verified*
  step (a damaged step is skipped, never silently half-loaded); restore
  verifies checksums before any byte reaches a device and raises a clear
  error naming the damaged file.
* **Elastic re-shard** — arrays are saved in *global* layout; restore
  ``device_put``s against whatever mesh the new job brings up, so a restart
  on a different pod count (or after losing a slice) re-shards transparently
  (ZeRO/TP placement is recomputed from the schema, not from the file).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.streams import StreamPool

__all__ = ["CheckpointManager"]


def _fsync_dir(path: str) -> None:
    """fsync a directory entry (required for rename durability on POSIX);
    best-effort where the filesystem refuses directory fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _tree_to_flat(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_tree_to_flat(v, f"{prefix}{k}|"))
    else:
        out[prefix.rstrip("|")] = np.asarray(tree)
    return out


def _flat_to_tree(flat: Dict[str, np.ndarray]):
    tree: Dict = {}
    for key, val in flat.items():
        parts = key.split("|")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 pool: Optional[StreamPool] = None):
        self.dir = directory
        self.keep = keep
        self.pool = pool or StreamPool(max_active=4)
        os.makedirs(directory, exist_ok=True)
        self._pending = []
        # a crashed writer leaves step_XXXX.tmp behind; it can never become
        # a checkpoint (the rename is what commits), so reclaim the space
        for d in os.listdir(directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    # -- save -------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[dict] = None,
             *, blocking: bool = False):
        """Snapshot host-side, then drain asynchronously."""
        flat = _tree_to_flat({"params": params, "opt": opt_state})
        meta = {"step": step, "time": time.time(), "extra": extra or {},
                "files": {}}
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)

        def write_one(name: str, arr: np.ndarray) -> Tuple[str, str, str]:
            fn = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
            path = os.path.join(tmp, fn)
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":       # numpy can't round-trip bf16
                arr = arr.view(np.uint16)
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())           # durable BEFORE the rename
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            return fn, digest, dtype_name

        futures = {name: self.pool.submit(write_one, name, arr)
                   for name, arr in flat.items()}

        def finalize():
            for name, fut in futures.items():
                fn, digest, dtype_name = fut.result()
                meta["files"][name] = {"file": fn, "sha256": digest,
                                       "dtype": dtype_name}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(tmp)                  # entries durable before commit
            os.replace(tmp, final)           # atomic commit
            _fsync_dir(self.dir)             # the rename itself durable
            self._gc()

        fut = self.pool.submit(finalize)
        self._pending.append(fut)
        if blocking:
            fut.result()
        return fut

    def wait(self):
        for f in self._pending:
            f.result()
        self._pending.clear()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def verify_step(self, step: int) -> bool:
        """True iff ``step``'s manifest parses and every shard file matches
        its recorded checksum — a crashed/corrupted step returns False."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                meta = json.load(f)
            for name, info in meta["files"].items():
                with open(os.path.join(d, info["file"]), "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest() != info["sha256"]:
                        return False
        except (OSError, ValueError, KeyError, TypeError):
            return False
        return True

    def latest(self, *, verify: bool = True) -> Optional[int]:
        """The newest restorable step.  ``verify`` (default) checksums each
        candidate and *skips damaged steps* — a torn write of step N must
        fall back to step N-1, not take the whole run down."""
        for step in reversed(self.steps()):
            if not verify or self.verify_step(step):
                return step
        return None

    def restore(self, step: Optional[int] = None, *,
                shard_fn: Optional[Callable[[str, np.ndarray], jax.Array]] = None):
        """Returns (step, params, opt_state, extra).

        ``shard_fn(name, array)`` places each global array onto the *current*
        mesh (elastic re-shard); identity if None.  A damaged step raises
        ``IOError`` naming the file — garbage never reaches a device.
        """
        if step is None:
            step = self.latest()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise IOError(
                f"checkpoint step {step} is damaged: unreadable manifest "
                f"({e}) — refusing to restore") from e
        flat = {}
        for name, info in meta["files"].items():
            path = os.path.join(d, info["file"])
            with open(path, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != info["sha256"]:
                    raise IOError(
                        f"checkpoint step {step} is damaged: checksum "
                        f"mismatch for {name} ({info['file']}) — refusing "
                        "to load garbage")
            arr = np.load(path)
            if info.get("dtype") == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            flat[name] = shard_fn(name, arr) if shard_fn else arr
        tree = _flat_to_tree(flat)
        return step, tree["params"], tree["opt"], meta.get("extra", {})

"""Optimizers on local parameter shards (flax/optax-free).

Because every parameter enters the step pre-sharded (ZeRO-3 over "data",
TP over "model"), the optimizer state automatically inherits the same
sharding — ZeRO-1 falls out of the PGAS placement for free.  AdamW for the
small archs, Adafactor (factored second moment, no first moment) for the
≥30B ones where Adam state cannot fit the per-device HBM plan.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "adafactor", "cosine_schedule", "Optimizer",
           "global_norm", "clip_by_global_norm", "bucketed_sq_norm"]

F32 = jnp.float32


def bucketed_sq_norm(bufs: Dict[str, jax.Array], plan) -> jax.Array:
    """Local sum-of-squares of reduced flat gradient buckets, each weighted
    by 1/duplication (replicated copies count once in the global norm).

    The flat-bucket counterpart of the per-param loop in
    ``train.step.sharded_global_norm``: every member of a bucket shares one
    duplication factor by construction (it is part of the bucket partition
    key), so one fused ``sum(buf**2) / dup`` per bucket replaces one
    weighted reduction per parameter; bucket padding is zeros and
    contributes nothing.  The caller still owns the single cross-device
    psum + sqrt.
    """
    total = jnp.zeros((), F32)
    for b in plan.buckets:
        total = total + jnp.sum(bufs[b.key].astype(F32) ** 2) / b.dup
    return total


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable           # params -> opt_state
    update: Callable         # (grads, state, params, step) -> (updates, state)
    state_structs: Callable  # param_structs -> state structs (dry-run)


def cosine_schedule(peak_lr: float, warmup: int = 100, total: int = 10_000,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(F32) if hasattr(step, "astype") else F32(step)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        tree), norm


def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        }

    def update(grads, state, params, step):
        t = step.astype(F32) + 1.0
        lr = lr_fn(step)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(F32),
                         state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g.astype(F32) ** 2,
                         state["v"], grads)
        def upd(mm, vv, p):
            mh = mm / (1 - b1 ** t)
            vh = vv / (1 - b2 ** t)
            return (-lr * (mh / (jnp.sqrt(vh) + eps)
                           + weight_decay * p.astype(F32))).astype(p.dtype)
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v}

    def structs(pstructs):
        f = lambda s: jax.ShapeDtypeStruct(s.shape, F32)
        return {"m": jax.tree.map(f, pstructs), "v": jax.tree.map(f, pstructs)}

    return Optimizer(init, update, structs)


def _factored_dims(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr_fn, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              dim_axes: Dict[str, Tuple] = None) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum.

    ``dim_axes[name] = (last_axes, prev_axes)`` — the mesh axes the last /
    second-to-last param dims are sharded over (from the PGAS placement).
    The factored row/col statistics are means over the *full* dims, so
    sharded dims reduce through an explicit OMPCCL pmean; the result is
    invariant over those axes, matching the factored state's sharding.
    """
    dim_axes = dim_axes or {}

    def _pmean(x, axes):
        if not axes:
            return x
        from repro.core import ompccl
        from repro.core.groups import DiompGroup
        return ompccl.allreduce(x, DiompGroup(tuple(axes)), op="mean")

    def _state_for(p_shape):
        if _factored_dims(p_shape):
            return {"vr": jnp.zeros(p_shape[:-1], F32),
                    "vc": jnp.zeros(p_shape[:-2] + p_shape[-1:], F32)}
        return {"v": jnp.zeros(p_shape, F32)}

    def init(params):
        return {n: _state_for(p.shape) for n, p in params.items()}

    def update(grads, state, params, step):
        t = step.astype(F32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr = lr_fn(step)

        def upd(name, g, st, p):
            last_ax, prev_ax = dim_axes.get(name, ((), ()))
            gf = g.astype(F32)
            g2 = gf * gf + eps
            if "vr" in st:
                vr = beta * st["vr"] + (1 - beta) * _pmean(g2.mean(-1), last_ax)
                vc = beta * st["vc"] + (1 - beta) * _pmean(g2.mean(-2), prev_ax)
                vr_mean = _pmean(vr.mean(-1, keepdims=True), prev_ax)
                denom = (vr / jnp.maximum(vr_mean, eps))[..., None] * \
                    vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                new = {"v": v}
            rms = jnp.sqrt(_pmean(jnp.mean(u * u),
                                  tuple(last_ax) + tuple(prev_ax)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), new

        out = {n: upd(n, grads[n], state[n], params[n]) for n in grads}
        return ({n: o[0] for n, o in out.items()},
                {n: o[1] for n, o in out.items()})

    def structs(pstructs):
        def f(s):
            if _factored_dims(s.shape):
                return {"vr": jax.ShapeDtypeStruct(s.shape[:-1], F32),
                        "vc": jax.ShapeDtypeStruct(s.shape[:-2] + s.shape[-1:],
                                                   F32)}
            return {"v": jax.ShapeDtypeStruct(s.shape, F32)}
        return {n: f(s) for n, s in pstructs.items()}

    return Optimizer(init, update, structs)


def adafactor_dim_axes(cfg, mesh, rules=None) -> Dict[str, Tuple]:
    """Build adafactor's dim_axes table from the schema placement."""
    from repro.models import schema as sch
    from repro.distributed.sharding import DEFAULT_RULES, logical_to_spec

    out = {}
    for name, spec_meta in sch.build_schema(cfg).items():
        spec = logical_to_spec(spec_meta.axes, mesh, rules or DEFAULT_RULES)
        rank = len(spec_meta.shape)
        parts = list(spec) + [None] * (rank - len(spec))

        def axes_of(part):
            if part is None:
                return ()
            return tuple(part) if isinstance(part, tuple) else (part,)

        out[name] = (axes_of(parts[-1]) if rank >= 1 else (),
                     axes_of(parts[-2]) if rank >= 2 else ())
    return out

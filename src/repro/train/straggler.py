"""Straggler mitigation: step-time outlier detection + adaptive response.

At 1000+ nodes, slow hosts (thermal throttling, failing NICs, noisy
neighbors) stretch every synchronous step.  The monitor keeps an EWMA of
step times, flags outliers, and drives two mitigations:

* **prefetch boost** — tell the data pipeline to deepen its prefetch queue
  so a host-side hiccup doesn't starve the device;
* **escalation** — after ``evict_after`` consecutive outlier steps, report
  the host for eviction; with elastic restore (checkpoint.py) the job
  resumes on the surviving topology.

On this single-host container the monitor is exercised by the tests/bench
with synthetic timings; the interface is what the trainer wires in.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerEvent:
    step: int
    dt: float
    ewma: float
    action: str


class StragglerMonitor:
    def __init__(self, *, alpha: float = 0.1, threshold: float = 2.0,
                 evict_after: int = 5,
                 on_prefetch_boost: Optional[Callable[[int], None]] = None,
                 on_evict: Optional[Callable[[], None]] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.evict_after = evict_after
        self.ewma: Optional[float] = None
        self.consecutive = 0
        self.events: List[StragglerEvent] = []
        self._on_boost = on_prefetch_boost
        self._on_evict = on_evict
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int, dt: Optional[float] = None) -> Optional[str]:
        """Record a step; returns the action taken ('boost'|'evict'|None)."""
        if dt is None:
            dt = time.monotonic() - (self._t0 or time.monotonic())
        if self.ewma is None:
            self.ewma = dt
            return None
        action = None
        if dt > self.threshold * self.ewma:
            self.consecutive += 1
            if self.consecutive >= self.evict_after:
                action = "evict"
                if self._on_evict:
                    self._on_evict()
                self.consecutive = 0
            else:
                action = "boost"
                if self._on_boost:
                    self._on_boost(self.consecutive)
        else:
            self.consecutive = 0
        # outliers update the EWMA slowly so one hiccup doesn't poison it
        a = self.alpha if action is None else self.alpha / 4
        self.ewma = (1 - a) * self.ewma + a * dt
        if action:
            self.events.append(StragglerEvent(step, dt, self.ewma, action))
        return action

    def escalate(self, step: int, reason: str = "") -> str:
        """Immediate eviction, bypassing the EWMA streak — for faults the
        runtime *knows* about (a rank died, a retry budget exhausted)
        rather than infers from timing.  Fires ``on_evict`` and records
        the event; returns 'evict'."""
        self.consecutive = 0
        self.events.append(
            StragglerEvent(step, 0.0, self.ewma or 0.0,
                           f"evict:{reason}" if reason else "evict"))
        if self._on_evict:
            self._on_evict()
        return "evict"

    def reset(self) -> None:
        """Forget the timing distribution — call after a topology change
        (elastic restart on fewer devices shifts every step time, and the
        old EWMA would flag the whole new regime as outliers)."""
        self.ewma = None
        self.consecutive = 0
        self._t0 = None

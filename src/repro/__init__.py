"""DiOMP-on-JAX reproduction.

The runtime entry point is the communicator-handle API::

    import repro as diomp

    ctx = diomp.init(mesh=mesh)          # the unified runtime table
    comm = ctx.communicator(group)       # OMPCCL handle (collectives + RMA)

Attribute access is lazy so importing :mod:`repro` stays side-effect-free
(the dry-run must set XLA_FLAGS before anything touches jax).
"""

_CONTEXT_EXPORTS = (
    "init",
    "DiompContext",
    "Communicator",
    "default_context",
    "use_default",
    "reset_default_context",
)

__all__ = list(_CONTEXT_EXPORTS)


def __getattr__(name):
    if name in _CONTEXT_EXPORTS:
        from repro.core import context as _context

        return getattr(_context, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")

"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) moe_d_ff=1536 vocab=151936, no shared
expert, no qkv bias, head_dim=128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    attention="gqa",
    rope_theta=1_000_000.0,
    moe=True,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
)

REDUCED = ModelConfig(
    name="qwen3-moe-235b-a22b-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=16,
    kv_heads=4,
    head_dim=4,
    d_ff=32,
    vocab_size=160,
    attention="gqa",
    moe=True,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    capacity_factor=2.0,
)

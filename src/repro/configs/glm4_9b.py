"""glm4-9b [dense] — RoPE (partial), GQA [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.  GLM uses
half-dim rotary (rope_fraction=0.5).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_fraction=0.5,
)

REDUCED = ModelConfig(
    name="glm4-9b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=16,
    kv_heads=2,
    head_dim=4,
    d_ff=128,
    vocab_size=160,
    rope_fraction=0.5,
)

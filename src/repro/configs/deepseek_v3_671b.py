"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

61L d_model=7168 128H (MLA) moe_d_ff=2048 vocab=129280, 256 experts top-8,
first 3 layers dense (d_ff=18432), q_lora=1536, kv_lora=512,
qk nope/rope = 128/64, v_head=128.  Trains with Adafactor (Adam state for
671B params does not fit the 256x16 GB plan).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    kv_heads=128,
    head_dim=192,            # qk head dim (nope 128 + rope 64)
    d_ff=18432,              # the dense (first-3) layers' FFN
    vocab_size=129280,
    attention="mla",
    moe=True,
    num_experts=256,
    experts_per_token=8,
    moe_d_ff=2048,
    shared_experts=1,
    first_k_dense=3,
    mtp=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
)

REDUCED = ModelConfig(
    name="deepseek-v3-671b-reduced",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=16,
    kv_heads=16,
    head_dim=12,
    d_ff=128,
    vocab_size=160,
    attention="mla",
    moe=True,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
    shared_experts=1,
    first_k_dense=1,
    mtp=True,
    q_lora_rank=32,
    kv_lora_rank=32,
    qk_rope_head_dim=4,
    qk_nope_head_dim=8,
    v_head_dim=8,
    capacity_factor=2.0,
)

"""command-r-plus-104b [dense] — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
)

REDUCED = ModelConfig(
    name="command-r-plus-104b-reduced",
    family="dense",
    num_layers=2,
    d_model=96,
    num_heads=16,          # 96 q-heads reduced to 16 (keeps hp path)
    kv_heads=8,
    head_dim=6,
    d_ff=192,
    vocab_size=160,
)

"""hubert-xlarge [audio] — encoder-only masked prediction
[arXiv:2106.07447; unverified].

48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 (cluster targets).  The
conv waveform frontend is a STUB: input_specs() supplies precomputed frame
embeddings.  Encoder-only: no decode shapes (decode_32k / long_500k skip).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope_fraction=0.0,       # sinusoidal additive positions (no rotary)
)

REDUCED = ModelConfig(
    name="hubert-xlarge-reduced",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=16,
    kv_heads=16,
    head_dim=4,
    d_ff=128,
    vocab_size=24,
    causal=False,
    rope_fraction=0.0,
)

"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.  StableLM uses
partial rotary (25%).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    rope_fraction=0.25,
)

REDUCED = ModelConfig(
    name="stablelm-3b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=16,
    kv_heads=16,
    head_dim=4,
    d_ff=128,
    vocab_size=160,
    rope_fraction=0.25,
)

"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.  The
shared attention+MLP block (one parameter set, reused) is applied after
every 6 mamba layers (DESIGN.md records the periodicity choice; the release
interleaves two shared blocks with LoRA adapters — adapters omitted).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    conv_width=4,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    num_layers=4,
    d_model=256,
    num_heads=16,
    kv_heads=16,
    head_dim=16,
    d_ff=512,
    vocab_size=160,
    ssm_state=32,
    attn_every=2,
    conv_width=4,
)

"""rwkv6-7b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.  O(1) decode
state -> runs long_500k natively.  The attention-sharding aspects of the
runtime are N/A (no attention) — recorded in DESIGN.md §Arch-applicability;
the PGAS/OMPCCL runtime drives all projections and channel-mix reductions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
    rwkv_head_dim=64,
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced",
    family="ssm",
    num_layers=2,
    d_model=512,
    num_heads=0,
    kv_heads=0,
    head_dim=0,
    d_ff=1024,
    vocab_size=160,
    attention="none",
    rwkv_head_dim=64,
)

"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.  The SigLIP
frontend is a STUB per the assignment: input_specs() supplies 256
precomputed patch embeddings per image (gemma's prefix-LM attention window
covers them).  8 heads do not divide MAX_TP=16 -> token-parallel attention
(DESIGN.md §5).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=10_000.0,
    prefix_tokens=256,
)

REDUCED = ModelConfig(
    name="paligemma-3b-reduced",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=8,
    kv_heads=1,
    head_dim=8,
    d_ff=128,
    vocab_size=160,
    prefix_tokens=8,
)

"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen1.5-110b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=16,
    kv_heads=8,
    head_dim=4,
    d_ff=128,
    vocab_size=160,
    qkv_bias=True,
)

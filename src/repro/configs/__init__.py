"""Assigned-architecture registry: ``get(name)`` -> (full, reduced) configs."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

ARCHS = (
    "paligemma_3b",
    "deepseek_v3_671b",
    "qwen3_moe_235b_a22b",
    "hubert_xlarge",
    "rwkv6_7b",
    "qwen1_5_110b",
    "glm4_9b",
    "command_r_plus_104b",
    "stablelm_3b",
    "zamba2_1_2b",
)

# CLI ids (--arch) use dashes, matching the assignment table
CLI_IDS = {a.replace("_", "-"): a for a in ARCHS}


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{CLI_IDS.get(name, name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{CLI_IDS.get(name, name)}")
    return mod.REDUCED


def all_archs():
    return [a.replace("_", "-") for a in ARCHS]

"""Mamba2 (SSD) blocks + the Zamba2 hybrid (mamba stack + shared attention).

Mamba2 state update per head:  h_t = exp(A·Δt)·h_{t-1} + Δt·B_tᵀx_t,
y_t = C_t·h_t + D·x_t — the unified linear_scan with scalar per-head decay
broadcast over the state dim, post-readout.

Zamba2 wiring (DESIGN.md §5): an unrolled python loop over mamba layers with
the SHARED attention+MLP block (one parameter set — the PGAS runtime
registers it once and every invocation reads the same region) applied after
every ``attn_every`` mamba layers.  Each application keeps its own KV cache
slot at decode time.

TP: the inner dim (2·d) is sharded over "model" via the head dim; B/C
projections are small and replicated; the gated output norm reduces its
statistics across TP with an explicit OMPCCL psum so the math is
partition-invariant.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ompccl
from repro.core.vma import zeros_varying
from repro.kernels.linear_scan.ops import linear_scan
from .config import ModelConfig, ParallelCtx
from .layers import (F32, KVCache, attention_block, ce_loss, col_matmul,
                     embed_lookup, gather_fsdp, mlp_block, rmsnorm,
                     row_matmul, local_kv_heads)

__all__ = ["zamba_forward", "zamba_loss", "zamba_init_state", "zamba_decode"]


def _rmsnorm_tp(x, scale_loc, ctx: ParallelCtx, eps: float):
    """RMSNorm over a TP-sharded channel dim: stats psum'd across TP."""
    xf = x.astype(F32)
    sq = (xf * xf).sum(-1, keepdims=True)
    n = x.shape[-1] * ctx.tp
    if ctx.tp > 1:
        sq = ompccl.allreduce(sq, ctx.tp_group)
    inv = lax.rsqrt(sq / n + eps)
    return (xf * inv * scale_loc.astype(F32)).astype(x.dtype)


def _causal_conv(x, w_loc, b_loc, state: Optional[jax.Array]):
    """Depthwise causal conv along T.  x: (B, T, C_loc); w: (cw, C_loc).

    Returns (y, new_state) where state carries the trailing cw-1 inputs.
    """
    B, T, C = x.shape
    cw = w_loc.shape[0]
    if state is None:
        hist = zeros_varying((B, cw - 1, C), x.dtype, x)
    else:
        hist = state
    xp = jnp.concatenate([hist, x], axis=1)            # (B, T+cw-1, C)
    y = zeros_varying((B, T, C), F32, x)
    for i in range(cw):
        y = y + w_loc[i].astype(F32) * xp[:, i:i + T].astype(F32)
    y = y + b_loc.astype(F32)
    new_state = xp[:, -(cw - 1):] if cw > 1 else hist
    return y.astype(x.dtype), new_state


def mamba_block(x, lp, cfg: ModelConfig, ctx: ParallelCtx,
                state: Optional[dict] = None, *, scan_impl: str = "ref"):
    """One Mamba2 block.  Returns (x', new_state)."""
    B, T, d = x.shape
    din = 2 * d
    din_loc = din // ctx.tp
    hd = 64
    nh_loc = din_loc // hd
    st = cfg.ssm_state

    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    x_in = col_matmul(h, lp["w_x"], ctx)               # (B, T, din_loc)
    z = col_matmul(h, lp["w_z"], ctx)                  # (B, T, din_loc)
    bc = jnp.dot(h, gather_fsdp(lp["w_bc"], ctx, dim=0),
                 preferred_element_type=F32)           # replicated (B, T, 2st)
    B_, C_ = bc[..., :st], bc[..., st:]
    dt = jax.nn.softplus(
        col_matmul(h, lp["w_dt"], ctx).astype(F32)
        + lp["dt_bias"].astype(F32))                   # (B, T, nh_loc)

    x_c, conv_state = _causal_conv(
        x_in, lp["conv_w"], lp["conv_b"],
        state["conv"] if state is not None else None)
    x_c = jax.nn.silu(x_c.astype(F32))

    A = -jnp.exp(lp["A_log"].astype(F32))              # (nh_loc,)
    a = jnp.exp(A * dt)                                # (B, T, nh_loc)

    xh = x_c.reshape(B, T, nh_loc, hd)
    p = xh * dt[..., None]                             # (B, T, nh, hd)

    def flat_h(t):  # (B, T, nh, k) -> (B*nh, T, k)
        return t.transpose(0, 2, 1, 3).reshape(B * nh_loc, T, -1)

    q_in = jnp.broadcast_to(B_[:, :, None, :], (B, T, nh_loc, st))
    r_in = jnp.broadcast_to(C_[:, :, None, :], (B, T, nh_loc, st))
    a_in = jnp.broadcast_to(a[..., None], (B, T, nh_loc, st))

    s0 = state["S"].reshape(B * nh_loc, hd, st) if state is not None else None
    y, s_fin = linear_scan(
        flat_h(p), flat_h(q_in), flat_h(a_in), flat_h(r_in), s0,
        readout_pre=False, impl=scan_impl if state is None else "ref")
    y = y.reshape(B, nh_loc, T, hd).transpose(0, 2, 1, 3)
    y = y + lp["D"].astype(F32)[None, None, :, None] * xh

    y = y.reshape(B, T, din_loc)
    y = _rmsnorm_tp(y.astype(x.dtype), lp["out_norm"], ctx, cfg.norm_eps)
    y = (y.astype(F32) * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = row_matmul(y, lp["w_out"], ctx)

    new_state = None
    if state is not None:
        new_state = {"conv": conv_state,
                     "S": s_fin.reshape(B, nh_loc, hd, st)}
    return x + out, new_state


def _shared_params(params):
    return {k[len("shared/"):]: v for k, v in params.items()
            if k.startswith("shared/")}


def zamba_forward(params, tokens, cfg: ModelConfig, ctx: ParallelCtx,
                  cache: Optional[dict] = None, *, seq_sharded: bool = False,
                  scan_impl: str = "ref"):
    """Zamba2: L mamba blocks, shared attn+MLP after every attn_every.

    ``cache``: {"mamba": stacked mamba states, "k"/"v": (n_app, B, S, KH, D),
    "pos": ()} — None for training.  Returns (hidden, new_cache).
    """
    x = embed_lookup(tokens, params["embed/table"], cfg, ctx)
    L = cfg.num_layers
    every = max(cfg.attn_every, 1)
    shared = _shared_params(params)
    sl = lambda t, i: jax.tree.map(lambda a: a[i], t)
    plen = len("layers/")
    stack = {k[plen:]: v for k, v in params.items() if k.startswith("layers/")}

    pos = cache["pos"] if cache is not None else None
    positions = (jnp.full((1,), pos, jnp.int32) if cache is not None
                 and tokens.shape[1] == 1 else None)

    new_mamba, new_k, new_v = [], [], []
    app = 0
    for i in range(L):
        st = sl(cache["mamba"], i) if cache is not None else None

        def blk(h, st=st, i=i):
            return mamba_block(h, sl(stack, i), cfg, ctx, st,
                               scan_impl=scan_impl)

        if ctx.remat and cache is None:
            blk = jax.checkpoint(blk)
        x, st2 = blk(x)
        if cache is not None:
            new_mamba.append(st2)
        if (i + 1) % every == 0:
            kv_cache = None
            if cache is not None:
                kv_cache = KVCache(cache["k"][app], cache["v"][app], pos,
                                   seq_sharded=seq_sharded)

            def shared_blk(h, kv_cache=kv_cache):
                hn = rmsnorm(h, shared["attn_norm"], cfg.norm_eps)
                attn, kv2 = attention_block(
                    hn, shared, cfg, ctx, positions=positions, cache=kv_cache)
                h = h + attn
                hn = rmsnorm(h, shared["mlp_norm"], cfg.norm_eps)
                return h + mlp_block(hn, shared, ctx), kv2

            if ctx.remat and cache is None:
                shared_blk = jax.checkpoint(shared_blk)
            x, kv2 = shared_blk(x)
            if cache is not None:
                new_k.append(kv2.k)
                new_v.append(kv2.v)
            app += 1

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
            "pos": pos + tokens.shape[1],
        }
    return x, new_cache


def zamba_loss(params, batch, cfg: ModelConfig, ctx: ParallelCtx):
    h, _ = zamba_forward(params, batch["tokens"], cfg, ctx)
    return ce_loss(h[:, :-1], params["lm_head"], batch["tokens"][:, 1:],
                   cfg, ctx)


def zamba_init_state(cfg: ModelConfig, ctx: ParallelCtx, B_loc: int, S: int,
                     *, seq_sharded: bool = False, dtype=jnp.bfloat16):
    d = cfg.d_model
    din_loc = 2 * d // ctx.tp
    nh_loc = din_loc // 64
    L = cfg.num_layers
    every = max(cfg.attn_every, 1)
    n_app = L // every
    KH_loc = local_kv_heads(cfg, ctx)
    S_loc = S // ctx.fsdp if seq_sharded else S
    return {
        "mamba": {
            "conv": jnp.zeros((L, B_loc, cfg.conv_width - 1, din_loc), dtype),
            "S": jnp.zeros((L, B_loc, nh_loc, 64, cfg.ssm_state), jnp.float32),
        },
        "k": jnp.zeros((n_app, B_loc, S_loc, KH_loc, cfg.head_dim), dtype),
        "v": jnp.zeros((n_app, B_loc, S_loc, KH_loc, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def zamba_decode(params, tokens, cfg, ctx, cache, *, seq_sharded=False):
    h, cache = zamba_forward(params, tokens, cfg, ctx, cache,
                             seq_sharded=seq_sharded)
    logits = jnp.dot(h.astype(F32), params["lm_head"].astype(F32))
    return logits, cache

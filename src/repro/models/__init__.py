"""Pure-JAX model zoo for the assigned architectures.

All forwards are *manual-SPMD*: they run inside ``shard_map`` and issue every
cross-device transfer explicitly through OMPCCL / RMA verbs, so the DiOMP
runtime owns the full communication schedule (DESIGN.md §4).
"""

from .config import ModelConfig, ParallelCtx  # noqa: F401

"""Family-dispatch API: one uniform surface over the five model families.

The launch layer (dry-run, trainer, server) talks only to these functions;
each returns both abstract structure (ShapeDtypeStruct + PartitionSpec, for
the no-allocation dry-run) and the concrete step callables.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .config import ModelConfig, ParallelCtx
from . import schema as sch
from .layers import local_kv_heads
from .transformer import (init_cache, transformer_decode, transformer_loss,
                          transformer_prefill)
from .rwkv import rwkv_decode, rwkv_init_state, rwkv_loss
from .ssm import zamba_decode, zamba_init_state, zamba_loss

__all__ = [
    "loss_fn", "decode_fn", "batch_structs", "cache_structs", "has_decode",
]

TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


def loss_fn(cfg: ModelConfig) -> Callable:
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer_loss
    if cfg.family == "ssm":
        return rwkv_loss
    if cfg.family == "hybrid":
        return zamba_loss
    raise ValueError(cfg.family)


def decode_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(B,1), cfg, ctx, cache, *, seq_sharded) -> (logits, cache)."""
    if cfg.family in TRANSFORMER_FAMILIES:
        return lambda p, t, cfg, ctx, cache, seq_sharded=False: (
            transformer_decode(p, t, cfg, ctx, cache, seq_sharded=seq_sharded))
    if cfg.family == "ssm":
        return lambda p, t, cfg, ctx, cache, seq_sharded=False: (
            rwkv_decode(p, t, cfg, ctx, cache))
    if cfg.family == "hybrid":
        return lambda p, t, cfg, ctx, cache, seq_sharded=False: (
            zamba_decode(p, t, cfg, ctx, cache, seq_sharded=seq_sharded))
    raise ValueError(cfg.family)


def has_decode(cfg: ModelConfig) -> bool:
    return cfg.family != "audio"  # encoder-only archs have no decode step


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic decode-state archs."""
    return cfg.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# abstract batch / cache structure (dry-run currency)
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh, B: int,
                dp_axes: Tuple[str, ...] = ("pod", "data")) -> Tuple[str, ...]:
    axes = tuple(a for a in dp_axes if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if (axes and B % n == 0) else ()


def batch_structs(cfg: ModelConfig, mesh: Mesh, B: int, S: int,
                  dtype=jnp.bfloat16, dp_axes=("pod", "data")):
    """(ShapeDtypeStruct pytree, PartitionSpec pytree) for one train batch."""
    ba = _batch_axes(mesh, B, dp_axes)
    bspec = P(ba if ba else None)
    if cfg.family == "audio":
        structs = {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
        specs = {"embeds": bspec, "targets": bspec, "mask": bspec}
    elif cfg.family == "vlm":
        Ptoks = cfg.prefix_tokens
        structs = {
            "tokens": jax.ShapeDtypeStruct((B, S - Ptoks), jnp.int32),
            "prefix_embeds": jax.ShapeDtypeStruct((B, Ptoks, cfg.d_model), dtype),
        }
        specs = {"tokens": bspec, "prefix_embeds": bspec}
    else:
        structs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"tokens": bspec}
    return structs, specs


def cache_structs(cfg: ModelConfig, mesh: Mesh, ctx: ParallelCtx, B: int,
                  S: int, *, seq_sharded: bool = False, dtype=jnp.bfloat16):
    """Global-view decode cache (structs, specs).

    Local shapes inside shard_map are produced by init_cache /
    *_init_state; the global view multiplies sharded dims back up.  For
    head-parallel archs with replicated KV weights the cache's global KV dim
    is local_kv_heads·tp (each device holds its q-block's kv group).
    """
    ba = _batch_axes(mesh, B)
    bspec = ba if ba else None
    sspec = "data" if seq_sharded else None
    S_glob = S
    kd = cfg.first_k_dense if cfg.moe else 0
    L = cfg.num_layers - kd

    def k_struct_spec():
        KH_loc = local_kv_heads(cfg, ctx)
        # the cache is model-sharded whenever heads are parallel (each device
        # then holds only its q-block's kv group), else fully replicated
        kv_model = sch.kv_sharded(cfg) or (
            sch.head_parallel(cfg) and ctx.tp > 1)
        KH_glob = KH_loc * ctx.tp if kv_model else cfg.kv_heads
        spec = P(None, bspec, sspec, "model" if kv_model else None, None)
        return (jax.ShapeDtypeStruct((L, B, S_glob, KH_glob, cfg.head_dim),
                                     dtype), spec)

    if cfg.family in TRANSFORMER_FAMILIES:
        if cfg.attention == "mla":
            structs = {
                "c": jax.ShapeDtypeStruct((L, B, S, cfg.kv_lora_rank), dtype),
                "kr": jax.ShapeDtypeStruct((L, B, S, cfg.qk_rope_head_dim), dtype),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
            specs = {"c": P(None, bspec, None, None),
                     "kr": P(None, bspec, None, None), "pos": P()}
            if kd:
                structs["dense_c"] = jax.ShapeDtypeStruct(
                    (kd, B, S, cfg.kv_lora_rank), dtype)
                structs["dense_kr"] = jax.ShapeDtypeStruct(
                    (kd, B, S, cfg.qk_rope_head_dim), dtype)
                specs["dense_c"] = P(None, bspec, None, None)
                specs["dense_kr"] = P(None, bspec, None, None)
            return structs, specs
        ks, kp = k_struct_spec()
        return ({"k": ks, "v": ks, "pos": jax.ShapeDtypeStruct((), jnp.int32)},
                {"k": kp, "v": kp, "pos": P()})

    if cfg.family == "ssm":
        d = cfg.d_model
        hd = cfg.rwkv_head_dim
        H = d // hd
        Lr = cfg.num_layers
        structs = {
            "x_tm": jax.ShapeDtypeStruct((Lr, B, d), dtype),
            "x_cm": jax.ShapeDtypeStruct((Lr, B, d), dtype),
            "S": jax.ShapeDtypeStruct((Lr, B, H, hd, hd), jnp.float32),
        }
        specs = {"x_tm": P(None, bspec, None), "x_cm": P(None, bspec, None),
                 "S": P(None, bspec, "model", None, None)}
        return structs, specs

    if cfg.family == "hybrid":
        d = cfg.d_model
        din = 2 * d
        nh = din // 64
        Lh = cfg.num_layers
        n_app = Lh // max(cfg.attn_every, 1)
        KH_loc = local_kv_heads(cfg, ctx)
        kv_model = sch.kv_sharded(cfg)
        KH_glob = cfg.kv_heads
        kspec = P(None, bspec, sspec, "model" if kv_model else None, None)
        structs = {
            "mamba": {
                "conv": jax.ShapeDtypeStruct(
                    (Lh, B, cfg.conv_width - 1, din), dtype),
                "S": jax.ShapeDtypeStruct((Lh, B, nh, 64, cfg.ssm_state),
                                          jnp.float32),
            },
            "k": jax.ShapeDtypeStruct((n_app, B, S, KH_glob, cfg.head_dim),
                                      dtype),
            "v": jax.ShapeDtypeStruct((n_app, B, S, KH_glob, cfg.head_dim),
                                      dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = {
            "mamba": {"conv": P(None, bspec, None, "model"),
                      "S": P(None, bspec, "model", None, None)},
            "k": kspec, "v": kspec, "pos": P(),
        }
        return structs, specs
    raise ValueError(cfg.family)


def decode_batch_structs(cfg: ModelConfig, mesh: Mesh, B: int):
    ba = _batch_axes(mesh, B)
    return ({"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)},
            {"tokens": P(ba if ba else None)})
